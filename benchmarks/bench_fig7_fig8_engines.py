"""Paper Figs. 7-8 reproduction + TPU analogue.

The paper compares two GPU libraries (cuDNN vs cuBLAS) running the SAME FC
layers fwd/bwd.  Two parts here:

1. Model replay: the calibrated K40-cuDNN / K40-cuBLAS device models
   regenerate the paper's speedup/power/energy deltas (claim C7).
2. Measured analogue on this host: the XLA engine vs the Pallas MXU kernel
   for the same FC layers, fwd and bwd, wall-clock microseconds — the
   'library choice matters' lesson transferred to the TPU stack.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import layer_cost
from repro.core.device_models import K40_CUBLAS, K40_CUDNN
from repro.core.layer_model import FCSpec, alexnet_spec
from repro.kernels import ops, ref

_FC = [l for l in alexnet_spec() if isinstance(l, FCSpec)]


def _time(fn, *args, reps=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rows = []
    # --- part 1: paper-model replay (C7) --------------------------------
    for direction in ("fwd", "bwd"):
        t_dnn = sum(layer_cost(l, K40_CUDNN, batch=109,
                               direction=direction).t_total for l in _FC)
        t_blas = sum(layer_cost(l, K40_CUBLAS, batch=109,
                                direction=direction).t_total for l in _FC)
        e_dnn = sum(layer_cost(l, K40_CUDNN, batch=109,
                               direction=direction).energy_j for l in _FC)
        e_blas = sum(layer_cost(l, K40_CUBLAS, batch=109,
                                direction=direction).energy_j for l in _FC)
        expected = 1.69 if direction == "fwd" else 24.89
        rows.append(("fig7_8_model", f"cublas_speedup_{direction}",
                     t_dnn / t_blas, f"paper={expected}",
                     "MATCH" if abs(t_dnn / t_blas - expected) < 0.1 * expected
                     else "MISMATCH"))
        rows.append(("fig7_8_model", f"energy_ratio_{direction}",
                     e_dnn / e_blas, "cuDNN/cuBLAS energy", ""))

    # --- part 2: measured XLA vs Pallas engines on this host ------------
    rng = np.random.default_rng(0)
    for l in _FC:
        x = jnp.asarray(rng.normal(size=(16, l.n_in)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(l.n_in, l.k_o)), jnp.float32)
        t_xla = _time(jax.jit(lambda a, b: ref.matmul_ref(a, b)), x, w)
        t_pal = _time(lambda a, b: ops.matmul(a, b), x, w)
        rows.append(("fig7_8_measured", f"{l.name}_fwd_xla_us", t_xla, "", ""))
        rows.append(("fig7_8_measured", f"{l.name}_fwd_pallas_us", t_pal,
                     "interpret=True on CPU (Mosaic on real TPU)", ""))
        # bwd via vjp on the XLA engine
        f = jax.jit(lambda a, b: jnp.sum(ref.matmul_ref(a, b)))
        t_bwd = _time(jax.jit(jax.grad(f, argnums=(0, 1))), x, w)
        rows.append(("fig7_8_measured", f"{l.name}_bwd_xla_us", t_bwd, "", ""))
    return rows
