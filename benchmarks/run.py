"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus a status column).

    PYTHONPATH=src python -m benchmarks.run [--only fig6]
"""
import argparse
import sys
import traceback

from . import (bench_fig6_tradeoff, bench_fig7_fig8_engines, bench_roofline,
               bench_scheduler, bench_table1_flops, bench_table3_resources)

MODULES = {
    "table1": bench_table1_flops,
    "fig6": bench_fig6_tradeoff,
    "fig7_8": bench_fig7_fig8_engines,
    "table3": bench_table3_resources,
    "scheduler": bench_scheduler,
    "roofline": bench_roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=list(MODULES))
    args = ap.parse_args()

    mods = {args.only: MODULES[args.only]} if args.only else MODULES
    print("bench,name,value,derived,status")
    failures = []
    for key, mod in mods.items():
        try:
            for bench, name, value, derived, status in mod.run():
                print(f"{bench},{name},{value},{derived!r},{status}")
                if status in ("FAIL", "MISMATCH", "OVERFLOW"):
                    failures.append((bench, name, status))
        except Exception as e:
            traceback.print_exc()
            failures.append((key, "exception", str(e)))
    if failures:
        print(f"\n{len(failures)} benchmark failures:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        raise SystemExit(1)
    print("\nall benchmarks passed")


if __name__ == "__main__":
    main()
