"""Paper Table I/II reproduction: layer descriptions + exact FLOP counts.

Validates claim C6: our declarative layer model reproduces the paper's
fp-operations-per-image numbers for FC6/7/8 forward and backward EXACTLY.
"""
from repro.core.layer_model import alexnet_spec

_EXPECTED = {  # Table II, fp operations per image
    ("FC6", "fwd"): 75_497_472, ("FC7", "fwd"): 33_554_432,
    ("FC8", "fwd"): 8_192_000,
    ("FC6", "bwd"): 150_994_944, ("FC7", "bwd"): 67_108_864,
    ("FC8", "bwd"): 16_384_000,
}


def run():
    rows = []
    net = alexnet_spec()
    for spec in net:
        fwd, bwd = spec.flops(1), spec.bwd_flops(1)
        for d, v in (("fwd", fwd), ("bwd", bwd)):
            exp = _EXPECTED.get((spec.name, d))
            ok = "" if exp is None else ("MATCH" if v == exp else
                                         f"MISMATCH(exp={exp})")
            rows.append(("table1_flops", f"{spec.name}_{d}", v,
                         f"params={spec.param_count()}", ok))
    return rows
