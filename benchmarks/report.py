"""Generates the EXPERIMENTS.md §Dry-run and §Roofline tables from
dryrun_results.json.

    PYTHONPATH=src python -m benchmarks.report [--results path]
"""
import argparse
import json

from .bench_roofline import roofline_rows

HBM_GB = 16.0


def dryrun_table(records):
    rows = ["| arch | shape | mesh | compile s | GFLOP/dev (raw) | HBM GB "
            "(args+temp) | coll MB/dev | status |",
            "|---|---|---|---|---|---|---|---|"]
    full = [r for r in records if not r.get("calibration")
            and not r.get("overrides")]
    for r in sorted(full, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["status"] == "skipped":
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — "
                        f"| — | — | ERROR |")
            continue
        m = r["memory"]
        hbm = (m["argument_bytes"] + m["temp_bytes"]) / 2**30
        flag = "ok" if hbm <= HBM_GB else "ok (CPU-f32-widen, see note)"
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compile_s']:.0f} | {r['flops_per_device']/1e9:,.0f} | "
            f"{hbm:.1f} | {r['collective_bytes_per_device']/2**20:,.0f} | "
            f"{flag} |")
    return "\n".join(rows)


def skips_table(records):
    rows = ["| arch | shape | reason |", "|---|---|---|"]
    seen = set()
    for r in records:
        if r.get("status") == "skipped" and not r.get("calibration"):
            key = (r["arch"], r["shape"])
            if key in seen:
                continue
            seen.add(key)
            rows.append(f"| {r['arch']} | {r['shape']} | "
                        f"{r.get('reason', '')[:90]} |")
    return "\n".join(rows)


def roofline_table(records):
    rows = ["| arch | shape | t_comp ms | t_mem ms | t_coll ms | dominant | "
            "useful (6ND/HLO) | roofline frac |",
            "|---|---|---|---|---|---|---|---|"]
    for r in roofline_rows(records, mesh="pod"):
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']*1e3:.1f} | "
            f"{r['t_memory_s']*1e3:.1f} | {r['t_collective_s']*1e3:.1f} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.1%}"
            + (" (uncal)" if r["uncalibrated"] else "") + " |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="dryrun_results.json")
    ap.add_argument("--section", default="all",
                    choices=["all", "dryrun", "roofline", "skips"])
    args = ap.parse_args()
    records = json.load(open(args.results))
    if args.section in ("all", "dryrun"):
        print("### Dry-run table\n")
        print(dryrun_table(records))
        print("\n### Skipped cells\n")
        print(skips_table(records))
    if args.section in ("all", "roofline"):
        print("\n### Roofline (single-pod 16x16, scan-corrected)\n")
        print(roofline_table(records))


if __name__ == "__main__":
    main()
