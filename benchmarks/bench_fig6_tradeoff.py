"""Paper Fig. 6 reproduction: GPU (K40) vs FPGA (DE5) trade-off analysis.

Per layer x device: execution time, throughput (GFLOPS), power (W),
energy (J), GFLOPS/W and GFLOP/J — the paper's four panels plus the
performance-density discussion of §IV.B.  Claims C1-C5 are validated
against the paper's reported values.
"""
from repro.core import tradeoff
from repro.core.device_models import DE5, K40
from repro.core.layer_model import alexnet_spec


def run():
    rows = []
    net = alexnet_spec()
    for r in tradeoff.analyze(net, [K40, DE5],
                              batch=tradeoff.PAPER_WORKLOAD_IMAGES):
        rows.append(("fig6_tradeoff", f"{r.device}:{r.layer}",
                     r.time_s * 1e6,
                     f"thr={r.throughput_gflops:.2f}GFLOPS "
                     f"P={r.power_w:.2f}W E={r.energy_j:.3f}J "
                     f"dens={r.gflops_per_watt:.2f}GFLOPS/W "
                     f"ope={r.gflop_per_joule:.2f}GFLOP/J", ""))
    claims = tradeoff.check_paper_claims()
    for name, c in claims.items():
        rows.append(("fig6_claims", name, 1.0 if c["ok"] else 0.0,
                     str(c["value"])[:120], "PASS" if c["ok"] else "FAIL"))
    return rows
