"""Serving benchmark: continuous batching vs the legacy static-batch server.

    PYTHONPATH=src python -m benchmarks.bench_serving --scale smoke

Offers the same open-loop mixed-length workload (repro.serving.request) to
both paths and writes ``BENCH_serving.json``: throughput (tok/s, req/s),
TTFT/latency percentiles and the continuous/static speedup per offered
load, plus a per-request bit-identity check of the greedy outputs (the two
paths run the same decode math, so tokens must match exactly — the
continuous engine runs the default block-paged KV layout, so every load's
check also gates paged-vs-dense numerics).  The ``paged`` section
quantifies the layout itself: KV bytes resident paged vs dense at equal
slots, the slot count a paged pool fits in the dense byte budget, the
saturation-throughput cost of the page gather, and paged/dense
bit-identity in colocated and disaggregated modes.  The ``prefix``
section prices prefix sharing at a dense-equal block budget: under 50%
and 90% prefix-shared traffic, refcounted shared pages with copy-on-write
tails must raise peak concurrent slots (and cut TTFT) versus the same
pool without sharing, bit-identically.  The ``streaming``
section compares incremental (burst-boundary) token delivery against the
completion pull in both colocated and disaggregated modes — streamed
deltas must concatenate to exactly the completion rows, and the honest
(host-visible) TTFT is reported next to the old dispatch-time stamp.
The ``observability`` section prices the tracing layer: NullTracer and
fully traced throughput relative to the untraced baseline (the NullTracer
ratio is the gated overhead bound) plus bit-identity of every traced run
and trace-health counts (spans balanced, lifecycle coverage, ring drops).
The ``adaptive`` section closes the loop: under an injected admission
mispricing that clamps the token budget to 1, the watchdog's mid-run
re-pricing must recover throughput and TTFT (bit-identically — admission
policy never changes outputs), and tracer+watchdog throughput must stay
within the gated overhead of tracer-only.  The ``speculative`` section
drives draft-model speculative decoding through the programmatic API
(``repro.serving.api.serve``): a forced-depth run must stay bit-identical
to plain decode with its accepted-token rate measured, the
analyzer-priced run must fall back to plain serving when speculation
prices worse at these smoke shapes, and an adversarially de-rated draft
device must price speculation off outright.

Static batching groups requests by prompt length (the legacy server is
rectangular), waits for a full batch to arrive, and decodes every batch to
its longest generation — short requests pay head-of-line blocking, and the
accelerator idles between generations.  The continuous engine refills slots
the moment a request finishes, which is where the speedup comes from.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.serve import Server
from repro.models import transformer as T
from repro.obs import Observability, Tracer
from repro.serving import (DisaggregatedEngineLoop, EngineLoop, ServeMetrics,
                           place_phases, prefix_shared_workload,
                           synthetic_workload)

SMOKE_CFG = T.ModelConfig(
    name="bench-serving-smoke", n_layers=4, d_model=96, n_heads=6,
    n_kv_heads=2, d_ff=192, vocab=512, qkv_bias=True, attention_impl="dot",
    scan_chunk=16, remat=False)

PROMPT_LENS = (8, 16)
GEN_LENS = (4, 8, 16, 64)

# best-of-N repetitions for the observability overhead ratios: sub-second
# smoke runs jitter by a few percent on a shared host, and the gated
# NullTracer bound must measure tracing cost, not scheduler noise
_OBS_REPS = 5


def _workload(n: int, rate: float, vocab: int, seed: int):
    return synthetic_workload(n, rate=rate, vocab=vocab,
                              prompt_lens=PROMPT_LENS, gen_lens=GEN_LENS,
                              seed=seed)


def run_static(cfg, params, requests, *, batch: int, max_len: int,
               metrics: ServeMetrics) -> Dict[int, List[int]]:
    """Legacy path: rectangular batches per prompt length, decode to the
    batch's longest generation.  Returns rid -> greedy tokens."""
    server = Server(cfg, params, None, max_len)
    # batch formation: per prompt-length group, in arrival order
    groups: Dict[int, List] = {}
    for r in sorted(requests, key=lambda r: (r.arrival, r.rid)):
        groups.setdefault(r.prompt_len, []).append(r)
    batches = []
    for plen, grp in groups.items():
        for i in range(0, len(grp), batch):
            chunk = grp[i:i + batch]
            batches.append((max(r.arrival for r in chunk), chunk))
    batches.sort(key=lambda b: b[0])

    # warm up compiles (one decode width + one prefill per prompt length)
    for plen in groups:
        server.generate(jnp.zeros((batch, plen), jnp.int32), 2)

    outputs: Dict[int, List[int]] = {}
    t0 = time.perf_counter()
    for ready, chunk in batches:
        now = time.perf_counter() - t0
        if now < ready:                  # static batching waits for a full
            time.sleep(ready - now)      # batch before launching it
        rows = [r.prompt for r in chunk]
        while len(rows) < batch:         # rectangular pad: repeat last row
            rows.append(rows[-1])
        prompts = jnp.asarray(np.stack(rows))
        gmax = max(r.max_new_tokens for r in chunk)
        toks = np.asarray(server.generate(prompts, gmax))
        done = time.perf_counter() - t0
        for j, r in enumerate(chunk):
            outputs[r.rid] = toks[j, :r.max_new_tokens].tolist()
            r.output = outputs[r.rid]
            # tokens only land at batch end: dispatch and host visibility
            # coincide for the static path
            r.t_first_token = done
            r.t_first_dispatch = done
            r.t_done = done
            metrics.observe(r)
        metrics.n_steps += prompts.shape[1] + gmax
    metrics.elapsed_s = time.perf_counter() - t0
    return outputs


def run_continuous(cfg, params, requests, *, slots: int, max_len: int
                   ) -> ServeMetrics:
    engine = EngineLoop(cfg, params, n_slots=slots, max_seq=max_len)
    engine.warmup()                      # compile all burst buckets
    return engine.run(requests)


def kv_cache_bytes(cfg, n_slots: int, max_len: int, *, layout: str,
                   block_size: int = 16, total_blocks=None) -> int:
    """Resident attention-KV bytes of a slot cache under `layout`, computed
    from cache leaf shapes via eval_shape (nothing is allocated).  Counts
    only the attention K/V storage — the axis the paged layout changes;
    the paged figure includes its trash page (it is resident too)."""
    if layout == "paged":
        shapes = jax.eval_shape(
            lambda: T.init_slot_cache_paged(cfg, n_slots, max_len,
                                            block_size=block_size,
                                            total_blocks=total_blocks))
    else:
        shapes = jax.eval_shape(
            lambda: T.init_slot_cache(cfg, n_slots, max_len))
    blocks, rem = shapes["layers"]
    total = 0
    for c in list(blocks) + list(rem):
        if isinstance(c, dict) and "k" in c:
            for leaf in jax.tree.leaves(c):
                total += int(np.prod(leaf.shape)) * leaf.dtype.itemsize
    return total


def run_paged(cfg, params, baselines: Dict, *, n_requests: int, slots: int,
              max_len: int, seed: int, block_size: int = 16) -> Dict:
    """Paged vs dense KV layout on the saturation workload.

    The paged pool is provisioned for tokens-in-flight (mean per-request
    block footprint x slots) instead of the ``slots x max_seq`` dense
    worst case, so the section reports the KV bytes actually resident at
    equal ``n_slots``, the slot count a paged pool could host inside the
    dense byte budget, and the saturation-throughput cost of the page
    gather.  Correctness contract: per-request greedy outputs are
    bit-identical between the layouts in both colocated and disaggregated
    modes (``baselines`` supplies :func:`run_disaggregation`'s paged runs,
    reused so those serving runs + warmup compiles aren't paid twice)."""
    bps = -(-max_len // block_size)
    dense_reqs = _workload(n_requests, 1e9, cfg.vocab, seed)
    # provision the paged arena for tokens-in-flight: the workload's mean
    # per-request block footprint x slots (deterministic generator, so
    # dense_reqs is the same draw every layout serves)
    mean_blocks = float(np.mean([-(-r.total_tokens // block_size)
                                 for r in dense_reqs]))
    provisioned = max(int(np.ceil(mean_blocks * slots)), bps)
    dense_eng = EngineLoop(cfg, params, n_slots=slots, max_seq=max_len,
                           block_size=block_size, kv_layout="dense")
    dense_eng.warmup()
    m_dense = dense_eng.run(dense_reqs)

    paged_reqs = _workload(n_requests, 1e9, cfg.vocab, seed)
    paged_eng = EngineLoop(cfg, params, n_slots=slots, max_seq=max_len,
                           block_size=block_size, kv_layout="paged",
                           total_blocks=provisioned)
    paged_eng.warmup()
    m_paged = paged_eng.run(paged_reqs)

    ddense_reqs = _workload(n_requests, 1e9, cfg.vocab, seed)
    ddense = DisaggregatedEngineLoop(
        cfg, params, n_prefill_slots=max(slots // 2, 1),
        n_decode_slots=slots, max_seq=max_len, block_size=block_size,
        kv_layout="dense")
    ddense.warmup()
    ddense.run(ddense_reqs)
    _, dpaged_reqs = baselines["disaggregated"]   # paged (default layout)

    out_d = {r.rid: r.output for r in dense_reqs}
    out_p = {r.rid: r.output for r in paged_reqs}
    out_dd = {r.rid: r.output for r in ddense_reqs}
    out_dp = {r.rid: r.output for r in dpaged_reqs}

    bytes_dense = kv_cache_bytes(cfg, slots, max_len, layout="dense")
    bytes_paged = kv_cache_bytes(cfg, slots, max_len, layout="paged",
                                 block_size=block_size,
                                 total_blocks=provisioned)
    d, p = m_dense.summary(), m_paged.summary()
    section = {
        "block_size": block_size,
        "blocks_per_slot": bps,
        "total_blocks": provisioned,
        "dense_equiv_blocks": slots * bps,
        "kv_bytes_dense": bytes_dense,
        "kv_bytes_paged": bytes_paged,
        "kv_bytes_ratio": bytes_paged / bytes_dense,
        # slots a paged pool of this per-slot footprint fits in the dense
        # byte budget (the capacity headroom paging buys at equal memory)
        "achievable_n_slots_at_dense_budget": int(
            bytes_dense // max(bytes_paged / slots, 1)),
        "dense": d,
        "paged": p,
        "tok_per_s_ratio": p["tok_per_s"] / d["tok_per_s"],
        "bit_identical_colocated": out_d == out_p,
        "bit_identical_disaggregated": out_dd == out_dp,
    }
    section["all_identical"] = (section["bit_identical_colocated"]
                                and section["bit_identical_disaggregated"])
    print(f"[bench_serving] paged: {bytes_paged} KV bytes resident vs "
          f"{bytes_dense} dense ({section['kv_bytes_ratio']:.2f}x, "
          f"{section['achievable_n_slots_at_dense_budget']} slots at the "
          f"dense budget); saturation {p['tok_per_s']:.1f} vs "
          f"{d['tok_per_s']:.1f} tok/s "
          f"({section['tok_per_s_ratio']:.2f}x); "
          f"bit_identical={section['all_identical']}", flush=True)
    return section


def run_prefix(cfg, params, *, n_requests: int, seed: int,
               block_size: int = 16) -> Dict:
    """Prefix sharing vs unshared paging at a dense-equal KV budget.

    Workload: the chat/agent system-prompt pattern — a ``shared_frac`` of
    requests front-load one common 48-token prefix (3 full blocks) ahead of
    a unique suffix (``prefix_shared_workload``).  Both runs get the *same*
    constrained pool: 16 engine slots but only enough blocks to hold 8
    dense residents (``total_blocks = 8 x blocks_per_slot``), so block
    supply — not slot count — caps concurrency.  Without sharing every
    request draws its full footprint from the free list and at most 8 ever
    run at once; with sharing, once an early resident has written and
    published the common prefix blocks, later arrivals map onto them
    (refcounted, copy-on-write at the divergent tail) and draw only their
    unique blocks, so more land in flight and the queue drains sooner.

    Reported per shared-traffic fraction: peak concurrent slots and the
    ratio (the admitted-capacity win), TTFT p50 and queue-wait ratios, the
    prefix-cache hit/skip/COW counters, and bit-identity — shared KV pages
    hold exactly the values the request would have written itself, so
    greedy outputs must match the unshared run token for token.  The 90%
    fraction's capacity win and its bit-identity are the gated claims."""
    shared_prefix_len = 3 * block_size           # 48: full-block chain
    suffix_lens = (block_size // 2, block_size)  # unique tail, 1 block
    gen_lens = (4, 8, 16)
    max_len = shared_prefix_len + max(suffix_lens) + max(gen_lens)
    bps = -(-max_len // block_size)
    n_slots = 16
    dense_slots = 8                              # the byte budget: 8 dense
    total_blocks = dense_slots * bps             # residents, 16 slot leases

    def _workload_p(frac):
        return prefix_shared_workload(
            n_requests, rate=1e9, vocab=cfg.vocab,
            shared_prefix_len=shared_prefix_len, shared_frac=frac,
            suffix_lens=suffix_lens, gen_lens=gen_lens, seed=seed)

    def _run(frac, sharing):
        reqs = _workload_p(frac)
        eng = EngineLoop(cfg, params, n_slots=n_slots, max_seq=max_len,
                         block_size=block_size, kv_layout="paged",
                         total_blocks=total_blocks, prefix_sharing=sharing)
        eng.warmup()
        m = eng.run(reqs)
        return eng, m, {r.rid: r.output for r in reqs}

    section: Dict[str, object] = {
        "block_size": block_size,
        "blocks_per_slot": bps,
        "n_slots": n_slots,
        "total_blocks": total_blocks,
        "dense_equivalent_slots": dense_slots,
        "shared_prefix_len": shared_prefix_len,
        "n_requests": n_requests,
    }
    identical = []
    for frac in (0.5, 0.9):
        off_eng, m_off, out_off = _run(frac, False)
        on_eng, m_on, out_on = _run(frac, True)
        off, on = m_off.summary(), m_on.summary()
        st_off, st_on = off_eng.pool.stats(), on_eng.pool.stats()
        bit_identical = out_off == out_on
        identical.append(bit_identical)
        entry = {
            "unshared": off,
            "shared": on,
            "peak_slots_unshared": st_off["peak_slots_in_use"],
            "peak_slots_shared": st_on["peak_slots_in_use"],
            "admitted_slots_ratio": (st_on["peak_slots_in_use"]
                                     / max(st_off["peak_slots_in_use"], 1)),
            "ttft_p50_ratio": off["ttft_p50_s"] / on["ttft_p50_s"],
            "tok_per_s_ratio": on["tok_per_s"] / off["tok_per_s"],
            "prefix_hits": st_on["prefix_hits"],
            "tokens_prefill_skipped": st_on["tokens_prefill_skipped"],
            "cow_copies": st_on["cow_copies"],
            "bit_identical": bit_identical,
        }
        section[f"shared_frac_{int(frac * 100)}"] = entry
        print(f"[bench_serving] prefix[{frac:.0%} shared]: peak "
              f"{st_on['peak_slots_in_use']} slots shared vs "
              f"{st_off['peak_slots_in_use']} unshared "
              f"({entry['admitted_slots_ratio']:.2f}x) at the "
              f"{dense_slots}-dense-slot block budget; ttft p50 "
              f"{entry['ttft_p50_ratio']:.2f}x better, "
              f"{entry['prefix_hits']} hits / "
              f"{entry['tokens_prefill_skipped']} prefill tokens skipped / "
              f"{entry['cow_copies']} cow copies, "
              f"bit_identical={bit_identical}", flush=True)
    section["all_identical"] = all(identical)
    return section


def run_disaggregation(cfg, params, *, n_requests: int, slots: int,
                       max_len: int, seed: int):
    """Disaggregated vs colocated on the same saturation workload + the
    placement analyzer's call on the paper engine set.  Returns the JSON
    section plus the (metrics, requests) completion-pull baselines that
    :func:`run_streaming` builds on.

    Both loops run the same engine pair (the buildable XLA engine for both
    phases), so per-request outputs must be bit-identical — the hand-off
    is exact state migration, not an approximation.  The tok/s ratio is
    the measured cost of the phase boundary on this host; the placement
    table is what the trade-off analyzer would pick per objective."""
    colo_reqs = _workload(n_requests, 1e9, cfg.vocab, seed)
    dis_reqs = _workload(n_requests, 1e9, cfg.vocab, seed)

    colo = EngineLoop(cfg, params, n_slots=slots, max_seq=max_len)
    colo.warmup()
    c_metrics = colo.run(colo_reqs)

    dis = DisaggregatedEngineLoop(
        cfg, params, n_prefill_slots=max(slots // 2, 1),
        n_decode_slots=slots, max_seq=max_len)
    dis.warmup()
    d_metrics = dis.run(dis_reqs)

    bit_identical = ({r.rid: r.output for r in colo_reqs}
                     == {r.rid: r.output for r in dis_reqs})
    # completion-pull baselines run_streaming reuses (same workload/config),
    # so the bench doesn't pay these runs + warmup compiles twice
    baselines = {"colocated": (c_metrics, colo_reqs),
                 "disaggregated": (d_metrics, dis_reqs)}
    placements = {}
    for objective in ("latency", "energy", "perf_density"):
        d = place_phases(cfg, objective=objective,
                         prompt_len=max(PROMPT_LENS),
                         gen_len=max(GEN_LENS), batch=slots)
        placements[objective] = {
            "prefill_engine": d.prefill_engine,
            "decode_engine": d.decode_engine,
            "colocated": d.colocated,
            "value": d.best.value,
            "handoff_s": d.best.handoff.t_transfer,
        }
    c, dd = c_metrics.summary(), d_metrics.summary()
    out = {
        "colocated": c,
        "disaggregated": dd,
        "tok_per_s_ratio": dd["tok_per_s"] / c["tok_per_s"],
        "bit_identical": bit_identical,
        "handoff": dis.handoff.stats(),
        "placement": placements,
    }
    print(f"[bench_serving] disaggregation: colocated {c['tok_per_s']:.1f} "
          f"tok/s vs disaggregated {dd['tok_per_s']:.1f} tok/s "
          f"({out['tok_per_s_ratio']:.2f}x, {dis.handoff.n_handoffs} "
          f"handoffs, bit_identical={bit_identical})", flush=True)
    return out, baselines


def run_streaming(cfg, params, baselines: Dict, *, n_requests: int,
                  slots: int, max_len: int, seed: int) -> Dict:
    """Streaming vs completion-pull token delivery on the same workload,
    colocated and disaggregated.

    Streaming syncs the device chain at burst boundaries and emits newly
    readable tokens as deltas, so TTFT measures *delivered* tokens; the
    completion path only surfaces a request's row when it finishes (its
    first token becomes host-visible with its last).  ``ttft_dispatch``
    keeps the old dispatch-time stamp in both modes, so the section
    quantifies the gap the dispatch-stamped metric used to hide.  The
    correctness contract: streamed outputs are bit-identical to the
    completion-pull rows, and the deltas concatenate to exactly those rows.

    ``baselines`` is :func:`run_disaggregation`'s completion-pull runs
    (same workload, config and seed), reused here so the bench doesn't pay
    those serving runs and warmup compiles a second time.
    """
    section: Dict[str, Dict] = {}
    for mode, mk in (
            ("colocated",
             lambda: EngineLoop(cfg, params, n_slots=slots, max_seq=max_len)),
            ("disaggregated",
             lambda: DisaggregatedEngineLoop(
                 cfg, params, n_prefill_slots=max(slots // 2, 1),
                 n_decode_slots=slots, max_seq=max_len))):
        m_comp, comp_reqs = baselines[mode]
        strm_reqs = _workload(n_requests, 1e9, cfg.vocab, seed)
        strm_eng = mk()
        strm_eng.warmup()
        deltas: Dict[int, List[int]] = {}
        m_strm = strm_eng.run(
            strm_reqs,
            on_delta=lambda d: deltas.setdefault(d.rid, []).extend(d.tokens))

        comp_out = {r.rid: r.output for r in comp_reqs}
        strm_out = {r.rid: r.output for r in strm_reqs}
        gaps = [r.ttft - r.ttft_dispatch for r in strm_reqs
                if r.ttft is not None and r.ttft_dispatch is not None]
        c, s = m_comp.summary(), m_strm.summary()
        section[mode] = {
            "completion": c,
            "streaming": s,
            "bit_identical": comp_out == strm_out,
            "delta_concat_identical": deltas == comp_out,
            "ttft_dispatch_leq_ttft": all(
                r.ttft_dispatch <= r.ttft for r in comp_reqs + strm_reqs
                if r.ttft is not None and r.ttft_dispatch is not None),
            # host-visibility gap the dispatch-stamped TTFT used to hide
            # (None, not NaN: the report must stay strict JSON)
            "ttft_gap_p50_s": (float(np.percentile(np.asarray(gaps), 50))
                               if gaps else None),
            "sync_cost_tok_per_s_ratio": s["tok_per_s"] / c["tok_per_s"],
        }
        print(f"[bench_serving] streaming[{mode}]: ttft p50 "
              f"{s['ttft_p50_s']*1e3:.1f}ms streamed vs "
              f"{c['ttft_p50_s']*1e3:.1f}ms completion-pull "
              f"(dispatch stamp {s['ttft_dispatch_p50_s']*1e3:.1f}ms); "
              f"{s['tokens_streamed']} tokens in {s['stream_deltas']} "
              f"deltas, sync cost "
              f"{section[mode]['sync_cost_tok_per_s_ratio']:.2f}x, "
              f"bit_identical={section[mode]['bit_identical']}", flush=True)
    section["all_identical"] = all(
        section[m]["bit_identical"] and section[m]["delta_concat_identical"]
        and section[m]["ttft_dispatch_leq_ttft"]
        for m in ("colocated", "disaggregated"))
    return section


def run_observability(cfg, params, baselines: Dict, *, n_requests: int,
                      slots: int, max_len: int, seed: int) -> Dict:
    """Cost and correctness of the observability layer on the saturation
    workload.

    Three colocated configurations of the same workload: an untraced
    baseline, a run with the default ``NullTracer`` constructed
    explicitly (the tracing-off tax: guard branches only), and a fully
    traced run (ring-buffer ``Tracer`` plus per-iteration registry
    sampling), plus a traced disaggregated run so the hand-off span is
    exercised.  Tracing happens strictly between device dispatches, so
    every run must stay bit-identical to the untraced outputs
    (``baselines`` supplies :func:`run_disaggregation`'s reference rows);
    the NullTracer throughput ratio is the gated overhead bound (the
    traced ratio is reported, not gated — a full ring-buffer trace is a
    debugging artifact, not the steady state).  A sub-second smoke run's
    tok/s jitters by several percent on a shared host, so the reps are
    interleaved round-robin across the three configurations (every config
    samples the same host-load windows) and each reports its best rep —
    the standard min-time estimator — rather than one sample."""
    _, untraced_reqs = baselines["colocated"]
    untraced_out = {r.rid: r.output for r in untraced_reqs}
    _, dis_reqs = baselines["disaggregated"]
    dis_out = {r.rid: r.output for r in dis_reqs}

    def _mk(obs):
        eng = EngineLoop(cfg, params, n_slots=slots, max_seq=max_len,
                         obs=obs)
        eng.warmup()
        return eng

    traced_obs = Observability(tracer=Tracer())
    engines = {"untraced": _mk(None),       # EngineLoop's default obs
               "null": _mk(Observability()),
               "traced": _mk(traced_obs)}
    best: Dict[str, object] = {}
    outs: Dict[str, Dict[int, List[int]]] = {}
    for _ in range(_OBS_REPS):
        for key, eng in engines.items():
            reqs = _workload(n_requests, 1e9, cfg.vocab, seed)
            m = eng.run(reqs)
            if key not in best or m.summary()["tok_per_s"] > \
                    best[key].summary()["tok_per_s"]:
                best[key] = m
            rows = {r.rid: r.output for r in reqs}
            assert outs.setdefault(key, rows) == rows   # deterministic reps
    m_untraced, m_null, m_traced = (best["untraced"], best["null"],
                                    best["traced"])
    plain_out, null_out, traced_out = (outs["untraced"], outs["null"],
                                       outs["traced"])

    dtraced_obs = Observability(tracer=Tracer())
    dtraced_reqs = _workload(n_requests, 1e9, cfg.vocab, seed)
    dtraced = DisaggregatedEngineLoop(
        cfg, params, n_prefill_slots=max(slots // 2, 1),
        n_decode_slots=slots, max_seq=max_len, obs=dtraced_obs)
    dtraced.warmup()
    dtraced.run(dtraced_reqs)
    dtraced_out = {r.rid: r.output for r in dtraced_reqs}

    names = {e.name for e in traced_obs.tracer.events}
    dnames = {e.name for e in dtraced_obs.tracer.events}
    lifecycle = {"queued", "prefill", "decode", "burst", "sync",
                 "first_token", "done"}
    u, nl, tr = (m_untraced.summary(), m_null.summary(),
                 m_traced.summary())
    section = {
        "untraced": u,
        "null_tracer": nl,
        "traced": tr,
        # gated bound: the cost of shipping with tracing compiled in but
        # off; the traced ratio is informational
        "overhead_ratio_null": nl["tok_per_s"] / u["tok_per_s"],
        "overhead_ratio_traced": tr["tok_per_s"] / u["tok_per_s"],
        "bit_identical_null": untraced_out == plain_out == null_out,
        "bit_identical_traced": untraced_out == traced_out,
        "bit_identical_traced_disagg": dis_out == dtraced_out,
        "trace_events": len(traced_obs.tracer),
        "trace_events_disagg": len(dtraced_obs.tracer),
        "trace_dropped": traced_obs.tracer.n_dropped,
        "trace_spans_balanced": (traced_obs.tracer.n_open == 0
                                 and dtraced_obs.tracer.n_open == 0),
        "lifecycle_spans_present": lifecycle <= names,
        "handoff_span_present": "handoff" in dnames,
        "metrics_series_points": traced_obs.registry.n_samples,
    }
    section["all_identical"] = (section["bit_identical_null"]
                                and section["bit_identical_traced"]
                                and section["bit_identical_traced_disagg"]
                                and section["trace_spans_balanced"]
                                and section["lifecycle_spans_present"]
                                and section["handoff_span_present"])
    print(f"[bench_serving] observability: null-tracer "
          f"{section['overhead_ratio_null']:.3f}x, traced "
          f"{section['overhead_ratio_traced']:.3f}x of untraced tok/s; "
          f"{section['trace_events']} events "
          f"({section['trace_dropped']} dropped), "
          f"bit_identical={section['all_identical']}", flush=True)
    return section


def run_adaptive(cfg, params, baselines: Dict, *, n_requests: int,
                 slots: int, max_len: int, seed: int) -> Dict:
    """The watchdog control loop under an injected pricing error, plus the
    overhead of running it.

    Drifted-cost scenario: admission is priced on a device model de-rated
    (``drift_scaled_device``) until the analytic step time at batch 2 is
    4x the step SLO, so the static token budget clamps to 1 and the loop
    serializes.  The real hardware is far faster than that price, so the
    watchdog's EWMA of observed/priced crosses the gate, the driver hands
    the alert to ``on_drift``, and the batcher re-prices from telemetry
    (ratio-scaled analytic first, fitted latency(batch) curve once two
    batch sizes were observed) — the budget refits against the same SLO
    and the run recovers full batching mid-flight.  Gated: re-pricing must
    improve saturation throughput AND p50 TTFT, at least one alert and one
    re-price must fire, and both runs must stay bit-identical to the
    untouched baseline (admission policy must never change outputs).

    Overhead: tracer+watchdog vs tracer-only throughput on the undrifted
    configuration, interleaved best-of-``_OBS_REPS`` like the
    observability section (the watchdog syncs each burst to time it — that
    sync is the cost being gated)."""
    from repro.core import device_models
    from repro.obs import PerfWatchdog
    from repro.serving.batcher import step_time_model
    from repro.serving.placement import drift_scaled_device

    _, base_reqs = baselines["colocated"]
    base_out = {r.rid: r.output for r in base_reqs}

    # de-rate the pricing device until batch 2 breaks the step SLO: the
    # static budget pins to 1 while the hardware could batch freely
    slo = 0.1
    base_dev = device_models.get("tpu-v5e")
    factor = 4.0 * slo / step_time_model(cfg, max_len, 2, device=base_dev)
    drifted = drift_scaled_device(base_dev, factor)

    def _run(watchdog):
        obs = (Observability(watchdog=watchdog)
               if watchdog is not None else None)
        eng = EngineLoop(cfg, params, n_slots=slots, max_seq=max_len,
                         device_model=drifted, step_slo_s=slo, obs=obs)
        eng.warmup()                     # timing the schedule, not jit
        reqs = _workload(n_requests, 1e9, cfg.vocab, seed)
        m = eng.run(reqs)
        return eng, m, {r.rid: r.output for r in reqs}

    off_eng, m_off, out_off = _run(None)
    wd = PerfWatchdog()
    on_eng, m_on, out_on = _run(wd)

    # overhead leg: same undrifted tracer-only vs tracer+watchdog engines,
    # reps interleaved so both sample the same host-load windows
    def _mk(obs):
        eng = EngineLoop(cfg, params, n_slots=slots, max_seq=max_len,
                         obs=obs)
        eng.warmup()
        return eng
    engines = {"traced": _mk(Observability(tracer=Tracer())),
               "watchdog": _mk(Observability(tracer=Tracer(),
                                             watchdog=PerfWatchdog()))}
    best: Dict[str, float] = {}
    outs: Dict[str, Dict[int, List[int]]] = {}
    for _ in range(_OBS_REPS):
        for key, eng in engines.items():
            reqs = _workload(n_requests, 1e9, cfg.vocab, seed)
            m = eng.run(reqs)
            best[key] = max(best.get(key, 0.0), m.summary()["tok_per_s"])
            rows = {r.rid: r.output for r in reqs}
            assert outs.setdefault(key, rows) == rows   # deterministic reps

    off, on = m_off.summary(), m_on.summary()
    section = {
        "scenario": {
            "step_slo_s": slo,
            "misprice_factor": factor,
            "priced_device": drifted.name,
        },
        "static_priced": off,
        "adaptive": on,
        "tok_per_s_ratio": on["tok_per_s"] / off["tok_per_s"],
        # >1: re-pricing cut the median time-to-first-token
        "ttft_p50_ratio": off["ttft_p50_s"] / on["ttft_p50_s"],
        "n_alerts": len(wd.alerts),
        "n_reprices": len(wd.reprices),
        "token_budget_static": off_eng.batcher.token_budget,
        "token_budget_final": on_eng.batcher.token_budget,
        "price_source_final": on_eng.batcher.price_source,
        "overhead_ratio_watchdog": best["watchdog"] / best["traced"],
        "bit_identical_static": base_out == out_off,
        "bit_identical_adaptive": base_out == out_on,
        "bit_identical_overhead": outs["traced"] == outs["watchdog"]
                                  == base_out,
    }
    section["all_identical"] = (section["bit_identical_static"]
                                and section["bit_identical_adaptive"]
                                and section["bit_identical_overhead"])
    print(f"[bench_serving] adaptive: drifted-cost {on['tok_per_s']:.1f} "
          f"tok/s watchdog-on vs {off['tok_per_s']:.1f} off "
          f"({section['tok_per_s_ratio']:.2f}x, ttft p50 "
          f"{section['ttft_p50_ratio']:.2f}x better), budget "
          f"{section['token_budget_static']} -> "
          f"{section['token_budget_final']} "
          f"({section['price_source_final']}, {section['n_alerts']} alerts, "
          f"{section['n_reprices']} reprices); watchdog overhead "
          f"{section['overhead_ratio_watchdog']:.3f}x traced; "
          f"bit_identical={section['all_identical']}", flush=True)
    return section


def _multidevice_section(*, n_requests: int, slots: int, seed: int) -> Dict:
    """Two-device serving legs (the child side of :func:`run_multidevice`).

    Meant to run in a subprocess under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=2`` so the phase
    engines land on two real XLA devices; degrades to whatever devices
    are visible (``n_devices``/``distinct_devices`` report which world the
    numbers came from, and the gate adapts).  Legs: colocated baseline,
    cross-device disagg with the async hand-off, the same with
    ``--sync-handoff`` (prefill blocks on every transfer — the stall
    baseline the overlap win is measured against), and a mid-run
    placement migration (decode device model priced ~1e6x too fast, the
    watchdog's placement re-run flips decode onto the prefill engine and
    live-migrates in-flight slots).  Every leg must stay bit-identical to
    colocated serving."""
    from repro.core import engines as engines_lib
    from repro.launch.mesh import device_assignment, device_label
    from repro.obs import PerfWatchdog
    from repro.profiling.transfer import measure_link_bandwidth
    from repro.serving.placement import drift_scaled_device

    cfg = SMOKE_CFG
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    max_len = max(PROMPT_LENS) + max(GEN_LENS)
    asn = device_assignment()
    link = (measure_link_bandwidth(asn.prefill, asn.decode)
            if asn.distinct else None)

    colo = EngineLoop(cfg, params, n_slots=slots, max_seq=max_len)
    colo.warmup()

    def _mk_dis(async_handoff, assignment=asn):
        d = DisaggregatedEngineLoop(
            cfg, params, n_prefill_slots=max(slots // 2, 1),
            n_decode_slots=slots, max_seq=max_len, assignment=assignment,
            async_handoff=async_handoff)
        d.warmup()
        return d

    # interleaved best-of reps, like the observability section: the
    # overlap/stall split and the two-device throughput ratio are the
    # gated numbers, and sub-second runs jitter on a shared host.  The
    # "shared" leg is the same disagg loop with both phases on the
    # default device — the throughput baseline the distinct assignment
    # must not lose to (the disagg loop itself already pays the phase
    # boundary; that cost is the `disaggregation` section's claim)
    engines = {"colocated": colo, "async": _mk_dis(True),
               "sync": _mk_dis(False), "shared": _mk_dis(True, None)}
    best: Dict[str, ServeMetrics] = {}
    outs: Dict[str, Dict[int, List[int]]] = {}
    for _ in range(3):
        for key, eng in engines.items():
            reqs = _workload(n_requests, 1e9, cfg.vocab, seed)
            m = eng.run(reqs)
            if key not in best or m.summary()["tok_per_s"] > \
                    best[key].summary()["tok_per_s"]:
                best[key] = m
            rows = {r.rid: r.output for r in reqs}
            assert outs.setdefault(key, rows) == rows   # deterministic reps

    # mid-run migration leg: equal phase pools so the flip has spare
    # prefill capacity to migrate decode slots into, smaller workload so
    # slots are in flight (not queued) when the drift alert lands
    mig_n = min(n_requests, 2 * slots)
    mig_reqs = _workload(mig_n, 1e9, cfg.vocab, seed + 1)
    colo.run(mig_reqs)
    mig_ref = {r.rid: r.output for r in mig_reqs}
    wd = PerfWatchdog()
    dis_m = DisaggregatedEngineLoop(
        cfg, params, n_prefill_slots=slots, n_decode_slots=slots,
        max_seq=max_len, assignment=asn,
        obs=Observability(watchdog=wd),
        prefill_device=engines_lib.XLA_ENGINE.device,
        decode_device=drift_scaled_device(engines_lib.K40_LM_ENGINE.device,
                                          1e-6),
        prefill_placement_engine_name="xla",
        decode_placement_engine_name="k40-roofline")
    dis_m.warmup()
    mig_run = _workload(mig_n, 1e9, cfg.vocab, seed + 1)
    mm = dis_m.run(mig_run)
    migration = {
        "n_requests": mig_n,
        "n_done": mm.n_done,
        "n_dropped": mm.n_dropped,
        "n_live_migrations": dis_m.handoff.n_live_migrations,
        "n_alerts": len(wd.alerts),
        "decode_target": dis_m.decode_target,
        "requests_preserved": mm.n_done == mig_n and mm.n_dropped == 0,
        "bit_identical": {r.rid: r.output for r in mig_run} == mig_ref,
    }

    sync_stall = engines["sync"].handoff.stall_s
    async_stall = engines["async"].handoff.stall_s
    c, a, s, sh = (best["colocated"].summary(), best["async"].summary(),
                   best["sync"].summary(), best["shared"].summary())
    section = {
        "n_devices": len(jax.devices()),
        "distinct_devices": asn.distinct,
        "assignment": {"prefill": device_label(asn.prefill),
                       "decode": device_label(asn.decode)},
        "measured_link_bw": None if link is None else link["link_bw"],
        "colocated": c,
        "disagg_async": a,
        "disagg_sync": s,
        "disagg_shared_device": sh,
        "tok_per_s_ratio_vs_colocated": a["tok_per_s"] / c["tok_per_s"],
        "tok_per_s_ratio_vs_sync": a["tok_per_s"] / s["tok_per_s"],
        # the gated two-device claim: real cross-device hand-offs must not
        # cost throughput against the same loop on one shared device
        "tok_per_s_ratio_vs_shared": a["tok_per_s"] / sh["tok_per_s"],
        "handoff_async": engines["async"].handoff.stats(),
        "handoff_sync": engines["sync"].handoff.stats(),
        "sync_stall_s": sync_stall,
        "async_stall_s": async_stall,
        "async_overlap_s": engines["async"].handoff.overlap_s,
        # the gated overlap win: time decode blocked on transfers, async
        # over the blocking baseline (<= 0.5 means the pipeline hid at
        # least half the measured transfer time)
        "stall_ratio": async_stall / max(sync_stall, 1e-12),
        "bit_identical_async": outs["async"] == outs["colocated"],
        "bit_identical_sync": outs["sync"] == outs["colocated"],
        "bit_identical_shared": outs["shared"] == outs["colocated"],
        "migration": migration,
    }
    section["all_identical"] = (section["bit_identical_async"]
                                and section["bit_identical_sync"]
                                and section["bit_identical_shared"]
                                and migration["bit_identical"])
    return section


def run_multidevice(*, n_requests: int, slots: int, seed: int) -> Dict:
    """The ``multidevice`` section: async hand-off overlap, two-device
    throughput and mid-run migration under a forced two-device host.

    ``--xla_force_host_platform_device_count`` only works before the
    first jax import, and this process already initialized its backend —
    so the legs run in a subprocess carrying the flag (the same world the
    CI multidevice job and ``tests/test_multidevice.py`` exercise).  If
    the subprocess fails the section is measured in-process on whatever
    devices exist; ``n_devices``/``distinct_devices`` record which, and
    ``check_regression`` gates the overlap/throughput claims only on a
    genuinely distinct assignment."""
    import subprocess
    import sys

    from repro.launch.mesh import forced_host_device_env

    cmd = [sys.executable, "-m", "benchmarks.bench_serving",
           "--multidevice-child", "--requests", str(n_requests),
           "--slots", str(slots)]
    try:
        proc = subprocess.run(cmd, env=forced_host_device_env(2),
                              capture_output=True, text=True, timeout=1800)
    except (OSError, subprocess.TimeoutExpired) as e:
        proc = None
        print(f"[bench_serving] multidevice subprocess failed: {e!r}",
              flush=True)
    if proc is not None and proc.returncode == 0:
        section = json.loads(proc.stdout.strip().splitlines()[-1])
        section["forced_subprocess"] = True
    else:
        if proc is not None:
            print(f"[bench_serving] multidevice subprocess exited "
                  f"{proc.returncode}: {proc.stderr[-2000:]}", flush=True)
        print("[bench_serving] multidevice: degrading to in-process "
              "devices", flush=True)
        section = _multidevice_section(n_requests=n_requests, slots=slots,
                                       seed=seed)
        section["forced_subprocess"] = False
    mig = section["migration"]
    print(f"[bench_serving] multidevice[{section['assignment']['prefill']}"
          f"|{section['assignment']['decode']}]: async "
          f"{section['disagg_async']['tok_per_s']:.1f} tok/s "
          f"({section['tok_per_s_ratio_vs_shared']:.2f}x shared-device, "
          f"{section['tok_per_s_ratio_vs_colocated']:.2f}x colocated), "
          f"stall {section['async_stall_s']*1e3:.2f}ms async vs "
          f"{section['sync_stall_s']*1e3:.2f}ms sync "
          f"(ratio {section['stall_ratio']:.2f}); migration "
          f"{mig['n_live_migrations']} live / {mig['n_done']} done; "
          f"bit_identical={section['all_identical']}", flush=True)
    return section


def run_speculative() -> Dict:
    """Draft-model speculative decoding through the programmatic serving
    API (``repro.serving.api.serve``), plus the analyzer's pricing calls.

    Four legs.  (1) *Forced*: the registry pairing — ``qwen2_1_5b``
    drafting for ``granite_34b`` at smoke scale — with ``draft_k=2``;
    greedy verification makes speculative outputs bitwise the plain
    run's, and the measured accepted-token rate is reported.  (2)
    *Priced*: the same pair with the depth left to the trade-off
    analyzer; at these smoke shapes the projected draft+verify cost
    loses to plain decode, so the gated claim is the *fallback* — the
    run must serve plain, bit-identically, and record why.  (3)
    *Adversarial price*: a draft device de-rated 100x must price
    speculation off even at a 0.95 acceptance prior.  (4) The
    full-scale registry pair's pricing table across acceptance rates
    (informational: where speculation wins once the draft really is
    ~20x cheaper than the target)."""
    from repro.configs import registry
    from repro.core.device_models import get as get_device
    from repro.serving.api import ServeOptions, serve
    from repro.serving.placement import (choose_speculation,
                                         drift_scaled_device)

    target, draft = "granite_34b", "qwen2_1_5b"
    shape = dict(arch=target, requests=6, slots=4, prompt_len=8,
                 gen_len=16, rate=1e9)

    def _opts(**overrides):
        o = ServeOptions()
        flat = o.flat_fields()
        for key, v in {**shape, **overrides}.items():
            setattr(getattr(o, flat[key]), key, v)
        o.validate()
        return o

    plain = serve(_opts())
    forced = serve(_opts(speculate=True, draft_arch=draft, draft_k=2))
    priced = serve(_opts(speculate=True, draft_arch=draft))
    st = forced.speculation

    tgt_cfg = registry.get(target).config
    draft_cfg = registry.get(draft).config
    slow_draft = drift_scaled_device(get_device("tpu-v5e"), 100.0)
    adversarial = choose_speculation(
        tgt_cfg, draft_cfg, kv_len=1024, n_tokens=8, acceptance=0.95,
        draft_name=draft, draft_device=slow_draft)
    pricing = {}
    for alpha in (0.5, 0.8, 0.95):
        d = choose_speculation(tgt_cfg, draft_cfg, kv_len=1024,
                               n_tokens=8, acceptance=alpha,
                               draft_name=draft)
        pricing[f"acceptance_{int(alpha * 100)}"] = d.summary()

    p, f, pr = plain.summary, forced.summary, priced.summary
    section = {
        "target": target,
        "draft": draft,
        "scale": "smoke",
        "workload": shape,
        "plain": p,
        "forced": f,
        "speculation": st,
        "accepted_token_rate": st["acceptance_rate"],
        "n_rounds": st["n_rounds"],
        "tok_per_s_ratio_forced": f["tok_per_s"] / p["tok_per_s"],
        "bit_identical_forced": forced.outputs == plain.outputs,
        "priced": priced.speculation,
        "priced_engaged": bool(priced.speculation["engaged"]),
        "priced_fallback": bool(
            priced.speculation.get("priced_fallback", False)),
        "tok_per_s_ratio_priced": pr["tok_per_s"] / p["tok_per_s"],
        "bit_identical_priced": priced.outputs == plain.outputs,
        "adversarial": {"draft_derate_factor": 100.0, "acceptance": 0.95,
                        "decision": adversarial.summary()},
        "pricing_full_scale": pricing,
    }
    section["all_identical"] = (section["bit_identical_forced"]
                                and section["bit_identical_priced"])
    print(f"[bench_serving] speculative[{draft}->{target}]: forced k=2 "
          f"{st['n_rounds']} rounds, acceptance "
          f"{st['acceptance_rate']:.2f}, "
          f"{section['tok_per_s_ratio_forced']:.2f}x plain tok/s; priced "
          f"leg {'engaged' if section['priced_engaged'] else 'fell back'} "
          f"({section['tok_per_s_ratio_priced']:.2f}x); adversarial "
          f"use={adversarial.use}; "
          f"bit_identical={section['all_identical']}", flush=True)
    return section


def run_bench(*, n_requests: int, slots: int, rates: List[float],
              seed: int = 7) -> Dict:
    cfg = SMOKE_CFG
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    max_len = max(PROMPT_LENS) + max(GEN_LENS)
    results = {"config": {
        "model": cfg.name, "n_requests": n_requests, "slots": slots,
        "prompt_lens": list(PROMPT_LENS), "gen_lens": list(GEN_LENS),
        "max_len": max_len,
    }, "loads": []}
    for rate in rates:
        static_reqs = _workload(n_requests, rate, cfg.vocab, seed)
        cont_reqs = _workload(n_requests, rate, cfg.vocab, seed)

        s_metrics = ServeMetrics()
        s_out = run_static(cfg, params, static_reqs, batch=slots,
                           max_len=max_len, metrics=s_metrics)
        c_metrics = run_continuous(cfg, params, cont_reqs, slots=slots,
                                   max_len=max_len)
        c_out = {r.rid: r.output for r in cont_reqs}
        bit_identical = all(s_out[rid] == c_out[rid] for rid in s_out)

        s, c = s_metrics.summary(), c_metrics.summary()
        speedup = c["tok_per_s"] / s["tok_per_s"]
        results["loads"].append({
            "offered_rate_req_s": rate,
            "static": s,
            "continuous": c,
            "speedup_tok_per_s": speedup,
            "bit_identical": bit_identical,
        })
        print(f"[bench_serving] rate={rate:g} req/s: static "
              f"{s['tok_per_s']:.1f} tok/s vs continuous "
              f"{c['tok_per_s']:.1f} tok/s -> {speedup:.2f}x "
              f"(bit_identical={bit_identical})", flush=True)
    results["disaggregation"], baselines = run_disaggregation(
        cfg, params, n_requests=n_requests, slots=slots, max_len=max_len,
        seed=seed)
    results["paged"] = run_paged(
        cfg, params, baselines, n_requests=n_requests, slots=slots,
        max_len=max_len, seed=seed)
    results["prefix"] = run_prefix(
        cfg, params, n_requests=max(n_requests * 2 // 3, 8), seed=seed)
    results["streaming"] = run_streaming(
        cfg, params, baselines, n_requests=n_requests, slots=slots,
        max_len=max_len, seed=seed)
    results["observability"] = run_observability(
        cfg, params, baselines, n_requests=n_requests, slots=slots,
        max_len=max_len, seed=seed)
    results["adaptive"] = run_adaptive(
        cfg, params, baselines, n_requests=n_requests, slots=slots,
        max_len=max_len, seed=seed)
    results["multidevice"] = run_multidevice(
        n_requests=n_requests, slots=slots, seed=seed)
    results["speculative"] = run_speculative()
    results["max_speedup"] = max(l["speedup_tok_per_s"]
                                 for l in results["loads"])
    results["all_bit_identical"] = all(
        [l["bit_identical"] for l in results["loads"]]
        + [results["disaggregation"]["bit_identical"],
           results["paged"]["all_identical"],
           results["prefix"]["all_identical"],
           results["streaming"]["all_identical"],
           results["observability"]["all_identical"],
           results["adaptive"]["all_identical"],
           results["multidevice"]["all_identical"],
           results["speculative"]["all_identical"]])
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="smoke", choices=["smoke", "tiny"])
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--rates", type=float, nargs="+", default=None,
                    help="offered loads (req/s); 1e9 ~= saturation")
    ap.add_argument("--out", default="BENCH_serving.json")
    # internal: run only the multidevice legs and print their JSON on the
    # last stdout line (run_multidevice spawns this under the forced
    # two-device XLA flag, which must precede the first jax import)
    ap.add_argument("--multidevice-child", action="store_true",
                    help=argparse.SUPPRESS)
    args = ap.parse_args()

    n = args.requests or (16 if args.scale == "tiny" else 48)
    if args.multidevice_child:
        section = _multidevice_section(n_requests=n, slots=args.slots,
                                       seed=7)
        print(json.dumps(section, allow_nan=False))
        return
    rates = args.rates or ([1e9] if args.scale == "tiny" else [16.0, 1e9])
    results = run_bench(n_requests=n, slots=args.slots, rates=rates)
    with open(args.out, "w") as f:
        # strict JSON: a NaN stat leaking into the report is a bug (see
        # ServeMetrics.summary on zero-completion runs), not a value
        json.dump(results, f, indent=2, allow_nan=False)
    print(f"[bench_serving] wrote {args.out}: max speedup "
          f"{results['max_speedup']:.2f}x, bit_identical="
          f"{results['all_bit_identical']}")
    if not results["all_bit_identical"]:
        raise SystemExit("continuous outputs diverged from static path")


if __name__ == "__main__":
    main()
