"""CNNLab DSE benchmark (paper §III.A processing flow).

Measures the middleware itself: scheduling latency for AlexNet over the full
engine registry, plan quality across objectives, and the latency/energy
frontier the trade-off analysis exposes (the paper's 'design space is
searched' step)."""
import time

from repro.core import engines, scheduler
from repro.core.cost_model import OBJECTIVES
from repro.core.layer_model import alexnet_full_spec


def run():
    rows = []
    net = alexnet_full_spec()
    t0 = time.perf_counter()
    reps = 50
    for _ in range(reps):
        plan = scheduler.schedule(net, engines.ALL_ENGINES,
                                  objective="latency")
    dse_us = (time.perf_counter() - t0) / reps * 1e6
    rows.append(("scheduler", "dse_latency_us", dse_us,
                 f"{len(net)} layers x {len(engines.ALL_ENGINES)} engines", ""))

    for obj in OBJECTIVES:
        plan = scheduler.schedule(net, engines.ALL_ENGINES, objective=obj,
                                  batch=109)
        picks = ",".join(sorted({a.engine for a in plan.assignments}))
        rows.append(("scheduler", f"plan_{obj}", plan.total_time * 1e3,
                     f"ms total; E={plan.total_energy:.2f}J "
                     f"peakP={plan.peak_power:.1f}W engines={picks}", ""))

    # power-capped schedule (the paper's data-center power motivation)
    plan = scheduler.schedule(net, engines.ALL_ENGINES, objective="latency",
                              power_cap_w=50.0, batch=109)
    rows.append(("scheduler", "plan_latency_cap50W", plan.total_time * 1e3,
                 f"ms total; peakP={plan.peak_power:.1f}W", ""))
    return rows
