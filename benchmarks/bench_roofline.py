"""§Roofline reporter: turns dry-run JSON into the three-term roofline table.

Per (arch x shape) on the single-pod 16x16 mesh:

    compute term    = HLO_FLOPs  / (chips x 197 TFLOP/s)
    memory term     = HLO_bytes  / (chips x 819 GB/s)
    collective term = coll_bytes / (chips x 50 GB/s/link)

HLO_FLOPs/bytes are **scan-corrected**: XLA's HloCostAnalysis counts while
bodies once, so the raw compiled numbers are combined with the L0/L1
calibration compiles (launch/dryrun.py --calibrate):

    corrected = L0 + (n_layers / unit_len) x (L1 - L0)

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) for train; 2·N·D for
prefill; 2·N per token for decode.  The useful-compute ratio
MODEL_FLOPS / HLO_FLOPs exposes remat/dispatch waste.
"""
import json
import os
from typing import Dict, List, Optional

from repro.configs import registry
from repro.models.transformer import count_active_params

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # B/s / chip
LINK_BW = 50e9            # B/s / link

RESULTS = os.environ.get("DRYRUN_RESULTS",
                         os.path.join(os.path.dirname(__file__), "..",
                                      "dryrun_results.json"))


def load(path: Optional[str] = None) -> List[Dict]:
    with open(path or RESULTS) as f:
        return json.load(f)


def model_flops_per_device(arch_name: str, shape_name: str,
                           n_chips: int) -> float:
    arch = registry.get(arch_name)
    cfg = arch.config
    n_active = count_active_params(cfg)
    shape = registry.SHAPES[shape_name]
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n_active * tokens
    elif shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_active * shape.global_batch
    return total / n_chips


def corrected_costs(records: List[Dict]) -> Dict:
    """Combine full-compile records with calibration records."""
    cal = {(r["arch"], r["shape"]): r for r in records
           if r.get("calibration") and r.get("status") == "ok"}
    out = {}
    for r in records:
        if r.get("calibration") or r.get("status") != "ok" \
                or r.get("overrides"):
            continue
        key = (r["arch"], r["shape"], r["mesh"])
        c = cal.get((r["arch"], r["shape"]))
        rec = dict(r)
        if c:
            scale = c["n_layers"] / max(c["unit_len"], 1)
            for k in ("flops_per_device", "bytes_per_device",
                      "collective_bytes_per_device"):
                body = c[f"L1_{k}"] - c[f"L0_{k}"]
                rec[f"corrected_{k}"] = c[f"L0_{k}"] + scale * max(body, 0.0)
            # collectives: the full compile sees loop-hoisted collectives the
            # calibration can't attribute; keep the larger (conservative)
            rec["corrected_collective_bytes_per_device"] = max(
                rec["corrected_collective_bytes_per_device"],
                r["collective_bytes_per_device"])
        else:
            for k in ("flops_per_device", "bytes_per_device",
                      "collective_bytes_per_device"):
                rec[f"corrected_{k}"] = r[k]
            rec["uncalibrated"] = True
        out[key] = rec
    return out


def roofline_rows(records: List[Dict], mesh: str = "pod") -> List[Dict]:
    rows = []
    for (arch, shape, m), r in sorted(corrected_costs(records).items()):
        if m != mesh:
            continue
        # train cells run grad_accum sequential microbatch passes; the accum
        # scan is one more while loop HloCostAnalysis counts once
        accum = 1
        if registry.SHAPES[shape].mode == "train":
            accum = max(registry.get(arch).config.grad_accum, 1)
        t_c = accum * r["corrected_flops_per_device"] / PEAK_FLOPS
        t_m = accum * r["corrected_bytes_per_device"] / HBM_BW
        t_x = accum * r["corrected_collective_bytes_per_device"] / LINK_BW
        terms = {"compute": t_c, "memory": t_m, "collective": t_x}
        dom = max(terms, key=terms.get)
        mf = model_flops_per_device(arch, shape, r["n_chips"])
        t_total = max(terms.values())
        rows.append({
            "arch": arch, "shape": shape, "mesh": m,
            "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
            "dominant": dom,
            "model_flops_per_device": mf,
            "hlo_flops_per_device": accum * r["corrected_flops_per_device"],
            "useful_ratio": mf / max(
                accum * r["corrected_flops_per_device"], 1.0),
            # fraction of the compute roofline achieved if the step ran at
            # the modeled time (MODEL_FLOPS / t_total / peak)
            "roofline_fraction": mf / max(t_total, 1e-12) / PEAK_FLOPS,
            "mem_gb_per_device": (r["memory"]["argument_bytes"]
                                  + r["memory"]["temp_bytes"]) / 2**30,
            "uncalibrated": r.get("uncalibrated", False),
        })
    return rows


def run():
    if not os.path.exists(RESULTS):
        return [("roofline", "missing_dryrun_results", 0.0,
                 f"run launch/dryrun.py first ({RESULTS})", "SKIP")]
    rows = []
    for r in roofline_rows(load()):
        detail = (f"tc={r['t_compute_s']*1e3:.1f}ms "
                  f"tm={r['t_memory_s']*1e3:.1f}ms "
                  f"tx={r['t_collective_s']*1e3:.1f}ms "
                  f"dom={r['dominant']} "
                  f"useful={r['useful_ratio']:.2f} "
                  f"roofline={r['roofline_fraction']:.2%}"
                  + (" UNCAL" if r["uncalibrated"] else ""))
        rows.append(("roofline", f"{r['arch']}:{r['shape']}",
                     max(r["t_compute_s"], r["t_memory_s"],
                         r["t_collective_s"]) * 1e3, detail, r["dominant"]))
    return rows
