"""Benchmark regression gate: fresh BENCH_serving.json vs the committed one.

    PYTHONPATH=src python -m benchmarks.check_regression \
        --baseline BENCH_serving.json --fresh /tmp/BENCH_serving.json

Fails (exit 1) when the fresh run regresses >``--threshold`` (default 20%)
on throughput at saturation.  Raw tok/s is not comparable across hosts
(the committed baseline and a CI runner are different machines), so the
default gate compares the *continuous-over-static speedup* at the highest
offered rate — both paths run on the same host in the same process, so
their ratio is a machine-normalized throughput measure.  ``--absolute``
additionally gates raw tok/s for same-host comparisons.

Correctness gates always apply: every load's continuous outputs must be
bit-identical to static, the disaggregated run's outputs must be
bit-identical to colocated, and the ``streaming`` section must be present
and well-formed — streamed outputs bit-identical to the completion pull,
deltas concatenating to exactly the completion rows, and
``ttft_dispatch <= ttft`` — so a malformed BENCH_serving.json fails the
gate instead of slipping through.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Tuple


def saturation_load(results: dict) -> dict:
    return max(results["loads"], key=lambda l: l["offered_rate_req_s"])


# per-mode summaries the streaming section must carry, with the numeric
# fields the TTFT/TPOT comparison reads (ServeMetrics.summary keys)
_STREAMING_SUMMARY_KEYS = ("tok_per_s", "ttft_p50_s", "ttft_dispatch_p50_s",
                           "tpot_p50_s", "tokens_streamed", "stream_deltas",
                           "tokens_out")
_STREAMING_BOOL_KEYS = ("bit_identical", "delta_concat_identical",
                        "ttft_dispatch_leq_ttft")


def validate_streaming(fresh: dict) -> List[Tuple[str, bool, str]]:
    """Schema + correctness checks for the ``streaming`` section."""
    checks: List[Tuple[str, bool, str]] = []
    section = fresh.get("streaming")
    if not isinstance(section, dict):
        return [("streaming section present", False,
                 f"missing or not an object: {type(section).__name__}")]
    problems: List[str] = []
    for mode in ("colocated", "disaggregated"):
        entry = section.get(mode)
        if not isinstance(entry, dict):
            problems.append(f"{mode}: missing")
            continue
        for kind in ("completion", "streaming"):
            summ = entry.get(kind)
            if not isinstance(summ, dict):
                problems.append(f"{mode}.{kind}: missing summary")
                continue
            for k in _STREAMING_SUMMARY_KEYS:
                if not isinstance(summ.get(k), (int, float)):
                    problems.append(f"{mode}.{kind}.{k}: not a number")
        for k in _STREAMING_BOOL_KEYS:
            if not isinstance(entry.get(k), bool):
                problems.append(f"{mode}.{k}: not a bool")
        strm = entry.get("streaming")
        if isinstance(strm, dict) and isinstance(
                strm.get("tokens_streamed"), (int, float)):
            # streaming mode must deliver every output token incrementally
            if strm["tokens_streamed"] != strm.get("tokens_out"):
                problems.append(
                    f"{mode}: streamed {strm['tokens_streamed']} of "
                    f"{strm.get('tokens_out')} output tokens")
    checks.append(("streaming section schema", not problems,
                   "; ".join(problems) if problems else
                   "colocated + disaggregated, completion + streaming "
                   "summaries well-formed"))
    for mode in ("colocated", "disaggregated"):
        entry = section.get(mode)
        if not isinstance(entry, dict):
            continue
        ok = all(entry.get(k) is True for k in _STREAMING_BOOL_KEYS)
        checks.append((
            f"streamed outputs identical to completion pull ({mode})", ok,
            ", ".join(f"{k}={entry.get(k)}" for k in _STREAMING_BOOL_KEYS)))
    return checks


def compare(baseline: dict, fresh: dict, *, threshold: float,
            absolute: bool) -> List[Tuple[str, bool, str]]:
    """Returns [(check name, ok, detail), ...]."""
    checks: List[Tuple[str, bool, str]] = []
    base_l, fresh_l = saturation_load(baseline), saturation_load(fresh)

    base_s = base_l["speedup_tok_per_s"]
    fresh_s = fresh_l["speedup_tok_per_s"]
    floor = base_s * (1.0 - threshold)
    checks.append((
        "saturation speedup (continuous/static)",
        fresh_s >= floor,
        f"fresh {fresh_s:.2f}x vs baseline {base_s:.2f}x "
        f"(floor {floor:.2f}x at {threshold:.0%} regression budget)"))

    if absolute:
        base_t = base_l["continuous"]["tok_per_s"]
        fresh_t = fresh_l["continuous"]["tok_per_s"]
        floor_t = base_t * (1.0 - threshold)
        checks.append((
            "saturation continuous tok/s (same-host)",
            fresh_t >= floor_t,
            f"fresh {fresh_t:.1f} vs baseline {base_t:.1f} "
            f"(floor {floor_t:.1f})"))

    checks.append(("all loads bit-identical to static",
                   all(l["bit_identical"] for l in fresh["loads"]),
                   f"{sum(l['bit_identical'] for l in fresh['loads'])}/"
                   f"{len(fresh['loads'])} loads"))
    dis = fresh.get("disaggregation")
    if dis is not None:
        checks.append(("disaggregated bit-identical to colocated",
                       bool(dis["bit_identical"]),
                       f"{dis['handoff']['n_handoffs']} handoffs, "
                       f"{dis['handoff']['bytes_moved']} bytes"))
    checks.extend(validate_streaming(fresh))
    return checks


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default="BENCH_serving.json",
                    help="committed benchmark results (the reference)")
    ap.add_argument("--fresh", required=True,
                    help="freshly generated benchmark results to gate")
    ap.add_argument("--threshold", type=float, default=0.20,
                    help="allowed fractional regression (default 0.20)")
    ap.add_argument("--absolute", action="store_true",
                    help="also gate raw tok/s (only meaningful when "
                         "baseline and fresh ran on the same host)")
    args = ap.parse_args()

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)

    failed = False
    for name, ok, detail in compare(baseline, fresh,
                                    threshold=args.threshold,
                                    absolute=args.absolute):
        print(f"[check_regression] {'PASS' if ok else 'FAIL'}: "
              f"{name} — {detail}")
        failed |= not ok
    if failed:
        sys.exit(1)
    print("[check_regression] OK")


if __name__ == "__main__":
    main()
