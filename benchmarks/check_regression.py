"""Benchmark regression gate: fresh BENCH_serving.json vs the committed one.

    PYTHONPATH=src python -m benchmarks.check_regression \
        --baseline BENCH_serving.json --fresh /tmp/BENCH_serving.json

Fails (exit 1) when the fresh run regresses >``--threshold`` (default 20%)
on throughput at saturation.  Raw tok/s is not comparable across hosts
(the committed baseline and a CI runner are different machines), so the
default gate compares the *continuous-over-static speedup* at the highest
offered rate — both paths run on the same host in the same process, so
their ratio is a machine-normalized throughput measure.

``--absolute`` additionally gates raw tok/s against a *per-host recorded
baseline*: ``benchmarks/baselines/<host-key>.json``, keyed like the
profiling cache (jax version + backend) plus the platform triple and the
visible hardware (CPU model digest + core count), so a baseline recorded
on one machine never gates a different one — unlike-keyed hosts record
their own floors.  The first run on a
host records the baseline (``--record-absolute``); later runs on the same
host must stay within the threshold of it.  CI persists the baselines
directory across runs with ``actions/cache`` so ephemeral runners gate
against their own image's history.

Correctness gates always apply: every load's continuous outputs must be
bit-identical to static, the disaggregated run's outputs must be
bit-identical to colocated, the ``paged`` section must be present and
well-formed — paged outputs bit-identical to dense in colocated and
disaggregated modes and ``kv_bytes_paged`` strictly below
``kv_bytes_dense`` at equal slots — the ``prefix`` section must be
present and well-formed (shared outputs bit-identical to unshared at both
traffic mixes, prefix hits actually fired, and a >=1.5x peak-slots or p50
TTFT win at 90% shared traffic under the dense-equal block budget) — and
the ``streaming`` section must be
present and well-formed (streamed outputs bit-identical to the completion
pull, deltas concatenating to exactly the completion rows,
``ttft_dispatch <= ttft``) — so a malformed BENCH_serving.json fails the
gate instead of slipping through.  The ``observability`` section must be
present and well-formed: traced runs bit-identical to untraced, spans
balanced with full lifecycle coverage, and the NullTracer throughput
ratio at or above the overhead floor.  Every required stat is checked
with :func:`_num`, which rejects NaN/inf — a zero-completion run's
``None`` percentiles fail the gate instead of sailing through as NaN.

The ``adaptive`` section must be present and well-formed: under the
bench's injected admission mispricing the watchdog must have fired
(>=1 alert, >=1 re-price, token budget raised) and improved both
saturation throughput and p50 TTFT bit-identically, and tracer+watchdog
throughput must hold the same overhead floor as the NullTracer bound.

The ``multidevice`` section must be present and well-formed: every leg
bit-identical to colocated serving, and — when the run saw two distinct
devices — the async hand-off must hide at least half the transfer stall
the blocking baseline pays, distinct-device throughput must hold the
shared-device floor, and the watchdog-actuated mid-run migration must
complete every request with >=1 in-flight slot live-migrated.

The ``speculative`` section must be present and well-formed: the
forced-depth speculative run bit-identical to plain decode with >=1
round and a measured accepted-token rate in [0, 1], the analyzer-priced
run either beating plain throughput within the regression budget or
explicitly falling back to plain decode, and the adversarially de-rated
draft device pricing speculation off.

``--trace trace.json`` gates a Chrome trace-event file written by
``serve --trace`` (``--fresh`` becomes optional): strict JSON (NaN and
Infinity literals rejected), non-empty well-formed ``traceEvents``, no
unclosed spans, and at least one span/instant per request-lifecycle
stage (``--require-handoff`` adds the disaggregated hand-off span;
``--require-watchdog`` adds the drift_alert + reprice instants a
``serve --watchdog --misprice`` run must emit).
"""
from __future__ import annotations

import argparse
import json
import math
import os
import platform
import sys
from typing import List, Tuple

DEFAULT_BASELINES_DIR = os.path.join(os.path.dirname(__file__), "baselines")


def _num(v) -> bool:
    """True only for finite real numbers: a required stat that is None,
    NaN or inf is a malformed report, not a value (bool is an int
    subclass, so it is rejected explicitly)."""
    return (isinstance(v, (int, float)) and not isinstance(v, bool)
            and math.isfinite(v))


def host_key() -> str:
    """Stable identifier for 'the machine + numeric environment this bench
    ran on': platform triple + visible hardware (CPU model where the OS
    reports one, core count) + python + the profiling cache's environment
    key (jax version, backend).  Absolute tok/s is only comparable within
    one such key — different hardware hashes to a different key and
    records its own baseline instead of being gated by another machine's
    floor."""
    import hashlib

    import jax

    cpu = platform.processor() or platform.machine()
    hw = hashlib.sha256(cpu.encode()).hexdigest()[:8]
    return "-".join([
        platform.system().lower(), platform.machine(),
        f"cpu{os.cpu_count()}x{hw}",
        f"py{sys.version_info[0]}.{sys.version_info[1]}",
        f"jax{jax.__version__}", jax.default_backend(),
    ])


def saturation_load(results: dict) -> dict:
    return max(results["loads"], key=lambda l: l["offered_rate_req_s"])


# per-mode summaries the streaming section must carry, with the numeric
# fields the TTFT/TPOT comparison reads (ServeMetrics.summary keys)
_STREAMING_SUMMARY_KEYS = ("tok_per_s", "ttft_p50_s", "ttft_dispatch_p50_s",
                           "tpot_p50_s", "tokens_streamed", "stream_deltas",
                           "tokens_out")
_STREAMING_BOOL_KEYS = ("bit_identical", "delta_concat_identical",
                        "ttft_dispatch_leq_ttft")


# numeric fields the paged section must carry (bench run_paged keys) and
# the per-layout summaries the throughput comparison reads
_PAGED_NUMERIC_KEYS = ("block_size", "blocks_per_slot", "total_blocks",
                       "dense_equiv_blocks", "kv_bytes_dense",
                       "kv_bytes_paged", "kv_bytes_ratio",
                       "achievable_n_slots_at_dense_budget",
                       "tok_per_s_ratio")
_PAGED_BOOL_KEYS = ("bit_identical_colocated", "bit_identical_disaggregated",
                    "all_identical")


def validate_paged(fresh: dict) -> List[Tuple[str, bool, str]]:
    """Schema + correctness checks for the ``paged`` section: well-formed
    summaries, paged-vs-dense bit-identity in both serving modes, and KV
    bytes resident strictly below dense at equal slots."""
    checks: List[Tuple[str, bool, str]] = []
    section = fresh.get("paged")
    if not isinstance(section, dict):
        return [("paged section present", False,
                 f"missing or not an object: {type(section).__name__}")]
    problems: List[str] = []
    for k in _PAGED_NUMERIC_KEYS:
        if not _num(section.get(k)):
            problems.append(f"{k}: not a finite number")
    for k in _PAGED_BOOL_KEYS:
        if not isinstance(section.get(k), bool):
            problems.append(f"{k}: not a bool")
    for layout in ("dense", "paged"):
        summ = section.get(layout)
        if not isinstance(summ, dict):
            problems.append(f"{layout}: missing summary")
            continue
        for k in ("tok_per_s", "tokens_out", "requests_done"):
            if not _num(summ.get(k)):
                problems.append(f"{layout}.{k}: not a finite number")
    checks.append(("paged section schema", not problems,
                   "; ".join(problems) if problems else
                   "layout summaries + memory accounting well-formed"))
    if problems:
        return checks
    checks.append((
        "paged outputs bit-identical to dense",
        section["bit_identical_colocated"]
        and section["bit_identical_disaggregated"],
        ", ".join(f"{k}={section[k]}" for k in _PAGED_BOOL_KEYS[:2])))
    checks.append((
        "paged KV bytes resident strictly below dense",
        section["kv_bytes_paged"] < section["kv_bytes_dense"],
        f"paged {section['kv_bytes_paged']} vs dense "
        f"{section['kv_bytes_dense']} bytes "
        f"({section['kv_bytes_ratio']:.2f}x) at equal n_slots"))
    return checks


def validate_streaming(fresh: dict) -> List[Tuple[str, bool, str]]:
    """Schema + correctness checks for the ``streaming`` section."""
    checks: List[Tuple[str, bool, str]] = []
    section = fresh.get("streaming")
    if not isinstance(section, dict):
        return [("streaming section present", False,
                 f"missing or not an object: {type(section).__name__}")]
    problems: List[str] = []
    for mode in ("colocated", "disaggregated"):
        entry = section.get(mode)
        if not isinstance(entry, dict):
            problems.append(f"{mode}: missing")
            continue
        for kind in ("completion", "streaming"):
            summ = entry.get(kind)
            if not isinstance(summ, dict):
                problems.append(f"{mode}.{kind}: missing summary")
                continue
            for k in _STREAMING_SUMMARY_KEYS:
                if not _num(summ.get(k)):
                    problems.append(f"{mode}.{kind}.{k}: not a finite "
                                    f"number")
        for k in _STREAMING_BOOL_KEYS:
            if not isinstance(entry.get(k), bool):
                problems.append(f"{mode}.{k}: not a bool")
        strm = entry.get("streaming")
        if isinstance(strm, dict) and isinstance(
                strm.get("tokens_streamed"), (int, float)):
            # streaming mode must deliver every output token incrementally
            if strm["tokens_streamed"] != strm.get("tokens_out"):
                problems.append(
                    f"{mode}: streamed {strm['tokens_streamed']} of "
                    f"{strm.get('tokens_out')} output tokens")
    checks.append(("streaming section schema", not problems,
                   "; ".join(problems) if problems else
                   "colocated + disaggregated, completion + streaming "
                   "summaries well-formed"))
    for mode in ("colocated", "disaggregated"):
        entry = section.get(mode)
        if not isinstance(entry, dict):
            continue
        ok = all(entry.get(k) is True for k in _STREAMING_BOOL_KEYS)
        checks.append((
            f"streamed outputs identical to completion pull ({mode})", ok,
            ", ".join(f"{k}={entry.get(k)}" for k in _STREAMING_BOOL_KEYS)))
    return checks


# the capacity win prefix sharing must show at 90% shared traffic and a
# dense-equal block budget: >=1.5x peak concurrent slots, or equivalently
# >=1.5x lower p50 TTFT (the same win read off the latency axis)
PREFIX_CAPACITY_FLOOR = 1.5

_PREFIX_NUMERIC_KEYS = ("block_size", "blocks_per_slot", "n_slots",
                        "total_blocks", "dense_equivalent_slots",
                        "shared_prefix_len", "n_requests")
_PREFIX_ENTRY_NUMERIC_KEYS = ("peak_slots_unshared", "peak_slots_shared",
                              "admitted_slots_ratio", "ttft_p50_ratio",
                              "tok_per_s_ratio", "prefix_hits",
                              "tokens_prefill_skipped", "cow_copies")


def validate_prefix(fresh: dict) -> List[Tuple[str, bool, str]]:
    """Schema + correctness checks for the ``prefix`` section: well-formed
    per-fraction entries, shared outputs bit-identical to unshared at both
    traffic mixes, prefix hits actually fired, and the 90%-shared capacity
    win at or above :data:`PREFIX_CAPACITY_FLOOR` on peak admitted slots
    or p50 TTFT at the dense-equal block budget."""
    checks: List[Tuple[str, bool, str]] = []
    section = fresh.get("prefix")
    if not isinstance(section, dict):
        return [("prefix section present", False,
                 f"missing or not an object: {type(section).__name__}")]
    problems: List[str] = []
    for k in _PREFIX_NUMERIC_KEYS:
        if not _num(section.get(k)):
            problems.append(f"{k}: not a finite number")
    if not isinstance(section.get("all_identical"), bool):
        problems.append("all_identical: not a bool")
    for frac in ("shared_frac_50", "shared_frac_90"):
        entry = section.get(frac)
        if not isinstance(entry, dict):
            problems.append(f"{frac}: missing")
            continue
        for k in _PREFIX_ENTRY_NUMERIC_KEYS:
            if not _num(entry.get(k)):
                problems.append(f"{frac}.{k}: not a finite number")
        if not isinstance(entry.get("bit_identical"), bool):
            problems.append(f"{frac}.bit_identical: not a bool")
        for kind in ("unshared", "shared"):
            summ = entry.get(kind)
            if not isinstance(summ, dict):
                problems.append(f"{frac}.{kind}: missing summary")
                continue
            for k in ("tok_per_s", "ttft_p50_s", "tokens_out",
                      "requests_done"):
                if not _num(summ.get(k)):
                    problems.append(f"{frac}.{kind}.{k}: not a finite "
                                    f"number")
    checks.append(("prefix section schema", not problems,
                   "; ".join(problems) if problems else
                   "50% + 90% shared-traffic entries well-formed"))
    if problems:
        return checks
    checks.append((
        "shared outputs bit-identical to unshared",
        section["all_identical"]
        and all(section[f]["bit_identical"]
                for f in ("shared_frac_50", "shared_frac_90")),
        ", ".join(f"{f}={section[f]['bit_identical']}"
                  for f in ("shared_frac_50", "shared_frac_90"))))
    e90 = section["shared_frac_90"]
    checks.append((
        "prefix cache actually shared pages",
        e90["prefix_hits"] >= 1 and e90["tokens_prefill_skipped"] >= 1,
        f"{e90['prefix_hits']} hits, {e90['tokens_prefill_skipped']} "
        f"prefill tokens skipped, {e90['cow_copies']} cow copies at 90%"))
    win = max(e90["admitted_slots_ratio"], e90["ttft_p50_ratio"])
    checks.append((
        "prefix sharing capacity win at dense-equal budget",
        win >= PREFIX_CAPACITY_FLOOR,
        f"90% shared: {e90['peak_slots_shared']} vs "
        f"{e90['peak_slots_unshared']} peak slots "
        f"({e90['admitted_slots_ratio']:.2f}x), ttft p50 "
        f"{e90['ttft_p50_ratio']:.2f}x better "
        f"(floor {PREFIX_CAPACITY_FLOOR}x on either axis)"))
    return checks


# the overhead floor the NullTracer path must hold: tracing compiled in
# but switched off may cost at most 2% of untraced saturation throughput
OBS_OVERHEAD_FLOOR = 0.98

_OBS_RATIO_KEYS = ("overhead_ratio_null", "overhead_ratio_traced")
_OBS_BOOL_KEYS = ("bit_identical_null", "bit_identical_traced",
                  "bit_identical_traced_disagg", "trace_spans_balanced",
                  "lifecycle_spans_present", "handoff_span_present",
                  "all_identical")
_OBS_COUNT_KEYS = ("trace_events", "trace_events_disagg", "trace_dropped",
                   "metrics_series_points")


def validate_observability(fresh: dict) -> List[Tuple[str, bool, str]]:
    """Schema + correctness checks for the ``observability`` section:
    well-formed summaries, traced runs bit-identical to untraced with
    balanced full-lifecycle spans, and the NullTracer throughput ratio at
    or above :data:`OBS_OVERHEAD_FLOOR` (the traced ratio is reported but
    not gated — a full ring-buffer trace is a debugging artifact)."""
    checks: List[Tuple[str, bool, str]] = []
    section = fresh.get("observability")
    if not isinstance(section, dict):
        return [("observability section present", False,
                 f"missing or not an object: {type(section).__name__}")]
    problems: List[str] = []
    for k in _OBS_RATIO_KEYS + _OBS_COUNT_KEYS:
        if not _num(section.get(k)):
            problems.append(f"{k}: not a finite number")
    for k in _OBS_BOOL_KEYS:
        if not isinstance(section.get(k), bool):
            problems.append(f"{k}: not a bool")
    for run in ("untraced", "null_tracer", "traced"):
        summ = section.get(run)
        if not isinstance(summ, dict):
            problems.append(f"{run}: missing summary")
            continue
        for k in ("tok_per_s", "tokens_out", "requests_done"):
            if not _num(summ.get(k)):
                problems.append(f"{run}.{k}: not a finite number")
    checks.append(("observability section schema", not problems,
                   "; ".join(problems) if problems else
                   "untraced + null-tracer + traced summaries well-formed"))
    if problems:
        return checks
    checks.append((
        "traced outputs bit-identical to untraced",
        section["all_identical"],
        ", ".join(f"{k}={section[k]}" for k in _OBS_BOOL_KEYS[:3])))
    checks.append((
        "trace spans balanced with full lifecycle coverage",
        section["trace_spans_balanced"]
        and section["lifecycle_spans_present"]
        and section["handoff_span_present"]
        and section["trace_events"] > 0,
        f"{section['trace_events']} events colocated, "
        f"{section['trace_events_disagg']} disaggregated, "
        f"{section['trace_dropped']} dropped"))
    checks.append((
        "null-tracer overhead within budget",
        section["overhead_ratio_null"] >= OBS_OVERHEAD_FLOOR,
        f"null-tracer {section['overhead_ratio_null']:.3f}x of untraced "
        f"tok/s (floor {OBS_OVERHEAD_FLOOR}; traced "
        f"{section['overhead_ratio_traced']:.3f}x, not gated)"))
    return checks


# the adaptive (watchdog) section: numeric/bool schema plus the gated
# control-loop outcomes — re-pricing must recover throughput AND TTFT
# under the injected mispricing, with at least one alert + re-price, and
# tracer+watchdog throughput must hold the same overhead floor the
# NullTracer bound uses
_ADAPTIVE_NUMERIC_KEYS = ("tok_per_s_ratio", "ttft_p50_ratio", "n_alerts",
                          "n_reprices", "token_budget_static",
                          "token_budget_final", "overhead_ratio_watchdog")
_ADAPTIVE_BOOL_KEYS = ("bit_identical_static", "bit_identical_adaptive",
                       "bit_identical_overhead", "all_identical")


def validate_adaptive(fresh: dict) -> List[Tuple[str, bool, str]]:
    """Schema + correctness checks for the ``adaptive`` section: the
    watchdog's mid-run re-pricing must beat the statically mispriced run
    on saturation throughput and p50 TTFT (bit-identically — admission
    policy never changes outputs), must actually have fired (>=1 alert,
    >=1 re-price, budget raised), and the tracer+watchdog overhead ratio
    must stay at or above :data:`OBS_OVERHEAD_FLOOR`."""
    checks: List[Tuple[str, bool, str]] = []
    section = fresh.get("adaptive")
    if not isinstance(section, dict):
        return [("adaptive section present", False,
                 f"missing or not an object: {type(section).__name__}")]
    problems: List[str] = []
    for k in _ADAPTIVE_NUMERIC_KEYS:
        if not _num(section.get(k)):
            problems.append(f"{k}: not a finite number")
    for k in _ADAPTIVE_BOOL_KEYS:
        if not isinstance(section.get(k), bool):
            problems.append(f"{k}: not a bool")
    for run in ("static_priced", "adaptive"):
        summ = section.get(run)
        if not isinstance(summ, dict):
            problems.append(f"{run}: missing summary")
            continue
        for k in ("tok_per_s", "ttft_p50_s", "tokens_out", "requests_done"):
            if not _num(summ.get(k)):
                problems.append(f"{run}.{k}: not a finite number")
    checks.append(("adaptive section schema", not problems,
                   "; ".join(problems) if problems else
                   "static + adaptive summaries well-formed"))
    if problems:
        return checks
    checks.append((
        "watchdog control loop fired",
        section["n_alerts"] >= 1 and section["n_reprices"] >= 1
        and section["token_budget_final"] > section["token_budget_static"],
        f"{section['n_alerts']} alerts, {section['n_reprices']} reprices, "
        f"budget {section['token_budget_static']} -> "
        f"{section['token_budget_final']} "
        f"({section.get('price_source_final')})"))
    checks.append((
        "re-pricing improves the drifted-cost run",
        section["tok_per_s_ratio"] > 1.0 and section["ttft_p50_ratio"] > 1.0,
        f"tok/s {section['tok_per_s_ratio']:.2f}x, ttft p50 "
        f"{section['ttft_p50_ratio']:.2f}x better with the watchdog on"))
    checks.append((
        "adaptive outputs bit-identical",
        section["all_identical"],
        ", ".join(f"{k}={section[k]}" for k in _ADAPTIVE_BOOL_KEYS[:3])))
    checks.append((
        "watchdog overhead within budget",
        section["overhead_ratio_watchdog"] >= OBS_OVERHEAD_FLOOR,
        f"tracer+watchdog {section['overhead_ratio_watchdog']:.3f}x of "
        f"tracer-only tok/s (floor {OBS_OVERHEAD_FLOOR})"))
    return checks


# the multidevice section: real per-phase device assignment + the async
# hand-off.  The overlap and throughput gates apply when the run actually
# saw two distinct devices (the bench child forces two host devices; a
# degraded single-device run keeps schema + bit-identity gates only): the
# double-buffered hand-off must hide at least half the transfer stall the
# blocking baseline pays (async/sync stall ratio, gated only when the
# sync baseline's stall clears an absolute measurement floor), and the
# distinct assignment must not lose throughput against the same disagg
# loop sharing one device.  The watchdog-actuated migration leg is gated
# in both worlds — its trigger is the mispriced device *model*, not the
# device count: every request completes, at least one in-flight slot
# live-migrates, and outputs stay bit-identical to colocated serving.
MULTIDEVICE_STALL_CEILING = 0.5
MULTIDEVICE_STALL_FLOOR_S = 1e-3

_MULTIDEVICE_NUMERIC_KEYS = ("n_devices", "tok_per_s_ratio_vs_colocated",
                             "tok_per_s_ratio_vs_sync",
                             "tok_per_s_ratio_vs_shared", "sync_stall_s",
                             "async_stall_s", "async_overlap_s",
                             "stall_ratio")
_MULTIDEVICE_BOOL_KEYS = ("distinct_devices", "bit_identical_async",
                          "bit_identical_sync", "bit_identical_shared",
                          "all_identical", "forced_subprocess")
_MULTIDEVICE_SUMMARIES = ("colocated", "disagg_async", "disagg_sync",
                          "disagg_shared_device")
_MIGRATION_NUMERIC_KEYS = ("n_requests", "n_done", "n_dropped",
                           "n_live_migrations", "n_alerts")
_MIGRATION_BOOL_KEYS = ("requests_preserved", "bit_identical")


def validate_multidevice(fresh: dict, *,
                         threshold: float) -> List[Tuple[str, bool, str]]:
    """Schema + correctness checks for the ``multidevice`` section: async
    hand-off overlap vs the blocking baseline, distinct-device throughput
    vs the shared-device loop, and mid-run migration preserving in-flight
    slots — every leg bit-identical to colocated serving."""
    checks: List[Tuple[str, bool, str]] = []
    section = fresh.get("multidevice")
    if not isinstance(section, dict):
        return [("multidevice section present", False,
                 f"missing or not an object: {type(section).__name__}")]
    problems: List[str] = []
    for k in _MULTIDEVICE_NUMERIC_KEYS:
        if not _num(section.get(k)):
            problems.append(f"{k}: not a finite number")
    for k in _MULTIDEVICE_BOOL_KEYS:
        if not isinstance(section.get(k), bool):
            problems.append(f"{k}: not a bool")
    asn = section.get("assignment")
    if not (isinstance(asn, dict)
            and isinstance(asn.get("prefill"), str)
            and isinstance(asn.get("decode"), str)):
        problems.append("assignment: missing prefill/decode device labels")
    link = section.get("measured_link_bw")
    if link is not None and not (_num(link) and link > 0):
        problems.append("measured_link_bw: neither null nor a positive "
                        "number")
    for leg in _MULTIDEVICE_SUMMARIES:
        summ = section.get(leg)
        if not isinstance(summ, dict):
            problems.append(f"{leg}: missing summary")
            continue
        for k in ("tok_per_s", "tokens_out", "requests_done"):
            if not _num(summ.get(k)):
                problems.append(f"{leg}.{k}: not a finite number")
    mig = section.get("migration")
    if not isinstance(mig, dict):
        problems.append("migration: missing")
    else:
        for k in _MIGRATION_NUMERIC_KEYS:
            if not _num(mig.get(k)):
                problems.append(f"migration.{k}: not a finite number")
        for k in _MIGRATION_BOOL_KEYS:
            if not isinstance(mig.get(k), bool):
                problems.append(f"migration.{k}: not a bool")
        if not isinstance(mig.get("decode_target"), str):
            problems.append("migration.decode_target: not a string")
    checks.append(("multidevice section schema", not problems,
                   "; ".join(problems) if problems else
                   f"{section.get('n_devices')} devices "
                   f"({asn.get('prefill')} | {asn.get('decode')}), four "
                   f"serving legs + migration well-formed"))
    if problems:
        return checks

    checks.append((
        "multidevice outputs bit-identical to colocated",
        section["all_identical"],
        ", ".join(f"{k}={section[k]}"
                  for k in _MULTIDEVICE_BOOL_KEYS[1:4])
        + f", migration={mig['bit_identical']}"))

    distinct = section["distinct_devices"]
    if distinct:
        # a sync stall too small to measure cannot anchor a ratio — the
        # overlap gate needs the blocking baseline to have actually paid
        # a visible transfer cost
        if section["sync_stall_s"] >= MULTIDEVICE_STALL_FLOOR_S:
            checks.append((
                "async hand-off hides the transfer stall",
                section["stall_ratio"] <= MULTIDEVICE_STALL_CEILING,
                f"async stall {section['async_stall_s']*1e3:.2f}ms vs sync "
                f"{section['sync_stall_s']*1e3:.2f}ms "
                f"(ratio {section['stall_ratio']:.2f}, ceiling "
                f"{MULTIDEVICE_STALL_CEILING}; overlap "
                f"{section['async_overlap_s']*1e3:.2f}ms)"))
        else:
            checks.append((
                "async hand-off hides the transfer stall",
                True,
                f"sync stall {section['sync_stall_s']*1e3:.2f}ms below the "
                f"{MULTIDEVICE_STALL_FLOOR_S*1e3:.0f}ms measurement floor; "
                f"ratio not gated"))
        floor = 1.0 - threshold
        checks.append((
            "distinct-device throughput holds the shared-device floor",
            section["tok_per_s_ratio_vs_shared"] >= floor,
            f"{section['tok_per_s_ratio_vs_shared']:.2f}x the same loop on "
            f"one device (floor {floor:.2f}x; vs sync hand-off "
            f"{section['tok_per_s_ratio_vs_sync']:.2f}x, vs colocated "
            f"{section['tok_per_s_ratio_vs_colocated']:.2f}x)"))
    else:
        checks.append((
            "multidevice ran on distinct devices",
            True,
            f"degraded to {section['n_devices']} visible device(s); "
            f"overlap + throughput gates skipped "
            f"(forced_subprocess={section['forced_subprocess']})"))

    checks.append((
        "mid-run migration preserves in-flight slots",
        mig["requests_preserved"] and mig["n_live_migrations"] >= 1
        and mig["bit_identical"],
        f"{mig['n_done']}/{mig['n_requests']} done, "
        f"{mig['n_dropped']} dropped, {mig['n_live_migrations']} live "
        f"migrations, {mig['n_alerts']} alerts, decode -> "
        f"{mig['decode_target']} engine"))
    return checks


# the speculative section: forced-depth speculative decoding must stay
# bit-identical to plain decode with real rounds and a sane measured
# accepted-token rate; the analyzer-priced run must either beat plain
# throughput (within the regression budget) or have explicitly fallen
# back to plain decode; and the adversarially de-rated draft device must
# price speculation off
_SPECULATIVE_NUMERIC_KEYS = ("accepted_token_rate", "n_rounds",
                             "tok_per_s_ratio_forced",
                             "tok_per_s_ratio_priced")
_SPECULATIVE_BOOL_KEYS = ("bit_identical_forced", "bit_identical_priced",
                          "priced_engaged", "priced_fallback",
                          "all_identical")
_SPECULATIVE_ROUND_KEYS = ("n_rounds", "n_proposed", "n_accepted",
                           "n_committed")


def validate_speculative(fresh: dict, *,
                         threshold: float) -> List[Tuple[str, bool, str]]:
    """Schema + correctness checks for the ``speculative`` section:
    forced-depth speculation bit-identical to plain decode with >=1 round
    and an accepted-token rate in [0, 1], the analyzer-priced run holding
    the plain-decode throughput floor (or explicitly falling back), and
    the adversarial draft pricing rejecting speculation."""
    checks: List[Tuple[str, bool, str]] = []
    section = fresh.get("speculative")
    if not isinstance(section, dict):
        return [("speculative section present", False,
                 f"missing or not an object: {type(section).__name__}")]
    problems: List[str] = []
    for k in _SPECULATIVE_NUMERIC_KEYS:
        if not _num(section.get(k)):
            problems.append(f"{k}: not a finite number")
    for k in _SPECULATIVE_BOOL_KEYS:
        if not isinstance(section.get(k), bool):
            problems.append(f"{k}: not a bool")
    for run in ("plain", "forced"):
        summ = section.get(run)
        if not isinstance(summ, dict):
            problems.append(f"{run}: missing summary")
            continue
        for k in ("tok_per_s", "tokens_out", "requests_done"):
            if not _num(summ.get(k)):
                problems.append(f"{run}.{k}: not a finite number")
    spec = section.get("speculation")
    if not isinstance(spec, dict):
        problems.append("speculation: missing round accounting")
    else:
        for k in _SPECULATIVE_ROUND_KEYS:
            if not _num(spec.get(k)):
                problems.append(f"speculation.{k}: not a finite number")
    adv = section.get("adversarial")
    adv_decision = adv.get("decision") if isinstance(adv, dict) else None
    if not (isinstance(adv_decision, dict)
            and isinstance(adv_decision.get("use"), bool)):
        problems.append("adversarial.decision: missing or no 'use' bool")
    checks.append(("speculative section schema", not problems,
                   "; ".join(problems) if problems else
                   "plain + forced + priced runs and pricing decisions "
                   "well-formed"))
    if problems:
        return checks
    checks.append((
        "speculative outputs bit-identical to plain decode",
        section["all_identical"],
        f"forced={section['bit_identical_forced']}, "
        f"priced={section['bit_identical_priced']}"))
    rate = section["accepted_token_rate"]
    checks.append((
        "speculative rounds actually ran",
        section["n_rounds"] >= 1 and 0.0 <= rate <= 1.0,
        f"{section['n_rounds']} rounds, "
        f"{spec['n_accepted']}/{spec['n_proposed']} proposals accepted "
        f"(rate {rate:.2f}), {spec['n_committed']} tokens committed"))
    floor = 1.0 - threshold
    priced_ok = (section["priced_fallback"]
                 or section["tok_per_s_ratio_priced"] >= floor)
    checks.append((
        "priced speculation holds the plain-decode floor",
        priced_ok,
        (f"analyzer fell back to plain decode "
         f"({section['tok_per_s_ratio_priced']:.2f}x plain tok/s)"
         if section["priced_fallback"] else
         f"engaged at {section['tok_per_s_ratio_priced']:.2f}x plain "
         f"tok/s (floor {floor:.2f}x; forced leg "
         f"{section['tok_per_s_ratio_forced']:.2f}x, not gated)")))
    checks.append((
        "adversarial draft price rejects speculation",
        adv_decision["use"] is False,
        f"draft device de-rated {adv['draft_derate_factor']:g}x at "
        f"acceptance {adv['acceptance']:.2f} -> use={adv_decision['use']}"))
    return checks


# every request lifecycle stage a serve --trace file must cover: complete
# ("X") spans and instant ("i") markers emitted by the obs tracer
_TRACE_REQUIRED_SPANS = ("queued", "prefill", "decode", "burst", "sync")
_TRACE_REQUIRED_INSTANTS = ("first_token", "done")
# what a serve --watchdog --misprice trace must additionally carry: the
# detection and action instants of the re-pricing control loop
_TRACE_WATCHDOG_INSTANTS = ("drift_alert", "reprice")


def validate_trace(path: str, *, require_handoff: bool = False,
                   require_watchdog: bool = False
                   ) -> List[Tuple[str, bool, str]]:
    """Schema gate for a Chrome trace-event file written by
    ``serve --trace``: strict JSON, well-formed events, no unclosed
    spans, and at least one span per request-lifecycle stage."""
    def _reject(const):
        raise ValueError(f"non-finite JSON constant {const!r}")

    try:
        with open(path) as f:
            trace = json.load(f, parse_constant=_reject)
    except (OSError, ValueError) as e:
        return [("trace is strict JSON", False, f"{path}: {e}")]
    checks = [("trace is strict JSON", True, path)]

    events = trace.get("traceEvents") if isinstance(trace, dict) else None
    if not isinstance(events, list) or not events:
        checks.append(("trace has events", False,
                       "traceEvents missing, not a list, or empty"))
        return checks

    problems: List[str] = []
    spans: dict = {}
    instants: dict = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict) or not isinstance(ev.get("ph"), str) \
                or not isinstance(ev.get("name"), str):
            problems.append(f"event {i}: malformed")
            continue
        ph, name = ev["ph"], ev["name"]
        if ph == "M":                    # process_name metadata
            continue
        if not _num(ev.get("ts")):
            problems.append(f"event {i} ({name}): ts not a finite number")
        if ph == "X":
            if not _num(ev.get("dur")) or ev["dur"] < 0:
                problems.append(f"event {i} ({name}): bad dur")
            spans[name] = spans.get(name, 0) + 1
        elif ph == "i":
            instants[name] = instants.get(name, 0) + 1
    checks.append(("trace events well-formed", not problems,
                   "; ".join(problems[:5]) if problems else
                   f"{len(events)} events, {sum(spans.values())} spans"))

    other = trace.get("otherData", {})
    n_open = other.get("n_open", 0) if isinstance(other, dict) else 0
    checks.append(("trace spans balanced", n_open == 0,
                   f"{n_open} unclosed spans at export"))

    required = list(_TRACE_REQUIRED_SPANS)
    if require_handoff:
        required.append("handoff")
    required_instants = list(_TRACE_REQUIRED_INSTANTS)
    if require_watchdog:
        required_instants.extend(_TRACE_WATCHDOG_INSTANTS)
    missing = ([f"span:{n}" for n in required if not spans.get(n)]
               + [f"instant:{n}" for n in required_instants
                  if not instants.get(n)])
    checks.append(("trace covers the request lifecycle", not missing,
                   "missing " + ", ".join(missing) if missing else
                   ", ".join(f"{n}x{spans[n]}" for n in required)))
    return checks


def absolute_baseline_metrics(fresh: dict) -> dict:
    """The raw-throughput figures a host baseline records/gates."""
    sat = saturation_load(fresh)
    out = {"continuous_tok_per_s": sat["continuous"]["tok_per_s"]}
    paged = fresh.get("paged")
    if isinstance(paged, dict) and isinstance(paged.get("paged"), dict):
        out["paged_tok_per_s"] = paged["paged"].get("tok_per_s")
    return out


def check_absolute(fresh: dict, *, threshold: float, baselines_dir: str,
                   record: bool) -> List[Tuple[str, bool, str]]:
    """Gate raw tok/s against this host's recorded baseline (recording it
    first when absent and ``record`` is set — a host's first run defines
    its floor, later runs must hold it)."""
    key = host_key()
    path = os.path.join(baselines_dir, f"{key}.json")
    metrics = absolute_baseline_metrics(fresh)
    if not os.path.exists(path):
        if record:
            os.makedirs(baselines_dir, exist_ok=True)
            with open(path, "w") as f:
                json.dump({"host_key": key, "metrics": metrics}, f, indent=2)
            return [("absolute tok/s vs host baseline", True,
                     f"no baseline for {key}; recorded {path}")]
        return [("absolute tok/s vs host baseline", True,
                 f"no baseline recorded for {key} "
                 f"(run with --record-absolute to create one); skipped")]
    with open(path) as f:
        recorded = json.load(f)
    checks: List[Tuple[str, bool, str]] = []
    for name, base_v in recorded.get("metrics", {}).items():
        fresh_v = metrics.get(name)
        if not _num(base_v) or not _num(fresh_v):
            checks.append((f"absolute {name} vs host baseline", False,
                           f"baseline {base_v!r} vs fresh {fresh_v!r}: "
                           f"not comparable"))
            continue
        floor = base_v * (1.0 - threshold)
        checks.append((
            f"absolute {name} vs host baseline ({key})",
            fresh_v >= floor,
            f"fresh {fresh_v:.1f} vs recorded {base_v:.1f} "
            f"(floor {floor:.1f} at {threshold:.0%} regression budget)"))
    return checks


def compare(baseline: dict, fresh: dict, *, threshold: float,
            absolute: bool, baselines_dir: str = DEFAULT_BASELINES_DIR,
            record_absolute: bool = False) -> List[Tuple[str, bool, str]]:
    """Returns [(check name, ok, detail), ...]."""
    checks: List[Tuple[str, bool, str]] = []
    base_l, fresh_l = saturation_load(baseline), saturation_load(fresh)

    base_s = base_l["speedup_tok_per_s"]
    fresh_s = fresh_l["speedup_tok_per_s"]
    floor = base_s * (1.0 - threshold)
    checks.append((
        "saturation speedup (continuous/static)",
        fresh_s >= floor,
        f"fresh {fresh_s:.2f}x vs baseline {base_s:.2f}x "
        f"(floor {floor:.2f}x at {threshold:.0%} regression budget)"))

    if absolute:
        checks.extend(check_absolute(fresh, threshold=threshold,
                                     baselines_dir=baselines_dir,
                                     record=record_absolute))

    checks.append(("all loads bit-identical to static",
                   all(l["bit_identical"] for l in fresh["loads"]),
                   f"{sum(l['bit_identical'] for l in fresh['loads'])}/"
                   f"{len(fresh['loads'])} loads"))
    dis = fresh.get("disaggregation")
    if dis is not None:
        checks.append(("disaggregated bit-identical to colocated",
                       bool(dis["bit_identical"]),
                       f"{dis['handoff']['n_handoffs']} handoffs, "
                       f"{dis['handoff']['bytes_moved']} bytes"))
    checks.extend(validate_paged(fresh))
    checks.extend(validate_prefix(fresh))
    checks.extend(validate_streaming(fresh))
    checks.extend(validate_observability(fresh))
    checks.extend(validate_adaptive(fresh))
    checks.extend(validate_multidevice(fresh, threshold=threshold))
    checks.extend(validate_speculative(fresh, threshold=threshold))
    return checks


def build_parser() -> argparse.ArgumentParser:
    """The regression gate's argument parser (module-level so tests and
    the docs consistency gate can introspect the flag set)."""
    ap = argparse.ArgumentParser(prog="benchmarks.check_regression",
                                 description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default="BENCH_serving.json",
                    help="committed benchmark results (the reference)")
    ap.add_argument("--fresh", default=None,
                    help="freshly generated benchmark results to gate "
                         "(required unless --trace is given)")
    ap.add_argument("--threshold", type=float, default=0.20,
                    help="allowed fractional regression (default 0.20)")
    ap.add_argument("--absolute", action="store_true",
                    help="also gate raw tok/s against this host's recorded "
                         "baseline (benchmarks/baselines/<host-key>.json)")
    ap.add_argument("--record-absolute", action="store_true",
                    help="with --absolute: record this host's baseline "
                         "when none exists yet (first run on a host "
                         "defines its floor)")
    ap.add_argument("--baselines-dir", default=DEFAULT_BASELINES_DIR,
                    help="directory of per-host absolute baselines")
    ap.add_argument("--trace", default=None,
                    help="gate a serve --trace Chrome trace-event file "
                         "(schema + lifecycle coverage)")
    ap.add_argument("--require-handoff", action="store_true",
                    help="with --trace: require the disaggregated "
                         "hand-off span")
    ap.add_argument("--require-watchdog", action="store_true",
                    help="with --trace: require the watchdog's "
                         "drift_alert + reprice instants (a serve "
                         "--watchdog --misprice run must have detected "
                         "and corrected the injected drift)")
    return ap


def main() -> None:
    ap = build_parser()
    args = ap.parse_args()
    if args.fresh is None and args.trace is None:
        ap.error("at least one of --fresh / --trace is required")

    checks: List[Tuple[str, bool, str]] = []
    if args.fresh is not None:
        with open(args.baseline) as f:
            baseline = json.load(f)
        with open(args.fresh) as f:
            fresh = json.load(f)
        checks.extend(compare(baseline, fresh,
                              threshold=args.threshold,
                              absolute=args.absolute,
                              baselines_dir=args.baselines_dir,
                              record_absolute=args.record_absolute))
    if args.trace is not None:
        checks.extend(validate_trace(
            args.trace, require_handoff=args.require_handoff,
            require_watchdog=args.require_watchdog))

    failed = False
    for name, ok, detail in checks:
        print(f"[check_regression] {'PASS' if ok else 'FAIL'}: "
              f"{name} — {detail}")
        failed |= not ok
    if failed:
        sys.exit(1)
    print("[check_regression] OK")


if __name__ == "__main__":
    main()
