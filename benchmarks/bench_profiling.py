"""Profiling/calibration benchmark -> BENCH_profiling.json.

    PYTHONPATH=src python -m benchmarks.bench_profiling --scale smoke

Quantifies what the empirical profiling runtime (repro.profiling) buys over
the static analytic cost model on this container's actually-buildable
engines (xla, pallas):

* per layer kind and per engine: analytic vs calibrated prediction error
  (MAPE against the measured medians) and the fitted achieved rates;
* plan deltas: the DSE run with analytic vs measured pricing — which
  layers move engine, and the modeled plan time under each pricing source.

The headline claim checked at the end: calibration reduces prediction
error on every measured engine.
"""
from __future__ import annotations

import argparse
import json
from typing import Dict

from repro.core import engines as engines_lib
from repro.core import scheduler
from repro.core.layer_model import alexnet_full_spec
from repro.launch.profile import tiny_net
from repro.profiling import (MeasuredPricer, ProfileCache, calibration_report,
                             environment, profile_network)


def run_bench(*, net, repeats: int, warmup: int,
              cache_path: str, objective: str = "latency") -> Dict:
    engines = [e for e in engines_lib.ALL_ENGINES if e.buildable]
    cache = ProfileCache.load(cache_path, strict=False)
    measurements = profile_network(net, engines, repeats=repeats,
                                   warmup=warmup, cache=cache)
    cache.save(cache_path)

    results = {"config": {
        "net": net.name, "n_layers": len(net), "repeats": repeats,
        "warmup": warmup, **environment(),
        "engines": [e.name for e in engines],
    }, "engines": {}}

    for eng in engines:
        rep = calibration_report(eng, list(net), measurements)
        results["engines"][eng.name] = {
            "n_measurements": rep.model.n_measurements,
            "analytic_mape": rep.analytic_mape,
            "calibrated_mape": rep.calibrated_mape,
            "per_kind": rep.per_kind(),
            "fitted_rates_gflops": {k: v / 1e9
                                    for k, v in rep.model.throughput.items()},
        }
        print(f"[bench_profiling] {eng.name}: MAPE "
              f"{rep.analytic_mape:.2%} analytic -> "
              f"{rep.calibrated_mape:.2%} calibrated "
              f"({rep.model.n_measurements} measurements)", flush=True)

    pricer = MeasuredPricer(cache, measure_on_miss=True, warmup=warmup,
                            repeats=repeats, autosave=False)
    plan_a = scheduler.schedule(net, engines, objective=objective)
    plan_m = scheduler.schedule(net, engines, objective=objective,
                                price="measured", pricer=pricer)
    cache.save(cache_path)
    changed = [a.spec.name for a, b in zip(plan_a.assignments,
                                           plan_m.assignments)
               if a.engine != b.engine]
    results["plans"] = {
        "objective": objective,
        "analytic": {
            "assignments": {a.spec.name: a.engine
                            for a in plan_a.assignments},
            "modeled_total_s": plan_a.total_time,
        },
        "measured": {
            "assignments": {a.spec.name: a.engine
                            for a in plan_m.assignments},
            "modeled_total_s": plan_m.total_time,
        },
        "n_changed": len(changed),
        "changed_layers": changed,
    }
    results["calibration_improves_all_engines"] = all(
        e["calibrated_mape"] < e["analytic_mape"]
        for e in results["engines"].values())
    print(f"[bench_profiling] measured pricing moved {len(changed)}/"
          f"{len(net)} layers; measured-plan modeled time "
          f"{plan_m.total_time*1e3:.3f} ms vs analytic-plan belief "
          f"{plan_a.total_time*1e3:.3f} ms", flush=True)
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="smoke", choices=["smoke", "tiny"],
                    help="smoke: full AlexNet (Table I + LRN/pool); "
                         "tiny: 2-layer CI workload")
    ap.add_argument("--repeats", type=int, default=None)
    ap.add_argument("--cache", default="profile_cache.json")
    ap.add_argument("--out", default="BENCH_profiling.json")
    args = ap.parse_args()

    net = tiny_net() if args.scale == "tiny" else alexnet_full_spec()
    repeats = args.repeats or (3 if args.scale == "tiny" else 5)
    results = run_bench(net=net, repeats=repeats, warmup=2,
                        cache_path=args.cache)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"[bench_profiling] wrote {args.out}: calibration improves all "
          f"engines = {results['calibration_improves_all_engines']}")
    if not results["calibration_improves_all_engines"]:
        raise SystemExit("calibrated model did not beat the analytic model")


if __name__ == "__main__":
    main()
