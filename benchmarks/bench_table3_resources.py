"""Paper Table III analogue: per-module resource utilization.

The FPGA table reported ALUTs/registers/DSPs/RAM blocks per accelerator
module.  The TPU-kernel analogue is the static VMEM working set each Pallas
kernel claims via its BlockSpecs, against the ~16 MiB VMEM budget — the same
'does the module fit the fabric' question.  Also reports the paper's
original Table III numbers through the DE5 device model (theoretical module
peak = DSPs x 2 x clock).
"""
from repro.core.device_models import _DE5_MODULES, fpga_module_peak
from repro.core.layer_model import alexnet_full_spec
from repro.kernels.conv2d import conv2d_vmem_bytes

_VMEM = 16 * 2 ** 20


def run():
    rows = []
    # paper's module inventory (DE5)
    for kind, (dsps, mhz) in _DE5_MODULES.items():
        rows.append(("table3_fpga", f"de5_{kind}", fpga_module_peak(kind) / 1e9,
                     f"DSPs={dsps} clock={mhz}MHz (theoretical GFLOPS)", ""))
    # TPU kernel VMEM working sets
    for spec in alexnet_full_spec():
        if spec.kind == "conv":
            h, w, c = spec.m_i
            oc, ic, kh, kw = spec.m_k
            b = conv2d_vmem_bytes(h + 2 * spec.padding, w + 2 * spec.padding,
                                  ic, oc, kh, kw, spec.stride)
            rows.append(("table3_vmem", f"conv_kernel_{spec.name}",
                         b / 2 ** 20,
                         f"MiB of 16 MiB VMEM ({100 * b / _VMEM:.0f}%)",
                         "FITS" if b < _VMEM else "OVERFLOW"))
    # matmul kernel default blocks: bm*bk + bk*bn + bm*bn fp32
    bm, bn, bk = 256, 256, 512
    b = 4 * (bm * bk + bk * bn + bm * bn)
    rows.append(("table3_vmem", "matmul_kernel_blocks", b / 2 ** 20,
                 f"bm={bm} bn={bn} bk={bk} ({100 * b / _VMEM:.0f}% VMEM)",
                 "FITS" if b < _VMEM else "OVERFLOW"))
    # flash attention: q/k/v blocks + acc + m/l
    bq = bk_ = 512
    d = 128
    b = 4 * (bq * d + 2 * bk_ * d + bq * d + 2 * bq * 128) + 2 * bq * bk_ * 4
    rows.append(("table3_vmem", "flash_attention_blocks", b / 2 ** 20,
                 f"bq={bq} bk={bk_} d={d} ({100 * b / _VMEM:.0f}% VMEM)",
                 "FITS" if b < _VMEM else "OVERFLOW"))
    return rows
