"""Renders the final EXPERIMENTS.md: fills the DRYRUN/ROOFLINE/PERF markers
from dryrun_results.json (+ archived v0/v1 for the perf before/after log).

    PYTHONPATH=src python -m benchmarks.finalize_experiments
"""
import json
import os

from .bench_roofline import roofline_rows
from .report import dryrun_table, roofline_table, skips_table

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _load(name):
    path = os.path.join(ROOT, name)
    return json.load(open(path)) if os.path.exists(path) else []


def _cell_mem(records, arch, shape, mesh="pod"):
    for r in records:
        if (r.get("arch"), r.get("shape"), r.get("mesh")) == (arch, shape,
                                                              mesh) \
                and r.get("status") == "ok" and not r.get("calibration"):
            m = r["memory"]
            return ((m["argument_bytes"] + m["temp_bytes"]) / 2**30,
                    r["collective_bytes_per_device"] / 2**30,
                    r["flops_per_device"] / 1e12)
    return None


def perf_history_table(cells):
    v0, v1, v2 = _load("dryrun_results_v0.json"), \
        _load("dryrun_results_v1.json"), _load("dryrun_results.json")
    rows = ["| cell | metric | v0 (paper-faithful baseline) | v1 | v2 (final) |",
            "|---|---|---|---|---|"]
    for arch, shape in cells:
        for vname, vals in (("HBM GB", 0), ("coll GB/dev", 1)):
            a = _cell_mem(v0, arch, shape)
            b = _cell_mem(v1, arch, shape)
            c = _cell_mem(v2, arch, shape)
            fmt = lambda x: f"{x[vals]:.1f}" if x else "—"
            rows.append(f"| {arch}:{shape} | {vname} | {fmt(a)} | {fmt(b)} | "
                        f"{fmt(c)} |")
    return "\n".join(rows)


def pick_hillclimb_cells(records):
    rows = roofline_rows(records, mesh="pod")
    if not rows:
        return []
    # decode cells are ~0% by construction (one token of useful FLOPs);
    # pick the worst among compute-meaningful (train/prefill) cells
    big = [r for r in rows if r["shape"] in ("train_4k", "prefill_32k")]
    worst = min(big, key=lambda r: r["roofline_fraction"])
    coll = max(big, key=lambda r: r["t_collective_s"]
               / max(max(r["t_compute_s"], r["t_memory_s"]), 1e-12))
    return [("worst roofline fraction (train/prefill)", worst),
            ("most collective-bound", coll)]


def main():
    records = _load("dryrun_results.json")
    exp_path = os.path.join(ROOT, "EXPERIMENTS.md")
    text = open(exp_path).read()

    dr = dryrun_table(records) + "\n\n### Skipped cells\n\n" + \
        skips_table(records)
    text = text.replace("<!-- DRYRUN_TABLE -->", dr)
    text = text.replace("<!-- ROOFLINE_TABLE -->", roofline_table(records))

    picks = pick_hillclimb_cells(records)
    notes = ["**Hillclimb cell selection (per assignment):**", ""]
    for label, r in picks:
        notes.append(f"* {label}: **{r['arch']}:{r['shape']}** "
                     f"(dominant={r['dominant']}, "
                     f"roofline fraction {r['roofline_fraction']:.1%})")
    notes.append("* most representative of the paper's technique: "
                 "**mixtral_8x7b:train_4k** (the MoE layer is where the "
                 "CNNLab engine/placement decision bites hardest)")
    text = text.replace("<!-- ROOFLINE_NOTES -->", "\n".join(notes))

    hist_cells = [("qwen2_1_5b", "train_4k"),
                  ("granite_34b", "train_4k"),
                  ("deepseek_coder_33b", "train_4k"),
                  ("falcon_mamba_7b", "train_4k"),
                  ("seamless_m4t_medium", "train_4k"),
                  ("mixtral_8x7b", "train_4k"),
                  ("llama32_vision_90b", "train_4k"),
                  ("minicpm_2b", "decode_32k")]
    text = text.replace("<!-- PERF_HISTORY -->",
                        perf_history_table(hist_cells))
    open(exp_path, "w").write(text)
    print("EXPERIMENTS.md tables rendered")


if __name__ == "__main__":
    main()
