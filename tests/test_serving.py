"""Serving runtime: KV-pool invariants, token-budgeted admission, and
continuous-vs-static greedy-token equivalence (bit-identical outputs)."""
import jax
import numpy as np
import pytest

from repro.models import transformer as T
from repro.serving import (ContinuousBatcher, EngineLoop, KVPool, Request,
                           RequestState, decode_network_spec,
                           step_time_model, synthetic_workload,
                           token_budget_for_slo)

TINY = T.ModelConfig(
    name="serve-tiny", n_layers=3, d_model=32, n_heads=4, n_kv_heads=2,
    d_ff=64, vocab=64, attention_impl="dot", remat=False)


@pytest.fixture(scope="module")
def tiny_params():
    return T.init_params(jax.random.PRNGKey(0), TINY)


# ------------------------------------------------------------- KV pool
def test_pool_alloc_free_roundtrip():
    pool = KVPool(n_slots=4, max_seq=64, block_size=16)
    assert pool.total_blocks == 16
    slot = pool.alloc(rid=1, n_tokens=33)            # 3 blocks
    assert pool.free_slot_count == 3
    assert pool.free_block_count == 13
    assert pool.lease(1).slot == slot
    assert pool.free(1) == slot
    assert pool.free_slot_count == 4
    assert pool.free_block_count == 16


def test_pool_rejects_double_alloc_and_double_free():
    pool = KVPool(n_slots=2, max_seq=32, block_size=16)
    pool.alloc(rid=7, n_tokens=10)
    with pytest.raises(ValueError):
        pool.alloc(rid=7, n_tokens=10)
    pool.free(7)
    with pytest.raises(ValueError):
        pool.free(7)


def test_pool_admission_bounds():
    pool = KVPool(n_slots=2, max_seq=32, block_size=16, total_blocks=3)
    assert not pool.can_admit(33)                    # over slot row
    assert not pool.can_admit(3 * 16 + 1)            # over block budget
    assert pool.can_admit(32)
    pool.alloc(0, 32)                                # 2 blocks
    assert not pool.can_admit(17)                    # 1 block left
    assert pool.can_admit(16)
    pool.alloc(1, 16)
    assert not pool.can_admit(1)                     # no slots, no blocks


def test_pool_block_exclusivity_and_conservation():
    rng = np.random.default_rng(0)
    pool = KVPool(n_slots=8, max_seq=64, block_size=8)
    live = {}
    for step in range(200):
        if live and (len(live) == 8 or rng.random() < 0.4):
            rid = rng.choice(list(live))
            pool.free(rid)
            del live[rid]
        else:
            rid = step + 1000
            n = int(rng.integers(1, 65))
            if pool.can_admit(n):
                pool.alloc(rid, n)
                live[rid] = n
        # invariants
        owned = [b for r in live for b in pool.lease(r).blocks]
        assert len(owned) == len(set(owned))         # no block shared
        assert pool.free_block_count + len(owned) == pool.total_blocks
        assert 0.0 <= pool.utilization() <= 1.0
        assert 0.0 <= pool.occupancy() <= 1.0


def test_pool_utilization_tracks_writes():
    pool = KVPool(n_slots=2, max_seq=32, block_size=16)
    pool.alloc(1, 32)                                # 2 blocks = 32 tokens
    assert pool.utilization() == 0.0
    pool.note_write(1, 16)
    assert pool.utilization() == pytest.approx(0.5)
    with pytest.raises(ValueError):
        pool.note_write(1, 17)                       # past reservation


# ------------------------------------------------------------- batcher
def _req(rid, plen, glen, arrival=0.0, priority=0, deadline=None):
    return Request(rid=rid, prompt=np.zeros((plen,), np.int32),
                   max_new_tokens=glen, arrival=arrival, priority=priority,
                   deadline=deadline)


def test_batcher_respects_token_budget():
    pool = KVPool(n_slots=8, max_seq=64)
    b = ContinuousBatcher(TINY, pool, token_budget=3)
    queue = [_req(i, 8, 8, arrival=i) for i in range(6)]
    dec = b.admit(queue, n_active=0, now=0.0)
    assert [r.rid for r in dec.admitted] == [0, 1, 2]
    assert all(r.state is RequestState.PREFILL for r in dec.admitted)
    # with 2 already active only one more fits the budget
    queue2 = [_req(10 + i, 8, 8) for i in range(3)]
    dec2 = b.admit(queue2, n_active=2, now=0.0)
    assert len(dec2.admitted) == 1


def test_batcher_sheds_expired_and_unservable():
    pool = KVPool(n_slots=4, max_seq=32)
    b = ContinuousBatcher(TINY, pool)
    queue = [_req(0, 8, 8, deadline=1.0),            # expired at now=2
             _req(1, 30, 8),                         # 38 > max_seq: never fits
             _req(2, 8, 8)]
    dec = b.admit(queue, n_active=0, now=2.0)
    assert [r.rid for r in dec.dropped] == [0, 1]
    assert all(r.state is RequestState.DROPPED for r in dec.dropped)
    assert [r.rid for r in dec.admitted] == [2]


def test_batcher_backfills_past_blocked_request():
    pool = KVPool(n_slots=4, max_seq=64, block_size=16, total_blocks=5)
    b = ContinuousBatcher(TINY, pool)
    queue = [_req(0, 40, 20, arrival=0.0),           # 60 tokens = 4 blocks
             _req(1, 50, 14, arrival=1.0),           # 64 tokens: blocked
             _req(2, 8, 8, arrival=2.0)]             # 16 tokens: backfills
    dec = b.admit(queue, n_active=0, now=0.0)
    assert [r.rid for r in dec.admitted] == [0, 2]
    assert [r.rid for r in queue] == [1]


def test_batcher_priority_order():
    pool = KVPool(n_slots=2, max_seq=32)
    b = ContinuousBatcher(TINY, pool, token_budget=1)
    queue = [_req(0, 8, 8, arrival=0.0, priority=1),
             _req(1, 8, 8, arrival=5.0, priority=0)]
    dec = b.admit(queue, n_active=0, now=6.0)
    assert [r.rid for r in dec.admitted] == [1]      # lower priority value


def test_cost_model_admission_pricing():
    spec = decode_network_spec(TINY, kv_len=64)
    # one attention + one MLP tuple per layer
    assert len(spec) == 2 * TINY.n_layers
    t1 = step_time_model(TINY, 64, 1)
    t8 = step_time_model(TINY, 64, 8)
    assert 0 < t1 <= t8
    # generous SLO admits every slot; the tightest admits at least one
    assert token_budget_for_slo(TINY, 64, 8, step_slo_s=10.0) == 8
    assert token_budget_for_slo(TINY, 64, 8, step_slo_s=0.0) == 1


# ------------------------------------------------- engine loop end-to-end
def _virtual_clock():
    t = [0.0]

    def now():
        t[0] += 1e-3
        return t[0]

    return now


def _static_reference(params, requests, batch, max_len, cfg=TINY):
    """Per-request greedy tokens through the legacy static path — the same
    baseline construction the benchmark times (shared, so the bit-identity
    contract the test asserts is exactly what BENCH_serving.json reports)."""
    from benchmarks.bench_serving import run_static
    from repro.serving import ServeMetrics
    return run_static(cfg, params, requests, batch=batch, max_len=max_len,
                      metrics=ServeMetrics())


def test_continuous_matches_static_greedy_tokens(tiny_params):
    max_len = 8 + 12
    reqs = synthetic_workload(9, rate=1e9, vocab=TINY.vocab,
                              prompt_lens=(4, 8), gen_lens=(3, 6, 12),
                              seed=11)
    want = _static_reference(tiny_params, [
        Request(rid=r.rid, prompt=r.prompt.copy(),
                max_new_tokens=r.max_new_tokens, arrival=r.arrival)
        for r in reqs], batch=3, max_len=max_len)

    # 3 slots for 9 requests: slots recycle mid-stream, positions stagger
    engine = EngineLoop(TINY, tiny_params, n_slots=3, max_seq=max_len)
    metrics = engine.run(reqs, now_fn=_virtual_clock())
    assert metrics.n_done == 9
    got = {r.rid: r.output for r in reqs}
    assert got == want                               # bit-identical greedy


def test_engine_recycles_slots_and_accounts_pool(tiny_params):
    reqs = synthetic_workload(6, rate=1e9, vocab=TINY.vocab,
                              prompt_lens=(4,), gen_lens=(4,), seed=5)
    engine = EngineLoop(TINY, tiny_params, n_slots=2, max_seq=16)
    metrics = engine.run(reqs, now_fn=_virtual_clock())
    assert metrics.n_done == 6
    assert engine.pool.free_slot_count == 2          # everything released
    assert engine.pool.free_block_count == engine.pool.total_blocks
    assert all(r.state is RequestState.DONE for r in reqs)
    assert all(len(r.output) == 4 for r in reqs)
    s = metrics.summary()
    assert s["tokens_out"] == 24
    assert s["ttft_p50_s"] > 0 and s["latency_p99_s"] > 0


def test_recycled_slot_does_not_leak_ssm_state():
    # hybrid arch: recurrent state carries no position, so slot recycling
    # must explicitly reset it (regression: second tenant of a slot used to
    # inherit the first tenant's RG-LRU/Mamba hidden state)
    cfg = T.ModelConfig(
        name="serve-rec", n_layers=4, d_model=32, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab=64, block_pattern=("rec", "attn"),
        attention_impl="dot", remat=False)
    params = T.init_params(jax.random.PRNGKey(2), cfg)
    reqs = synthetic_workload(2, rate=1e9, vocab=cfg.vocab, prompt_lens=(4,),
                              gen_lens=(4,), seed=21)
    want = _static_reference(params, [
        Request(rid=r.rid, prompt=r.prompt.copy(),
                max_new_tokens=r.max_new_tokens, arrival=r.arrival)
        for r in reqs], batch=1, max_len=8, cfg=cfg)
    engine = EngineLoop(cfg, params, n_slots=1, max_seq=8)
    engine.run(reqs, now_fn=_virtual_clock())
    assert {r.rid: r.output for r in reqs} == want


def test_idle_engine_fast_forwards_to_next_arrival(tiny_params):
    # arrivals far apart vs service time: the clock must jump to each
    # arrival, never stamping TTFT/latency before the request arrived
    reqs = [_req(0, 4, 4, arrival=5.0), _req(1, 4, 4, arrival=50.0)]
    for r in reqs:
        r.prompt = np.arange(4, dtype=np.int32)
    engine = EngineLoop(TINY, tiny_params, n_slots=2, max_seq=16)
    metrics = engine.run(reqs, now_fn=_virtual_clock())
    assert metrics.n_done == 2
    assert all(t >= 0 for t in metrics.ttft_s)
    assert all(t >= 0 for t in metrics.latency_s)
    assert metrics.elapsed_s >= 50.0     # offered-load timeline, not wall


def test_engine_drops_expired_queued_requests(tiny_params):
    # one slot; the second request's deadline passes while it queues
    r0 = _req(0, 4, 8)
    r0.prompt = np.arange(4, dtype=np.int32)
    r1 = _req(1, 4, 4, arrival=0.0, deadline=1e-9)
    r1.prompt = np.arange(4, dtype=np.int32)
    engine = EngineLoop(TINY, tiny_params, n_slots=1, max_seq=16)
    metrics = engine.run([r0, r1], now_fn=_virtual_clock())
    assert metrics.n_done == 1
    assert metrics.n_dropped == 1
    assert r1.state is RequestState.DROPPED and r1.output == []
