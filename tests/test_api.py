"""The programmatic serving API: options construction/validation and
end-to-end `repro.serving.api.serve()` runs (the ISSUE's requirement that
at least one suite drives serving through the API, not the CLI).

Validation is the no-op-flag audit: every flag interaction the runtime
would silently ignore must raise instead.
"""
import argparse
import dataclasses

import pytest

from repro.launch.serve import build_parser
from repro.serving.api import (EFFECTIVE_DEFAULTS, ServeOptions, serve)


def _opts(**overrides) -> ServeOptions:
    """ServeOptions with leaf fields set by flat name."""
    o = ServeOptions()
    groups = o.flat_fields()
    for name, value in overrides.items():
        setattr(getattr(o, groups[name]), name, value)
    return o


# ---------------------------------------------------------------------
# options tree <-> argparse
# ---------------------------------------------------------------------
def test_from_args_roundtrip():
    args = build_parser().parse_args(
        ["--arch", "granite_34b", "--requests", "5", "--rate", "3.5",
         "--placement", "disagg", "--sync-handoff", "--slots", "6",
         "--speculate", "--draft-arch", "qwen2_1_5b", "--draft-k", "3"])
    o = ServeOptions.from_args(args)
    assert o.workload.arch == "granite_34b"
    assert o.workload.requests == 5
    assert o.workload.rate == 3.5
    assert o.engine.slots == 6
    assert o.placement.placement == "disagg"
    assert o.placement.sync_handoff is True
    assert o.speculative.speculate is True
    assert o.speculative.draft_arch == "qwen2_1_5b"
    assert o.speculative.draft_k == 3
    o.validate()


def test_parser_defaults_are_valid():
    """A bare `python -m repro.launch.serve` must validate."""
    ServeOptions.from_args(build_parser().parse_args([])).validate()


def test_flat_fields_unique_and_grouped():
    flat = ServeOptions.flat_fields()
    assert flat["arch"] == "workload"
    assert flat["kv_layout"] == "engine"
    assert flat["draft_k"] == "speculative"
    # every group contributes at least one leaf
    assert set(flat.values()) == {g for g, _ in ServeOptions.groups()}


def test_effective_defaults_cover_every_none_default_with_one():
    """Options whose parser default is None *because* validation needs to
    see absence, but which have a real runtime default, must map to it."""
    for name in ("shared_frac", "calibrated_engine", "misprice_phase",
                 "slo_ttft_ms", "slo_tpot_ms", "draft_arch"):
        assert name in EFFECTIVE_DEFAULTS


# ---------------------------------------------------------------------
# validation: silently-no-op interactions raise
# ---------------------------------------------------------------------
@pytest.mark.parametrize("overrides,match", [
    ({"placement": "auto", "prefill_engine": "xla"}, "placement auto"),
    ({"stream": True, "static_batching": True}, "continuous engine"),
    ({"static_batching": True, "watchdog": True}, "static-batching"),
    ({"static_batching": True, "trace": "/tmp/t.json"}, "static-batching"),
    ({"static_batching": True, "sync_handoff": True,
      "placement": "disagg"}, "static-batching"),
    ({"prefix_sharing": True, "kv_layout": "dense"}, "paged"),
    ({"prefix_sharing": True, "static_batching": True}, "KV pool"),
    ({"shared_prefix_len": 0}, "shared-prefix-len"),
    ({"shared_frac": 0.5}, "shared-frac"),
    ({"misprice": 0.0, "watchdog": True}, "misprice"),
    ({"misprice_phase": "decode", "watchdog": True}, "misprice-phase"),
    ({"misprice": 2.0}, "watchdog"),
    ({"drift_gate": 1.2}, "watchdog"),
    ({"slo_ttft_ms": 100.0}, "slo-report"),
    ({"slo_tpot_ms": 10.0}, "slo-report"),
    ({"calibrated_engine": "xla"}, "calibrated-cache"),
    ({"sync_handoff": True}, "disagg"),
    ({"prefill_slots": 4}, "disagg"),
    ({"handoff_link_bw": 1e9}, "disagg"),
    ({"speculate": True, "static_batching": True}, "static-batching"),
    ({"speculate": True, "prefix_sharing": True}, "prefix-sharing"),
    ({"speculate": True, "kv_layout": "dense"}, "paged"),
    ({"draft_arch": "qwen2_1_5b"}, "speculate"),
    ({"draft_k": 2}, "speculate"),
    ({"speculate": True, "draft_k": 0}, "draft-k"),
])
def test_validate_raises(overrides, match):
    with pytest.raises(ValueError, match=match):
        _opts(**overrides).validate()


def test_cli_rejects_invalid_combination():
    """main()'s parse path turns validation errors into argparse errors."""
    ap = build_parser()
    args = ap.parse_args(["--shared-frac", "0.5"])
    with pytest.raises(ValueError):
        ServeOptions.from_args(args).validate()


def test_validate_accepts_consistent_options():
    _opts(shared_prefix_len=16, shared_frac=0.5).validate()
    _opts(watchdog=True, misprice=4.0, misprice_phase="decode").validate()
    _opts(slo_report=True, slo_ttft_ms=100.0).validate()
    _opts(placement="disagg", sync_handoff=True,
          prefill_slots=4).validate()
    _opts(speculate=True, draft_arch="qwen2_1_5b", draft_k=2).validate()


# ---------------------------------------------------------------------
# end-to-end through serve()
# ---------------------------------------------------------------------
def _serve_opts(**overrides) -> ServeOptions:
    base = dict(arch="qwen2_1_5b", requests=4, prompt_len=4, gen_len=8,
                rate=1e9, slots=2)
    base.update(overrides)
    return _opts(**base)


def test_serve_continuous_smoke():
    report = serve(_serve_opts())
    assert report.summary["tokens_out"] > 0
    assert len(report.requests) == 4
    assert all(len(out) > 0 for out in report.outputs.values())
    assert report.pool_stats["kv_pool"]["slots_in_use"] == 0
    assert report.admission[0]["n_admitted"] == 4
    assert report.speculation is None
    assert report.handoff is None


def test_serve_static_smoke():
    report = serve(_serve_opts(static_batching=True, batch=2))
    assert report.summary["static_batching"] is True
    assert report.summary["tokens"] == 4 * 8
    assert report.static_tokens and report.metrics is None


def test_serve_speculative_forced_bit_identical():
    """The API's speculative path (self-draft, forced depth) produces
    bitwise the plain path's outputs and reports the round accounting."""
    plain = serve(_serve_opts(gen_len=16, slots=4, requests=6))
    spec = serve(_serve_opts(gen_len=16, slots=4, requests=6,
                             speculate=True, draft_arch="qwen2_1_5b",
                             draft_k=2))
    assert spec.outputs == plain.outputs
    st = spec.speculation
    assert st["engaged"] and st["forced"] and st["k"] == 2
    assert st["n_rounds"] > 0
    # self-draft: the draft IS the target, so everything is accepted
    assert st["acceptance_rate"] == 1.0
