"""Attention engines: chunked (flash-in-XLA, custom-vjp backward) vs the
dot-product reference — outputs AND gradients, across GQA/window/padding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (chunked_attention, decode_attention,
                                    dot_attention)

rng = np.random.default_rng(0)


def _qkv(hq, hk, s, t, d=32, dtype=jnp.float32):
    q = jnp.asarray(rng.normal(size=(2, hq, s, d)), dtype)
    k = jnp.asarray(rng.normal(size=(2, hk, t, d)), dtype)
    v = jnp.asarray(rng.normal(size=(2, hk, t, d)), dtype)
    return q, k, v


@pytest.mark.parametrize("hq,hk,s,t,causal,window,bk", [
    (4, 4, 64, 64, True, None, 16),
    (8, 2, 64, 64, True, None, 32),       # GQA
    (4, 2, 64, 64, True, 24, 16),         # sliding window
    (4, 2, 48, 100, False, None, 32),     # cross-attn, padded T
    (4, 1, 128, 128, True, None, 128),    # MQA, single chunk
])
def test_chunked_matches_dot_fwd_and_grads(hq, hk, s, t, causal, window, bk):
    q, k, v = _qkv(hq, hk, s, t)

    def f_chunked(q, k, v):
        return jnp.sum(jnp.sin(chunked_attention(
            q, k, v, causal=causal, window=window, kv_chunk=bk)))

    def f_dot(q, k, v):
        return jnp.sum(jnp.sin(dot_attention(
            q, k, v, causal=causal, window=window)))

    np.testing.assert_allclose(f_chunked(q, k, v), f_dot(q, k, v),
                               rtol=2e-3, atol=2e-3)
    g1 = jax.grad(f_chunked, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_dot, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(g1, g2, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-3, err_msg=name)


def test_chunked_grad_invariant_to_chunk_size():
    q, k, v = _qkv(4, 2, 64, 64)
    grads = []
    for bk in (16, 32, 64):
        f = lambda q, k, v: jnp.sum(chunked_attention(
            q, k, v, causal=True, kv_chunk=bk) ** 2)
        grads.append(jax.grad(f)(q, k, v))
    np.testing.assert_allclose(np.asarray(grads[0]), np.asarray(grads[1]),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(grads[0]), np.asarray(grads[2]),
                               rtol=1e-4, atol=1e-5)


def test_decode_matches_full_attention_row():
    """decode_attention on a filled cache == last row of full attention."""
    q, k, v = _qkv(4, 2, 16, 16)
    full = dot_attention(q, k, v, causal=True)
    out = decode_attention(q[:, :, -1:], k, v, pos=jnp.asarray(15))
    np.testing.assert_allclose(np.asarray(out), np.asarray(full[:, :, -1:]),
                               rtol=1e-5, atol=1e-6)


def test_decode_windowed_rolling_cache():
    """Rolling-buffer semantics: a cache of size W with slot = pos % W must
    reproduce windowed attention at any pos."""
    w = 8
    q, k, v = _qkv(2, 2, 32, 32)
    full = dot_attention(q, k, v, causal=True, window=w)
    pos = 31
    idx = (np.arange(w) + (pos + 1 - w)) % 32            # positions in window
    slots = idx % w
    k_cache = np.zeros((2, 2, w, 32), np.float32)
    v_cache = np.zeros((2, 2, w, 32), np.float32)
    k_cache[:, :, slots] = np.asarray(k[:, :, idx])
    v_cache[:, :, slots] = np.asarray(v[:, :, idx])
    out = decode_attention(q[:, :, -1:], jnp.asarray(k_cache),
                           jnp.asarray(v_cache), pos=jnp.asarray(pos),
                           window=w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(full[:, :, -1:]),
                               rtol=1e-5, atol=1e-6)
