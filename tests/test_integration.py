"""Integration tests: end-to-end training (loss actually decreases on
structured data), checkpoint-resume exactness, serve loop, train CLI with
preemption, sharding policy resolution."""
import functools
import subprocess
import sys
import os

import jax
import numpy as np
import pytest

from repro.configs import get
from repro.data import DataConfig, SyntheticLM
from repro.models import transformer as T
from repro.models import sharding as shard_lib
from repro.optim import adamw, schedules


def _tiny_cfg():
    return T.ModelConfig(
        name="tiny", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=64, scan_chunk=16, attention_impl="dot", remat=False)


def test_training_reduces_loss_on_structured_data():
    cfg = _tiny_cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    init_opt, update = adamw.make_optimizer(
        schedules.cosine_schedule(1e-2, 10, 150))
    opt = init_opt(params)
    pipe = SyntheticLM(DataConfig(global_batch=8, seq_len=32, vocab=64))

    @jax.jit
    def step(p, o, b):
        loss, grads = jax.value_and_grad(T.loss_fn)(p, cfg, b)
        newp, newo, _ = update(grads, o, p)
        return newp, newo, loss

    losses = []
    for _ in range(150):
        b = next(pipe)
        params, opt, loss = step(params, opt, b)
        losses.append(float(loss))
    # bigram data has ~log(8)=2.08 nats of true entropy; start is ~log(64)=4.16
    assert losses[0] > 3.5
    assert min(losses[-10:]) < losses[0] - 0.8, losses[::15]


def test_checkpoint_resume_bitexact(tmp_path):
    """Train 6 steps straight vs 3 + save + restore + 3: identical params."""
    from repro.checkpoint import save_checkpoint, restore_latest
    cfg = _tiny_cfg()
    init_opt, update = adamw.make_optimizer(schedules.constant(1e-3))

    @jax.jit
    def step(p, o, b):
        loss, grads = jax.value_and_grad(T.loss_fn)(p, cfg, b)
        newp, newo, _ = update(grads, o, p)
        return newp, newo, loss

    def fresh():
        p = T.init_params(jax.random.PRNGKey(0), cfg)
        return p, init_opt(p)

    dc = DataConfig(global_batch=4, seq_len=16, vocab=64)
    # run A: 6 straight steps
    pa, oa = fresh()
    pipe = SyntheticLM(dc)
    for _ in range(6):
        pa, oa, _ = step(pa, oa, next(pipe))
    # run B: 3 steps, checkpoint, restore, 3 more
    pb, ob = fresh()
    pipe_b = SyntheticLM(dc)
    for _ in range(3):
        pb, ob, _ = step(pb, ob, next(pipe_b))
    d = str(tmp_path / "ck")
    save_checkpoint(d, 3, {"p": pb, "o": ob},
                    extra={"data": pipe_b.state()})
    pc, oc = fresh()
    pipe_c = SyntheticLM(dc)
    _, state, extra = restore_latest(d, {"p": pc, "o": oc})
    pc, oc = state["p"], state["o"]
    pipe_c.restore(extra["data"])
    for _ in range(3):
        pc, oc, _ = step(pc, oc, next(pipe_c))
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pc)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_grad_accum_matches_full_batch():
    from repro.launch.steps import _accum_grads
    cfg = _tiny_cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    b = next(SyntheticLM(DataConfig(global_batch=8, seq_len=16, vocab=64)))
    loss_full, grads_full = jax.value_and_grad(T.loss_fn)(params, cfg, b)
    loss_acc, grads_acc = _accum_grads(params, cfg, b, n=4)
    assert float(loss_full) == pytest.approx(float(loss_acc), rel=1e-4)
    for a, g in zip(jax.tree.leaves(grads_acc), jax.tree.leaves(grads_full)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(g),
                                   rtol=5e-2, atol=1e-4)


def test_train_cli_runs_and_resumes(tmp_path):
    env = dict(os.environ, PYTHONPATH="src")
    ck = str(tmp_path / "ckpt")
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch", "qwen2_1_5b",
           "--scale", "smoke", "--steps", "6", "--batch", "2", "--seq", "32",
           "--ckpt-dir", ck, "--ckpt-interval", "2", "--log-every", "2"]
    r = subprocess.run(cmd, capture_output=True, text=True, cwd="/root/repo",
                       env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "loss" in r.stdout
    # resume: starts from step 5 (last checkpoint), runs to 8
    cmd2 = [c if c != "6" else "8" for c in cmd]
    r2 = subprocess.run(cmd2, capture_output=True, text=True, cwd="/root/repo",
                        env=env, timeout=600)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "[restore] resumed at step" in r2.stdout


def test_serve_cli_generates(tmp_path):
    env = dict(os.environ, PYTHONPATH="src")
    base = [sys.executable, "-m", "repro.launch.serve", "--arch",
            "qwen2_1_5b", "--scale", "smoke", "--batch", "2", "--prompt-len",
            "8", "--gen-len", "8", "--requests", "4"]
    # default path: continuous-batching engine (repro.serving)
    r = subprocess.run(base + ["--slots", "2", "--rate", "100"],
                       capture_output=True, text=True, cwd="/root/repo",
                       env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "requests_done: 4" in r.stdout
    assert "requests_dropped: 0" in r.stdout
    # legacy fallback: static batching
    r = subprocess.run(base + ["--static-batching"], capture_output=True,
                       text=True, cwd="/root/repo", env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "served 4 requests" in r.stdout


# --------------------------------------------------------------- sharding
def test_policy_tp_vs_fsdp_mode():
    from jax.sharding import Mesh
    devs = np.asarray(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(devs, ("data", "model"))
    # single-device mesh: everything resolves to replicated but must not error
    for arch_name in ("qwen2_1_5b", "mixtral_8x7b", "falcon_mamba_7b"):
        cfg = get(arch_name).config
        policy = shard_lib.make_policy(cfg, mesh)
        shapes = jax.eval_shape(
            functools.partial(T.init_params, cfg=cfg), jax.random.PRNGKey(0))
        sh = shard_lib.param_shardings(cfg, policy, shapes)
        assert len(jax.tree.leaves(sh)) == len(jax.tree.leaves(shapes))


def test_resolver_divisibility_fallbacks():
    from jax.sharding import Mesh, PartitionSpec as P
    devs = np.asarray(jax.devices() * 1)[:1]
    # fake 16x16 mesh shape via Mesh of 1 device can't be built; test the
    # resolver's pure logic with a mocked mesh-shape mapping instead
    class FakeMesh:
        shape = {"data": 16, "model": 16}
    pol = shard_lib.ShardingPolicy(mesh=FakeMesh(), tp_mode=True)
    # heads=56 (deepseek): not divisible by 16 -> replicated
    assert pol.resolve((7168, 7168), ["embed", "heads"]) == P("data", "model")
    assert pol.resolve((7168, 56 * 128), ["embed", "heads"])[1] == "model"
    # kv_heads=8: replicated on a 16-way axis
    spec = pol.resolve((4096, 8 * 128), ["embed", "kv_heads"])
    assert spec[1] == "model"  # 1024 % 16 == 0 -> sharded (flattened dim)
    # expert=16 divides -> 'model'; then ff can't reuse 'model'
    spec = pol.resolve((16, 4096, 6400), ["expert", "embed", "ff"])
    assert spec[0] == "model" and spec[2] is None
    # expert=8 does not divide 16 -> ff gets 'model'
    spec = pol.resolve((8, 4096, 14336), ["expert", "embed", "ff"])
    assert spec[0] is None and spec[2] == "model"


def test_cache_shardings_kv_and_ssm():
    from jax.sharding import PartitionSpec as P
    class FakeMesh:
        shape = {"data": 16, "model": 16}
    cfg = get("mixtral_8x7b").config
    pol = shard_lib.ShardingPolicy(mesh=FakeMesh(), tp_mode=True)
    cache = jax.eval_shape(lambda: T.init_cache(cfg, 128, max_seq=4096))
    sh = shard_lib.cache_shardings(cfg, pol, cache)
    flat = jax.tree_util.tree_flatten_with_path(sh)[0]
    kv = [s for p, s in flat if any(getattr(q, "key", "") == "k" for q in p)]
    assert kv, "kv cache leaves missing"
    spec = getattr(kv[0], "spec", kv[0])   # FakeMesh returns bare P
    # mixtral kv=8 heads won't shard over 16 -> time dim takes 'model'
    assert spec[3] == "model" and spec[1] == "data"
