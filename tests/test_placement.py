"""Phase placement + disaggregated serving: the trade-off analyzer picks
the paper's GPU/FPGA split for the two serving phases, the hand-off is
priced by the offload-overhead model, and the disaggregated engine loop's
outputs stay bit-identical to colocated serving."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import device_models as dm
from repro.core import engines as engines_lib
from repro.core.cost_model import transfer_cost
from repro.core.layer_model import (AttentionSpec, MLPSpec, MoESpec,
                                    NetworkSpec, SSMSpec)
from repro.core.scheduler import schedule
from repro.models import transformer as T
from repro.serving import (DisaggregatedEngineLoop, EngineLoop,
                           handoff_payload_bytes, phase_cost,
                           phase_network_spec, place_phases,
                           prefill_network_spec, synthetic_workload)

TINY = T.ModelConfig(
    name="place-tiny", n_layers=3, d_model=32, n_heads=4, n_kv_heads=2,
    d_ff=64, vocab=64, attention_impl="dot", remat=False)

PAPER_PAIR = (engines_lib.K40_LM_ENGINE, engines_lib.DE5_LM_ENGINE)


@pytest.fixture(scope="module")
def tiny_params():
    return T.init_params(jax.random.PRNGKey(0), TINY)


def _virtual_clock():
    t = [0.0]

    def now():
        t[0] += 1e-3
        return t[0]

    return now


# ---------------------------------------------------------- offload model
def test_transfer_cost_free_on_same_device_and_scales_with_bytes():
    free = transfer_cost(10**9, dm.TPU_V5E, dm.TPU_V5E)
    assert free.t_transfer == 0.0 and free.energy_j == 0.0
    a = transfer_cost(10**6, dm.K40_ROOFLINE, dm.DE5_ROOFLINE)
    b = transfer_cost(2 * 10**6, dm.K40_ROOFLINE, dm.DE5_ROOFLINE)
    assert b.t_transfer == pytest.approx(2 * a.t_transfer)
    # neither paper board declares a link: the slower mem_bw bounds it
    assert a.link_bw == min(dm.K40_ROOFLINE.mem_bw, dm.DE5_ROOFLINE.mem_bw)
    assert a.energy_j > 0


def test_plan_offload_overhead_prices_engine_switches():
    net = NetworkSpec("mix", (
        MLPSpec("big", d_model=256, d_ff=4096, seq=64),
        AttentionSpec("attn", d_model=256, n_heads=8, n_kv_heads=8,
                      seq=64, kv_len=64),
    ))
    plan = schedule(net, engines_lib.PLACEMENT_ENGINES, objective="energy")
    boundaries = plan.offload_overhead()
    switches = sum(a.engine != b.engine for a, b in
                   zip(plan.assignments, plan.assignments[1:]))
    assert len(boundaries) == switches
    for la, lb, cost in boundaries:
        assert cost.t_transfer > 0 and cost.bytes_moved > 0


# ------------------------------------------------------------- placement
def test_prefill_lands_compute_strong_decode_lands_bandwidth_strong():
    """The paper's split applied to the serving phases: under the K40/DE5
    roofline models, energy/perf-density placement puts compute-bound
    prefill on the GPU and memory-bound decode on the low-power FPGA."""
    for objective in ("energy", "perf_density"):
        d = place_phases(TINY, PAPER_PAIR, objective=objective,
                         prompt_len=256, gen_len=256, batch=8)
        assert d.prefill_engine == "k40-roofline", objective
        assert d.decode_engine == "de5-roofline", objective
        assert not d.colocated


def test_latency_placement_collapses_to_fastest_engine():
    d = place_phases(TINY, PAPER_PAIR, objective="latency",
                     prompt_len=256, gen_len=256, batch=8)
    assert d.colocated and d.prefill_engine == "k40-roofline"


def test_colocated_wins_when_handoff_dominates():
    split = place_phases(TINY, PAPER_PAIR, objective="energy",
                         prompt_len=256, gen_len=256, batch=8)
    assert not split.colocated
    choked = place_phases(TINY, PAPER_PAIR, objective="energy",
                          prompt_len=256, gen_len=256, batch=8,
                          link_bw=10.0)   # ~bytes/10s hand-off: prohibitive
    assert choked.colocated


def test_placement_ranks_all_pairs_and_is_deterministic():
    d = place_phases(TINY, PAPER_PAIR, objective="energy",
                     prompt_len=64, gen_len=64)
    assert len(d.ranked) == 4            # 2 engines x 2 phases
    values = [p.value for p in d.ranked]
    assert values == sorted(values)
    assert d.best is d.ranked[0]
    d2 = place_phases(TINY, PAPER_PAIR, objective="energy",
                      prompt_len=64, gen_len=64)
    assert [(p.prefill.engine, p.decode.engine) for p in d.ranked] == \
        [(p.prefill.engine, p.decode.engine) for p in d2.ranked]
    assert "chosen" in d.summary()


def test_measured_pricing_degrades_cleanly_without_cache(tmp_path):
    d = place_phases(TINY, PAPER_PAIR, objective="energy",
                     prompt_len=64, gen_len=64, price="measured",
                     cache_path=str(tmp_path / "missing.json"))
    a = place_phases(TINY, PAPER_PAIR, objective="energy",
                     prompt_len=64, gen_len=64)
    assert (d.prefill_engine, d.decode_engine) == \
        (a.prefill_engine, a.decode_engine)


def test_handoff_payload_counts_kv_and_recurrent_state():
    plain = handoff_payload_bytes(TINY, prompt_len=64, dtype_bytes=2)
    # 3 attn layers x 2 (K+V) x n_kv_heads x head_dim x 64 positions x 2B
    kv = 3 * 2 * TINY.n_kv_heads * TINY.hd * 64 * 2
    assert plain == kv + TINY.d_model * 2
    # the implementation migrates whole slot rows: padded KV + int32 buffers
    padded = handoff_payload_bytes(TINY, prompt_len=64, dtype_bytes=2,
                                   slot_len=128)
    assert padded == 2 * kv + TINY.d_model * 2 + 2 * 128 * 4
    hybrid = T.ModelConfig(name="h", n_layers=4, d_model=32, n_heads=4,
                           n_kv_heads=2, d_ff=64, vocab=64,
                           block_pattern=("rec", "attn"))
    assert handoff_payload_bytes(hybrid, prompt_len=64) > 0


def test_phase_specs_shapes():
    pre = prefill_network_spec(TINY, prompt_len=32)
    dec = phase_network_spec(TINY, seq=1, kv_len=48)
    assert all(l.seq == 32 for l in pre if hasattr(l, "seq"))
    assert all(l.seq == 1 for l in dec if hasattr(l, "seq"))
    # prefill is the compute-heavy phase per token
    assert pre.flops(1) > dec.flops(1) * 8


def test_phase_cost_rejects_unsupported_engine():
    with pytest.raises(ValueError):
        phase_cost(TINY, engines_lib.K40_ENGINE, "decode",
                   prompt_len=8, gen_len=8)   # empirical K40: CNN kinds only


# ------------------------------------------- decode-step engine builders
@pytest.mark.parametrize("spec", [
    AttentionSpec("a", d_model=32, n_heads=4, n_kv_heads=2, seq=1,
                  kv_len=16, qkv_bias=True),
    MLPSpec("m", d_model=32, d_ff=64, seq=1),
    MoESpec("e", d_model=32, d_ff=64, seq=1, n_experts=4, top_k=2),
    SSMSpec("s", d_model=32, d_state=8, d_conv=4, expand=2, seq=1,
            variant="mamba1"),
    SSMSpec("r", d_model=32, d_state=8, d_conv=4, expand=2, seq=1,
            variant="rglru"),
])
def test_xla_engine_builds_decode_step_kinds(spec):
    """ROADMAP follow-on: the decode-step spec kinds are now buildable, so
    the profiling runtime can measure what admission/placement price."""
    from repro.profiling import time_layer
    eng = engines_lib.XLA_ENGINE
    fn = eng.build(spec)
    params = engines_lib.init_layer_params(spec, jax.random.PRNGKey(0))
    y = fn(jnp.zeros((2, spec.seq, spec.d_model), jnp.float32), params)
    assert y.shape == (2, spec.seq, spec.d_model)
    assert bool(jnp.isfinite(y).all())
    m = time_layer(eng, spec, batch=2, warmup=1, repeats=2)
    assert m.t_median > 0 and m.flops == spec.flops(2)


def test_decode_step_measurements_calibrate_serving_kinds():
    """Measured decode-step timings produce a calibrated model covering the
    kinds serving admission actually prices (not the CNN fallback)."""
    from repro.profiling import calibrate_engine, profile_network
    net = phase_network_spec(TINY, seq=1, kv_len=16)
    ms = profile_network(net, [engines_lib.XLA_ENGINE], batch=2,
                         warmup=1, repeats=2)
    assert {m.kind for m in ms} == {"attention", "mlp"}
    model = calibrate_engine(engines_lib.XLA_ENGINE, ms)
    assert set(model.throughput) == {"attention", "mlp"}
    assert all(v > 0 for v in model.throughput.values())


# --------------------------------------------- disaggregated engine loop
def test_disaggregated_outputs_bit_identical_to_colocated(tiny_params):
    max_len = 8 + 12
    reqs_c = synthetic_workload(9, rate=1e9, vocab=TINY.vocab,
                                prompt_lens=(4, 8), gen_lens=(1, 3, 6, 12),
                                seed=11)
    reqs_d = synthetic_workload(9, rate=1e9, vocab=TINY.vocab,
                                prompt_lens=(4, 8), gen_lens=(1, 3, 6, 12),
                                seed=11)
    colo = EngineLoop(TINY, tiny_params, n_slots=3, max_seq=max_len)
    m_c = colo.run(reqs_c, now_fn=_virtual_clock())
    dis = DisaggregatedEngineLoop(TINY, tiny_params, n_prefill_slots=2,
                                  n_decode_slots=3, max_seq=max_len)
    m_d = dis.run(reqs_d, now_fn=_virtual_clock())
    assert m_c.n_done == m_d.n_done == 9
    assert {r.rid: r.output for r in reqs_c} == \
        {r.rid: r.output for r in reqs_d}
    # every request crossed the phase boundary exactly once, and both
    # pools drained
    assert dis.handoff.n_handoffs == 9
    assert dis.handoff.bytes_moved > 0
    assert dis.prefill.pool.free_slot_count == 2
    assert dis.decode.pool.free_slot_count == 3


def test_disaggregated_handoff_priced_on_phase_devices(tiny_params):
    reqs = synthetic_workload(4, rate=1e9, vocab=TINY.vocab,
                              prompt_lens=(4,), gen_lens=(4,), seed=3)
    dis = DisaggregatedEngineLoop(
        TINY, tiny_params, n_prefill_slots=2, n_decode_slots=2, max_seq=8,
        prefill_device=dm.K40_ROOFLINE, decode_device=dm.DE5_ROOFLINE)
    dis.run(reqs, now_fn=_virtual_clock())
    assert dis.handoff.n_handoffs == 4
    # cross-device: the ledger carries a nonzero modeled transfer price
    assert dis.handoff.modeled_s > 0
    assert dis.handoff.modeled_s == pytest.approx(
        dis.handoff.bytes_moved
        / min(dm.K40_ROOFLINE.mem_bw, dm.DE5_ROOFLINE.mem_bw))


def test_disaggregated_recycles_slots_and_does_not_leak_ssm_state():
    cfg = T.ModelConfig(
        name="place-rec", n_layers=4, d_model=32, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab=64, block_pattern=("rec", "attn"),
        attention_impl="dot", remat=False)
    params = T.init_params(jax.random.PRNGKey(2), cfg)
    reqs_c = synthetic_workload(6, rate=1e9, vocab=cfg.vocab,
                                prompt_lens=(4,), gen_lens=(4,), seed=21)
    reqs_d = synthetic_workload(6, rate=1e9, vocab=cfg.vocab,
                                prompt_lens=(4,), gen_lens=(4,), seed=21)
    colo = EngineLoop(cfg, params, n_slots=1, max_seq=8)
    colo.run(reqs_c, now_fn=_virtual_clock())
    # 1 slot per phase for 6 requests: both sides recycle, and recurrent
    # state must cross the boundary (and be reset between tenants)
    dis = DisaggregatedEngineLoop(cfg, params, n_prefill_slots=1,
                                  n_decode_slots=1, max_seq=8)
    m = dis.run(reqs_d, now_fn=_virtual_clock())
    assert m.n_done == 6
    assert {r.rid: r.output for r in reqs_c} == \
        {r.rid: r.output for r in reqs_d}


def test_disaggregated_sheds_requests_that_never_fit_decode(tiny_params):
    from repro.serving import Request
    big = Request(rid=0, prompt=np.zeros((30,), np.int32), max_new_tokens=8)
    ok = Request(rid=1, prompt=np.arange(4, dtype=np.int32),
                 max_new_tokens=4)
    dis = DisaggregatedEngineLoop(TINY, tiny_params, n_prefill_slots=2,
                                  n_decode_slots=2, max_seq=16)
    m = dis.run([big, ok], now_fn=_virtual_clock())
    assert m.n_done == 1 and m.n_dropped == 1
    assert big.output == []


def test_per_phase_batchers_budget_independently(tiny_params):
    dis = DisaggregatedEngineLoop(
        TINY, tiny_params, n_prefill_slots=2, n_decode_slots=4, max_seq=16,
        prefill_device=dm.K40_ROOFLINE, decode_device=dm.DE5_ROOFLINE)
    pre, dec = dis.batchers
    assert (pre.phase, dec.phase) == ("prefill", "decode")
    assert pre.device_name == "nvidia-k40-roofline"
    assert dec.device_name == "altera-de5-roofline"
    assert pre.token_budget <= 2 and dec.token_budget <= 4
