"""Unified open-loop driver + streaming output channel.

The contracts this file pins:
  * colocated and disaggregated loops run the SAME driver scaffolding and
    produce bit-identical per-request outputs;
  * streaming (burst-boundary delta emission) does not perturb scheduling —
    outputs, step counts and admission accounting match the completion-pull
    run exactly, and the deltas concatenate to exactly the completion rows;
  * TTFT is honest: ``t_first_token`` is stamped at host visibility, the
    old dispatch-time stamp survives as ``ttft_dispatch``, and
    ``ttft_dispatch <= ttft`` for every observed request;
  * disaggregated pool metrics are capacity-weighted, slot migration
    preserves every cache key (including per-slot cross-attention rows),
    and the batcher's deferred-rid set stays bounded by the live queue.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as T
from repro.serving import (ContinuousBatcher, DisaggregatedEngineLoop,
                           EngineLoop, KVPool, Request, SlotEngine,
                           sample_pools, synthetic_workload)

TINY = T.ModelConfig(
    name="driver-tiny", n_layers=3, d_model=32, n_heads=4, n_kv_heads=2,
    d_ff=64, vocab=64, attention_impl="dot", remat=False)

CROSS = T.ModelConfig(
    name="driver-xattn", n_layers=4, d_model=32, n_heads=4, n_kv_heads=2,
    d_ff=64, vocab=64, cross_attn_every=2, frontend="vision", img_seq=4,
    attention_impl="dot", remat=False)


@pytest.fixture(scope="module")
def tiny_params():
    return T.init_params(jax.random.PRNGKey(0), TINY)


def _virtual_clock():
    t = [0.0]

    def now():
        t[0] += 1e-3
        return t[0]

    return now


def _workload(n=9, seed=11, gen_lens=(1, 3, 6, 12)):
    return synthetic_workload(n, rate=1e9, vocab=TINY.vocab,
                              prompt_lens=(4, 8), gen_lens=gen_lens,
                              seed=seed)


def _collector():
    deltas, events = {}, []

    def on_delta(d):
        deltas.setdefault(d.rid, []).extend(d.tokens)
        events.append(d)

    return deltas, events, on_delta


MAX_LEN = 8 + 12


# ------------------------------------------- streaming == completion pull
def test_streaming_does_not_perturb_scheduling(tiny_params):
    """Outputs, step counts and admission accounting are identical with and
    without the burst-boundary sync — streaming only changes delivery."""
    comp_reqs, strm_reqs = _workload(), _workload()
    comp = EngineLoop(TINY, tiny_params, n_slots=3, max_seq=MAX_LEN)
    m_comp = comp.run(comp_reqs, now_fn=_virtual_clock())
    strm = EngineLoop(TINY, tiny_params, n_slots=3, max_seq=MAX_LEN)
    deltas, events, on_delta = _collector()
    m_strm = strm.run(strm_reqs, now_fn=_virtual_clock(), on_delta=on_delta)

    want = {r.rid: r.output for r in comp_reqs}
    assert {r.rid: r.output for r in strm_reqs} == want
    assert m_strm.n_steps == m_comp.n_steps
    assert m_strm.n_done == m_comp.n_done == 9
    assert m_strm.n_dropped == m_comp.n_dropped == 0
    assert strm.batcher.n_admitted == comp.batcher.n_admitted
    assert strm.batcher.n_deferred == comp.batcher.n_deferred
    # the deltas concatenate to exactly the completion-pull rows
    assert deltas == want
    # every output token was delivered incrementally, and every request
    # got a final done-marked delta
    assert m_strm.tokens_streamed == m_strm.tokens_out
    assert sum(d.done for d in events) == 9
    # completion-pull run streams nothing
    assert m_comp.tokens_streamed == 0 and m_comp.n_stream_deltas == 0


def test_streaming_disaggregated_matches_colocated_completion(tiny_params):
    """The driver contract across both loops: streamed disaggregated
    outputs == completion-pull colocated outputs, token for token."""
    colo_reqs, dis_reqs = _workload(), _workload()
    colo = EngineLoop(TINY, tiny_params, n_slots=3, max_seq=MAX_LEN)
    colo.run(colo_reqs, now_fn=_virtual_clock())
    want = {r.rid: r.output for r in colo_reqs}

    dis = DisaggregatedEngineLoop(TINY, tiny_params, n_prefill_slots=2,
                                  n_decode_slots=3, max_seq=MAX_LEN)
    deltas, events, on_delta = _collector()
    m = dis.run(dis_reqs, now_fn=_virtual_clock(), on_delta=on_delta)
    assert m.n_done == 9
    assert {r.rid: r.output for r in dis_reqs} == want
    assert deltas == want
    assert m.tokens_streamed == m.tokens_out
    assert sum(d.done for d in events) == 9
    for r in dis_reqs:
        assert r.n_streamed == r.max_new_tokens


def test_ttft_is_host_visible_and_dispatch_stamp_precedes(tiny_params):
    for streaming in (False, True):
        reqs = _workload()
        engine = EngineLoop(TINY, tiny_params, n_slots=3, max_seq=MAX_LEN)
        on_delta = (lambda d: None) if streaming else None
        engine.run(reqs, now_fn=_virtual_clock(), on_delta=on_delta)
        for r in reqs:
            assert r.ttft is not None and r.ttft_dispatch is not None
            assert r.ttft_dispatch <= r.ttft, (streaming, r.rid)
        if streaming:
            # burst-boundary delivery: multi-token requests see their first
            # token strictly before completion
            assert any(r.t_first_token < r.t_done for r in reqs
                       if r.max_new_tokens > 1)
        else:
            # completion pull: the first token becomes host-visible with
            # the last, so honest TTFT == request latency
            assert all(r.t_first_token == r.t_done for r in reqs)


def test_ttft_dispatch_precedes_ttft_disaggregated(tiny_params):
    reqs = _workload()
    dis = DisaggregatedEngineLoop(TINY, tiny_params, n_prefill_slots=2,
                                  n_decode_slots=3, max_seq=MAX_LEN)
    m = dis.run(reqs, now_fn=_virtual_clock(), on_delta=lambda d: None)
    assert m.n_done == 9
    for r in reqs:
        assert r.ttft_dispatch is not None and r.ttft_dispatch <= r.ttft
    assert len(m.ttft_dispatch_s) == len(m.ttft_s) == 9
    s = m.summary()
    assert s["ttft_dispatch_p50_s"] <= s["ttft_p50_s"]
    assert s["tokens_streamed"] == s["tokens_out"]


def test_streaming_metrics_summary_keys(tiny_params):
    reqs = _workload(n=3, gen_lens=(4,))
    engine = EngineLoop(TINY, tiny_params, n_slots=2, max_seq=MAX_LEN)
    m = engine.run(reqs, now_fn=_virtual_clock(), on_delta=lambda d: None)
    s = m.summary()
    for k in ("tokens_streamed", "stream_deltas", "ttft_dispatch_p50_s",
              "ttft_dispatch_p99_s"):
        assert k in s
    assert s["stream_deltas"] == m.n_stream_deltas > 0


# ------------------------------------------------- weighted pool metrics
def test_sample_pools_weights_by_capacity():
    a = KVPool(n_slots=2, max_seq=32, block_size=16)      # 4 blocks total
    b = KVPool(n_slots=4, max_seq=64, block_size=16)      # 16 blocks total
    a.alloc(1, 32)                                        # 2 blocks
    a.note_write(1, 16)
    b.alloc(2, 48)                                        # 3 blocks
    b.note_write(2, 6)
    occ, util = sample_pools((a, b))
    # occupancy weighted by total_blocks: (2 + 3) / (4 + 16)
    assert occ == pytest.approx(5 / 20)
    # utilization weighted by allocated-block capacity: (16 + 6) / (32 + 48)
    assert util == pytest.approx(22 / 80)
    # the unweighted means the old loop reported are different numbers
    assert occ != pytest.approx((a.occupancy() + b.occupancy()) / 2)
    assert util != pytest.approx((a.utilization() + b.utilization()) / 2)
    # one pool degenerates to the pool's own accounting
    assert sample_pools((a,)) == (a.occupancy(), a.utilization())


def test_disaggregated_loop_samples_weighted_pools(tiny_params):
    from repro.serving import ServeMetrics
    dis = DisaggregatedEngineLoop(TINY, tiny_params, n_prefill_slots=1,
                                  n_decode_slots=4, max_seq=16)
    dis.prefill.pool.alloc(0, 16)
    dis.prefill.pool.note_write(0, 8)
    dis.decode.pool.alloc(1, 8)
    m = ServeMetrics()
    dis.sample(m)
    occ, util = sample_pools((dis.prefill.pool, dis.decode.pool))
    assert m.occupancy == [occ] and m.utilization == [util]
    dis.prefill.pool.free(0)
    dis.decode.pool.free(1)


# ------------------------------------------------- slot migration fixes
def test_import_slot_preserves_unknown_cache_keys():
    # regression: import_slot used to rebuild the cache as a literal
    # {"layers", "pos", "cross"} dict, silently dropping any other key
    # init_slot_cache (or a future model) carries
    pool_a = KVPool(n_slots=2, max_seq=8)
    pool_b = KVPool(n_slots=2, max_seq=8)
    src = SlotEngine(TINY, None, pool_a)
    dst = SlotEngine(TINY, None, pool_b)
    dst.cache["extra"] = jnp.arange(3)
    state = src.export_slot(0)
    dst.import_slot(1, state)
    assert "extra" in dst.cache
    assert np.array_equal(np.asarray(dst.cache["extra"]), [0, 1, 2])


def test_export_import_migrates_cross_rows():
    # regression: per-slot cross-attention state was shared (the importing
    # engine kept its own rows) rather than migrated with the slot
    pool_a = KVPool(n_slots=2, max_seq=8)
    pool_b = KVPool(n_slots=3, max_seq=8)
    src = SlotEngine(CROSS, None, pool_a)
    dst = SlotEngine(CROSS, None, pool_b)
    assert src.cache["cross"] is not None
    src.cache["cross"] = src.cache["cross"].at[1].set(7.0)
    state = src.export_slot(1)
    assert state["cross"] is not None
    dst.import_slot(0, state)
    got = np.asarray(dst.cache["cross"])
    assert np.all(got[0] == 7.0)                  # migrated row installed
    assert np.all(got[1:] == 0.0)                 # other slots untouched
    # hand-off payload accounting covers the cross row
    assert SlotEngine.state_nbytes(state) > SlotEngine.state_nbytes(
        {k: v for k, v in state.items() if k != "cross"})


def test_import_slot_rejects_cross_config_mismatch_both_ways():
    pool = KVPool(n_slots=2, max_seq=8)
    src = SlotEngine(CROSS, None, pool)
    state = src.export_slot(0)
    state["cross"] = None
    dst = SlotEngine(CROSS, None, KVPool(n_slots=2, max_seq=8))
    with pytest.raises(ValueError, match="cross"):
        dst.import_slot(0, state)
    # inverse direction: a cross row must not be silently discarded by an
    # engine whose cache has no cross entry
    state2 = src.export_slot(0)
    assert state2["cross"] is not None
    plain = SlotEngine(TINY, None, KVPool(n_slots=2, max_seq=8))
    # the guard fires before any layer-tree op, so the mismatch surfaces
    # as this error rather than a tree-structure traceback
    with pytest.raises(ValueError, match="cross"):
        plain.import_slot(0, state2)


def test_disaggregated_cross_config_bit_identical_to_colocated():
    # end-to-end regression for the cross-cache migration: a
    # cross_attn_every > 0 config crosses the phase boundary and still
    # matches colocated outputs token for token
    params = T.init_params(jax.random.PRNGKey(1), CROSS)
    reqs_c = synthetic_workload(4, rate=1e9, vocab=CROSS.vocab,
                                prompt_lens=(4,), gen_lens=(4,), seed=3)
    reqs_d = synthetic_workload(4, rate=1e9, vocab=CROSS.vocab,
                                prompt_lens=(4,), gen_lens=(4,), seed=3)
    colo = EngineLoop(CROSS, params, n_slots=2, max_seq=8)
    colo.run(reqs_c, now_fn=_virtual_clock())
    dis = DisaggregatedEngineLoop(CROSS, params, n_prefill_slots=1,
                                  n_decode_slots=2, max_seq=8)
    m = dis.run(reqs_d, now_fn=_virtual_clock())
    assert m.n_done == 4
    assert {r.rid: r.output for r in reqs_c} == \
        {r.rid: r.output for r in reqs_d}


# ------------------------------------------------- bounded deferred set
def test_deferred_set_bounded_and_counter_monotone():
    pool = KVPool(n_slots=4, max_seq=32)
    b = ContinuousBatcher(TINY, pool, token_budget=1)
    queue = [Request(rid=i, prompt=np.zeros((4,), np.int32),
                     max_new_tokens=4) for i in range(4)]
    b.admit(queue, n_active=0, now=0.0)          # admits rid 0, defers 1-3
    assert b.n_deferred == 3
    assert len(b._deferred_rids) == len(queue) == 3
    b.admit(queue, n_active=0, now=0.0)          # admits rid 1, defers 2-3
    assert b.n_deferred == 3                     # monotone: no recount
    # admitted rids leave the set: bounded by the live queue, not by the
    # total requests the server has ever seen
    assert len(b._deferred_rids) == len(queue) == 2
    while queue:
        b.admit(queue, n_active=0, now=0.0)
    assert not b._deferred_rids                  # drained queue, empty set
    assert b.n_deferred == 3                     # history preserved


def test_deferred_set_drops_dropped_and_shed_requests():
    pool = KVPool(n_slots=2, max_seq=32)
    b = ContinuousBatcher(TINY, pool, token_budget=1)
    q = [Request(rid=0, prompt=np.zeros((4,), np.int32), max_new_tokens=4),
         Request(rid=1, prompt=np.zeros((4,), np.int32), max_new_tokens=4,
                 deadline=1.0)]
    b.admit(q, n_active=1, now=0.0)              # budget full: both defer
    assert b.n_deferred == 2 and len(b._deferred_rids) == 2
    b.admit(q, n_active=0, now=5.0)              # rid 1 expired -> dropped
    assert len(b._deferred_rids) == 0            # admitted + dropped leave
    assert b.n_deferred == 2
    # out-of-band shedding (the disaggregated loop's pre-admission check)
    b2 = ContinuousBatcher(TINY, pool, token_budget=1)
    q2 = [Request(rid=7, prompt=np.zeros((20,), np.int32),
                  max_new_tokens=8),
          Request(rid=8, prompt=np.zeros((4,), np.int32), max_new_tokens=4)]
    b2.admit(q2, n_active=1, now=0.0)
    assert 7 in b2._deferred_rids
    b2.note_resolved(7)                          # shed outside admit()
    assert 7 not in b2._deferred_rids
    assert b2.n_deferred == 2


def test_disaggregated_shed_does_not_leak_deferred_rids(tiny_params):
    # a request too big for the decode pool defers once (budget pressure)
    # then gets shed before admission: its rid must leave the batcher's set
    big = Request(rid=0, prompt=np.zeros((30,), np.int32), max_new_tokens=8)
    ok = [Request(rid=1 + i, prompt=np.arange(4, dtype=np.int32),
                  max_new_tokens=4) for i in range(3)]
    dis = DisaggregatedEngineLoop(TINY, tiny_params, n_prefill_slots=1,
                                  n_decode_slots=2, max_seq=16)
    m = dis.run([big] + ok, now_fn=_virtual_clock())
    assert m.n_done == 3 and m.n_dropped == 1
    assert not dis.prefill_batcher._deferred_rids
