"""Prefix sharing with copy-on-write KV pages: index publication rules,
hash-collision safety, COW placement at the first divergent token,
refcount lifetimes across donor/sharer frees, bitwise shared-vs-unshared
engine identity, and the regression-gate schema for the bench's
``prefix`` section."""
import jax
import numpy as np
import pytest

from benchmarks import check_regression as cr
from repro.models import transformer as T
from repro.serving import KVPool, Request, SlotEngine

TINY = T.ModelConfig(
    name="prefix-tiny", n_layers=3, d_model=32, n_heads=4, n_kv_heads=2,
    d_ff=64, vocab=64, attention_impl="dot", remat=False)

MAX_LEN = 32
BS = 8


@pytest.fixture(scope="module")
def tiny_params():
    return T.init_params(jax.random.PRNGKey(0), TINY)


def _pool(**kw):
    kw.setdefault("prefix_sharing", True)
    return KVPool(n_slots=4, max_seq=MAX_LEN, block_size=BS, **kw)


# ------------------------------------------------------- index publication
def test_publication_tracks_written_full_prompt_blocks():
    """A block enters the index only once every one of its positions is
    both inside the prompt and actually written — sharing can never serve
    KV that does not exist yet."""
    pool = _pool()
    donor = tuple(range(24))             # 3 full blocks
    pool.alloc(1, 28, prompt=donor)
    probe = donor + (60, 61)             # longer twin, so no plen-1 cap
    assert pool.shared_prefix_tokens(probe) == 0     # nothing written
    pool.note_write(1, BS - 1)
    assert pool.shared_prefix_tokens(probe) == 0     # block 0 not full
    pool.note_write(1, 1)
    assert pool.shared_prefix_tokens(probe) == BS    # block 0 published
    pool.note_write(1, 16)
    assert pool.shared_prefix_tokens(probe) == 24    # all prompt blocks
    # the donor's own prompt is capped at plen-1: the engine must feed the
    # last prompt token to produce the first sample
    assert pool.shared_prefix_tokens(donor) == 23


def test_generated_tokens_are_never_published():
    """Blocks past the prompt hold sampled KV, not prompt KV — they must
    never enter the index even once fully written."""
    pool = _pool()
    donor = tuple(range(8))              # exactly 1 block of prompt
    pool.alloc(1, 24, prompt=donor)
    pool.note_write(1, 24)               # prompt + 16 generated tokens
    probe = donor + tuple(range(8, 24))
    assert pool.shared_prefix_tokens(probe) == BS    # prompt block only


def test_hash_collision_misses_never_false_shares():
    """With every chain key colliding, lookups still verify parent + the
    full token tuple — a different prompt shares nothing, an identical
    one still shares."""
    pool = _pool(prefix_hash=lambda parent, tokens: 7)
    donor = tuple(range(16))
    pool.alloc(1, 20, prompt=donor)
    pool.note_write(1, 16)
    assert len(pool._prefix_index) == 1              # one bucket, key 7
    assert len(pool._prefix_index[7]) == 2           # both depths collide
    other = tuple(range(30, 46))         # differs from token 0 on
    assert pool.shared_prefix_tokens(other) == 0
    twin = donor + (50, 51)
    assert pool.shared_prefix_tokens(twin) == 16
    slot = pool.alloc(2, 20, prompt=twin)
    assert slot != pool.lease(1).slot
    assert pool.lease(2).shared_tokens == 16
    assert pool.lease(2).blocks[:2] == pool.lease(1).blocks[:2]


# ------------------------------------------------------------ COW placement
@pytest.mark.parametrize("divergence", [BS * 2 - 1, BS * 2, BS * 2 + 1])
def test_cow_triggered_exactly_at_first_divergent_token(divergence):
    """A sharer diverging at token d shares exactly d tokens; a COW page
    copy is scheduled iff d falls mid-block, sourced from the donor's page
    holding position d into the sharer's own fresh page."""
    pool = _pool()
    donor = tuple(range(24))
    pool.alloc(1, 28, prompt=donor)
    pool.note_write(1, 24)
    sharer = donor[:divergence] + tuple(
        55 + i for i in range(4))        # diverges exactly at `divergence`
    pool.alloc(2, len(sharer) + 4, prompt=sharer)
    lease = pool.lease(2)
    assert lease.shared_tokens == divergence
    ops = pool.consume_cow(2)
    if divergence % BS == 0:
        assert ops == []                 # boundary divergence: no hazard
    else:
        src_block = pool.lease(1).blocks[divergence // BS]
        dst_block = lease.blocks[divergence // BS]
        assert ops == [(src_block, dst_block)]
        assert dst_block not in pool.lease(1).blocks  # private copy
    pool.free(2)


def test_unconsumed_cow_source_ref_released_on_free():
    """free() drops the pending COW source's extra ref, so an admitted-
    then-cancelled sharer cannot leak the donor's page."""
    pool = _pool()
    donor = tuple(range(24))
    pool.alloc(1, 28, prompt=donor)
    pool.note_write(1, 24)
    sharer = donor[:20] + (60, 61, 62, 63)
    pool.alloc(2, 28, prompt=sharer)
    src = pool.lease(1).blocks[2]
    assert pool._block_refs[src] == 2    # donor + pending COW ref
    pool.free(2)                         # COW never consumed
    assert pool._block_refs[src] == 1
    assert pool.free_block_count + len(pool._block_refs) == pool.total_blocks


# -------------------------------------------------------- refcount lifetime
def test_shared_blocks_survive_donor_free():
    """Refcounts, not ownership, decide a block's lifetime: the donor
    freeing first leaves the shared pages (and their index entries) alive
    for the sharer; the last holder freeing evicts and recycles them."""
    pool = _pool()
    donor = tuple(range(16))
    pool.alloc(1, 24, prompt=donor)
    pool.note_write(1, 16)
    twin = donor + (40, 41, 42, 43)
    pool.alloc(2, 24, prompt=twin)
    shared = pool.lease(2).blocks[:2]
    pool.free(1)
    assert all(pool._block_refs[b] == 1 for b in shared)
    late = donor + (50, 51)              # donor gone, index still serves
    assert pool.shared_prefix_tokens(late) == 16
    pool.free(2)
    assert pool._block_refs == {}
    assert pool._prefix_index == {}
    assert pool.free_block_count == pool.total_blocks


# --------------------------------------------- engine-level bitwise identity
def _serve_one(eng, pool, rid, prompt, gen, *, sharing):
    """Admit + bind + run one request to completion on a SlotEngine,
    leaving its lease alive (so its published pages stay indexed) but its
    slot inactive.  Returns the greedy output tokens."""
    req = Request(rid=rid, prompt=np.asarray(prompt, np.int32),
                  max_new_tokens=gen)
    total = req.prompt_len + gen
    req.slot = pool.alloc(rid, total,
                          prompt=tuple(prompt) if sharing else None)
    shared = pool.shared_tokens(rid) if sharing else 0
    eng.bind(req, steps_total=req.prompt_len - shared + gen - 1,
             start_pos=shared)
    s = req.slot
    while eng.steps_done[s] < eng.steps_total[s]:
        eng.dispatch(1, eng.active)
    eng.active[s] = False                # keep the lease (and the index)
    return eng.pull_output(s)[:gen].tolist(), shared


def test_shared_vs_unshared_bitwise_identical_with_cow_tail(tiny_params):
    """The gated correctness claim, at engine level: a sharer mapping 2
    full blocks + a 4-token COW tail onto a live donor's pages decodes
    exactly the tokens it produces with sharing off — the shared pages
    hold bit-identical KV to what the sharer would have written itself."""
    rng = np.random.default_rng(5)
    donor_p = rng.integers(0, TINY.vocab, size=(24,))
    sharer_p = np.concatenate([donor_p[:20],
                               rng.integers(0, TINY.vocab, size=(4,))])

    ref = {}
    for rid, (p, g) in enumerate([(donor_p, 4), (sharer_p, 4)]):
        pool = KVPool(n_slots=2, max_seq=MAX_LEN, block_size=BS)
        eng = SlotEngine(TINY, tiny_params, pool, kv_layout="paged")
        ref[rid], _ = _serve_one(eng, pool, rid, p, g, sharing=False)

    pool = _pool()
    eng = SlotEngine(TINY, tiny_params, pool, kv_layout="paged")
    out_donor, shared_d = _serve_one(eng, pool, 0, donor_p, 4, sharing=True)
    out_sharer, shared_s = _serve_one(eng, pool, 1, sharer_p, 4,
                                      sharing=True)
    assert shared_d == 0                 # empty index at donor admission
    assert shared_s == 20                # 2 full blocks + 4-token COW tail
    assert pool.cow_copies == 1
    assert pool.tokens_prefill_skipped == 20
    assert out_donor == ref[0]
    assert out_sharer == ref[1]
    # the sharer's first two logical pages ARE the donor's physical pages
    assert pool.lease(1).blocks[:2] == pool.lease(0).blocks[:2]
    assert pool.lease(1).blocks[2] != pool.lease(0).blocks[2]


def test_shared_vs_unshared_identical_at_block_boundary(tiny_params):
    """Same contract when the divergence lands exactly on a block
    boundary: full-block sharing only, no COW copy at all."""
    rng = np.random.default_rng(9)
    donor_p = rng.integers(0, TINY.vocab, size=(24,))
    sharer_p = np.concatenate([donor_p[:16],
                               rng.integers(0, TINY.vocab, size=(6,))])

    pool_ref = KVPool(n_slots=2, max_seq=MAX_LEN, block_size=BS)
    eng_ref = SlotEngine(TINY, tiny_params, pool_ref, kv_layout="paged")
    ref, _ = _serve_one(eng_ref, pool_ref, 0, sharer_p, 5, sharing=False)

    pool = _pool()
    eng = SlotEngine(TINY, tiny_params, pool, kv_layout="paged")
    _serve_one(eng, pool, 0, donor_p, 4, sharing=True)
    out, shared = _serve_one(eng, pool, 1, sharer_p, 5, sharing=True)
    assert shared == 16 and pool.cow_copies == 0
    assert out == ref


# ------------------------------------------------- regression-gate schema
def _good_prefix_section():
    summ = {"tok_per_s": 100.0, "ttft_p50_s": 0.01, "tokens_out": 10,
            "requests_done": 2}

    def entry(ratio):
        return {
            "unshared": dict(summ), "shared": dict(summ),
            "peak_slots_unshared": 8, "peak_slots_shared": int(8 * ratio),
            "admitted_slots_ratio": ratio, "ttft_p50_ratio": ratio,
            "tok_per_s_ratio": 1.1, "prefix_hits": 12,
            "tokens_prefill_skipped": 500, "cow_copies": 1,
            "bit_identical": True,
        }

    return {
        "block_size": 16, "blocks_per_slot": 5, "n_slots": 16,
        "total_blocks": 40, "dense_equivalent_slots": 8,
        "shared_prefix_len": 48, "n_requests": 32,
        "shared_frac_50": entry(1.25), "shared_frac_90": entry(1.75),
        "all_identical": True,
    }


def test_validate_prefix_accepts_well_formed_section():
    checks = cr.validate_prefix({"prefix": _good_prefix_section()})
    assert checks and all(ok for _, ok, _ in checks)


@pytest.mark.parametrize("mutate,name", [
    (lambda s: s.clear(), "prefix section schema"),
    (lambda s: s.pop("shared_frac_90"), "prefix section schema"),
    (lambda s: s["shared_frac_50"].pop("unshared"),
     "prefix section schema"),
    (lambda s: s["shared_frac_90"].update(admitted_slots_ratio=None),
     "prefix section schema"),
    (lambda s: (s["shared_frac_90"].update(bit_identical=False),
                s.update(all_identical=False)),
     "shared outputs bit-identical to unshared"),
    (lambda s: s["shared_frac_90"].update(prefix_hits=0),
     "prefix cache actually shared pages"),
    (lambda s: s["shared_frac_90"].update(admitted_slots_ratio=1.0,
                                          ttft_p50_ratio=1.0),
     "prefix sharing capacity win"),
])
def test_validate_prefix_fails_malformed_or_regressed(mutate, name):
    section = _good_prefix_section()
    mutate(section)
    checks = cr.validate_prefix({"prefix": section})
    failed = [n for n, ok, _ in checks if not ok]
    assert any(name in n for n in failed), (failed, name)


def test_validate_prefix_missing_section_fails():
    checks = cr.validate_prefix({})
    assert len(checks) == 1
    name, ok, _ = checks[0]
    assert name == "prefix section present" and not ok
