"""Profiling runtime: cache round-trip + environment invalidation,
calibrator error reduction, and measured-pricing scheduler agreement."""
import json

import jax
import jax.numpy as jnp
import pytest

from repro.core import engines as engines_lib
from repro.core import scheduler
from repro.core.cost_model import layer_cost
from repro.core.layer_model import FCSpec
from repro.core.plan import compile_plan, init_network_params
from repro.launch.profile import tiny_net
from repro.models import transformer as T
from repro.profiling import (CalibratedDeviceModel, MeasuredPricer,
                             Measurement, ProfileCache,
                             analytic_predicted_time, calibrate_engine,
                             calibration_report, environment, fingerprint,
                             profile_network, time_layer, validate_dict)
from repro.serving import ContinuousBatcher, KVPool, step_time_model

XLA = engines_lib.XLA_ENGINE
TINY_FC = FCSpec("TFC", m_i=(8, 8, 8), k_o=16)


def _measurement(spec, engine, t_median, *, batch=1, env=None):
    env = env or environment()
    return Measurement(
        layer=spec.name, kind=spec.kind, engine=engine, batch=batch,
        dtype="float32", repeats=3, t_median=t_median, t_iqr=t_median * 0.1,
        t_min=t_median * 0.9, t_mean=t_median, flops=spec.flops(batch),
        fingerprint=fingerprint(spec, batch, "float32"),
        jax_version=env["jax_version"], backend=env["backend"])


# ------------------------------------------------------------ fingerprint
def test_fingerprint_stable_and_sensitive():
    a = fingerprint(TINY_FC, 1, "float32")
    assert a == fingerprint(FCSpec("TFC", m_i=(8, 8, 8), k_o=16), 1,
                            "float32")
    assert a != fingerprint(FCSpec("TFC", m_i=(8, 8, 8), k_o=32), 1,
                            "float32")
    assert a != fingerprint(TINY_FC, 2, "float32")
    assert a != fingerprint(TINY_FC, 1, "bfloat16")


# ------------------------------------------------------------------ cache
def test_cache_roundtrip(tmp_path):
    path = str(tmp_path / "cache.json")
    cache = ProfileCache(path)
    m = _measurement(TINY_FC, "xla", 1e-3)
    cache.put(m)
    cache.save()
    loaded = ProfileCache.load(path)
    hit = loaded.get(TINY_FC, "xla")
    assert hit is not None
    assert Measurement.from_dict(hit) == m
    assert validate_dict(json.load(open(path))) == []


def test_cache_invalidation_on_jax_version_change(tmp_path):
    path = str(tmp_path / "cache.json")
    cache = ProfileCache(path)
    stale_env = {"jax_version": "0.0.1", "backend": environment()["backend"]}
    cache.put(_measurement(TINY_FC, "xla", 1e-3, env=stale_env))
    cache.save()
    loaded = ProfileCache.load(path)
    assert len(loaded) == 1
    # lookups are environment-scoped: the stale entry is invisible ...
    assert loaded.get(TINY_FC, "xla") is None
    assert loaded.measurements() == []
    # ... and invalidate_stale garbage-collects it
    assert loaded.invalidate_stale() == 1
    assert len(loaded) == 0


def _run_validate(*args):
    import os
    import subprocess
    import sys
    src = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.profiling.cache", "--validate", *args],
        capture_output=True, text=True, env=env)


def test_cache_validate_cli_agrees_with_lookups(tmp_path):
    """Regression: `--validate` used to exit 0 on caches no lookup could
    use (schema-valid but empty, or entirely stale) while serve
    --calibrated-cache then failed — the gate and the consumers must
    agree on what a usable cache is."""
    # missing file: a clean failure, not a traceback
    r = _run_validate(str(tmp_path / "nope.json"))
    assert r.returncode == 1
    assert "no such file" in (r.stdout + r.stderr)
    # schema-valid but zero entries: lookups would find nothing -> fail ...
    empty = tmp_path / "empty.json"
    empty.write_text(json.dumps({"schema": 1, "entries": {}}))
    r = _run_validate(str(empty))
    assert r.returncode == 1
    assert "no usable entries" in (r.stdout + r.stderr)
    # ... unless explicitly allowed
    assert _run_validate(str(empty), "--allow-empty").returncode == 0
    # entries from another environment are equally unusable here
    stale = tmp_path / "stale.json"
    cache = ProfileCache(str(stale))
    cache.put(_measurement(TINY_FC, "xla", 1e-3,
                           env={"jax_version": "0.0.1", "backend": "tpu"}))
    cache.save()
    r = _run_validate(str(stale))
    assert r.returncode == 1
    # a cache with a current-environment measurement passes
    good = tmp_path / "good.json"
    cache = ProfileCache(str(good))
    cache.put(_measurement(TINY_FC, "xla", 1e-3))
    cache.save()
    assert _run_validate(str(good)).returncode == 0


def test_cache_merge_and_invalidate(tmp_path):
    a, b = ProfileCache(), ProfileCache()
    a.put(_measurement(TINY_FC, "xla", 1e-3))
    b.put(_measurement(TINY_FC, "pallas", 2e-3))
    b.put(_measurement(TINY_FC, "xla", 5e-3))      # collision: b wins
    assert a.merge(b) == 2
    assert len(a) == 2
    assert a.get(TINY_FC, "xla")["t_median"] == 5e-3
    assert a.invalidate(engine="pallas") == 1
    assert a.invalidate() == 1                     # drop everything


def test_cache_schema_validation_catches_corruption():
    assert validate_dict([]) != []
    assert validate_dict({"schema": 99, "entries": {}}) != []
    m = _measurement(TINY_FC, "xla", 1e-3).to_dict()
    good = {"schema": 1, "entries": {}}
    cache = ProfileCache()
    cache.put(Measurement.from_dict(m))
    good["entries"] = cache.entries
    assert validate_dict(good) == []
    bad = json.loads(json.dumps(good))
    next(iter(bad["entries"].values())).pop("t_median")
    assert validate_dict(bad) != []
    neg = json.loads(json.dumps(good))
    next(iter(neg["entries"].values()))["t_median"] = -1.0
    assert validate_dict(neg) != []


# ------------------------------------------------------------- harness
def test_time_layer_smoke():
    m = time_layer(XLA, TINY_FC, warmup=1, repeats=3)
    assert m.engine == "xla" and m.kind == "fc" and m.repeats == 3
    assert m.t_median > 0 and m.t_min <= m.t_median
    assert m.flops == TINY_FC.flops(1)
    assert m.achieved_flops > 0
    assert m.jax_version == jax.__version__


def test_time_layer_rejects_cost_only_engine():
    with pytest.raises(ValueError, match="cost-only"):
        time_layer(engines_lib.K40_ENGINE, TINY_FC)


def test_profile_network_uses_cache(tmp_path):
    net = tiny_net()
    cache = ProfileCache(str(tmp_path / "c.json"))
    first = profile_network(net, [XLA], warmup=1, repeats=2, cache=cache)
    assert len(first) == len(net)
    # second pass must be pure cache: measure_on_miss=False still returns all
    second = profile_network(net, [XLA], cache=cache, measure_on_miss=False)
    assert second == first


# ------------------------------------------------------------ calibrator
def test_calibrator_reduces_error_on_synthetic_timings():
    net = tiny_net()
    # synthetic ground truth: each kind runs at a constant achieved rate
    # very different from the analytic model's belief
    rates = {"conv": 3e9, "fc": 1e9}
    ms = [_measurement(s, "xla", s.flops(1) / rates[s.kind])
          for s in net]
    rep = calibration_report(XLA, list(net), ms)
    assert rep.calibrated_mape < rep.analytic_mape
    assert rep.calibrated_mape < 1e-9        # exact on rate-constant data
    for kind, fitted in rep.model.throughput.items():
        assert fitted == pytest.approx(rates[kind])


def test_calibrated_model_drops_into_cost_model():
    ms = [_measurement(TINY_FC, "xla", 1e-3)]
    model = calibrate_engine(XLA, ms)
    assert isinstance(model, CalibratedDeviceModel) and not model.analytic
    cost = layer_cost(TINY_FC, model)
    assert cost.t_total == pytest.approx(1e-3)
    # unmeasured kinds fall back to the engine's nominal efficiency, not
    # raw peak (an under-profiled cache must not look infinitely fast)
    assert model.achieved_flops("conv") == pytest.approx(
        XLA.efficiency * XLA.device.peak_flops)


def test_calibrated_fallback_keeps_roofline_memory_term():
    """Unmeasured kinds on a calibrated model price with the FULL roofline
    (memory term included), not compute-only optimism — otherwise serving
    admission on memory-bound decode would blow its SLO."""
    from repro.core.layer_model import AttentionSpec
    model = calibrate_engine(XLA, [_measurement(TINY_FC, "xla", 1e-3)])
    attn = AttentionSpec("attn", d_model=256, n_heads=4, n_kv_heads=2,
                         seq=1, kv_len=2048)
    assert model.analytic_for("attention") and not model.analytic_for("fc")
    cal = layer_cost(attn, model, dtype_bytes=2)
    nominal = layer_cost(attn, XLA.device, dtype_bytes=2)
    assert cal.t_memory == pytest.approx(nominal.t_memory)
    assert cal.t_total >= nominal.t_total       # efficiency <= 1 only slows


def test_calibrate_engine_registers_in_device_registry():
    from repro.core import device_models as dm
    model = calibrate_engine(XLA, [_measurement(TINY_FC, "xla", 1e-3)],
                             register=True)
    try:
        assert dm.get(model.name) is model
    finally:
        dm.REGISTRY.pop(model.name, None)


# ------------------------------------------------- measured-price scheduling
def test_measured_plan_agrees_with_analytic_when_measurements_match():
    """price="measured" with a cache whose timings equal the analytic
    model's predictions must reproduce the analytic plan exactly."""
    net = tiny_net()
    cache = ProfileCache()
    for eng in engines_lib.DEFAULT_ENGINES:
        for spec in net:
            cache.put(_measurement(
                spec, eng.name, analytic_predicted_time(spec, eng)))
    pricer = MeasuredPricer(cache, measure_on_miss=False, autosave=False)
    plan_a = scheduler.schedule(net, engines_lib.DEFAULT_ENGINES)
    plan_m = scheduler.schedule(net, engines_lib.DEFAULT_ENGINES,
                                price="measured", pricer=pricer)
    assert plan_a.pricing == "analytic" and plan_m.pricing == "measured"
    assert [a.engine for a in plan_a.assignments] == \
        [a.engine for a in plan_m.assignments]
    for a, b in zip(plan_a.assignments, plan_m.assignments):
        assert b.cost.t_total == pytest.approx(a.cost.t_total)
    assert pricer.hits == len(net) * len(engines_lib.DEFAULT_ENGINES)


def test_schedule_rejects_unknown_price():
    with pytest.raises(ValueError, match="pricing"):
        scheduler.schedule(tiny_net(), engines_lib.DEFAULT_ENGINES,
                           price="vibes")


def test_measured_pricer_measures_on_miss_and_persists(tmp_path):
    path = str(tmp_path / "c.json")
    pricer = MeasuredPricer(ProfileCache(path), warmup=1, repeats=2)
    cost = pricer.price(TINY_FC, XLA)
    assert cost is not None and cost.t_total > 0
    assert (pricer.hits, pricer.misses) == (0, 1)
    assert ProfileCache.load(path).get(TINY_FC, "xla") is not None
    pricer.price(TINY_FC, XLA)
    assert (pricer.hits, pricer.misses) == (1, 1)
    # unmeasurable requests decline -> scheduler falls back to analytic
    assert pricer.price(TINY_FC, XLA, direction="bwd") is None
    assert pricer.price(TINY_FC, XLA, n_chips=2) is None
    assert pricer.price(TINY_FC, engines_lib.K40_ENGINE) is None


def test_plan_records_operating_point_and_reprice_preserves_it():
    from repro.core.plan import reprice_plan
    net = tiny_net()
    plan = scheduler.schedule(net, engines_lib.DEFAULT_ENGINES, batch=3)
    assert (plan.batch, plan.dtype_bytes) == (3, 4)
    cache = ProfileCache()
    for eng in engines_lib.DEFAULT_ENGINES:
        for spec in net:
            cache.put(_measurement(spec, eng.name, 1e-3, batch=3))
    pricer = MeasuredPricer(cache, measure_on_miss=False, autosave=False)
    replan = reprice_plan(plan, pricer=pricer)
    assert (replan.batch, replan.dtype_bytes) == (3, 4)
    assert pricer.hits > 0                       # priced at the plan's batch


def test_reprice_reconsiders_all_buildable_engines():
    """An analytic plan that collapsed onto one engine can still move when
    measurements say another buildable engine is faster."""
    net = tiny_net()
    plan = scheduler.schedule(net, [engines_lib.XLA_ENGINE])
    assert {a.engine for a in plan.assignments} == {"xla"}
    cache = ProfileCache()
    for spec in net:                             # pallas measures 10x faster
        cache.put(_measurement(spec, "xla", 1e-2))
        cache.put(_measurement(spec, "pallas", 1e-3))
    pricer = MeasuredPricer(cache, measure_on_miss=False, autosave=False)
    fn = compile_plan(plan, price="measured", pricer=pricer)
    assert {a.engine for a in fn.plan.assignments} == {"pallas"}


def test_pricer_derives_dtype_from_dtype_bytes():
    cache = ProfileCache()
    cache.put(_measurement(TINY_FC, "xla", 1e-3))      # float32 measurement
    pricer = MeasuredPricer(cache, measure_on_miss=False, autosave=False)
    assert pricer.price(TINY_FC, XLA, dtype_bytes=4) is not None
    # a bf16-priced schedule must not be fed float32 timings
    assert pricer.price(TINY_FC, XLA, dtype_bytes=2) is None
    assert pricer.price(TINY_FC, XLA, dtype_bytes=3) is None


def test_compile_plan_measured_end_to_end(tmp_path):
    net = tiny_net()
    pricer = MeasuredPricer(ProfileCache(str(tmp_path / "c.json")),
                            warmup=1, repeats=2)
    plan = scheduler.schedule(net, engines_lib.DEFAULT_ENGINES)
    fn = compile_plan(plan, price="measured", pricer=pricer)
    assert fn.plan.pricing == "measured"
    params = init_network_params(net, jax.random.PRNGKey(0))
    y = fn(jnp.ones((1, 8, 8, 3)), params)
    assert y.shape == (1, 16)
    assert bool(jnp.all(jnp.isfinite(y)))
    # already-measured plans are not re-priced
    fn2 = compile_plan(fn.plan, price="measured", pricer=pricer)
    assert fn2.plan is fn.plan


# --------------------------------------------- calibrated admission pricing
def test_batcher_prices_admission_on_calibrated_model():
    cfg = T.ModelConfig(
        name="prof-tiny", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab=64, attention_impl="dot", remat=False)
    model = calibrate_engine(XLA, [_measurement(TINY_FC, "xla", 1e-3)])
    nominal = step_time_model(cfg, 64, 4)
    calibrated = step_time_model(cfg, 64, 4, device=model)
    assert nominal > 0 and calibrated > 0
    pool = KVPool(n_slots=4, max_seq=64)
    b = ContinuousBatcher(cfg, pool, device_model=model, step_slo_s=10.0)
    assert b.device_name == model.name
    assert 1 <= b.token_budget <= 4
    assert (b.n_admitted, b.n_rejected, b.n_deferred) == (0, 0, 0)


def test_deferred_counts_unique_requests():
    from repro.serving import Request
    cfg = T.ModelConfig(
        name="prof-tiny2", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab=64, attention_impl="dot", remat=False)
    pool = KVPool(n_slots=2, max_seq=32)
    b = ContinuousBatcher(cfg, pool, token_budget=1)
    import numpy as np
    queue = [Request(rid=i, prompt=np.array([1], np.int32), max_new_tokens=4)
             for i in range(3)]
    b.admit(queue, n_active=0, now=0.0)          # admits 1, defers 2
    assert (b.n_admitted, b.n_deferred) == (1, 2)
    b.admit(queue, n_active=1, now=0.0)          # same 2 wait again
    assert b.n_deferred == 2                     # unique requests, not events
