"""Block-paged KV cache: allocator properties, bitwise paged-vs-dense
decode equivalence, block-granular export/import round-trips, and the
hardened regression-gate schema for the bench's ``paged`` section."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks import check_regression as cr
from repro.models import transformer as T
from repro.serving import (DisaggregatedEngineLoop, EngineLoop, KVPool,
                           Request, SlotEngine, synthetic_workload)

TINY = T.ModelConfig(
    name="paged-tiny", n_layers=3, d_model=32, n_heads=4, n_kv_heads=2,
    d_ff=64, vocab=64, attention_impl="dot", remat=False)

# odd max_seq vs block_size: 21 % 8 != 0, so the last logical block
# overhangs the sequence axis — the boundary the gather must trim exactly
MAX_LEN = 21
BS = 8


@pytest.fixture(scope="module")
def tiny_params():
    return T.init_params(jax.random.PRNGKey(0), TINY)


def _virtual_clock():
    t = [0.0]

    def now():
        t[0] += 1e-3
        return t[0]

    return now


def _workload():
    return synthetic_workload(7, rate=1e9, vocab=TINY.vocab,
                              prompt_lens=(4, 7), gen_lens=(3, 6, 13),
                              seed=11)


@pytest.fixture(scope="module")
def dense_outputs(tiny_params):
    """Per-request greedy tokens through the dense-layout engine — the
    reference every paged run must match bit-for-bit."""
    reqs = _workload()
    engine = EngineLoop(TINY, tiny_params, n_slots=3, max_seq=MAX_LEN,
                        block_size=BS, kv_layout="dense")
    metrics = engine.run(reqs, now_fn=_virtual_clock())
    assert metrics.n_done == len(reqs)
    return {r.rid: r.output for r in reqs}


# --------------------------------------------------------------- allocator
def test_pool_block_table_order_and_padding():
    pool = KVPool(n_slots=2, max_seq=64, block_size=16)
    pool.alloc(rid=5, n_tokens=33)                   # 3 blocks
    lease = pool.lease(5)
    table = pool.block_table(5, pad_to=4)
    assert table.dtype == np.int32 and table.shape == (4,)
    assert table[:3].tolist() == lease.blocks        # lease order IS logical
    assert table[3] == 0                             # padding
    with pytest.raises(ValueError):
        pool.block_table(5, pad_to=2)                # pad below block count


def test_pool_churn_never_leaks_and_fragmentation_never_blocks():
    """Alloc/free churn: blocks are conserved, never shared, never double-
    freed — and because physical pages are interchangeable, an admit whose
    block count fits the free list NEVER fails (no external
    fragmentation)."""
    rng = np.random.default_rng(7)
    pool = KVPool(n_slots=6, max_seq=64, block_size=8, total_blocks=24)
    live = {}
    for step in range(300):
        if live and (rng.random() < 0.45 or len(live) == 6):
            rid = rng.choice(list(live))
            pool.free(rid)
            del live[rid]
            with pytest.raises(ValueError):
                pool.free(rid)                       # double free raises
        else:
            rid = 1000 + step
            n = int(rng.integers(1, 65))
            fits = (pool.free_slot_count > 0
                    and pool.blocks_needed(n) <= pool.free_block_count
                    and n <= pool.max_seq)
            assert pool.can_admit(n) == fits         # fit => admissible
            if fits:
                pool.alloc(rid, n)                   # never raises on a fit
                live[rid] = n
        owned = [b for r in live for b in pool.lease(r).blocks]
        assert len(owned) == len(set(owned))
        assert pool.free_block_count + len(owned) == pool.total_blocks
    for rid in list(live):
        pool.free(rid)
    assert pool.free_block_count == pool.total_blocks
    assert pool.free_slot_count == 6


def test_pool_shared_churn_conserves_refcounts():
    """The churn property under prefix sharing: blocks may now be held by
    several leases (plus pending COW source refs), so the conservation law
    becomes refcounted — free + distinct-referenced == total, and every
    block's refcount equals exactly the number of leases holding it plus
    the pending COW copies sourcing from it.  Admission still never fails
    on a fit (fresh blocks, not total blocks, are what an admit draws)."""
    rng = np.random.default_rng(3)
    pool = KVPool(n_slots=6, max_seq=64, block_size=8, total_blocks=32,
                  prefix_sharing=True)
    families = [tuple(int(t) for t in rng.integers(0, 997, size=(16,)))
                for _ in range(3)]
    live = {}
    for step in range(400):
        if live and (rng.random() < 0.45 or len(live) == 6):
            rid = rng.choice(list(live))
            pool.free(rid)
            del live[rid]
        else:
            rid = 1000 + step
            prefix = families[int(rng.integers(0, 3))]
            suffix = tuple(int(t) for t in rng.integers(
                1000, 2000, size=(int(rng.integers(1, 17)),)))
            prompt = prefix + suffix
            n = min(len(prompt) + int(rng.integers(0, 17)), pool.max_seq)
            fits = (pool.free_slot_count > 0
                    and pool.fresh_blocks_needed(n, prompt)
                    <= pool.free_block_count)
            assert pool.can_admit(n, prompt) == fits
            if fits:
                pool.alloc(rid, n, prompt=prompt)
                live[rid] = n
                if rng.random() < 0.5:
                    pool.consume_cow(rid)    # engine materialized the copy
                lease = pool.lease(rid)
                room = lease.reserved_tokens - lease.written_tokens
                pool.note_write(rid, int(rng.integers(0, room + 1)))
        held = {}
        for r in live:
            for b in pool.lease(r).blocks:
                held[b] = held.get(b, 0) + 1
        for ops in pool._pending_cow.values():
            for src, _ in ops:
                held[src] = held.get(src, 0) + 1
        assert held == pool._block_refs      # refcounts exactly account
        assert (pool.free_block_count + len(pool._block_refs)
                == pool.total_blocks)        # conservation, shared or not
    for rid in list(live):
        pool.free(rid)
    assert pool.free_block_count == pool.total_blocks
    assert pool.free_slot_count == 6
    assert pool._block_refs == {} and pool._prefix_index == {}


# ------------------------------------------- bitwise decode equivalence
def test_paged_decode_step_bitwise_matches_dense(tiny_params):
    """decode_step_slots_paged == decode_step_slots bit-for-bit across
    steps that cross odd seq % block_size boundaries, with inactive slots
    mixed in.

    Active slots' logits and the whole persisted KV state must match
    bitwise every step.  (Inactive slots' *transient* step logits are not
    comparable by construction — the dense path attends against a write it
    then reverts, the paged path routes that write to the trash page — and
    the engine discards them either way.)"""
    from repro.kernels.ref import paged_gather

    n_slots = 3
    pool = KVPool(n_slots, MAX_LEN, block_size=BS)
    tables = []
    for rid in range(n_slots):
        pool.alloc(rid, MAX_LEN)
        tables.append(pool.block_table(rid, pad_to=pool.blocks_per_slot))
    tables = jnp.asarray(np.stack(tables))
    dense = T.init_slot_cache(TINY, n_slots, MAX_LEN)
    paged = T.init_slot_cache_paged(TINY, n_slots, MAX_LEN, block_size=BS)
    paged["block_tables"] = tables

    rng = np.random.default_rng(0)
    for step in range(12):
        toks = jnp.asarray(rng.integers(0, TINY.vocab, size=(n_slots, 1),
                                        dtype=np.int32))
        active = jnp.asarray(rng.random(n_slots) < 0.8)
        ld, dense = T.decode_step_slots(tiny_params, TINY, dense, toks,
                                        active)
        lp, paged = T.decode_step_slots_paged(tiny_params, TINY, paged,
                                              toks, active, max_seq=MAX_LEN)
        act = np.asarray(active)
        np.testing.assert_array_equal(np.asarray(ld)[act],
                                      np.asarray(lp)[act],
                                      err_msg=f"step {step}")
        np.testing.assert_array_equal(np.asarray(dense["pos"]),
                                      np.asarray(paged["pos"]))
        # persisted KV state identical for EVERY slot: the paged arenas,
        # gathered through the tables, equal the dense rows bit-for-bit
        (d_blocks, _), (p_blocks, _) = dense["layers"], paged["layers"]
        for dc, pc in zip(d_blocks, p_blocks):
            for key in ("k", "v"):
                for s in range(dc[key].shape[0]):        # super-block axis
                    rows = paged_gather(pc[key][s], tables, MAX_LEN)
                    np.testing.assert_array_equal(
                        np.asarray(dc[key][s]), np.asarray(rows),
                        err_msg=f"step {step} layer {s} {key}")


def test_paged_engine_matches_dense(tiny_params, dense_outputs):
    reqs = _workload()
    engine = EngineLoop(TINY, tiny_params, n_slots=3, max_seq=MAX_LEN,
                        block_size=BS, kv_layout="paged")
    engine.run(reqs, now_fn=_virtual_clock())
    assert {r.rid: r.output for r in reqs} == dense_outputs
    assert engine.pool.free_block_count == engine.pool.total_blocks


def test_paged_reduced_arena_matches_dense(tiny_params, dense_outputs):
    # tokens-in-flight provisioning: fewer physical pages than the dense
    # equivalent (9 blocks vs 3*3) — admission defers, outputs unchanged
    reqs = _workload()
    engine = EngineLoop(TINY, tiny_params, n_slots=3, max_seq=MAX_LEN,
                        block_size=BS, total_blocks=6, kv_layout="paged")
    engine.run(reqs, now_fn=_virtual_clock())
    assert {r.rid: r.output for r in reqs} == dense_outputs


def test_paged_disagg_matches_dense(tiny_params, dense_outputs):
    """Block-granular phase migration is exact: disaggregated paged
    serving produces the dense colocated tokens bit-for-bit."""
    reqs = _workload()
    loop = DisaggregatedEngineLoop(
        TINY, tiny_params, n_prefill_slots=2, n_decode_slots=3,
        max_seq=MAX_LEN, block_size=BS, kv_layout="paged")
    metrics = loop.run(reqs, now_fn=_virtual_clock())
    assert metrics.n_done == len(reqs)
    assert {r.rid: r.output for r in reqs} == dense_outputs
    assert loop.handoff.n_handoffs == len(reqs)


# ----------------------------------------------- export/import round-trip
def _bind_and_prefill(engine, pool, req, steps):
    req.slot = pool.alloc(req.rid, req.total_tokens)
    engine.bind(req, steps_total=steps)
    engine.dispatch(steps, engine.active.copy())


def test_paged_export_import_roundtrip_bit_identical(tiny_params):
    """A paged slot exported mid-flight and imported into a different
    engine (different physical pages) finishes with exactly the tokens an
    uninterrupted engine produces — and the snapshot ships only the pages
    holding written tokens."""
    prompt = np.arange(1, 8, dtype=np.int32)         # plen 7, gen 6
    mk = lambda: Request(rid=1, prompt=prompt.copy(), max_new_tokens=6)

    # uninterrupted reference
    pool_c = KVPool(2, MAX_LEN, block_size=BS)
    eng_c = SlotEngine(TINY, tiny_params, pool_c, kv_layout="paged")
    ref = mk()
    _bind_and_prefill(eng_c, pool_c, ref, 7 + 6 - 1)
    want = eng_c.pull_output(ref.slot)[:6]

    # prefill on A, migrate to B mid-flight
    pool_a = KVPool(2, MAX_LEN, block_size=BS)
    eng_a = SlotEngine(TINY, tiny_params, pool_a, kv_layout="paged")
    req = mk()
    _bind_and_prefill(eng_a, pool_a, req, 7)         # prefill phase only
    state = eng_a.export_slot(req.slot)
    assert state["layout"] == "paged" and state["kv_tokens"] == 7
    # only ceil(7/8) == 1 written page ships, not the 2-block reservation
    k_leaf = jax.tree.leaves(state["blocks"])[0]
    assert k_leaf.shape[1] == 1

    pool_b = KVPool(2, MAX_LEN, block_size=BS)
    pool_b.alloc(rid=99, n_tokens=10)                # shift physical ids
    eng_b = SlotEngine(TINY, tiny_params, pool_b, kv_layout="paged")
    eng_a.release(req)
    req.slot = pool_b.alloc(req.rid, req.total_tokens)
    eng_b.adopt(req, state, steps_total=6 - 1)
    eng_b.dispatch(5, eng_b.active.copy())
    got = eng_b.pull_output(req.slot)[:6]
    np.testing.assert_array_equal(got, want)


def test_import_rejects_layout_mismatch(tiny_params):
    pool_p = KVPool(1, MAX_LEN, block_size=BS)
    pool_d = KVPool(1, MAX_LEN, block_size=BS)
    eng_p = SlotEngine(TINY, tiny_params, pool_p, kv_layout="paged")
    eng_d = SlotEngine(TINY, tiny_params, pool_d, kv_layout="dense")
    req = Request(rid=1, prompt=np.arange(4, dtype=np.int32),
                  max_new_tokens=2)
    _bind_and_prefill(eng_p, pool_p, req, 4)
    state = eng_p.export_slot(req.slot)
    with pytest.raises(ValueError, match="layout"):
        eng_d.import_slot(0, state)
    with pytest.raises(ValueError, match="dest_blocks"):
        eng_p.import_slot(0, state)                  # paged needs a lease


def test_windowed_config_rejects_paged_layout():
    cfg = T.ModelConfig(name="swa", n_layers=2, d_model=32, n_heads=4,
                        n_kv_heads=2, d_ff=64, vocab=64, attn_window=8,
                        attention_impl="dot", remat=False)
    with pytest.raises(ValueError, match="sliding-window"):
        T.init_slot_cache_paged(cfg, 2, 32, block_size=8)


def test_reset_slot_state_preserves_block_tables():
    cache = T.init_slot_cache_paged(TINY, 2, MAX_LEN, block_size=BS)
    cache["block_tables"] = cache["block_tables"].at[1].set(7)
    out = T.reset_slot_state(TINY, cache, 1)
    assert "block_tables" in out                     # unknown keys survive
    np.testing.assert_array_equal(np.asarray(out["block_tables"]),
                                  np.asarray(cache["block_tables"]))


# ------------------------------------------------- regression-gate schema
def _good_paged_section():
    summ = {"tok_per_s": 100.0, "tokens_out": 10, "requests_done": 2}
    return {
        "block_size": 16, "blocks_per_slot": 5, "total_blocks": 24,
        "dense_equiv_blocks": 40, "kv_bytes_dense": 1000,
        "kv_bytes_paged": 600, "kv_bytes_ratio": 0.6,
        "achievable_n_slots_at_dense_budget": 13, "tok_per_s_ratio": 0.9,
        "dense": dict(summ), "paged": dict(summ),
        "bit_identical_colocated": True,
        "bit_identical_disaggregated": True, "all_identical": True,
    }


def test_validate_paged_accepts_well_formed_section():
    checks = cr.validate_paged({"paged": _good_paged_section()})
    assert checks and all(ok for _, ok, _ in checks)


@pytest.mark.parametrize("mutate,name", [
    (lambda s: s.clear(), "paged section schema"),
    (lambda s: s.pop("kv_bytes_paged"), "paged section schema"),
    (lambda s: s.pop("dense"), "paged section schema"),
    (lambda s: s.update(bit_identical_colocated=False),
     "paged outputs bit-identical to dense"),
    (lambda s: s.update(kv_bytes_paged=1000),
     "paged KV bytes resident strictly below dense"),
    (lambda s: s.update(kv_bytes_paged=2000),
     "paged KV bytes resident strictly below dense"),
])
def test_validate_paged_fails_malformed_or_regressed(mutate, name):
    section = _good_paged_section()
    mutate(section)
    checks = cr.validate_paged({"paged": section})
    failed = [n for n, ok, _ in checks if not ok]
    assert any(name in n for n in failed), (failed, name)


def test_validate_paged_missing_section_fails():
    checks = cr.validate_paged({})
    assert len(checks) == 1
    name, ok, _ = checks[0]
    assert name == "paged section present" and not ok


# ------------------------------------------------- absolute host baselines
def _fresh_bench():
    return {
        "loads": [{"offered_rate_req_s": 1e9, "bit_identical": True,
                   "speedup_tok_per_s": 2.0,
                   "continuous": {"tok_per_s": 500.0},
                   "static": {"tok_per_s": 250.0}}],
        "paged": {"paged": {"tok_per_s": 450.0}},
    }


def test_absolute_baseline_record_then_gate(tmp_path):
    d = str(tmp_path / "baselines")
    fresh = _fresh_bench()
    # first run records and passes
    checks = cr.check_absolute(fresh, threshold=0.2, baselines_dir=d,
                               record=True)
    assert all(ok for _, ok, _ in checks)
    path = tmp_path / "baselines" / f"{cr.host_key()}.json"
    assert path.exists()
    recorded = json.loads(path.read_text())
    assert recorded["metrics"]["continuous_tok_per_s"] == 500.0
    assert recorded["metrics"]["paged_tok_per_s"] == 450.0
    # same-host rerun within budget passes
    ok2 = cr.check_absolute(fresh, threshold=0.2, baselines_dir=d,
                            record=False)
    assert all(ok for _, ok, _ in ok2)
    # >20% regression on this host fails
    slow = _fresh_bench()
    slow["loads"][0]["continuous"]["tok_per_s"] = 300.0
    bad = cr.check_absolute(slow, threshold=0.2, baselines_dir=d,
                            record=False)
    assert any(not ok for _, ok, _ in bad)


def test_absolute_baseline_missing_without_record_skips(tmp_path):
    checks = cr.check_absolute(_fresh_bench(), threshold=0.2,
                               baselines_dir=str(tmp_path / "none"),
                               record=False)
    assert len(checks) == 1 and checks[0][1]
    assert "skipped" in checks[0][2]
