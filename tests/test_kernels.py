"""Per-kernel correctness: sweep shapes x dtypes, assert_allclose vs the
pure-jnp oracles in kernels/ref.py.  All Pallas kernels run interpret=True
(CPU container; TPU is the lowering target)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

_RTOL = {jnp.float32: 2e-4, jnp.bfloat16: 5e-2}
_ATOL = {jnp.float32: 2e-4, jnp.bfloat16: 5e-2}


def _assert_close(got, want, dtype):
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=_RTOL[dtype], atol=_ATOL[dtype])


# ---------------------------------------------------------------- matmul
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n", [
    (128, 128, 128),        # single tile
    (256, 512, 256),        # multi-tile all dims
    (100, 300, 70),         # unaligned (padding path)
    (1, 9216, 4096),        # FC6 row (paper Table II)
    (8, 64, 8),             # tiny
])
def test_matmul_shapes(rng, m, k, n, dtype):
    x = jnp.asarray(rng.normal(size=(m, k)), dtype)
    w = jnp.asarray(rng.normal(size=(k, n)), dtype)
    _assert_close(ops.matmul(x, w), ref.matmul_ref(x, w), dtype)


@pytest.mark.parametrize("activation", ["none", "relu", "sigmoid", "tanh"])
def test_matmul_bias_activation(rng, activation):
    x = jnp.asarray(rng.normal(size=(64, 96)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(96, 48)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(48,)), jnp.float32)
    _assert_close(ops.matmul(x, w, b, activation=activation),
                  ref.fc_ref(x, w, b, activation=activation), jnp.float32)


def test_matmul_block_sweep(rng):
    x = jnp.asarray(rng.normal(size=(512, 384)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(384, 256)), jnp.float32)
    want = ref.matmul_ref(x, w)
    for bm, bn, bk in [(128, 128, 128), (256, 256, 384), (512, 64, 192)]:
        got = ops.matmul(x, w, block_m=bm, block_n=bn, block_k=bk)
        _assert_close(got, want, jnp.float32)


# ---------------------------------------------------------------- conv2d
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("hw,cin,cout,kk,stride,pad", [
    (16, 3, 8, 3, 1, 1),
    (16, 4, 8, 3, 2, 0),
    (24, 3, 16, 5, 2, 2),
    (13, 8, 16, 3, 1, 1),      # conv3-5 geometry (reduced channels)
    (12, 3, 8, 11, 4, 2),      # conv1 geometry (reduced)
])
def test_conv2d_shapes(rng, hw, cin, cout, kk, stride, pad, dtype):
    x = jnp.asarray(rng.normal(size=(2, hw, hw, cin)), dtype)
    w = jnp.asarray(rng.normal(size=(cout, cin, kk, kk)), dtype)
    b = jnp.asarray(rng.normal(size=(cout,)), dtype)
    got = ops.conv2d(x, w, b, stride=stride, padding=pad, activation="relu")
    want = ref.conv2d_ref(x, w, b, stride=stride, padding=pad,
                          activation="relu")
    _assert_close(got, want, dtype)


# --------------------------------------------------------------- pooling
@pytest.mark.parametrize("pool_type", ["max", "avg"])
@pytest.mark.parametrize("hw,c,win,stride", [
    (13, 8, 3, 2), (27, 4, 3, 2), (8, 16, 2, 2), (9, 3, 3, 3),
])
def test_pool_shapes(rng, hw, c, win, stride, pool_type):
    x = jnp.asarray(rng.normal(size=(2, hw, hw, c)), jnp.float32)
    got = ops.pool(x, window=win, stride=stride, pool_type=pool_type)
    want = (ref.maxpool_ref(x, window=win, stride=stride) if pool_type == "max"
            else ref.avgpool_ref(x, window=win, stride=stride))
    _assert_close(got, want, jnp.float32)


# ------------------------------------------------------------------ lrn
@pytest.mark.parametrize("c,local", [(8, 5), (16, 3), (96, 5), (7, 5)])
def test_lrn_shapes(rng, c, local):
    x = jnp.asarray(rng.normal(size=(2, 7, 7, c)), jnp.float32)
    got = ops.lrn(x, local_size=local)
    want = ref.lrn_ref(x, local_size=local)
    _assert_close(got, want, jnp.float32)


# ------------------------------------------------------ flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("hq,hk", [(8, 8), (8, 2), (4, 1)])
def test_flash_attention_gqa(rng, hq, hk, dtype):
    q = jnp.asarray(rng.normal(size=(2, hq, 256, 64)), dtype)
    k = jnp.asarray(rng.normal(size=(2, hk, 256, 64)), dtype)
    v = jnp.asarray(rng.normal(size=(2, hk, 256, 64)), dtype)
    got = ops.flash_attention(q, k, v, causal=True, block_q=128, block_k=128)
    want = ref.attention_ref(q, k, v, causal=True)
    _assert_close(got, want, jnp.bfloat16)   # online softmax: bf16-level tol


@pytest.mark.parametrize("window", [32, 64, 250])
def test_flash_attention_windowed(rng, window):
    q = jnp.asarray(rng.normal(size=(1, 4, 256, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 256, 32)), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=64, block_k=64)
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    _assert_close(got, want, jnp.bfloat16)


def test_flash_attention_unaligned_padding():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 2, 100, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 100, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 100, 32)), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    want = ref.attention_ref(q, k, v, causal=True)
    _assert_close(got, want, jnp.bfloat16)


# ------------------------------------------------------ paged attention
def _paged_case(rng, *, b, hq, hk, d, bs, nb, dtype, shuffle=True):
    """Random decode case: arena of physical pages + per-slot block tables
    (non-contiguous when shuffled) + per-slot positions on odd block
    boundaries."""
    tb = b * nb + 1                                  # + trash page
    q = jnp.asarray(rng.normal(size=(b, hq, 1, d)), dtype)
    ka = jnp.asarray(rng.normal(size=(tb, hk, bs, d)), dtype)
    va = jnp.asarray(rng.normal(size=(tb, hk, bs, d)), dtype)
    ids = np.arange(tb - 1) + 1
    if shuffle:
        ids = rng.permutation(ids)
    bt = jnp.asarray(ids[:b * nb].reshape(b, nb).astype(np.int32))
    pos = jnp.asarray(
        rng.integers(0, nb * bs, size=(b,)).astype(np.int32))
    return q, ka, va, bt, pos


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("hq,hk", [(8, 8), (8, 2), (4, 1)])
def test_paged_attention_gqa(rng, hq, hk, dtype):
    q, ka, va, bt, pos = _paged_case(rng, b=3, hq=hq, hk=hk, d=64, bs=16,
                                     nb=4, dtype=dtype)
    got = ops.paged_attention(q, ka, va, bt, pos)
    want = ref.paged_attention_ref(q, ka, va, bt, pos)
    _assert_close(got, want, jnp.bfloat16)   # online softmax: bf16-level tol


@pytest.mark.parametrize("pos_list", [[0], [15], [16], [17], [63]])
def test_paged_attention_block_boundaries(rng, pos_list):
    # positions sitting exactly on / beside page edges — the block-skip
    # predicate and the boundary mask must agree with the dense oracle
    b = len(pos_list)
    q, ka, va, bt, _ = _paged_case(rng, b=b, hq=4, hk=2, d=32, bs=16, nb=4,
                                   dtype=jnp.float32)
    pos = jnp.asarray(np.asarray(pos_list, np.int32))
    got = ops.paged_attention(q, ka, va, bt, pos)
    want = ref.paged_attention_ref(q, ka, va, bt, pos)
    _assert_close(got, want, jnp.bfloat16)


def test_paged_ref_trims_sequence_overhang(rng):
    # max_seq not a multiple of block_size: the gathered rows must trim the
    # tail pages' overhang, matching a dense cache of exactly max_seq
    from repro.models.attention import decode_attention
    b, hk, d, bs, nb, max_seq = 2, 2, 32, 8, 3, 21
    q, ka, va, bt, _ = _paged_case(rng, b=b, hq=4, hk=hk, d=d, bs=bs, nb=nb,
                                   dtype=jnp.float32)
    pos = jnp.asarray(np.array([5, 20], np.int32))
    dense_k = ref.paged_gather(ka, bt, max_seq)
    dense_v = ref.paged_gather(va, bt, max_seq)
    assert dense_k.shape == (b, hk, max_seq, d)
    want = decode_attention(q, dense_k, dense_v, pos=pos)
    got = ref.paged_attention_ref(q, ka, va, bt, pos, max_seq=max_seq)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_conv_vmem_budget():
    """Every AlexNet conv layer's per-image working set fits 16 MiB VMEM —
    the Table III resource-constraint analogue."""
    from repro.core.layer_model import alexnet_full_spec
    from repro.kernels.conv2d import conv2d_vmem_bytes
    for spec in alexnet_full_spec():
        if spec.kind != "conv":
            continue
        h, w, c = spec.m_i
        oc, ic, kh, kw = spec.m_k
        pad = spec.padding
        bytes_ = conv2d_vmem_bytes(h + 2 * pad, w + 2 * pad, ic, oc, kh, kw,
                                   spec.stride)
        assert bytes_ < 16 * 2**20, (spec.name, bytes_)
