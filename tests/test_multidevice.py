"""Multi-device serving: phase device assignment, cross-device
disaggregation with the async hand-off, and watchdog-actuated live
migration.

The multi-device legs run in a subprocess under
``XLA_FLAGS=--xla_force_host_platform_device_count=2`` — the flag must
precede the first jax import, and pytest's process has already
initialized the backend, so the in-process tests only cover the
single-device degradation path and the pure helpers."""
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.mesh import (MULTI_DEVICE_HINT, device_assignment,
                               device_label, forced_host_device_env)
from repro.serving.engine_loop import (snapshot_ready, snapshot_wait,
                                       state_to_device)

SRC = Path(__file__).resolve().parents[1] / "src"


# ------------------------------------------------- in-process: assignment
def test_single_device_assignment_degrades_to_shared():
    asn = device_assignment()
    n = len(jax.devices())
    if n == 1:
        assert not asn.distinct
        assert asn.prefill == asn.decode == jax.devices()[0]
        assert "(shared)" in asn.summary()
    else:  # someone ran pytest itself under the XLA flag: still coherent
        assert asn.distinct and "(distinct)" in asn.summary()
    assert device_label(asn.prefill) in asn.summary()


def test_explicit_out_of_range_index_raises_with_hint():
    n = len(jax.devices())
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        device_assignment(decode_index=n)


def test_forced_host_device_env_appends_flag_without_mutating_environ():
    before = os.environ.get("XLA_FLAGS")
    env = forced_host_device_env(4)
    assert "--xla_force_host_platform_device_count=4" in env["XLA_FLAGS"]
    if before:  # pre-existing flags survive the overlay
        assert before in env["XLA_FLAGS"]
    assert os.environ.get("XLA_FLAGS") == before
    assert "device_count" in MULTI_DEVICE_HINT


# ---------------------------------------------- in-process: state helpers
def test_snapshot_helpers_roundtrip_mixed_state():
    dev = jax.devices()[0]
    state = {"kv": jnp.arange(8.0), "host": np.arange(4), "written": 7}
    moved = state_to_device(state, dev)
    # non-jax leaves pass through untouched; jax leaves land on the device
    assert moved["written"] == 7
    assert isinstance(moved["host"], np.ndarray)
    assert moved["kv"].devices() == {dev}
    snapshot_wait(moved)
    assert snapshot_ready(moved)
    assert np.array_equal(np.asarray(moved["kv"]), np.arange(8.0))


# ------------------------------------------------ subprocess: two devices
# One subprocess amortizes the jax + jit startup across every multi-device
# assertion; it prints a single JSON verdict on its last stdout line.
TWO_DEVICE_SCRIPT = r'''
import json

import jax
import numpy as np

from repro.core import engines as engines_lib
from repro.launch.mesh import device_assignment, device_label
from repro.models import transformer as T
from repro.obs import Observability, PerfWatchdog
from repro.serving import (DisaggregatedEngineLoop, EngineLoop,
                           synthetic_workload)
from repro.serving.placement import drift_scaled_device

out = {"n_devices": len(jax.devices())}
asn = device_assignment()
out["distinct"] = asn.distinct
out["prefill_dev"] = device_label(asn.prefill)
out["decode_dev"] = device_label(asn.decode)

cfg = T.ModelConfig(name="md-tiny", n_layers=3, d_model=32, n_heads=4,
                    n_kv_heads=2, d_ff=64, vocab=64, attention_impl="dot",
                    remat=False)
params = T.init_params(jax.random.PRNGKey(0), cfg)


def clock():
    t = [0.0]

    def now():
        t[0] += 1e-3
        return t[0]

    return now


def workload(seed=11):
    return synthetic_workload(9, rate=1e9, vocab=cfg.vocab,
                              prompt_lens=(4, 8), gen_lens=(1, 3, 6, 12),
                              seed=seed)


def first_dev(tree):
    return device_label(next(iter(jax.tree.leaves(tree)[0].devices())))


MAX_LEN = 8 + 12
reqs = workload()
EngineLoop(cfg, params, n_slots=3, max_seq=MAX_LEN).run(reqs,
                                                        now_fn=clock())
ref = {r.rid: r.output for r in reqs}

# async hand-off across two real devices
reqs = workload()
dis = DisaggregatedEngineLoop(cfg, params, n_prefill_slots=2,
                              n_decode_slots=3, max_seq=MAX_LEN,
                              assignment=asn)
dis.run(reqs, now_fn=clock())
out["async"] = {
    "identical": {r.rid: r.output for r in reqs} == ref,
    "n_handoffs": dis.handoff.n_handoffs,
    "prefill_params_dev": first_dev(dis.prefill.params),
    "decode_params_dev": first_dev(dis.decode.params),
    "prefill_cache_dev": first_dev(dis.prefill.cache),
    "decode_cache_dev": first_dev(dis.decode.cache),
}

# synchronous hand-off: same outputs through the same device boundary
reqs = workload()
dis_s = DisaggregatedEngineLoop(cfg, params, n_prefill_slots=2,
                                n_decode_slots=3, max_seq=MAX_LEN,
                                assignment=asn, async_handoff=False)
dis_s.run(reqs, now_fn=clock())
out["sync"] = {
    "identical": {r.rid: r.output for r in reqs} == ref,
    "n_handoffs": dis_s.handoff.n_handoffs,
}

# watchdog-actuated mid-run migration: the decode device model prices
# steps ~1e6x too fast, the drift alert re-runs placement over the two
# hosted engines, decode flips onto the prefill engine, and in-flight
# decode slots live-migrate through the export/adopt machinery
MIG_LEN = 8 + 16


def mig_workload():
    return synthetic_workload(10, rate=1e9, vocab=cfg.vocab,
                              prompt_lens=(4, 8), gen_lens=(12, 16),
                              seed=5)


reqs = mig_workload()
EngineLoop(cfg, params, n_slots=4, max_seq=MIG_LEN).run(reqs,
                                                        now_fn=clock())
mig_ref = {r.rid: r.output for r in reqs}
reqs = mig_workload()
dis_m = DisaggregatedEngineLoop(
    cfg, params, n_prefill_slots=4, n_decode_slots=4, max_seq=MIG_LEN,
    assignment=asn, obs=Observability(watchdog=PerfWatchdog()),
    prefill_device=engines_lib.XLA_ENGINE.device,
    decode_device=drift_scaled_device(engines_lib.K40_LM_ENGINE.device,
                                      1e-6),
    prefill_placement_engine_name="xla",
    decode_placement_engine_name="k40-roofline")
m = dis_m.run(reqs, now_fn=clock())
out["migration"] = {
    "n_done": m.n_done,
    "n_dropped": m.n_dropped,
    "identical": {r.rid: r.output for r in reqs} == mig_ref,
    "n_live_migrations": dis_m.handoff.n_live_migrations,
    "decode_target": dis_m.decode_target,
}
print(json.dumps(out))
'''


@pytest.fixture(scope="module")
def twodev():
    env = forced_host_device_env(2)
    env["PYTHONPATH"] = str(SRC) + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", TWO_DEVICE_SCRIPT],
                          env=env, capture_output=True, text=True,
                          timeout=900)
    assert proc.returncode == 0, (proc.stdout[-2000:], proc.stderr[-4000:])
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_forced_host_flag_yields_distinct_assignment(twodev):
    assert twodev["n_devices"] == 2
    assert twodev["distinct"]
    assert twodev["prefill_dev"] == "cpu:0"
    assert twodev["decode_dev"] == "cpu:1"


def test_cross_device_async_handoff_bit_identical(twodev):
    a = twodev["async"]
    assert a["identical"], "async cross-device outputs diverged"
    assert a["n_handoffs"] == 9
    # each phase's params and KV arena actually live on its device
    assert a["prefill_params_dev"] == "cpu:0"
    assert a["decode_params_dev"] == "cpu:1"
    assert a["prefill_cache_dev"] == "cpu:0"
    assert a["decode_cache_dev"] == "cpu:1"


def test_cross_device_sync_handoff_bit_identical(twodev):
    s = twodev["sync"]
    assert s["identical"], "sync cross-device outputs diverged"
    assert s["n_handoffs"] == 9


def test_midrun_migration_preserves_in_flight_slots(twodev):
    mig = twodev["migration"]
    assert mig["n_done"] == 10 and mig["n_dropped"] == 0
    assert mig["n_live_migrations"] >= 1
    assert mig["identical"], "migrated outputs diverged from colocated"
    assert mig["decode_target"] in ("prefill", "decode")
