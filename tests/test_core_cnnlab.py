"""CNNLab core: layer model accounting, cost model, scheduler, plan,
trade-off analysis vs the paper's claims (hypothesis property tests where
invariants matter)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import cost_model, device_models as dm, engines, plan, \
    scheduler, tradeoff
from repro.core.layer_model import (ConvSpec, FCSpec, MLPSpec,
                                    MoESpec, NetworkSpec,
                                    alexnet_full_spec, alexnet_spec)


# ------------------------------------------------------- FLOP accounting
def test_table2_flop_counts_exact():
    net = alexnet_spec()
    fc = {l.name: l for l in net if l.kind == "fc"}
    assert fc["FC6"].flops(1) == 75_497_472
    assert fc["FC7"].flops(1) == 33_554_432
    assert fc["FC8"].flops(1) == 8_192_000
    assert fc["FC6"].bwd_flops(1) == 150_994_944
    assert fc["FC7"].bwd_flops(1) == 67_108_864
    assert fc["FC8"].bwd_flops(1) == 16_384_000


def test_alexnet_conv_flops_plausible():
    net = alexnet_spec()
    conv_flops = sum(l.flops(1) for l in net if l.kind == "conv")
    # AlexNet convs are ~1.07 GMAC = ~2.15 GFLOP/image (2 FLOPs/MAC)
    assert 1.9e9 < conv_flops < 2.4e9


@given(st.integers(1, 64), st.integers(1, 512), st.integers(1, 512))
@settings(max_examples=30, deadline=None)
def test_fc_flops_formula(batch, n_in, k_o):
    spec = FCSpec("fc", m_i=(n_in,), k_o=k_o)
    assert spec.flops(batch) == 2 * batch * n_in * k_o
    assert spec.bwd_flops(batch) == 2 * spec.flops(batch)


@given(st.integers(1, 8))
@settings(max_examples=10, deadline=None)
def test_flops_linear_in_batch(batch):
    for spec in alexnet_full_spec():
        assert spec.flops(batch) == batch * spec.flops(1)


def test_moe_flops_only_counts_active_experts():
    dense = MLPSpec("mlp", d_model=64, d_ff=256, seq=32, gated=True)
    moe = MoESpec("moe", d_model=64, d_ff=256, seq=32, n_experts=8, top_k=2)
    # top-2 of 8 experts ~= 2x the dense MLP (+ router)
    assert moe.flops(1) < 2 * dense.flops(1) + 2 * 32 * 64 * 8 + 1
    assert moe.flops(1) >= 2 * dense.flops(1)


# ----------------------------------------------------------- cost model
@given(st.sampled_from(["conv", "fc"]), st.integers(1, 200))
@settings(max_examples=30, deadline=None)
def test_cost_monotone_in_batch(kind, batch):
    spec = (ConvSpec("c", m_i=(27, 27, 96), m_k=(64, 96, 5, 5),
                     m_o=(27, 27, 64)) if kind == "conv"
            else FCSpec("f", m_i=(4096,), k_o=1024))
    c1 = cost_model.layer_cost(spec, dm.K40, batch=batch)
    c2 = cost_model.layer_cost(spec, dm.K40, batch=batch + 1)
    assert c2.t_total > c1.t_total
    assert c2.energy_j > c1.energy_j


def test_roofline_terms_analytic_device():
    spec = FCSpec("f", m_i=(4096,), k_o=4096)
    c = cost_model.layer_cost(spec, dm.TPU_V5E, batch=1, dtype_bytes=4)
    # batch-1 FC is memory-bound on any modern chip
    assert c.dominant == "memory"
    c_big = cost_model.layer_cost(spec, dm.TPU_V5E, batch=8192, dtype_bytes=2)
    assert c_big.dominant == "compute"


def test_collective_term():
    spec = FCSpec("f", m_i=(4096,), k_o=4096)
    c = cost_model.layer_cost(spec, dm.TPU_V5E, batch=4,
                              collective_bytes=10 * 2**30)
    assert c.dominant == "collective"
    assert c.t_collective == pytest.approx(10 * 2**30 / dm.TPU_V5E.link_bw)


# ------------------------------------------------------------ scheduler
def test_scheduler_greedy_matches_exhaustive():
    net = NetworkSpec("sub", tuple(alexnet_full_spec())[:5])
    engs = engines.ALL_ENGINES
    for objective in cost_model.OBJECTIVES:
        g = scheduler.schedule(net, engs, objective=objective)
        e = scheduler.schedule_exhaustive(net, engs, objective=objective)
        assert g.total_objective() == pytest.approx(e.total_objective()), \
            objective


def test_scheduler_latency_prefers_gpu_power_prefers_fpga():
    net = alexnet_spec()
    lat = scheduler.schedule(net, engines.PAPER_ENGINES, objective="latency")
    pow_ = scheduler.schedule(net, engines.PAPER_ENGINES, objective="power")
    assert all(a.engine == "k40" for a in lat.assignments)
    assert all(a.engine == "de5-opencl" for a in pow_.assignments)


def test_scheduler_power_cap():
    net = alexnet_spec()
    capped = scheduler.schedule(net, engines.PAPER_ENGINES,
                                objective="latency", power_cap_w=10.0)
    assert capped.peak_power <= 10.0
    uncapped = scheduler.schedule(net, engines.PAPER_ENGINES,
                                  objective="latency")
    assert uncapped.total_time < capped.total_time


@given(st.sampled_from(["latency", "energy", "edp"]))
@settings(max_examples=5, deadline=None)
def test_plan_objective_is_minimal_per_layer(objective):
    """Property: no single-layer engine swap can improve the plan."""
    net = alexnet_spec()
    p = scheduler.schedule(net, engines.ALL_ENGINES, objective=objective)
    for a in p.assignments:
        for eng in engines.ALL_ENGINES:
            if not eng.supports(a.spec):
                continue
            eff = eng.efficiency if eng.device.analytic else 1.0
            alt = cost_model.layer_cost(a.spec, eng.device, batch=1,
                                        mxu_efficiency=eff)
            assert (cost_model.objective_value(a.cost, objective)
                    <= cost_model.objective_value(alt, objective) + 1e-12)


# ------------------------------------------------------ plan execution
def test_compiled_plan_engines_agree(rng):
    net = alexnet_full_spec()
    params = plan.init_network_params(net, jax.random.PRNGKey(0))
    x = jnp.asarray(rng.normal(size=(2, 224, 224, 3)), jnp.float32)
    p_xla = scheduler.schedule(net, [engines.XLA_ENGINE])
    p_pal = scheduler.schedule(net, [engines.PALLAS_ENGINE])
    y1 = plan.compile_plan(p_xla)(x, params)
    y2 = plan.compile_plan(p_pal)(x, params)
    assert y1.shape == (2, 1000)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y1.sum(-1)), 1.0, rtol=1e-5)


def test_paper_device_plan_falls_back_to_buildable_engine(rng):
    net = NetworkSpec("fc-only", tuple(l for l in alexnet_full_spec()
                                       if l.kind == "fc"))
    p = scheduler.schedule(net, engines.PAPER_ENGINES, objective="latency")
    f = plan.compile_plan(p)          # k40 is cost-only -> xla fallback
    params = plan.init_network_params(net, jax.random.PRNGKey(0))
    x = jnp.asarray(rng.normal(size=(2, 9216)), jnp.float32)
    y = f(x, params)
    assert y.shape == (2, 1000)
    assert bool(jnp.isfinite(y).all())


# --------------------------------------------------- paper-claim checks
def test_paper_claims_all_pass():
    claims = tradeoff.check_paper_claims()
    failed = {k: v for k, v in claims.items() if not v["ok"]}
    assert not failed, failed


def test_tradeoff_table_shapes():
    rows = tradeoff.analyze(alexnet_spec(), [dm.K40, dm.DE5], batch=16)
    assert len(rows) == 2 * 8
    for r in rows:
        assert r.time_s > 0 and r.throughput_gflops > 0
        assert r.gflops_per_watt == pytest.approx(
            r.throughput_gflops / r.power_w)
