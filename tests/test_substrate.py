"""Substrate tests: data pipeline determinism/resume, optimizer math,
schedules, gradient compression (hypothesis properties), checkpoint
round-trip + elastic restore, fault-tolerance runtime."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.checkpoint import restore_latest, save_checkpoint
from repro.data import DataConfig, SyntheticLM, TextFileLM
from repro.optim import adamw, compression, schedules
from repro.runtime import PreemptionHandler, StepTimer


# ------------------------------------------------------------------ data
def test_pipeline_deterministic_and_resumable():
    cfg = DataConfig(global_batch=4, seq_len=16, vocab=128, seed=7)
    p1 = SyntheticLM(cfg)
    batches = [next(p1) for _ in range(5)]
    state = p1.state()
    more = [next(p1) for _ in range(3)]

    p2 = SyntheticLM(cfg)
    p2.restore(state)
    replay = [next(p2) for _ in range(3)]
    for a, b in zip(more, replay):
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))


def test_pipeline_host_sharding_disjoint():
    full = DataConfig(global_batch=8, seq_len=8, vocab=64, seed=3)
    h0 = SyntheticLM(DataConfig(global_batch=8, seq_len=8, vocab=64, seed=3,
                                host_index=0, host_count=2))
    h1 = SyntheticLM(DataConfig(global_batch=8, seq_len=8, vocab=64, seed=3,
                                host_index=1, host_count=2))
    b0, b1 = next(h0), next(h1)
    assert b0["tokens"].shape == (4, 8)
    assert not np.array_equal(np.asarray(b0["tokens"]),
                              np.asarray(b1["tokens"]))


def test_pipeline_labels_are_next_tokens():
    cfg = DataConfig(global_batch=2, seq_len=32, vocab=64, seed=1)
    b = next(SyntheticLM(cfg))
    # bigram data: labels[t] is the successor of tokens[t] -> shifted overlap
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


def test_textfile_pipeline(tmp_path):
    path = tmp_path / "corpus.txt"
    path.write_bytes(bytes(range(256)) * 40)
    cfg = DataConfig(global_batch=2, seq_len=16, vocab=256)
    p = TextFileLM(cfg, str(path))
    b = next(p)
    assert b["tokens"].shape == (2, 16)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


# ------------------------------------------------------------- optimizer
def test_adamw_reduces_quadratic_loss():
    params = {"w": jnp.asarray([5.0, -3.0])}
    init, update = adamw.make_optimizer(
        schedules.constant(0.1), adamw.AdamWConfig(weight_decay=0.0,
                                                   clip_norm=None))
    state = init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        grads = jax.grad(loss)(params)
        params, state, _ = update(grads, state, params)
    assert float(loss(params)) < 1e-2


@given(st.floats(0.1, 10.0))
@settings(max_examples=20, deadline=None)
def test_clip_by_global_norm(max_norm):
    g = {"a": jnp.full((4,), 10.0), "b": jnp.full((3,), -10.0)}
    clipped, norm = adamw.clip_by_global_norm(g, max_norm)
    got = adamw.global_norm(clipped)
    assert float(got) <= max_norm * (1 + 1e-5)
    if float(norm) <= max_norm:   # below threshold -> untouched
        np.testing.assert_allclose(np.asarray(clipped["a"]), 10.0)


def test_wsd_schedule_phases():
    f = schedules.wsd_schedule(1.0, warmup_steps=10, stable_steps=100,
                               decay_steps=50)
    assert float(f(0)) == 0.0
    assert float(f(5)) == pytest.approx(0.5)
    assert float(f(50)) == pytest.approx(1.0)       # stable
    assert float(f(109)) == pytest.approx(1.0)
    assert float(f(160)) == pytest.approx(0.01, rel=1e-3)  # decayed
    # monotone decay inside the decay window
    assert float(f(120)) > float(f(140)) > float(f(159))


# ----------------------------------------------------------- compression
@given(st.integers(1, 2000), st.floats(0.01, 100.0))
@settings(max_examples=25, deadline=None)
def test_int8_roundtrip_error_bound(n, scale):
    rng = np.random.default_rng(n)
    g = jnp.asarray(rng.standard_normal(n) * scale, jnp.float32)
    q, s = compression.compress_int8(g)
    deq = compression.decompress_int8(q, s, g.shape, jnp.float32)
    # block-wise max error is scale/127 per block
    err = np.abs(np.asarray(deq - g))
    block_max = np.asarray(jnp.abs(g)).max()
    assert err.max() <= block_max / 127.0 + 1e-6


def test_error_feedback_preserves_sum():
    """Property: over k steps, sum(dequantized) + final_error == sum(grads)
    — error feedback never loses gradient mass."""
    rng = np.random.default_rng(0)
    params = {"w": jnp.zeros((64,))}
    err = compression.init_error(params)
    total_in, total_out = np.zeros(64), np.zeros(64)
    for i in range(10):
        g = {"w": jnp.asarray(rng.standard_normal(64) * 0.01, jnp.float32)}
        total_in += np.asarray(g["w"])
        deq, err = compression.compressed_allreduce_update(g, err)
        total_out += np.asarray(deq["w"])
    np.testing.assert_allclose(total_out + np.asarray(err["w"]), total_in,
                               rtol=1e-4, atol=1e-6)


# ------------------------------------------------------------ checkpoint
def _tree():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.ones((4,))},
            "opt": {"step": jnp.asarray(7, jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path / "ckpt")
    state = _tree()
    save_checkpoint(d, 10, state, extra={"data": {"step": 5}})
    out = restore_latest(d, jax.tree.map(jnp.zeros_like, state))
    assert out is not None
    step, restored, extra = out
    assert step == 10 and extra == {"data": {"step": 5}}
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_k_and_latest(tmp_path):
    d = str(tmp_path / "ckpt")
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(d, s, _tree(), keep=2)
    dirs = sorted(os.listdir(d))
    assert dirs == ["step_00000004", "step_00000005"]


def test_checkpoint_atomic_no_partial_visible(tmp_path):
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, _tree())
    # a crashed write leaves only a .tmp dir -> restore must ignore it
    os.makedirs(os.path.join(d, "step_00000002.tmp"))
    out = restore_latest(d, jax.tree.map(jnp.zeros_like, _tree()))
    assert out[0] == 1


def test_checkpoint_elastic_restore_resharding(tmp_path):
    """Restore with explicit (degenerate 1-device) shardings — the elastic
    path: arrays land with the *current* mesh's sharding."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    d = str(tmp_path / "ckpt")
    state = _tree()
    save_checkpoint(d, 3, state)
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
    step, restored, _ = restore_latest(d, jax.tree.map(jnp.zeros_like, state),
                                       shardings=sh)
    assert step == 3
    assert restored["params"]["w"].sharding == NamedSharding(mesh, P())


# -------------------------------------------------------- fault tolerance
def test_straggler_detection():
    t = StepTimer(min_steps=6, ratio=1.5, k_sigma=100.0)
    import time as _t
    for i in range(6):
        t.start()
        _t.sleep(0.01)
        assert t.stop(i) is None       # warmup: below min_steps, never flags
    t.start()
    _t.sleep(0.08)
    rep = t.stop(6)
    assert rep is not None and rep.duration_s > rep.threshold_s


def test_preemption_handler_flag():
    h = PreemptionHandler(install=False)
    assert not h.should_stop
    h.request_stop()
    assert h.should_stop
