"""Speculative decoding: multi-position verify bitwise identity, the
colocated and disaggregated serving loops' bit-identity to plain decode
across accept/reject boundaries, paged-pool conservation under rollback,
the trade-off analyzer's acceptance-rate pricing (including the
adversarial fall-back to plain decode), and the online acceptance veto.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cost_model import (expected_tokens_per_round,
                                   speculative_decode_cost)
from repro.core.device_models import get as get_device
from repro.models import transformer as T
from repro.obs.watchdog import AcceptanceTracker
from repro.serving import (DisaggregatedEngineLoop, EngineLoop, SpecPlan,
                           SpeculativeEngineLoop, choose_speculation,
                           synthetic_workload, validate_speculation)
from repro.serving.placement import drift_scaled_device

TGT = T.ModelConfig(name="spec-tgt", n_layers=3, d_model=32, n_heads=4,
                    n_kv_heads=2, d_ff=64, vocab=64, attention_impl="dot",
                    remat=False)
DRAFT = T.ModelConfig(name="spec-draft", n_layers=2, d_model=24, n_heads=4,
                      n_kv_heads=2, d_ff=48, vocab=64, attention_impl="dot",
                      remat=False)


@pytest.fixture(scope="module")
def params():
    return T.init_params(jax.random.PRNGKey(0), TGT)


@pytest.fixture(scope="module")
def dparams():
    return T.init_params(jax.random.PRNGKey(7), DRAFT)


def _clock():
    t = [0.0]

    def now():
        t[0] += 1e-3
        return t[0]

    return now


def _workload(seed=3, rate=1e9, n=9):
    return synthetic_workload(n, rate=rate, vocab=TGT.vocab,
                              prompt_lens=(5, 9), gen_lens=(4, 7, 13),
                              seed=seed)


def _run_colocated(params, *, plan=None, override=None, seed=3):
    reqs = _workload(seed=seed)
    kw = dict(n_slots=4, max_seq=32, block_size=8, kv_layout="paged")
    if plan is not None:
        loop = SpeculativeEngineLoop(TGT, params, plan=plan,
                                     propose_override=override, **kw)
    else:
        loop = EngineLoop(TGT, params, **kw)
    metrics = loop.run(reqs, now_fn=_clock())
    return {r.rid: list(r.output) for r in reqs}, metrics, loop


@pytest.fixture(scope="module")
def plain_outputs(params):
    outs, _, _ = _run_colocated(params)
    return outs


# ---------------------------------------------------------------------
# multi-position decode step == sequential single steps, bitwise
# ---------------------------------------------------------------------
def test_multi_step_bitwise_equals_sequential(params):
    B, MAX, BSZ, M = 3, 24, 8, 4
    rng = np.random.default_rng(0)
    toks = rng.integers(0, TGT.vocab, size=(B, 12)).astype(np.int32)
    active = jnp.asarray(np.array([True, True, False]))

    def fresh():
        c = T.init_slot_cache_paged(TGT, B, MAX, block_size=BSZ)
        bps = c["block_tables"].shape[1]
        c = dict(c)
        c["block_tables"] = jnp.asarray(
            np.arange(B * bps, dtype=np.int32).reshape(B, bps))
        return c

    c1, c2 = fresh(), fresh()
    for i in range(5):
        t = jnp.asarray(toks[:, i:i + 1])
        _, c1 = T.decode_step_slots_paged(params, TGT, c1, t, active,
                                          max_seq=MAX)
        _, c2 = T.decode_step_slots_paged(params, TGT, c2, t, active,
                                          max_seq=MAX)

    singles = []
    for i in range(5, 5 + M):
        lg, c1 = T.decode_step_slots_paged(
            params, TGT, c1, jnp.asarray(toks[:, i:i + 1]), active,
            max_seq=MAX)
        singles.append(lg[:, 0])
    single_logits = np.asarray(jnp.stack(singles, axis=1))

    multi_logits, c2 = T.decode_multi_step_slots_paged(
        params, TGT, c2, jnp.asarray(toks[:, 5:5 + M]), active,
        max_seq=MAX, advance=True)
    assert (np.asarray(multi_logits) == single_logits).all(), \
        "multi-position verify step must be BITWISE identical to " \
        "sequential decode steps — speculation's identity contract"
    assert (np.asarray(c1["pos"]) == np.asarray(c2["pos"])).all()

    # every live page of the KV arena matches too (the trash page —
    # index total_blocks, masked inactive slots write there and
    # attention never reads it — is the only page allowed to differ)
    a1 = [np.asarray(x) for x in jax.tree.leaves(c1["layers"])]
    a2 = [np.asarray(x) for x in jax.tree.leaves(c2["layers"])]
    assert all((x[:, :-1] == y[:, :-1]).all() for x, y in zip(a1, a2))

    # the serving path always runs jitted — same bits there
    jm = jax.jit(lambda p, c, t, a: T.decode_multi_step_slots_paged(
        p, TGT, c, t, a, max_seq=MAX, advance=True))
    c3 = fresh()
    for i in range(5):
        _, c3 = T.decode_step_slots_paged(
            params, TGT, c3, jnp.asarray(toks[:, i:i + 1]), active,
            max_seq=MAX)
    ml2, _ = jm(params, c3, jnp.asarray(toks[:, 5:5 + M]), active)
    assert (np.asarray(ml2) == single_logits).all()


# ---------------------------------------------------------------------
# serving bit-identity: speculative == plain, colocated
# ---------------------------------------------------------------------
@pytest.mark.parametrize("k", [1, 2, 3, 4])
def test_colocated_identity_all_depths(params, dparams, plain_outputs, k):
    plan = SpecPlan(draft_cfg=DRAFT, draft_params=dparams, k=k)
    outs, _, loop = _run_colocated(params, plan=plan)
    assert outs == plain_outputs
    st = loop.spec.stats()
    assert st["n_rounds"] > 0, "speculation never engaged"
    assert st["n_committed"] >= st["n_rounds"], \
        "every round commits at least the target's own token"


def test_self_draft_full_acceptance(params, plain_outputs):
    """Target drafting for itself accepts every proposal (alpha == 1)."""
    plan = SpecPlan(draft_cfg=TGT, draft_params=params, k=3)
    outs, _, loop = _run_colocated(params, plan=plan)
    assert outs == plain_outputs
    assert loop.spec.acceptance_rate == 1.0


@pytest.mark.parametrize("offset", [0, 1, 3])
def test_rejection_at_window_offset(params, plain_outputs, offset):
    """Corrupt the (otherwise perfect) self-draft's proposal at one
    window offset: rejection lands exactly there — first, middle, and
    last draft token — and outputs stay identical."""

    def corrupt(round_idx, proposals):
        p = proposals.copy()
        if offset < p.shape[1]:
            p[:, offset] = (p[:, offset] + 1) % TGT.vocab
        return p

    plan = SpecPlan(draft_cfg=TGT, draft_params=params, k=4)
    outs, _, loop = _run_colocated(params, plan=plan, override=corrupt)
    assert outs == plain_outputs
    # acceptance == accepted prefix of length `offset` every round
    assert loop.spec.acceptance_rate == pytest.approx(offset / 4)


def test_rollback_conserves_paged_pool(params, dparams):
    """Rejected verify windows must not leak or corrupt pages: after a
    speculative run the pool's ledger drains to empty, exactly like the
    plain run — rollback is a position move, never an alloc/free."""
    plan = SpecPlan(draft_cfg=DRAFT, draft_params=dparams, k=3)
    _, _, loop = _run_colocated(params, plan=plan)
    stats = loop.pool.stats()
    assert stats["slots_in_use"] == 0
    assert stats["blocks_in_use"] == 0
    assert stats["peak_slots_in_use"] > 0


# ---------------------------------------------------------------------
# disaggregated: speculation on the decode engine, hand-offs in flight
# ---------------------------------------------------------------------
@pytest.mark.parametrize("k,rate", [(1, 1e9), (2, 1e9), (2, 700.0),
                                    (3, 700.0)])
def test_disagg_identity_with_handoffs(params, dparams, plain_outputs, k,
                                       rate):
    reqs = _workload(rate=rate)
    loop = DisaggregatedEngineLoop(
        TGT, params, n_prefill_slots=4, n_decode_slots=4, max_seq=32,
        block_size=8,
        plan=SpecPlan(draft_cfg=DRAFT, draft_params=dparams, k=k))
    loop.run(reqs, now_fn=_clock())
    outs = {r.rid: list(r.output) for r in reqs}
    assert outs == plain_outputs
    assert loop.spec.stats()["n_rounds"] > 0
    assert loop.handoff.n_handoffs == len(reqs), \
        "every request crosses the phase hand-off exactly once"


def test_disagg_speculation_pins_actuation(params, dparams):
    """Speculation pins the decode engine: mid-run placement actuation
    must refuse rather than migrate the draft state."""
    loop = DisaggregatedEngineLoop(
        TGT, params, n_prefill_slots=4, n_decode_slots=4, max_seq=32,
        block_size=8,
        plan=SpecPlan(draft_cfg=DRAFT, draft_params=dparams, k=2))
    detail = loop._actuate_placement(decision=None)
    assert detail["actuated"] is False
    assert "speculative" in detail["reason"]


# ---------------------------------------------------------------------
# pricing: the trade-off analyzer's engage / fall-back decision
# ---------------------------------------------------------------------
def test_expected_tokens_per_round_bounds():
    assert expected_tokens_per_round(0.0, 4) == 1.0
    assert expected_tokens_per_round(1.0, 4) == 5.0
    # alpha=0.5, k=2: 0.5 + 0.25 + 1 = 1.75
    assert expected_tokens_per_round(0.5, 2) == pytest.approx(1.75)
    with pytest.raises(ValueError):
        expected_tokens_per_round(0.5, 0)


def test_speculative_cost_monotone_in_acceptance():
    lo = speculative_decode_cost(1e-4, 1e-3, 0.1, 3)
    hi = speculative_decode_cost(1e-4, 1e-3, 0.9, 3)
    assert hi < lo, "higher acceptance must price cheaper per token"


def _registry_pair():
    from repro.configs import registry
    return (registry.get("granite_34b").config,
            registry.get("qwen2_1_5b").config)


def test_choose_speculation_engages_cheap_draft():
    """The ISSUE pairing — a 1.5B draft for a 34B target — prices better
    than plain decode at realistic acceptance and the analyzer picks a
    depth from the candidate set."""
    tgt, draft = _registry_pair()
    d = choose_speculation(tgt, draft, kv_len=1024, n_tokens=8,
                           acceptance=0.9, draft_name="qwen2_1_5b")
    assert d.use
    assert d.k in (1, 2, 3, 4)
    assert d.spec_step_s < d.plain_step_s
    assert d.projected_speedup > 1.0
    s = d.summary()
    assert s["use"] and s["draft"] == "qwen2_1_5b" and len(s["table"]) == 4


def test_choose_speculation_adversarial_draft_price():
    """Price the draft's device 100x slower: even at 95% acceptance the
    analyzer must refuse speculation — the demonstrable fall-back."""
    tgt, draft = _registry_pair()
    slow = drift_scaled_device(get_device("tpu-v5e"), 100.0)
    d = choose_speculation(tgt, draft, kv_len=1024, n_tokens=8,
                           acceptance=0.95, draft_device=slow)
    assert not d.use, "a draft that costs more than the target must " \
                      "price speculation out"
    assert d.projected_speedup < 1.0


def test_choose_speculation_zero_acceptance_falls_back():
    tgt, draft = _registry_pair()
    d = choose_speculation(tgt, draft, kv_len=1024, n_tokens=8,
                           acceptance=0.0)
    assert not d.use


# ---------------------------------------------------------------------
# online veto: measured acceptance re-prices speculation off mid-run
# ---------------------------------------------------------------------
def test_acceptance_tracker_vetoes_on_redecision():
    decisions = []

    def decide(alpha):
        d = choose_speculation(TGT, DRAFT, kv_len=64, n_tokens=8,
                               acceptance=alpha)
        decisions.append((alpha, d.use))
        return d

    tr = AcceptanceTracker(warmup=2, redecide_every=2, decide=decide)
    for _ in range(6):
        tr.observe_round(8, 0)            # nothing ever accepted
    assert tr.disabled
    assert decisions and not decisions[-1][1]
    rep = tr.report()
    assert rep["disabled"] and rep["decisions"][-1]["use"] is False
    assert rep["acceptance_ewma"] == 0.0


def test_midrun_veto_keeps_outputs_identical(params, dparams,
                                             plain_outputs):
    """A tracker that vetoes after warmup disables speculation mid-run;
    the remaining tokens decode plain and outputs stay bit-identical."""

    class _Veto:
        use = False

    tracker = AcceptanceTracker(warmup=2, redecide_every=2,
                                decide=lambda alpha: _Veto())
    plan = SpecPlan(draft_cfg=DRAFT, draft_params=dparams, k=2,
                    tracker=tracker)
    outs, _, loop = _run_colocated(params, plan=plan)
    assert outs == plain_outputs
    assert loop.spec.disabled_midrun
    assert not loop.spec.enabled
    # the loop re-priced admission back to the plain analytic model
    assert loop.batcher.price_source == "speculation-disabled"


# ---------------------------------------------------------------------
# configuration guards
# ---------------------------------------------------------------------
def test_validate_speculation_rejects_bad_configs():
    with pytest.raises(ValueError, match="paged"):
        validate_speculation(TGT, DRAFT, kv_layout="dense",
                             prefix_sharing=False)
    with pytest.raises(ValueError, match="prefix sharing"):
        validate_speculation(TGT, DRAFT, kv_layout="paged",
                             prefix_sharing=True)
    other_vocab = T.ModelConfig(
        name="v128", n_layers=2, d_model=24, n_heads=4, n_kv_heads=2,
        d_ff=48, vocab=128, attention_impl="dot", remat=False)
    with pytest.raises(ValueError, match="vocab"):
        validate_speculation(TGT, other_vocab, kv_layout="paged",
                             prefix_sharing=False)


def test_multi_step_rejects_non_attention(params):
    hybrid = T.ModelConfig(
        name="hybrid", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab=64, attention_impl="dot", remat=False,
        block_pattern=("attn", "rec"))
    with pytest.raises(ValueError):
        validate_speculation(TGT, hybrid, kv_layout="paged",
                             prefix_sharing=False)
