"""Docs consistency gate.

Documentation drifts the moment nobody fails CI over it, so this module
cross-checks the prose against the code it describes:

* every flag a CLI parser actually exposes appears in ``docs/cli.md``
  (serve, profile, and the regression gate — all three export
  ``build_parser()`` precisely so this test can introspect them);
* every ``src/repro/*`` package appears in ``docs/architecture.md``'s
  module map;
* every intra-repo markdown link in ``README.md`` and ``docs/`` resolves
  to a real file, and anchored links resolve to a real heading.
"""
from __future__ import annotations

import re
from pathlib import Path

import pytest

from benchmarks import check_regression
from repro.launch import profile as profile_cli
from repro.launch import serve as serve_cli

REPO = Path(__file__).resolve().parent.parent
DOCS = REPO / "docs"

DOC_FILES = [REPO / "README.md", DOCS / "architecture.md", DOCS / "cli.md"]

PARSERS = {
    "repro.launch.serve": serve_cli.build_parser,
    "repro.launch.profile": profile_cli.build_parser,
    "benchmarks.check_regression": check_regression.build_parser,
}


def _flags(build_parser) -> list:
    """Every long option string the parser exposes, minus --help."""
    out = []
    for action in build_parser()._actions:
        out.extend(s for s in action.option_strings
                   if s.startswith("--") and s != "--help")
    return out


def test_docs_exist():
    for path in DOC_FILES:
        assert path.is_file(), f"missing doc: {path.relative_to(REPO)}"


@pytest.mark.parametrize("prog", sorted(PARSERS))
def test_every_cli_flag_documented(prog):
    text = (DOCS / "cli.md").read_text(encoding="utf-8")
    missing = [f for f in _flags(PARSERS[prog]) if f not in text]
    assert not missing, (
        f"{prog} flags missing from docs/cli.md: {missing} — "
        "document them (tables in docs/cli.md) or drop the flag")


@pytest.mark.parametrize("prog", sorted(PARSERS))
def test_no_phantom_flags_documented(prog):
    """Flags documented under a CLI's section must all still exist
    somewhere in that CLI (catches docs outliving a removed flag)."""
    real = {f for build in PARSERS.values() for f in _flags(build)}
    text = (DOCS / "cli.md").read_text(encoding="utf-8")
    documented = set(re.findall(r"`(--[a-z][a-z0-9-]*)\b", text))
    phantom = documented - real
    assert not phantom, f"docs/cli.md documents nonexistent flags: {phantom}"


def test_serve_options_match_serve_flags():
    """The programmatic API and the serve CLI stay 1:1: every ServeOptions
    leaf field has exactly one --flag and vice versa (rename or add on one
    side only and this fails)."""
    from repro.serving.api import ServeOptions
    flag_names = {f[2:].replace("-", "_")
                  for f in _flags(serve_cli.build_parser)}
    field_names = set(ServeOptions.flat_fields())
    assert flag_names == field_names, (
        f"serve CLI flags and ServeOptions fields diverged — "
        f"only flags: {sorted(flag_names - field_names)}, "
        f"only fields: {sorted(field_names - flag_names)}")


def test_every_package_in_module_map():
    text = (DOCS / "architecture.md").read_text(encoding="utf-8")
    packages = sorted(p.parent.name
                      for p in (REPO / "src" / "repro").glob("*/__init__.py"))
    assert packages, "no src/repro packages found — wrong repo layout?"
    missing = [p for p in packages
               if f"src/repro/{p}/" not in text]
    assert not missing, (
        f"packages missing from docs/architecture.md module map: {missing}")


_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def _github_slug(heading: str) -> str:
    """GitHub's anchor slug: strip markup, lowercase, drop punctuation,
    spaces to hyphens."""
    s = heading.replace("`", "").strip().lower()
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def _anchors(path: Path) -> set:
    return {_github_slug(h)
            for h in _HEADING.findall(path.read_text(encoding="utf-8"))}


@pytest.mark.parametrize("doc", DOC_FILES, ids=lambda p: p.name)
def test_intra_repo_links_resolve(doc):
    text = doc.read_text(encoding="utf-8")
    bad = []
    for target in _LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, fragment = target.partition("#")
        resolved = (doc.parent / path_part).resolve() if path_part else doc
        if not resolved.exists():
            bad.append(f"{target} (file missing)")
        elif fragment and resolved.suffix == ".md" \
                and fragment not in _anchors(resolved):
            bad.append(f"{target} (no such heading)")
    assert not bad, f"{doc.relative_to(REPO)} has dead links: {bad}"
