"""Per-architecture smoke tests: instantiate the REDUCED config of each
assigned arch, run one forward + one train step + one decode step on CPU,
assert output shapes and no NaNs.  (Full configs are exercised only via the
dry-run.)"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get
from repro.models import transformer as T
from repro.optim import adamw, schedules

LM_ARCHS = [n for n in ARCH_NAMES if n != "alexnet"]


def _batch_for(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
    }
    if cfg.encoder_decoder:
        batch["enc_inputs"] = jnp.asarray(
            rng.standard_normal((b, s, cfg.d_model)), jnp.float32)
    if cfg.frontend == "vision":
        batch["img_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.img_seq, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch_name", LM_ARCHS)
def test_smoke_forward_shapes_no_nan(arch_name):
    arch = get(arch_name)
    cfg = arch.smoke
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch_for(cfg)
    logits, _ = T.forward(params, cfg, batch["tokens"],
                          enc_inputs=batch.get("enc_inputs"),
                          img_embeds=batch.get("img_embeds"))
    assert logits.shape == (2, 32, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch_name", LM_ARCHS)
def test_smoke_train_step(arch_name):
    arch = get(arch_name)
    cfg = arch.smoke
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    init_opt, update = adamw.make_optimizer(schedules.constant(1e-3))
    opt = init_opt(params)
    batch = _batch_for(cfg)

    @jax.jit
    def step(p, o, b):
        loss, grads = jax.value_and_grad(T.loss_fn)(p, cfg, b)
        newp, newo, m = update(grads, o, p)
        return newp, newo, loss

    p1, o1, loss = step(params, opt, batch)
    assert bool(jnp.isfinite(loss)), arch_name
    # params actually changed
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in
                zip(jax.tree.leaves(p1), jax.tree.leaves(params)))
    assert delta > 0.0


@pytest.mark.parametrize("arch_name", LM_ARCHS)
def test_smoke_decode_step(arch_name):
    arch = get(arch_name)
    cfg = arch.smoke
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    cache = T.init_cache(cfg, batch=2, max_seq=16)
    if cfg.encoder_decoder:
        enc = jnp.asarray(np.random.default_rng(0).standard_normal(
            (2, 16, cfg.d_model)), jnp.float32)
        cache["cross"] = T.encode(params, cfg, enc)
    if cfg.frontend == "vision":
        cache["cross"] = jnp.asarray(np.random.default_rng(0).standard_normal(
            (2, cfg.img_seq, cfg.d_model)), jnp.bfloat16)
    tok = jnp.zeros((2, 1), jnp.int32)
    for _ in range(3):
        logits, cache = T.decode_step(params, cfg, cache, tok)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    assert logits.shape == (2, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert int(cache["pos"]) == 3


@pytest.mark.parametrize("arch_name", ["qwen2_1_5b", "mixtral_8x7b",
                                       "falcon_mamba_7b",
                                       "recurrentgemma_2b"])
def test_prefill_decode_consistency(arch_name):
    """Greedy continuation from a prefilled cache must match teacher-forced
    full-sequence logits (windowed archs: positions within the window).
    MoE: capacity_factor raised so no tokens drop — GShard capacity dropping
    is sequence-length dependent, which legitimately breaks step-vs-full
    equivalence at small capacity.  fp32 compute: this test checks
    STRUCTURAL equivalence; bf16 noise compounds over layers (router
    near-ties) and is covered by the bf16 smoke tests instead."""
    arch = get(arch_name)
    cfg = dataclasses.replace(arch.smoke, remat=False, capacity_factor=4.0,
                              compute_dtype=jnp.float32)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    s = 12
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (1, s)), jnp.int32)
    full_logits, _ = T.forward(params, cfg, tokens)

    cache = T.init_cache(cfg, batch=1, max_seq=s)
    outs = []
    for i in range(s):
        lg, cache = T.decode_step(params, cfg, cache, tokens[:, i:i + 1])
        outs.append(lg)
    dec_logits = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32), np.asarray(full_logits, np.float32),
        rtol=6e-2, atol=6e-2)


def test_count_params_matches_actual_tree():
    for arch_name in LM_ARCHS:
        cfg = get(arch_name).smoke
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        actual = sum(np.prod(p.shape) for p in jax.tree.leaves(params))
        predicted = T.count_params(cfg)
        assert abs(actual - predicted) / actual < 0.03, \
            (arch_name, actual, predicted)
