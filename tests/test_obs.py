"""Observability layer: tracer, metrics registry, export, telemetry feedback.

The contracts this file pins:
  * tracing is passive — a traced run's outputs, step counts and admission
    accounting are bit-identical to an untraced run, and the NullTracer
    records nothing while keeping the shared time source functional;
  * a traced run covers the whole request lifecycle with balanced spans on
    the injected deterministic clock (queued/prefill/decode per rid, burst
    and sync on the engine tracks, first_token/done instants, kv block
    lease events, the hand-off span in disaggregated mode) and the trace
    is reproducible event-for-event under the same virtual clock;
  * the exporter emits strict JSON Chrome trace-event / metrics files
    (no NaN tokens) that ``check_regression --trace`` validates;
  * ``ServeMetrics`` mirrors into the registry, the ``HandoffLedger`` is a
    thin view over registry counters, and zero-completion summaries report
    ``None`` percentiles, never NaN;
  * fed burst telemetry round-trips: cache entries validate against the
    profiling-cache schema and ``MeasuredPricer`` retrieves them under the
    exact (fingerprint, engine, environment) key admission pricing uses,
    with per-layer medians summing back to the observed step time.
"""
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.check_regression import validate_trace
from repro.core.engines import XLA_ENGINE
from repro.models import transformer as T
from repro.obs import (MetricsRegistry, NullTracer, Observability,
                       TelemetryFeedback, Tracer)
from repro.obs.export import chrome_trace, write_metrics, write_trace
from repro.profiling.cache import (SCHEMA_VERSION, ProfileCache,
                                   validate_dict)
from repro.profiling.pricer import MeasuredPricer
from repro.serving import (DisaggregatedEngineLoop, EngineLoop, Request,
                           ServeMetrics, synthetic_workload)
from repro.serving.batcher import decode_network_spec
from repro.serving.disagg import HandoffLedger

TINY = T.ModelConfig(
    name="obs-tiny", n_layers=3, d_model=32, n_heads=4, n_kv_heads=2,
    d_ff=64, vocab=64, attention_impl="dot", remat=False)

MAX_LEN = 8 + 12


@pytest.fixture(scope="module")
def tiny_params():
    return T.init_params(jax.random.PRNGKey(0), TINY)


def _virtual_clock():
    t = [0.0]

    def now():
        t[0] += 1e-3
        return t[0]

    return now


def _workload(n=9, seed=11, gen_lens=(1, 3, 6, 12)):
    return synthetic_workload(n, rate=1e9, vocab=TINY.vocab,
                              prompt_lens=(4, 8), gen_lens=gen_lens,
                              seed=seed)


def _traced_run(tiny_params, *, disagg=False, n=9):
    obs = Observability(tracer=Tracer())
    reqs = _workload(n)
    if disagg:
        loop = DisaggregatedEngineLoop(TINY, tiny_params, n_prefill_slots=2,
                                       n_decode_slots=3, max_seq=MAX_LEN,
                                       obs=obs)
    else:
        loop = EngineLoop(TINY, tiny_params, n_slots=3, max_seq=MAX_LEN,
                          obs=obs)
    m = loop.run(reqs, now_fn=_virtual_clock())
    return obs, reqs, m, loop


# ------------------------------------------------------------ tracer core
def test_tracer_ring_buffer_bounded():
    tr = Tracer(capacity=8)
    for i in range(20):
        tr.instant(f"e{i}", track="server", t=float(i))
    assert len(tr) == 8
    assert tr.n_dropped == 12
    # ring semantics: the oldest events fell out, the newest survive
    assert [e.name for e in tr.events] == [f"e{i}" for i in range(12, 20)]


def test_tracer_spans_handles_and_clock():
    clock = _virtual_clock()
    tr = Tracer(clock)
    h = tr.begin("burst", track="engine:colocated", cat="engine",
                 args={"steps": 4})
    h2 = tr.begin("sync", track="engine:colocated")
    assert tr.n_open == 2
    tr.end(h2)
    tr.end(h, args={"synced": True})
    assert tr.n_open == 0
    (sp,) = tr.spans("burst")
    assert sp.ph == "X" and sp.dur >= 0
    assert sp.args == {"steps": 4, "synced": True}   # end() merges args
    # explicit-stamp spans land where the caller says, clamped to dur >= 0
    tr.span("queued", 5.0, 4.0, track="requests", tid=7)
    (q,) = tr.spans("queued")
    assert q.ts == 5.0 and q.dur == 0.0 and q.tid == 7
    # same-named tracks share a pid; new names get fresh ones
    assert tr.track("requests") == tr.track("requests") != tr.track("server")


def test_null_tracer_is_inert_but_keeps_time():
    nt = NullTracer()
    nt.set_clock(_virtual_clock())
    assert not nt.enabled
    t1, t2 = nt.now(), nt.now()
    assert t2 > t1                       # the shared time source still works
    h = nt.begin("x", track="y")
    nt.end(h)
    nt.instant("z", track="w")
    nt.counter("c", {"v": 1.0}, track="server")
    nt.span("s", 0.0, 1.0, track="y")
    assert len(nt) == 0 and nt.spans() == [] and nt.n_open == 0
    assert nt.track("anything") == 0


# ------------------------------------------------- traced serving lifecycle
def test_traced_run_covers_request_lifecycle(tiny_params):
    obs, reqs, m, loop = _traced_run(tiny_params)
    tr = obs.tracer
    rids = {r.rid for r in reqs}
    assert m.n_done == 9 and tr.n_open == 0 and tr.n_dropped == 0
    # one lifecycle span of each stage per request, on the requests track
    for name in ("queued", "prefill", "decode"):
        spans = tr.spans(name)
        assert {e.tid for e in spans} == rids, name
        assert all(e.pid == tr.tracks["requests"] for e in spans)
    # admission records the priced per-step cost it admitted against
    for q in tr.spans("queued"):
        assert q.args["priced_step_s"] > 0
    # decode spans carry priced vs observed step cost for the comparison
    for d in tr.spans("decode"):
        assert d.args["priced_step_s"] > 0 and d.args["observed_step_s"] >= 0
    # first_token + done instants per request; kv lease events balance
    insts = [e for e in tr.events if e.ph == "i"]
    by_name = {}
    for e in insts:
        by_name.setdefault(e.name, set()).add(e.tid)
    assert by_name["first_token"] == by_name["done"] == rids
    assert by_name["kv_alloc"] == by_name["kv_free"] == rids
    # engine-level spans on their own track
    assert tr.spans("burst") and "engine:colocated" in tr.tracks
    # per-request ordering on the shared clock: admission precedes the
    # phase flip precedes completion
    ends = {}
    for name in ("queued", "prefill", "decode"):
        for e in tr.spans(name):
            ends.setdefault(e.tid, {})[name] = e.ts + e.dur
    for rid, e in ends.items():
        assert e["queued"] <= e["prefill"] <= e["decode"], rid


def test_traced_run_is_deterministic_under_virtual_clock(tiny_params):
    def key(obs):
        return [(e.name, e.ph, round(e.ts, 9), e.pid, e.tid,
                 round(e.dur or 0.0, 9)) for e in obs.tracer.events]

    a, _, _, _ = _traced_run(tiny_params)
    b, _, _, _ = _traced_run(tiny_params)
    assert key(a) == key(b)              # golden: same clock, same trace


def test_tracing_preserves_outputs_and_scheduling(tiny_params):
    plain_reqs = _workload()
    plain = EngineLoop(TINY, tiny_params, n_slots=3, max_seq=MAX_LEN)
    m_plain = plain.run(plain_reqs, now_fn=_virtual_clock())
    obs, traced_reqs, m_traced, loop = _traced_run(tiny_params)
    assert {r.rid: r.output for r in traced_reqs} == \
        {r.rid: r.output for r in plain_reqs}
    assert m_traced.n_steps == m_plain.n_steps
    assert loop.batcher.n_admitted == plain.batcher.n_admitted
    # the untraced loop defaults to a NullTracer: nothing recorded
    assert isinstance(plain.obs.tracer, NullTracer)


def test_traced_disaggregated_handoff_spans(tiny_params):
    obs, reqs, m, dis = _traced_run(tiny_params, disagg=True)
    tr = obs.tracer
    rids = {r.rid for r in reqs}
    assert m.n_done == 9 and tr.n_open == 0
    handoffs = tr.spans("handoff")
    assert {e.tid for e in handoffs} == rids
    for h in handoffs:
        assert h.args["bytes"] > 0 and h.args["modeled_s"] >= 0
    # the ledger is a view over the same registry the spans accompany
    assert dis.handoff.n_handoffs == len(handoffs) == 9
    assert dis.handoff.bytes_moved == sum(h.args["bytes"] for h in handoffs)
    assert obs.registry.counters["handoff_n"].value == 9
    # both phase engines traced their bursts on their own tracks
    assert {"engine:prefill", "engine:decode"} <= set(tr.tracks)
    # a block lease on each phase's pool per request
    allocs = [e for e in tr.events if e.ph == "i" and e.name == "kv_alloc"]
    assert len(allocs) == 2 * len(rids)


def test_dropped_request_emits_instant_and_counter(tiny_params):
    # a prompt that can never fit the pool is dropped at admission
    big = Request(rid=0, prompt=np.zeros((30,), np.int32), max_new_tokens=4)
    ok = Request(rid=1, prompt=np.arange(4, dtype=np.int32),
                 max_new_tokens=2)
    obs = Observability(tracer=Tracer())
    eng = EngineLoop(TINY, tiny_params, n_slots=2, max_seq=16, obs=obs)
    m = eng.run([big, ok], now_fn=_virtual_clock())
    assert m.n_done == 1 and m.n_dropped == 1
    drops = [e for e in obs.tracer.events
             if e.ph == "i" and e.name == "dropped"]
    assert [e.tid for e in drops] == [0]
    assert "reason" in drops[0].args
    assert obs.registry.counters["requests_dropped"].value == 1


# ------------------------------------------------------- metrics registry
def test_driver_samples_gauges_into_series(tiny_params):
    obs, reqs, m, _ = _traced_run(tiny_params)
    reg = obs.registry
    assert reg.counters["requests_done"].value == 9
    assert reg.counters["tokens_out"].value == m.tokens_out
    assert reg.histograms["ttft_s"].count == 9
    assert reg.n_samples == len(reg.series) > 0
    # the sampled occupancy trajectory covers the run, not just its mean
    occ = reg.series_values("kv_occupancy")
    assert len(occ) == reg.n_samples and max(occ) > 0
    # admission totals land as gauges refreshed per iteration
    assert reg.gauges["admitted_total"].value == 9
    snap = reg.snapshot()
    json.dumps(snap, allow_nan=False)    # JSON-safe tree
    assert snap["series_dropped"] == 0


def test_servemetrics_mirrors_registry():
    reg = MetricsRegistry()
    m = ServeMetrics(registry=reg)
    r = Request(rid=3, prompt=np.arange(4, dtype=np.int32),
                max_new_tokens=2, arrival=1.0)
    r.t_first_dispatch, r.t_first_token, r.t_done = 1.5, 2.0, 3.0
    r.output = [5, 6]
    m.observe(r)
    m.drop(3)
    assert reg.counters["requests_done"].value == 1
    assert reg.counters["tokens_out"].value == 2
    assert reg.counters["requests_dropped"].value == 3
    assert reg.histograms["ttft_s"].summary()["p50"] == pytest.approx(1.0)
    assert reg.histograms["latency_s"].summary()["p50"] == pytest.approx(2.0)
    assert m.n_done == 1 and m.n_dropped == 3


def test_handoff_ledger_is_registry_view():
    import types
    reg = MetricsRegistry()
    led = HandoffLedger(registry=reg)
    price = types.SimpleNamespace(t_transfer=0.25, energy_j=1.5)
    led.record(100, price)
    led.record(50, price)
    assert led.n_handoffs == 2 and led.bytes_moved == 150
    assert led.modeled_s == pytest.approx(0.5)
    assert led.modeled_energy_j == pytest.approx(3.0)
    # the same numbers are visible through the registry snapshot
    snap = reg.snapshot()
    assert snap["counters"]["handoff_bytes"] == 150
    assert led.stats() == {"n_handoffs": 2, "bytes_moved": 150,
                           "modeled_s": 0.5, "modeled_energy_j": 3.0}


def test_zero_completion_summary_is_none_not_nan():
    s = ServeMetrics().summary()
    for k in ("ttft_p50_s", "ttft_p99_s", "tpot_p50_s", "latency_p50_s",
              "ttft_dispatch_p50_s"):
        assert s[k] is None              # regression: these were NaN
    json.dumps(s, allow_nan=False)       # and the report stays strict JSON
    empty = MetricsRegistry().histogram("h").summary()
    assert empty["count"] == 0 and empty["p50"] is None


# ---------------------------------------------------------------- export
def test_chrome_export_strict_json(tmp_path, tiny_params):
    obs, reqs, m, _ = _traced_run(tiny_params)
    trace = chrome_trace(obs.tracer)
    # strict JSON: round-trips with NaN/Infinity literals rejected
    text = json.dumps(trace, allow_nan=False)
    loaded = json.loads(text, parse_constant=lambda c: pytest.fail(c))
    events = loaded["traceEvents"]
    meta = [e for e in events if e["ph"] == "M"]
    assert {e["args"]["name"] for e in meta} == set(obs.tracer.tracks)
    for e in events:
        if e["ph"] == "X":
            assert e["dur"] >= 0 and math.isfinite(e["ts"])
        elif e["ph"] == "i":
            assert e["s"] == "t"
    assert loaded["otherData"]["n_open"] == 0
    # the file writers hold the same contract
    tpath = write_trace(obs.tracer, str(tmp_path / "trace.json"))
    mpath = write_metrics(obs.registry, str(tmp_path / "metrics.json"),
                          extra={"summary": m.summary()})
    with open(mpath) as f:
        metrics = json.load(f, parse_constant=lambda c: pytest.fail(c))
    assert metrics["summary"]["requests_done"] == 9
    assert metrics["counters"]["requests_done"] == 9
    assert json.load(open(tpath))["traceEvents"]


def test_check_regression_trace_gate(tmp_path, tiny_params):
    colo, _, _, _ = _traced_run(tiny_params)
    dis, _, _, _ = _traced_run(tiny_params, disagg=True)
    cpath = write_trace(colo.tracer, str(tmp_path / "colo.json"))
    dpath = write_trace(dis.tracer, str(tmp_path / "dis.json"))
    assert all(ok for _, ok, _ in validate_trace(cpath))
    assert all(ok for _, ok, _ in validate_trace(dpath,
                                                 require_handoff=True))
    # a colocated trace has no hand-off span: the stricter gate fails
    checks = dict((n, ok) for n, ok, _ in
                  validate_trace(cpath, require_handoff=True))
    assert checks["trace covers the request lifecycle"] is False
    # non-strict JSON (a NaN token) fails the first gate
    bad = tmp_path / "bad.json"
    with open(bad, "w") as f:
        json.dump({"traceEvents": [{"name": "x", "ph": "X",
                                    "ts": float("nan"), "pid": 1, "tid": 0,
                                    "dur": 1.0}]}, f)   # allow_nan default
    assert validate_trace(str(bad))[0][1] is False
    # an empty trace fails too
    empty = tmp_path / "empty.json"
    empty.write_text('{"traceEvents": []}')
    assert not all(ok for _, ok, _ in validate_trace(str(empty)))


# ---------------------------------------------------- telemetry feedback
def test_feedback_roundtrip_through_measured_pricer():
    fb = TelemetryFeedback(TINY, kv_len=MAX_LEN)
    fb.observe_burst(3, 4, 0.04)         # 10 ms/step at batch 3
    fb.observe_burst(3, 2, 0.018)        # 9 ms/step
    fb.observe_burst(0, 4, 0.04)         # guarded: no tokens
    fb.observe_burst(3, 4, 0.0)          # guarded: no elapsed time
    assert fb.batches == [3] and fb.n_bursts == 2
    cache = ProfileCache()
    n = fb.flush(cache)
    assert n == len(fb.measurements()) > 0
    # fed entries pass the cache schema check, keys and all
    assert validate_dict({"schema": SCHEMA_VERSION,
                          "entries": cache.entries}) == []
    for m in cache.measurements():
        assert m["source"] == "serving-telemetry"
    # MeasuredPricer (cache-only) retrieves every priced layer at the
    # exact key admission uses, and per-layer medians sum back to the
    # observed per-step median
    pricer = MeasuredPricer(cache, measure_on_miss=False, autosave=False)
    net = decode_network_spec(TINY, MAX_LEN)
    total = 0.0
    for spec in net:
        got = pricer.measurement_for(spec, XLA_ENGINE, batch=3,
                                     dtype=jnp.float32)
        if spec.flops(3) <= 0:
            assert got is None           # gather layers are never fed
            continue
        assert got is not None and got.t_median > 0
        total += got.t_median
    assert total == pytest.approx(0.0095)   # median of (10ms, 9ms) steps
    assert pricer.hits > 0 and pricer.misses == 0
    # an unobserved batch size is a clean miss, not a stale hit
    spec = next(s for s in net if s.flops(3) > 0)
    assert pricer.measurement_for(spec, XLA_ENGINE, batch=5,
                                  dtype=jnp.float32) is None


def test_serving_run_feeds_cache_bit_identically(tiny_params):
    plain_reqs = _workload()
    plain = EngineLoop(TINY, tiny_params, n_slots=3, max_seq=MAX_LEN)
    plain.run(plain_reqs, now_fn=_virtual_clock())

    fb = TelemetryFeedback(TINY, kv_len=MAX_LEN)
    obs = Observability(feedback=fb)
    fed_reqs = _workload()
    eng = EngineLoop(TINY, tiny_params, n_slots=3, max_seq=MAX_LEN, obs=obs)
    m = eng.run(fed_reqs, now_fn=_virtual_clock())
    assert m.n_done == 9
    # the burst sync only waits — outputs stay bit-identical
    assert {r.rid: r.output for r in fed_reqs} == \
        {r.rid: r.output for r in plain_reqs}
    assert fb.n_bursts > 0 and fb.batches   # observed real bursts
    assert all(1 <= b <= 3 for b in fb.batches)
    cache = ProfileCache()
    assert fb.flush(cache) > 0
    pricer = MeasuredPricer(cache, measure_on_miss=False, autosave=False)
    spec = next(s for s in decode_network_spec(TINY, MAX_LEN)
                if s.flops(max(fb.batches)) > 0)
    got = pricer.measurement_for(spec, XLA_ENGINE, batch=max(fb.batches),
                                 dtype=jnp.float32)
    assert got is not None and got.t_median > 0


def test_observability_defaults():
    obs = Observability()
    assert isinstance(obs.tracer, NullTracer)
    assert isinstance(obs.registry, MetricsRegistry)
    assert obs.feedback is None
    traced = Observability(tracer=Tracer())
    assert traced.tracer.enabled and traced.registry is not obs.registry
