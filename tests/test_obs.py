"""Observability layer: tracer, metrics registry, export, telemetry feedback.

The contracts this file pins:
  * tracing is passive — a traced run's outputs, step counts and admission
    accounting are bit-identical to an untraced run, and the NullTracer
    records nothing while keeping the shared time source functional;
  * a traced run covers the whole request lifecycle with balanced spans on
    the injected deterministic clock (queued/prefill/decode per rid, burst
    and sync on the engine tracks, first_token/done instants, kv block
    lease events, the hand-off span in disaggregated mode) and the trace
    is reproducible event-for-event under the same virtual clock;
  * the exporter emits strict JSON Chrome trace-event / metrics files
    (no NaN tokens) that ``check_regression --trace`` validates;
  * ``ServeMetrics`` mirrors into the registry, the ``HandoffLedger`` is a
    thin view over registry counters, and zero-completion summaries report
    ``None`` percentiles, never NaN;
  * fed burst telemetry round-trips: cache entries validate against the
    profiling-cache schema and ``MeasuredPricer`` retrieves them under the
    exact (fingerprint, engine, environment) key admission pricing uses,
    with per-layer medians summing back to the observed step time;
  * the watchdog control loop is safe and effective: latency(batch) fits
    are monotone (isotonic) with a scaled-analytic fallback below two
    telemetry points, alerts are warm-up-gated / edge-triggered / re-armed
    by re-pricing, cold-start (jit-compile) bursts are discarded per batch
    bucket, a well-priced watchdog run is bit-identical to the plain
    traced run, and an injected mispricing is detected and corrected
    mid-run without changing outputs;
  * degenerate zero-cost telemetry is rejected at both ends: underflowed
    layer shares never reach the cache and a zero-median cache entry is a
    pricer miss, never a "free" layer.
"""
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.check_regression import validate_trace
from repro.core.engines import XLA_ENGINE
from repro.models import transformer as T
from repro.obs import (MetricsRegistry, NullTracer, Observability,
                       TelemetryFeedback, Tracer)
from repro.obs.export import chrome_trace, write_metrics, write_trace
from repro.profiling.cache import (SCHEMA_VERSION, ProfileCache,
                                   validate_dict)
from repro.profiling.pricer import MeasuredPricer
from repro.serving import (DisaggregatedEngineLoop, EngineLoop, Request,
                           ServeMetrics, synthetic_workload)
from repro.serving.batcher import decode_network_spec
from repro.serving.disagg import HandoffLedger

TINY = T.ModelConfig(
    name="obs-tiny", n_layers=3, d_model=32, n_heads=4, n_kv_heads=2,
    d_ff=64, vocab=64, attention_impl="dot", remat=False)

MAX_LEN = 8 + 12


@pytest.fixture(scope="module")
def tiny_params():
    return T.init_params(jax.random.PRNGKey(0), TINY)


def _virtual_clock():
    t = [0.0]

    def now():
        t[0] += 1e-3
        return t[0]

    return now


def _workload(n=9, seed=11, gen_lens=(1, 3, 6, 12)):
    return synthetic_workload(n, rate=1e9, vocab=TINY.vocab,
                              prompt_lens=(4, 8), gen_lens=gen_lens,
                              seed=seed)


def _traced_run(tiny_params, *, disagg=False, n=9):
    obs = Observability(tracer=Tracer())
    reqs = _workload(n)
    if disagg:
        loop = DisaggregatedEngineLoop(TINY, tiny_params, n_prefill_slots=2,
                                       n_decode_slots=3, max_seq=MAX_LEN,
                                       obs=obs)
    else:
        loop = EngineLoop(TINY, tiny_params, n_slots=3, max_seq=MAX_LEN,
                          obs=obs)
    m = loop.run(reqs, now_fn=_virtual_clock())
    return obs, reqs, m, loop


# ------------------------------------------------------------ tracer core
def test_tracer_ring_buffer_bounded():
    tr = Tracer(capacity=8)
    for i in range(20):
        tr.instant(f"e{i}", track="server", t=float(i))
    assert len(tr) == 8
    assert tr.n_dropped == 12
    # ring semantics: the oldest events fell out, the newest survive
    assert [e.name for e in tr.events] == [f"e{i}" for i in range(12, 20)]


def test_tracer_spans_handles_and_clock():
    clock = _virtual_clock()
    tr = Tracer(clock)
    h = tr.begin("burst", track="engine:colocated", cat="engine",
                 args={"steps": 4})
    h2 = tr.begin("sync", track="engine:colocated")
    assert tr.n_open == 2
    tr.end(h2)
    tr.end(h, args={"synced": True})
    assert tr.n_open == 0
    (sp,) = tr.spans("burst")
    assert sp.ph == "X" and sp.dur >= 0
    assert sp.args == {"steps": 4, "synced": True}   # end() merges args
    # explicit-stamp spans land where the caller says, clamped to dur >= 0
    tr.span("queued", 5.0, 4.0, track="requests", tid=7)
    (q,) = tr.spans("queued")
    assert q.ts == 5.0 and q.dur == 0.0 and q.tid == 7
    # same-named tracks share a pid; new names get fresh ones
    assert tr.track("requests") == tr.track("requests") != tr.track("server")


def test_null_tracer_is_inert_but_keeps_time():
    nt = NullTracer()
    nt.set_clock(_virtual_clock())
    assert not nt.enabled
    t1, t2 = nt.now(), nt.now()
    assert t2 > t1                       # the shared time source still works
    h = nt.begin("x", track="y")
    nt.end(h)
    nt.instant("z", track="w")
    nt.counter("c", {"v": 1.0}, track="server")
    nt.span("s", 0.0, 1.0, track="y")
    assert len(nt) == 0 and nt.spans() == [] and nt.n_open == 0
    assert nt.track("anything") == 0


# ------------------------------------------------- traced serving lifecycle
def test_traced_run_covers_request_lifecycle(tiny_params):
    obs, reqs, m, loop = _traced_run(tiny_params)
    tr = obs.tracer
    rids = {r.rid for r in reqs}
    assert m.n_done == 9 and tr.n_open == 0 and tr.n_dropped == 0
    # one lifecycle span of each stage per request, on the requests track
    for name in ("queued", "prefill", "decode"):
        spans = tr.spans(name)
        assert {e.tid for e in spans} == rids, name
        assert all(e.pid == tr.tracks["requests"] for e in spans)
    # admission records the priced per-step cost it admitted against
    for q in tr.spans("queued"):
        assert q.args["priced_step_s"] > 0
    # decode spans carry priced vs observed step cost for the comparison
    for d in tr.spans("decode"):
        assert d.args["priced_step_s"] > 0 and d.args["observed_step_s"] >= 0
    # first_token + done instants per request; kv lease events balance
    insts = [e for e in tr.events if e.ph == "i"]
    by_name = {}
    for e in insts:
        by_name.setdefault(e.name, set()).add(e.tid)
    assert by_name["first_token"] == by_name["done"] == rids
    assert by_name["kv_alloc"] == by_name["kv_free"] == rids
    # engine-level spans on their own track
    assert tr.spans("burst") and "engine:colocated" in tr.tracks
    # per-request ordering on the shared clock: admission precedes the
    # phase flip precedes completion
    ends = {}
    for name in ("queued", "prefill", "decode"):
        for e in tr.spans(name):
            ends.setdefault(e.tid, {})[name] = e.ts + e.dur
    for rid, e in ends.items():
        assert e["queued"] <= e["prefill"] <= e["decode"], rid


def test_traced_run_is_deterministic_under_virtual_clock(tiny_params):
    def key(obs):
        return [(e.name, e.ph, round(e.ts, 9), e.pid, e.tid,
                 round(e.dur or 0.0, 9)) for e in obs.tracer.events]

    a, _, _, _ = _traced_run(tiny_params)
    b, _, _, _ = _traced_run(tiny_params)
    assert key(a) == key(b)              # golden: same clock, same trace


def test_tracing_preserves_outputs_and_scheduling(tiny_params):
    plain_reqs = _workload()
    plain = EngineLoop(TINY, tiny_params, n_slots=3, max_seq=MAX_LEN)
    m_plain = plain.run(plain_reqs, now_fn=_virtual_clock())
    obs, traced_reqs, m_traced, loop = _traced_run(tiny_params)
    assert {r.rid: r.output for r in traced_reqs} == \
        {r.rid: r.output for r in plain_reqs}
    assert m_traced.n_steps == m_plain.n_steps
    assert loop.batcher.n_admitted == plain.batcher.n_admitted
    # the untraced loop defaults to a NullTracer: nothing recorded
    assert isinstance(plain.obs.tracer, NullTracer)


def test_traced_disaggregated_handoff_spans(tiny_params):
    obs, reqs, m, dis = _traced_run(tiny_params, disagg=True)
    tr = obs.tracer
    rids = {r.rid for r in reqs}
    assert m.n_done == 9 and tr.n_open == 0
    handoffs = tr.spans("handoff")
    assert {e.tid for e in handoffs} == rids
    for h in handoffs:
        assert h.args["bytes"] > 0 and h.args["modeled_s"] >= 0
    # the ledger is a view over the same registry the spans accompany
    assert dis.handoff.n_handoffs == len(handoffs) == 9
    assert dis.handoff.bytes_moved == sum(h.args["bytes"] for h in handoffs)
    assert obs.registry.counters["handoff_n"].value == 9
    # both phase engines traced their bursts on their own tracks
    assert {"engine:prefill", "engine:decode"} <= set(tr.tracks)
    # a block lease on each phase's pool per request
    allocs = [e for e in tr.events if e.ph == "i" and e.name == "kv_alloc"]
    assert len(allocs) == 2 * len(rids)


def test_dropped_request_emits_instant_and_counter(tiny_params):
    # a prompt that can never fit the pool is dropped at admission
    big = Request(rid=0, prompt=np.zeros((30,), np.int32), max_new_tokens=4)
    ok = Request(rid=1, prompt=np.arange(4, dtype=np.int32),
                 max_new_tokens=2)
    obs = Observability(tracer=Tracer())
    eng = EngineLoop(TINY, tiny_params, n_slots=2, max_seq=16, obs=obs)
    m = eng.run([big, ok], now_fn=_virtual_clock())
    assert m.n_done == 1 and m.n_dropped == 1
    drops = [e for e in obs.tracer.events
             if e.ph == "i" and e.name == "dropped"]
    assert [e.tid for e in drops] == [0]
    assert "reason" in drops[0].args
    assert obs.registry.counters["requests_dropped"].value == 1


# ------------------------------------------------------- metrics registry
def test_driver_samples_gauges_into_series(tiny_params):
    obs, reqs, m, _ = _traced_run(tiny_params)
    reg = obs.registry
    assert reg.counters["requests_done"].value == 9
    assert reg.counters["tokens_out"].value == m.tokens_out
    assert reg.histograms["ttft_s"].count == 9
    assert reg.n_samples == len(reg.series) > 0
    # the sampled occupancy trajectory covers the run, not just its mean
    occ = reg.series_values("kv_occupancy")
    assert len(occ) == reg.n_samples and max(occ) > 0
    # admission totals land as gauges refreshed per iteration
    assert reg.gauges["admitted_total"].value == 9
    snap = reg.snapshot()
    json.dumps(snap, allow_nan=False)    # JSON-safe tree
    assert snap["series_dropped"] == 0


def test_servemetrics_mirrors_registry():
    reg = MetricsRegistry()
    m = ServeMetrics(registry=reg)
    r = Request(rid=3, prompt=np.arange(4, dtype=np.int32),
                max_new_tokens=2, arrival=1.0)
    r.t_first_dispatch, r.t_first_token, r.t_done = 1.5, 2.0, 3.0
    r.output = [5, 6]
    m.observe(r)
    m.drop(3)
    assert reg.counters["requests_done"].value == 1
    assert reg.counters["tokens_out"].value == 2
    assert reg.counters["requests_dropped"].value == 3
    assert reg.histograms["ttft_s"].summary()["p50"] == pytest.approx(1.0)
    assert reg.histograms["latency_s"].summary()["p50"] == pytest.approx(2.0)
    assert m.n_done == 1 and m.n_dropped == 3


def test_handoff_ledger_is_registry_view():
    import types
    reg = MetricsRegistry()
    led = HandoffLedger(registry=reg)
    price = types.SimpleNamespace(t_transfer=0.25, energy_j=1.5)
    led.record(100, price)
    led.record(50, price)
    assert led.n_handoffs == 2 and led.bytes_moved == 150
    assert led.modeled_s == pytest.approx(0.5)
    assert led.modeled_energy_j == pytest.approx(3.0)
    # the same numbers are visible through the registry snapshot
    snap = reg.snapshot()
    assert snap["counters"]["handoff_bytes"] == 150
    assert led.stats() == {"n_handoffs": 2, "bytes_moved": 150,
                           "modeled_s": 0.5, "modeled_energy_j": 3.0,
                           "stall_s": 0.0, "overlap_s": 0.0,
                           "n_live_migrations": 0}


def test_zero_completion_summary_is_none_not_nan():
    s = ServeMetrics().summary()
    for k in ("ttft_p50_s", "ttft_p99_s", "tpot_p50_s", "latency_p50_s",
              "ttft_dispatch_p50_s"):
        assert s[k] is None              # regression: these were NaN
    json.dumps(s, allow_nan=False)       # and the report stays strict JSON
    empty = MetricsRegistry().histogram("h").summary()
    assert empty["count"] == 0 and empty["p50"] is None


# ---------------------------------------------------------------- export
def test_chrome_export_strict_json(tmp_path, tiny_params):
    obs, reqs, m, _ = _traced_run(tiny_params)
    trace = chrome_trace(obs.tracer)
    # strict JSON: round-trips with NaN/Infinity literals rejected
    text = json.dumps(trace, allow_nan=False)
    loaded = json.loads(text, parse_constant=lambda c: pytest.fail(c))
    events = loaded["traceEvents"]
    meta = [e for e in events if e["ph"] == "M"]
    assert {e["args"]["name"] for e in meta} == set(obs.tracer.tracks)
    for e in events:
        if e["ph"] == "X":
            assert e["dur"] >= 0 and math.isfinite(e["ts"])
        elif e["ph"] == "i":
            assert e["s"] == "t"
    assert loaded["otherData"]["n_open"] == 0
    # the file writers hold the same contract
    tpath = write_trace(obs.tracer, str(tmp_path / "trace.json"))
    mpath = write_metrics(obs.registry, str(tmp_path / "metrics.json"),
                          extra={"summary": m.summary()})
    with open(mpath) as f:
        metrics = json.load(f, parse_constant=lambda c: pytest.fail(c))
    assert metrics["summary"]["requests_done"] == 9
    assert metrics["counters"]["requests_done"] == 9
    assert json.load(open(tpath))["traceEvents"]


def test_check_regression_trace_gate(tmp_path, tiny_params):
    colo, _, _, _ = _traced_run(tiny_params)
    dis, _, _, _ = _traced_run(tiny_params, disagg=True)
    cpath = write_trace(colo.tracer, str(tmp_path / "colo.json"))
    dpath = write_trace(dis.tracer, str(tmp_path / "dis.json"))
    assert all(ok for _, ok, _ in validate_trace(cpath))
    assert all(ok for _, ok, _ in validate_trace(dpath,
                                                 require_handoff=True))
    # a colocated trace has no hand-off span: the stricter gate fails
    checks = dict((n, ok) for n, ok, _ in
                  validate_trace(cpath, require_handoff=True))
    assert checks["trace covers the request lifecycle"] is False
    # non-strict JSON (a NaN token) fails the first gate
    bad = tmp_path / "bad.json"
    with open(bad, "w") as f:
        json.dump({"traceEvents": [{"name": "x", "ph": "X",
                                    "ts": float("nan"), "pid": 1, "tid": 0,
                                    "dur": 1.0}]}, f)   # allow_nan default
    assert validate_trace(str(bad))[0][1] is False
    # an empty trace fails too
    empty = tmp_path / "empty.json"
    empty.write_text('{"traceEvents": []}')
    assert not all(ok for _, ok, _ in validate_trace(str(empty)))


# ---------------------------------------------------- telemetry feedback
def test_feedback_roundtrip_through_measured_pricer():
    fb = TelemetryFeedback(TINY, kv_len=MAX_LEN)
    fb.observe_burst(3, 4, 0.04)         # 10 ms/step at batch 3
    fb.observe_burst(3, 2, 0.018)        # 9 ms/step
    fb.observe_burst(0, 4, 0.04)         # guarded: no tokens
    fb.observe_burst(3, 4, 0.0)          # guarded: no elapsed time
    assert fb.batches == [3] and fb.n_bursts == 2
    cache = ProfileCache()
    n = fb.flush(cache)
    assert n == len(fb.measurements()) > 0
    # fed entries pass the cache schema check, keys and all
    assert validate_dict({"schema": SCHEMA_VERSION,
                          "entries": cache.entries}) == []
    for m in cache.measurements():
        assert m["source"] == "serving-telemetry"
    # MeasuredPricer (cache-only) retrieves every priced layer at the
    # exact key admission uses, and per-layer medians sum back to the
    # observed per-step median
    pricer = MeasuredPricer(cache, measure_on_miss=False, autosave=False)
    net = decode_network_spec(TINY, MAX_LEN)
    total = 0.0
    for spec in net:
        got = pricer.measurement_for(spec, XLA_ENGINE, batch=3,
                                     dtype=jnp.float32)
        if spec.flops(3) <= 0:
            assert got is None           # gather layers are never fed
            continue
        assert got is not None and got.t_median > 0
        total += got.t_median
    assert total == pytest.approx(0.0095)   # median of (10ms, 9ms) steps
    assert pricer.hits > 0 and pricer.misses == 0
    # an unobserved batch size is a clean miss, not a stale hit
    spec = next(s for s in net if s.flops(3) > 0)
    assert pricer.measurement_for(spec, XLA_ENGINE, batch=5,
                                  dtype=jnp.float32) is None


def test_serving_run_feeds_cache_bit_identically(tiny_params):
    plain_reqs = _workload()
    plain = EngineLoop(TINY, tiny_params, n_slots=3, max_seq=MAX_LEN)
    plain.run(plain_reqs, now_fn=_virtual_clock())

    fb = TelemetryFeedback(TINY, kv_len=MAX_LEN)
    obs = Observability(feedback=fb)
    fed_reqs = _workload()
    eng = EngineLoop(TINY, tiny_params, n_slots=3, max_seq=MAX_LEN, obs=obs)
    m = eng.run(fed_reqs, now_fn=_virtual_clock())
    assert m.n_done == 9
    # the burst sync only waits — outputs stay bit-identical
    assert {r.rid: r.output for r in fed_reqs} == \
        {r.rid: r.output for r in plain_reqs}
    assert fb.n_bursts > 0 and fb.batches   # observed real bursts
    assert all(1 <= b <= 3 for b in fb.batches)
    cache = ProfileCache()
    assert fb.flush(cache) > 0
    pricer = MeasuredPricer(cache, measure_on_miss=False, autosave=False)
    spec = next(s for s in decode_network_spec(TINY, MAX_LEN)
                if s.flops(max(fb.batches)) > 0)
    got = pricer.measurement_for(spec, XLA_ENGINE, batch=max(fb.batches),
                                 dtype=jnp.float32)
    assert got is not None and got.t_median > 0


def test_observability_defaults():
    obs = Observability()
    assert isinstance(obs.tracer, NullTracer)
    assert isinstance(obs.registry, MetricsRegistry)
    assert obs.feedback is None and obs.watchdog is None
    traced = Observability(tracer=Tracer())
    assert traced.tracer.enabled and traced.registry is not obs.registry


# ------------------------------------------------------- latency curves
def test_piecewise_interp_contract():
    from repro.core.cost_model import piecewise_interp
    xs, ys = [2.0, 4.0, 8.0], [1.0, 2.0, 3.0]
    assert piecewise_interp(xs, ys, 4.0) == pytest.approx(2.0)   # knot
    assert piecewise_interp(xs, ys, 3.0) == pytest.approx(1.5)   # interior
    # extrapolation continues the clamped edge slope
    assert piecewise_interp(xs, ys, 10.0) == pytest.approx(3.5)
    assert piecewise_interp(xs, ys, 1.0) == pytest.approx(0.5)
    # and never goes negative even when the edge slope would
    assert piecewise_interp([1.0, 2.0], [1.0, 0.1], 100.0) == 0.0
    with pytest.raises(ValueError):
        piecewise_interp([1.0], [1.0], 1.0)        # < 2 knots
    with pytest.raises(ValueError):
        piecewise_interp([2.0, 2.0], [1.0, 1.0], 1.0)   # not increasing


def test_isotonic_fit_restores_monotonicity():
    from repro.obs.curves import isotonic_fit
    ys = [1.0, 3.0, 2.0, 5.0]
    fit = isotonic_fit(ys)
    assert all(b >= a for a, b in zip(fit, fit[1:]))
    # PAV merges the violating pair to its mean, leaves the rest alone
    assert fit == pytest.approx([1.0, 2.5, 2.5, 5.0])
    assert isotonic_fit([1.0, 2.0, 3.0]) == pytest.approx([1.0, 2.0, 3.0])


def test_fitted_curve_from_non_monotone_telemetry():
    from repro.obs.curves import fit_latency_curve, median_points
    # batch 2 measured *below* batch 1 (noise): the fit must come out
    # monotone — a latency(batch) curve that dips would let admission
    # claim a bigger batch is cheaper than a smaller one
    curve = fit_latency_curve(
        median_points({1: [0.010], 2: [0.008, 0.009], 4: [0.016]}))
    assert curve is not None and curve.batches == (1, 2, 4)
    assert all(b >= a for a, b in zip(curve.step_s, curve.step_s[1:]))
    assert curve.raw_step_s == (0.010, 0.0085, 0.016)   # medians survive
    assert curve.predict(3) == pytest.approx(
        (curve.step_s[1] + curve.step_s[2]) / 2)
    # residuals quantify what isotonicity changed, per knot
    res = curve.residuals()
    assert res[1] > 0 and res[4] == pytest.approx(0.0)
    assert curve.max_batch_within(curve.step_s[1], 8) >= 2
    json.dumps(curve.summary(), allow_nan=False)


def test_single_telemetry_point_falls_back_to_scaled_analytic():
    from repro.obs import PerfWatchdog
    from repro.obs.curves import fit_latency_curve, median_points
    assert fit_latency_curve(
        median_points({4: [0.01, 0.012]})) is None   # one batch size
    assert fit_latency_curve({}) is None
    wd = PerfWatchdog(skip_first=0)
    analytic = lambda n: 1e-3 * n                          # noqa: E731
    # nothing observed: the analytic model passes through untouched
    fn, source = wd.step_time_fn("eng", "decode", analytic)
    assert source == "analytic" and fn is analytic
    # one batch size observed: analytic *shape* scaled by the EWMA ratio
    wd.observe_burst("eng", "decode", n_tokens=2, steps=10, elapsed_s=0.04,
                     priced_step_s=2e-3)
    fn, source = wd.step_time_fn("eng", "decode", analytic)
    assert source == "scaled-analytic"
    assert fn(2) == pytest.approx(2e-3 * 2.0)   # ratio = 4ms/2ms = 2
    assert wd.curve("eng", "decode") is None
    # two batch sizes observed: the fitted curve takes over
    wd.observe_burst("eng", "decode", n_tokens=4, steps=10, elapsed_s=0.08,
                     priced_step_s=4e-3)
    fn, source = wd.step_time_fn("eng", "decode", analytic)
    assert source == "fitted-curve"
    assert fn(2) == pytest.approx(4e-3) and fn(4) == pytest.approx(8e-3)


# ------------------------------------------------------- watchdog detector
def test_watchdog_warmup_gates_alerts_and_reprice_rearms():
    from repro.obs import PerfWatchdog
    wd = PerfWatchdog(warmup=4, skip_first=0, drift_gate=1.5,
                      ewma_alpha=1.0)
    feed = lambda: wd.observe_burst(                       # noqa: E731
        "eng", "decode", n_tokens=2, steps=1, elapsed_s=0.01,
        priced_step_s=1e-3)                                # ratio 10x
    for _ in range(3):
        assert feed() is None            # divergent but still warming up
    assert wd.alerts == [] and wd.pending_actions() == []
    alert = feed()                       # 4th observation crosses the gate
    assert alert is not None and alert.direction == "slow"
    assert alert.ewma_ratio == pytest.approx(10.0) and alert.n_obs == 4
    # edge-triggered: the alert stays active, no duplicates pile up
    assert feed() is None and len(wd.alerts) == 1
    assert wd.pending_actions() == [alert] and wd.pending_actions() == []
    # acting re-arms: the stream must re-warm against the new price
    wd.note_reprice(alert, {"pricing": "scaled-analytic"})
    assert wd.reprices[0]["pricing"] == "scaled-analytic"
    for _ in range(3):
        assert feed() is None
    assert feed() is not None and len(wd.alerts) == 2


def test_watchdog_skips_cold_start_burst_per_bucket():
    from repro.obs import PerfWatchdog
    wd = PerfWatchdog(warmup=1, skip_first=1, ewma_alpha=1.0)
    # first burst at bucket 2 carries jit compile time: ignored entirely
    wd.observe_burst("eng", "decode", n_tokens=2, steps=1, elapsed_s=30.0,
                     priced_step_s=1e-3)
    assert wd.ewma("eng", "decode") is None
    assert wd.curve("eng", "decode") is None
    wd.observe_burst("eng", "decode", n_tokens=2, steps=1, elapsed_s=2e-3,
                     priced_step_s=1e-3)
    assert wd.ewma("eng", "decode") == pytest.approx(2.0)
    # a new bucket (4) recompiles: its first burst is skipped too, while
    # the warm bucket keeps observing
    wd.observe_burst("eng", "decode", n_tokens=4, steps=1, elapsed_s=30.0,
                     priced_step_s=1e-3)
    assert wd.ewma("eng", "decode") == pytest.approx(2.0)
    wd.observe_burst("eng", "decode", n_tokens=4, steps=1, elapsed_s=4e-3,
                     priced_step_s=1e-3)
    st = wd.report()["streams"]["eng/decode"]
    assert st["batches_observed"] == [2, 4]


def test_watchdog_instrumentation_lands_in_registry_and_trace():
    from repro.obs import PerfWatchdog
    reg, tr = MetricsRegistry(), Tracer(_virtual_clock())
    wd = PerfWatchdog(warmup=2, skip_first=0, ewma_alpha=1.0)
    obs = Observability(tracer=tr, registry=reg, watchdog=wd)
    assert obs.watchdog is wd            # bundle binds and exposes it
    for _ in range(2):
        wd.observe_burst("eng", "decode", n_tokens=2, steps=1,
                         elapsed_s=0.01, priced_step_s=1e-3)
    (alert,) = wd.pending_actions()
    wd.note_reprice(alert, {"pricing": "fitted-curve", "token_budget": 4})
    assert reg.counters["watchdog_observations"].value == 2
    assert reg.counters["watchdog_alerts"].value == 1
    assert reg.counters["watchdog_reprices"].value == 1
    assert reg.gauges["drift_eng_decode"].value == pytest.approx(10.0)
    names = [e.name for e in tr.events if e.ph == "i"]
    assert "drift_alert" in names and "reprice" in names
    counters = [e for e in tr.events if e.ph == "C" and e.name == "drift"]
    assert counters and counters[-1].args["eng/decode"] == 10.0
    json.dumps(wd.report(), allow_nan=False)


def test_watchdog_sync_cadence_stretches_under_pressure():
    from repro.obs import PerfWatchdog
    wd = PerfWatchdog(skip_first=0, ewma_alpha=1.0, sync_budget_frac=0.25,
                      max_sync_every=4)
    assert wd.sync_cadence() == 1        # nothing observed yet
    wd.observe_burst("eng", "decode", n_tokens=2, steps=4, elapsed_s=0.1,
                     priced_step_s=1e-3)
    wd.observe_sync(0.01)                # 10% of burst cost: within budget
    assert wd.sync_cadence() == 1
    wd.observe_sync(0.2)                 # syncs dominate: stretch, capped
    assert wd.sync_cadence() == 4


# ------------------------------------------------------- the closed loop
def test_watchdog_run_bit_identical_to_traced_run(tiny_params):
    # the watchdog only observes (and in this well-priced run never acts):
    # outputs, steps and admissions match the plain traced run exactly
    from repro.obs import PerfWatchdog
    obs, reqs, m, loop = _traced_run(tiny_params)
    wd = PerfWatchdog()
    wobs = Observability(tracer=Tracer(), watchdog=wd)
    wreqs = _workload()
    weng = EngineLoop(TINY, tiny_params, n_slots=3, max_seq=MAX_LEN,
                      obs=wobs)
    wm = weng.run(wreqs, now_fn=_virtual_clock())
    assert {r.rid: r.output for r in wreqs} == \
        {r.rid: r.output for r in reqs}
    assert wm.n_steps == m.n_steps
    assert weng.batcher.n_admitted == loop.batcher.n_admitted


def test_watchdog_reprices_mispriced_engine(tiny_params):
    # inject a device model priced ~100x the step SLO at batch 2: static
    # admission pins the token budget to 1, the watchdog must notice the
    # hardware is far cheaper than the price and re-open the batch
    from repro.core import device_models
    from repro.obs import PerfWatchdog
    from repro.serving.batcher import step_time_model
    from repro.serving.placement import drift_scaled_device
    base = device_models.get("tpu-v5e")
    slo = 0.05
    factor = 100.0 * slo / step_time_model(TINY, MAX_LEN, 2, device=base)
    drifted = drift_scaled_device(base, factor)

    plain_reqs = _workload(n=12)
    plain = EngineLoop(TINY, tiny_params, n_slots=3, max_seq=MAX_LEN)
    plain.run(plain_reqs)

    wd = PerfWatchdog()
    obs = Observability(tracer=Tracer(), watchdog=wd)
    eng = EngineLoop(TINY, tiny_params, n_slots=3, max_seq=MAX_LEN,
                     device_model=drifted, step_slo_s=slo, obs=obs)
    assert eng.batcher.token_budget == 1          # the mispriced state
    eng.warmup()      # compile every bucket: the watchdog must see real
    reqs = _workload(n=12)                        # step costs, not jit
    m = eng.run(reqs)
    assert m.n_done == 12
    assert len(wd.alerts) >= 1 and len(wd.reprices) >= 1
    assert wd.alerts[0].direction == "fast"       # priced >> observed
    assert eng.batcher.token_budget == 3          # re-opened to all slots
    assert eng.batcher.price_source in ("scaled-analytic", "fitted-curve")
    assert eng.batcher.n_reprices >= 1
    # re-pricing is pure admission policy: outputs stay bit-identical
    assert {r.rid: r.output for r in reqs} == \
        {r.rid: r.output for r in plain_reqs}
    names = [e.name for e in obs.tracer.events if e.ph == "i"]
    assert "drift_alert" in names and "reprice" in names
    assert obs.registry.counters["watchdog_reprices"].value == \
        len(wd.reprices)
    rep = wd.report()
    assert any(r["token_budget"] == 3 for r in rep["reprices"])
    json.dumps(rep, allow_nan=False)


def test_drift_scaled_device_and_placement_overrides():
    from repro.core import device_models
    from repro.serving.placement import drift_scaled_device
    base = device_models.get("tpu-v5e")
    d2 = drift_scaled_device(base, 2.0)
    assert d2.peak_flops == pytest.approx(base.peak_flops / 2)
    assert d2.mem_bw == pytest.approx(base.mem_bw / 2)
    assert "drift" in d2.name and base.name in d2.name
    for k, v in d2.throughput.items():
        assert v == pytest.approx(base.throughput[k] / 2)
    with pytest.raises(ValueError):
        drift_scaled_device(base, 0.0)


# --------------------------------------- snapshot health (ring + series)
def test_metrics_snapshot_surfaces_drops_and_sample_lengths(tmp_path,
                                                           tiny_params):
    obs, reqs, m, _ = _traced_run(tiny_params)
    snap = obs.registry.snapshot()
    assert snap["series_len"] == len(obs.registry.series)
    for h in snap["histograms"].values():
        assert h["n_samples"] >= 0       # bounded reservoir actually held
    assert snap["histograms"]["ttft_s"]["n_samples"] > 0
    # a deliberately tiny ring drops events, and the exported snapshot
    # says so instead of silently presenting a truncated trace as complete
    tr = Tracer(_virtual_clock(), capacity=4)
    for i in range(10):
        tr.instant(f"e{i}", track="server")
    path = write_metrics(obs.registry, str(tmp_path / "m.json"), tracer=tr,
                         extra={"summary": m.summary()})
    with open(path) as f:
        data = json.load(f, parse_constant=lambda c: pytest.fail(c))
    assert data["trace"] == {"n_events": 4, "n_dropped": 6, "n_open": 0,
                             "enabled": True}
    assert data["series_len"] == snap["series_len"]


# ------------------------------------ degenerate telemetry is not "free"
def test_zero_cost_cache_entries_are_misses_not_free_layers():
    # feed a real burst, then zero out one entry's median the way a
    # degenerate (clock-resolution) measurement would: the pricer must
    # treat it as a miss — a 0-cost hit makes MeasuredPricer price the
    # layer as free and poisons achieved-FLOPs calibration downstream
    fb = TelemetryFeedback(TINY, kv_len=MAX_LEN)
    fb.observe_burst(3, 4, 0.04)
    cache = ProfileCache()
    assert fb.flush(cache) > 0
    pricer = MeasuredPricer(cache, measure_on_miss=False, autosave=False)
    net = decode_network_spec(TINY, MAX_LEN)
    spec = next(s for s in net if s.flops(3) > 0)
    assert pricer.measurement_for(spec, XLA_ENGINE, batch=3,
                                  dtype=jnp.float32) is not None
    for entry in cache.entries.values():
        entry["t_median"] = 0.0
    assert pricer.measurement_for(spec, XLA_ENGINE, batch=3,
                                  dtype=jnp.float32) is None


def test_feedback_skips_underflowed_layer_shares():
    # a burst so short that a layer's FLOP-share apportionment underflows
    # to 0.0 must not be fed to the cache at all (same degenerate-entry
    # class the pricer guards against, cut off at the source)
    fb = TelemetryFeedback(TINY, kv_len=MAX_LEN)
    fb.observe_burst(3, 1, 5e-324)       # one denormal-seconds "step"
    assert fb.measurements() == []
    cache = ProfileCache()
    assert fb.flush(cache) == 0 and not cache.entries


def test_cache_measurements_source_filter():
    fb = TelemetryFeedback(TINY, kv_len=MAX_LEN)
    fb.observe_burst(3, 4, 0.04)
    cache = ProfileCache()
    n = fb.flush(cache)
    assert len(cache.measurements(source="serving-telemetry")) == n
    assert cache.measurements(source="microbench") == []


# --------------------------------------- drift-injection fuzz harness
# Seeded random walks on the TRUE step cost, replayed through a fresh
# watchdog: false-positive / false-negative rates and detection latency
# are pinned as deterministic contracts across gate / alpha / warmup
# settings (np.random.default_rng(seed) makes every walk reproducible).
def _simulate_watchdog(seed, *, gate=1.5, alpha=0.4, warmup=4,
                       n_bursts=60, noise=0.05, drift_at=None,
                       drift_factor=1.0, ramp_to=None, reprice=True,
                       steps=8, batch=4):
    """Replay one noisy priced-vs-observed walk; returns (wd, detections).

    The true per-step cost starts at the priced value, multiplied by
    lognormal(0, noise) jitter each burst; from ``drift_at`` on it is
    scaled by ``drift_factor`` (step change) or ramps linearly to
    ``ramp_to`` (gradual degradation).  ``reprice`` models the control
    loop: each alert re-prices to the observed level and re-arms, so a
    corrected system must drift *again* to alert again.  Detections are
    (burst index, DriftAlert) pairs.
    """
    from repro.obs import PerfWatchdog
    rng = np.random.default_rng(seed)
    wd = PerfWatchdog(drift_gate=gate, ewma_alpha=alpha, warmup=warmup)
    base = priced = 1e-3                 # true cost drifts off the base;
    detections = []                      # the price chases the truth
    for i in range(n_bursts):
        factor = 1.0
        if drift_at is not None and i >= drift_at:
            if ramp_to is not None:
                frac = (i - drift_at + 1) / max(n_bursts - drift_at, 1)
                factor = 1.0 + (ramp_to - 1.0) * frac
            else:
                factor = drift_factor
        observed_step = base * factor * rng.lognormal(0.0, noise)
        alert = wd.observe_burst("eng", "decode", n_tokens=batch,
                                 steps=steps,
                                 elapsed_s=observed_step * steps,
                                 priced_step_s=priced)
        if alert is not None:
            detections.append((i, alert))
            if reprice:
                wd.note_reprice(alert, {"pricing": "fuzz"})
                priced = observed_step   # corrected to the observed level
    return wd, detections


FUZZ_SEEDS = range(20)


def test_fuzz_no_false_positives_at_default_gate():
    # a well-priced stream under 5% lognormal noise never alerts at the
    # default gate across 20 seeds: FP rate is exactly 0
    for seed in FUZZ_SEEDS:
        wd, detections = _simulate_watchdog(seed)
        assert detections == [], f"false positive at seed {seed}"
        assert wd.alerts == []


def test_fuzz_tight_gate_under_heavy_noise_is_flappy():
    # the same healthy stream with gate 1.05 under 20% noise false-alarms
    # for most seeds — pinning WHY the default gate is 1.5, not 1.05
    fps = sum(
        bool(_simulate_watchdog(seed, gate=1.05, noise=0.2)[1])
        for seed in FUZZ_SEEDS)
    assert fps >= 10


def test_fuzz_detects_2x_step_drift_with_bounded_latency():
    # a 2x step change is always caught (FN rate 0) and within
    # warmup + 6 bursts of onset at the default alpha
    for seed in FUZZ_SEEDS:
        wd, detections = _simulate_watchdog(seed, drift_at=20,
                                            drift_factor=2.0)
        assert detections, f"false negative at seed {seed}"
        first_i, first = detections[0]
        assert first.direction == "slow"
        assert first.ewma_ratio > 1.5
        assert 20 <= first_i <= 20 + 4 + 6, \
            f"detection latency {first_i - 20} bursts at seed {seed}"
        # the correction sticks: re-priced to observed, the stream is
        # healthy again and the detector (re-armed) stays quiet
        assert len(detections) == 1


def test_fuzz_detects_inverse_drift_as_fast():
    # priced 2.5x too high -> observed/priced ~0.4 crosses 1/gate: the
    # alert fires in the "fast" direction (the placement-actuation case
    # where a device is better than its price)
    for seed in FUZZ_SEEDS:
        _, detections = _simulate_watchdog(seed, drift_at=20,
                                           drift_factor=0.4)
        assert detections and detections[0][1].direction == "fast"


def test_fuzz_detects_gradual_ramp():
    # slow degradation (linear ramp to 3x over 40 bursts) is still caught
    # before the run ends — EWMA drift detection is not step-change-only
    for seed in FUZZ_SEEDS:
        _, detections = _simulate_watchdog(seed, drift_at=20, ramp_to=3.0)
        assert detections, f"ramp missed at seed {seed}"
        assert detections[0][1].direction == "slow"


def test_fuzz_warmup_orders_detection_and_uncorrected_drift_realerts():
    # warmup gates the first alert (n_obs >= warmup at trigger), and
    # without the re-price leg the alert stays edge-triggered: exactly
    # one alert, not one per burst
    for seed in FUZZ_SEEDS:
        wd, detections = _simulate_watchdog(seed, drift_at=0,
                                            drift_factor=4.0,
                                            reprice=False)
        assert len(detections) == 1
        i, alert = detections[0]
        assert alert.n_obs >= 4 and i + 1 >= 1 + 4   # skip_first + warmup
        assert wd.report()["streams"]["eng/decode"]["alert_active"]


def test_fuzz_longer_warmup_trades_latency_for_confidence():
    # the same drifting walk detected under warmup 2 and warmup 12:
    # both catch it (FN 0), the longer warmup never fires earlier
    for seed in FUZZ_SEEDS:
        _, fast = _simulate_watchdog(seed, warmup=2, drift_at=20,
                                     drift_factor=2.0)
        _, slow = _simulate_watchdog(seed, warmup=12, drift_at=20,
                                     drift_factor=2.0, n_bursts=80)
        assert fast and slow
        assert slow[0][0] >= fast[0][0]


def test_fuzz_low_alpha_smooths_transient_spikes():
    # one isolated 3x spike burst (not sustained drift) at alpha 0.1
    # never alerts across seeds; alpha 1.0 (no smoothing) always does —
    # the EWMA is what separates transients from real drift
    from repro.obs import PerfWatchdog

    def one_spike(seed, alpha):
        rng = np.random.default_rng(seed)
        wd = PerfWatchdog(ewma_alpha=alpha)
        fired = []
        for i in range(30):
            f = 3.0 if i == 10 else 1.0
            step = 1e-3 * f * rng.lognormal(0.0, 0.05)
            a = wd.observe_burst("eng", "decode", n_tokens=4, steps=8,
                                 elapsed_s=step * 8, priced_step_s=1e-3)
            if a is not None:
                fired.append(a)
        return fired

    for seed in FUZZ_SEEDS:
        assert one_spike(seed, 0.1) == []
        assert one_spike(seed, 1.0) != []
