"""Token data pipeline: deterministic, resumable, host-shard aware.

Two sources:
* SyntheticLM — structured pseudo-text (a mixture of n-gram-ish processes
  with a PRNG keyed by (seed, step, host)) so loss curves are meaningful
  (there is learnable structure) without external data.
* TextFileLM  — byte-level tokenization of a local corpus file, chunked.

Determinism/resume: `state()` returns an opaque cursor stored in
checkpoints; `restore(cursor)` resumes the stream exactly — a node restart
replays no sample twice (fault-tolerance requirement).

Multi-host: each host produces only its shard of the global batch
(`host_index`/`host_count`); on a single-host dry-run/CI this degenerates to
the full batch.  Audio/vision stub frontends emit the precomputed embedding
tensors the assignment mandates.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int = 8
    seq_len: int = 128
    vocab: int = 256
    seed: int = 0
    host_index: int = 0
    host_count: int = 1
    frontend: str = "none"           # none | audio | vision
    d_model: int = 0                 # for frontend embedding stubs
    img_seq: int = 0
    enc_len: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.host_count == 0
        return self.global_batch // self.host_count


class SyntheticLM:
    """Markov-chain pseudo-language: tokens follow a fixed random bigram
    table, so a real model achieves loss << log(V) — tests can assert
    learning actually happens."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab
        # sparse-ish bigram transition table: each token prefers ~8 successors
        succ = rng.integers(0, v, size=(v, 8))
        self._succ = succ.astype(np.int32)
        self._step = 0

    def state(self) -> Dict:
        return {"step": self._step}

    def restore(self, state: Dict) -> None:
        self._step = int(state["step"])

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        return self

    def __next__(self) -> Dict[str, jax.Array]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed, self._step, cfg.host_index))
        b, s = cfg.host_batch, cfg.seq_len
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab, size=b)
        choices = rng.integers(0, 8, size=(b, s))
        for t in range(s):
            toks[:, t + 1] = self._succ[toks[:, t], choices[:, t]]
        self._step += 1
        batch = {"tokens": jnp.asarray(toks[:, :-1]),
                 "labels": jnp.asarray(toks[:, 1:])}
        batch.update(_frontend_stub(cfg, rng))
        return batch


class TextFileLM:
    """Byte-level LM over a local file, sequential chunks, resumable."""

    def __init__(self, cfg: DataConfig, path: str):
        self.cfg = cfg
        self.data = np.frombuffer(open(path, "rb").read(), dtype=np.uint8)
        assert self.data.size > cfg.seq_len + 1, "corpus too small"
        self._cursor = 0

    def state(self) -> Dict:
        return {"cursor": self._cursor}

    def restore(self, state: Dict) -> None:
        self._cursor = int(state["cursor"])

    def __iter__(self):
        return self

    def __next__(self) -> Dict[str, jax.Array]:
        cfg = self.cfg
        b, s = cfg.host_batch, cfg.seq_len
        n = self.data.size - (s + 1)
        rows = []
        for i in range(b):
            start = (self._cursor + i * (s + 1)) % n
            rows.append(self.data[start:start + s + 1].astype(np.int32))
        self._cursor = (self._cursor + b * (s + 1)) % n
        arr = np.stack(rows)
        return {"tokens": jnp.asarray(arr[:, :-1]),
                "labels": jnp.asarray(arr[:, 1:])}


def _frontend_stub(cfg: DataConfig, rng) -> Dict[str, jax.Array]:
    """Precomputed frontend embeddings (the assignment's modality stub)."""
    out = {}
    if cfg.frontend == "audio" and cfg.d_model:
        enc_len = cfg.enc_len or cfg.seq_len
        out["enc_inputs"] = jnp.asarray(
            rng.standard_normal((cfg.host_batch, enc_len, cfg.d_model),
                                dtype=np.float32))
    if cfg.frontend == "vision" and cfg.d_model:
        out["img_embeds"] = jnp.asarray(
            rng.standard_normal((cfg.host_batch, cfg.img_seq, cfg.d_model),
                                dtype=np.float32))
    return out


def make_pipeline(cfg: DataConfig, corpus: Optional[str] = None):
    if corpus:
        return TextFileLM(cfg, corpus)
    return SyntheticLM(cfg)


def batch_abstract_shapes(cfg: DataConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the dry-run (GLOBAL batch shapes)."""
    b, s = cfg.global_batch, cfg.seq_len
    out = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
           "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.frontend == "audio" and cfg.d_model:
        out["enc_inputs"] = jax.ShapeDtypeStruct(
            (b, cfg.enc_len or s, cfg.d_model), jnp.bfloat16)
    if cfg.frontend == "vision" and cfg.d_model:
        out["img_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.img_seq, cfg.d_model), jnp.bfloat16)
    return out
