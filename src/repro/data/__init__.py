"""Data pipeline substrate."""
from .pipeline import (DataConfig, SyntheticLM, TextFileLM, make_pipeline,  # noqa
                       batch_abstract_shapes)
