"""Jit'd public wrappers around the Pallas kernels.

Responsibilities:
* pad arbitrary shapes to kernel block alignment and unpad results;
* pick interpret mode automatically (this container is CPU-only; on a real
  TPU `interpret=False` compiles to Mosaic);
* expose a uniform signature the execution-engine registry
  (core/engines.py) can build against.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .compat import has_scalar_prefetch
from .conv2d import conv2d_pallas
from .flash_attention import flash_attention_pallas
from .lrn import lrn_pallas
from .matmul import matmul_pallas
from .paged_attention import paged_attention_pallas
from .pooling import pool_pallas


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


@functools.partial(jax.jit, static_argnames=(
    "activation", "block_m", "block_n", "block_k", "interpret"))
def matmul(x: jax.Array, w: jax.Array, bias: Optional[jax.Array] = None, *,
           activation: str = "none", block_m: int = 256, block_n: int = 256,
           block_k: int = 512, interpret: Optional[bool] = None) -> jax.Array:
    """(M, K) @ (K, N) via the tiled MXU kernel; arbitrary shapes."""
    interpret = default_interpret() if interpret is None else interpret
    m, k = x.shape
    _, n = w.shape
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    # round blocks to hardware tiles where the problem allows
    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w, 0, bk), 1, bn)
    bp = _pad_to(bias, 0, bn) if bias is not None else None
    out = matmul_pallas(xp, wp, bp, block_m=bm, block_n=bn, block_k=bk,
                        activation=activation, interpret=interpret)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=(
    "stride", "padding", "activation", "interpret"))
def conv2d(x: jax.Array, w: jax.Array, bias: Optional[jax.Array] = None, *,
           stride: int = 1, padding: int = 0, activation: str = "none",
           interpret: Optional[bool] = None) -> jax.Array:
    interpret = default_interpret() if interpret is None else interpret
    return conv2d_pallas(x, w, bias, stride=stride, padding=padding,
                         activation=activation, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "window", "stride", "pool_type", "interpret"))
def pool(x: jax.Array, *, window: int = 3, stride: int = 2,
         pool_type: str = "max", interpret: Optional[bool] = None) -> jax.Array:
    interpret = default_interpret() if interpret is None else interpret
    return pool_pallas(x, window=window, stride=stride, pool_type=pool_type,
                       interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "local_size", "alpha", "beta", "k", "interpret"))
def lrn(x: jax.Array, *, local_size: int = 5, alpha: float = 1e-4,
        beta: float = 0.75, k: float = 2.0,
        interpret: Optional[bool] = None) -> jax.Array:
    interpret = default_interpret() if interpret is None else interpret
    return lrn_pallas(x, local_size=local_size, alpha=alpha, beta=beta, k=k,
                      interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 512, block_k: int = 512,
                    interpret: Optional[bool] = None) -> jax.Array:
    """q: (B, HQ, S, D); k/v: (B, HK, T, D).  Pads S/T to block multiples."""
    interpret = default_interpret() if interpret is None else interpret
    b, hq, s, d = q.shape
    t = k.shape[2]
    bq, bk = min(block_q, s), min(block_k, t)
    sp, tp = s + (-s) % bq, t + (-t) % bk
    if sp != s or tp != t:
        # pad queries at the END, keys at the END; causal mask keeps padded
        # keys (positions >= t... but padded *queries* would attend) — since
        # we slice padded query rows off, only padded KEYS matter: they sit at
        # positions > every real query, so the causal mask removes them.  For
        # non-causal (encoder) calls we must mask explicitly — ref handles it.
        if not causal:
            return ref.attention_ref(q, k, v, causal=causal, window=window)
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sp - s), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, tp - t), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, tp - t), (0, 0)))
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 block_q=bq, block_k=bk, interpret=interpret)
    return out[:, :, :s, :]


@functools.partial(jax.jit, static_argnames=("max_seq", "interpret"))
def paged_attention(q: jax.Array, k_arena: jax.Array, v_arena: jax.Array,
                    block_tables: jax.Array, pos: jax.Array, *,
                    max_seq: Optional[int] = None,
                    interpret: Optional[bool] = None) -> jax.Array:
    """Paged decode attention: q (B, HQ, 1, D) against block arenas
    (TB, HK, BS, D) gathered through (B, NB) block tables.  Degrades to the
    pure-jnp gather oracle on jaxlibs without scalar prefetch."""
    interpret = default_interpret() if interpret is None else interpret
    if not has_scalar_prefetch():
        return ref.paged_attention_ref(q, k_arena, v_arena, block_tables,
                                       pos, max_seq=max_seq)
    return paged_attention_pallas(q, k_arena, v_arena, block_tables, pos,
                                  interpret=interpret)


# convenience: FC layer matching the paper's Eq. 1 (vector-matrix + f)
def fc(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None, *,
       activation: str = "none", interpret: Optional[bool] = None) -> jax.Array:
    if x.ndim > 2:
        x = x.reshape(x.shape[0], -1)
    if activation == "softmax":  # softmax handled outside the MXU kernel
        y = matmul(x, w, b, activation="none", interpret=interpret)
        return jax.nn.softmax(y, axis=-1)
    return matmul(x, w, b, activation=activation, interpret=interpret)
