"""Tiled MXU matmul Pallas kernel — the FC module (paper Table III, 'FC').

TPU-native design: grid (M/bm, N/bn, K/bk) with the K dimension innermost so
the fp32 accumulator tile stays resident in VMEM scratch across the K loop
(the 'revisiting' pattern).  Block shapes are multiples of the MXU's 128x128
systolic tile; default blocks keep the VMEM working set
bm*bk + bk*bn + bm*bn fp32 words well under the ~16 MiB budget.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import tpu_compiler_params


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int, activation: str,
                   bias_ref=None):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _finish():
        acc = acc_ref[...]
        if bias_ref is not None:
            acc = acc + bias_ref[...].astype(jnp.float32)
        if activation == "relu":
            acc = jnp.maximum(acc, 0.0)
        elif activation == "sigmoid":
            acc = jax.nn.sigmoid(acc)
        elif activation == "tanh":
            acc = jnp.tanh(acc)
        o_ref[...] = acc.astype(o_ref.dtype)


def matmul_pallas(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    activation: str = "none",
    interpret: bool = False,
) -> jax.Array:
    """(M, K) @ (K, N) [+ bias, activation].  Shapes must divide the blocks;
    `ops.matmul` pads arbitrary shapes to alignment and unpads the result."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"unaligned matmul {x.shape} @ {w.shape} with blocks {(bm, bn, bk)}")
    nk = k // bk

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
    ]
    args = [x, w]
    if bias is not None:
        in_specs.append(pl.BlockSpec((bn,), lambda i, j, kk: (j,)))
        args.append(bias)
        kernel = functools.partial(
            _matmul_with_bias_kernel, nk=nk, activation=activation)
    else:
        kernel = functools.partial(_matmul_kernel, nk=nk, activation=activation)

    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*args)


def _matmul_with_bias_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, nk: int,
                             activation: str):
    _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, nk=nk, activation=activation,
                   bias_ref=b_ref)
