"""Pooling Pallas kernel — the Pool module (paper Table III, 'Pooling').

The FPGA module was a comparator tree at 304.5 MHz with zero DSPs; the TPU
analogue is a VPU reduction.  Per-image grid; the window taps are unrolled
statically (like conv2d's im2col taps) and reduced with max / add.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pool_kernel(x_ref, o_ref, *, window: int, stride: int, oh: int, ow: int,
                 pool_type: str):
    x = x_ref[...][0]                      # (H, W, C)
    taps = []
    for i in range(window):
        for j in range(window):
            lim_h = i + (oh - 1) * stride + 1
            lim_w = j + (ow - 1) * stride + 1
            taps.append(x[i:lim_h:stride, j:lim_w:stride, :])
    stacked = jnp.stack(taps, axis=0)      # (win*win, OH, OW, C)
    if pool_type == "max":
        out = jnp.max(stacked, axis=0)
    else:
        out = jnp.mean(stacked.astype(jnp.float32), axis=0).astype(x.dtype)
    o_ref[...] = out[None]


def pool_pallas(
    x: jax.Array,
    *,
    window: int = 3,
    stride: int = 2,
    pool_type: str = "max",
    interpret: bool = False,
) -> jax.Array:
    """x: (N, H, W, C) NHWC, VALID padding."""
    n, h, w, c = x.shape
    oh = (h - window) // stride + 1
    ow = (w - window) // stride + 1
    kernel = functools.partial(
        _pool_kernel, window=window, stride=stride, oh=oh, ow=ow,
        pool_type=pool_type)
    return pl.pallas_call(
        kernel,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, h, w, c), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, oh, ow, c), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, oh, ow, c), x.dtype),
        interpret=interpret,
    )(x)
