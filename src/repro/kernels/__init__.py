"""Pallas TPU kernels for the CNNLab compute hot-spots.

One kernel per FPGA module of the paper Table III (Conv, LRN, FC/matmul,
Pooling) plus flash attention for the transformer architectures.  `ops`
exposes jit-d padding-aware wrappers; `ref` holds the pure-jnp oracles.
"""
from . import ops, ref  # noqa: F401
