"""Paged-attention decode Pallas kernel (vLLM-style block-table gather).

The serving engine's paged KV layout stores each layer's K/V in one
``(total_blocks + 1, n_kv_heads, block_size, head_dim)`` arena; a slot's
logical sequence is scattered across non-contiguous physical blocks named
by its block table.  This kernel attends a single decode query against
that layout WITHOUT materializing the dense per-slot row in HBM: the block
table is a scalar-prefetch operand, so each grid step's BlockSpec index
map dereferences ``block_tables[slot, j]`` and the DMA engine fetches
exactly one physical KV page into VMEM per step.  Online softmax (running
max / denominator / accumulator in VMEM scratch, same revisiting pattern
as kernels/flash_attention.py) folds the pages together.

TPU-native choices:

* grid (B * HK, NB) with the block dimension innermost ('arbitrary');
  GQA query groups ride along as rows of the (G, D) q tile, so KV is
  fetched once per kv head, never repeated;
* blocks past the slot's position are skipped wholesale (`pl.when` on the
  block start), the boundary block masks elementwise with
  broadcasted_iota;
* arena pages are (block_size, head_dim) tiles — block_size >= 8 keeps
  fp32 sublane alignment.

The pure-jnp oracle is kernels/ref.py:paged_attention_ref (gather through
the table, then dense decode attention); it is also what the serving
engine runs on CPU, where bit-identity with the dense KV path is asserted.
Scalar prefetch predates some supported jaxlibs — kernels/compat.py gates
it, and ops.paged_attention falls back to the oracle when absent.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import prefetch_grid_spec, tpu_compiler_params

_NEG_INF = -1e30
_LANES = 128


def _paged_kernel(bt_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, hk: int, nb: int, bs: int,
                  scale: float):
    bh, j = pl.program_id(0), pl.program_id(1)
    b = bh // hk
    p = pos_ref[b]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # skip blocks entirely past the slot's current position
    @pl.when(j * bs <= p)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)            # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)            # (bs, D)
        v = v_ref[0, 0].astype(jnp.float32)            # (bs, D)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        g = q.shape[0]
        kpos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (g, bs), 1)
        mask = kpos <= p
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_scr[:, :1]                          # (G, 1)
        l_prev = l_scr[:, :1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        pprob = jnp.exp(s - m_new) * mask              # re-mask kills exp(0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(pprob, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
            pprob, v, preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(j == nb - 1)
    def _finish():
        l = l_scr[:, :1]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_scr[...] / l_safe)[None, None].astype(o_ref.dtype)


def paged_attention_pallas(
    q: jax.Array,
    k_arena: jax.Array,
    v_arena: jax.Array,
    block_tables: jax.Array,
    pos: jax.Array,
    *,
    scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, HQ, 1, D); arenas: (TB, HK, BS, D) with HQ % HK == 0;
    block_tables: (B, NB) int32; pos: (B,) int32.  Returns (B, HQ, 1, D).

    Entries of ``block_tables`` past a slot's written blocks may be any
    valid arena index (the position mask hides them); the trailing trash
    page convention of the serving arena satisfies that for free.
    """
    b, hq, s1, d = q.shape
    assert s1 == 1, "paged decode kernel is single-query (decode step)"
    tb, hk, bs, _ = k_arena.shape
    assert hq % hk == 0, (hq, hk)
    group = hq // hk
    nb = block_tables.shape[1]
    scale = scale if scale is not None else 1.0 / (d ** 0.5)

    qg = q.reshape(b, hk, group, d)                    # kv-grouped queries
    bt = block_tables.astype(jnp.int32)
    pos32 = pos.astype(jnp.int32)

    def q_index(bh, j, bt_ref, pos_ref):
        del j, bt_ref, pos_ref
        return (bh // hk, bh % hk, 0, 0)

    def kv_index(bh, j, bt_ref, pos_ref):
        del pos_ref
        return (bt_ref[bh // hk, j], bh % hk, 0, 0)

    grid_spec = prefetch_grid_spec(
        num_scalar_prefetch=2,                         # block_tables, pos
        grid=(b * hk, nb),
        in_specs=[
            pl.BlockSpec((1, 1, group, d), q_index),
            pl.BlockSpec((1, 1, bs, d), kv_index),
            pl.BlockSpec((1, 1, bs, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, group, d), q_index),
        scratch_shapes=[
            pltpu.VMEM((group, _LANES), jnp.float32),
            pltpu.VMEM((group, _LANES), jnp.float32),
            pltpu.VMEM((group, d), jnp.float32),
        ],
    )
    if grid_spec is None:
        raise NotImplementedError(
            "this jaxlib has no PrefetchScalarGridSpec; use "
            "ref.paged_attention_ref (ops.paged_attention degrades "
            "automatically)")
    kernel = functools.partial(_paged_kernel, hk=hk, nb=nb, bs=bs,
                               scale=scale)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hk, group, d), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(bt, pos32, qg, k_arena, v_arena)
    return out.reshape(b, hq, 1, d)
