"""LRN Pallas kernel — the Norm module (paper Table III, 'LRN').

Across-channel local response normalization (AlexNet / Caffe form), Eq. 6's
⟨M_I, T, S, α, β⟩ tuple:

    y = x / (k + (α/n) · Σ_{window n over channels} x²) ^ β

The FPGA module used 3 DSPs + LUT math at 269 MHz; the TPU analogue is a VPU
elementwise pipeline.  The channel window is materialized with `local_size`
shifted adds over a channel-padded square — all VMEM-resident per image row
block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lrn_kernel(x_ref, o_ref, *, local_size: int, alpha: float, beta: float,
                k: float):
    x = x_ref[...].astype(jnp.float32)       # (1, BH, W, C)
    sq = jnp.square(x)
    half = local_size // 2
    c = x.shape[-1]
    padded = jnp.pad(sq, ((0, 0), (0, 0), (0, 0), (half, half)))
    acc = jnp.zeros_like(sq)
    for i in range(local_size):              # static unroll over the window
        acc = acc + jax.lax.dynamic_slice_in_dim(padded, i, c, axis=3)
    denom = jnp.power(k + (alpha / local_size) * acc, beta)
    o_ref[...] = (x / denom).astype(o_ref.dtype)


def lrn_pallas(
    x: jax.Array,
    *,
    local_size: int = 5,
    alpha: float = 1e-4,
    beta: float = 0.75,
    k: float = 2.0,
    interpret: bool = False,
) -> jax.Array:
    """x: (N, H, W, C) NHWC."""
    n, h, w, c = x.shape
    kernel = functools.partial(
        _lrn_kernel, local_size=local_size, alpha=alpha, beta=beta, k=k)
    return pl.pallas_call(
        kernel,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, h, w, c), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, h, w, c), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)
