"""jaxlib API compatibility shims for the Pallas TPU kernels.

jax renamed the TPU compiler-params container across releases:
``pltpu.TPUCompilerParams`` (<= 0.4.x era, e.g. the 0.4.37 this container
ships) became ``pltpu.CompilerParams`` (newer jaxlib).  The kernels go
through :func:`tpu_compiler_params` so they run on either spelling instead
of raising ``AttributeError`` at call time; if a future jaxlib drops both,
they degrade to compiler defaults (``compiler_params=None``).

The paged-attention decode kernel additionally needs scalar prefetch
(``pltpu.PrefetchScalarGridSpec``) so the block table can drive BlockSpec
index maps; :func:`prefetch_grid_spec` returns ``None`` on jaxlibs that
predate it, and callers fall back to the pure-jnp reference gather.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu


def tpu_compiler_params(**kwargs):
    """Build the installed jaxlib's TPU compiler-params object (or None)."""
    cls = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams", None)
    return cls(**kwargs) if cls is not None else None


def has_scalar_prefetch() -> bool:
    """Whether this jaxlib ships the scalar-prefetch grid spec the paged
    decode kernel is built on."""
    return hasattr(pltpu, "PrefetchScalarGridSpec")


def prefetch_grid_spec(**kwargs):
    """Build a ``PrefetchScalarGridSpec`` (or None when the installed
    jaxlib predates scalar prefetch — callers degrade to the jnp path)."""
    cls = getattr(pltpu, "PrefetchScalarGridSpec", None)
    return cls(**kwargs) if cls is not None else None
