"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references the kernel tests sweep against
(shapes x dtypes, interpret=True).  They are also the implementations the
XLA execution engine (core/engines.py) uses, so "engine A vs engine B" in
the CNNLab scheduler is literally "ref.py vs the Pallas kernel".
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """(M, K) @ (K, N) with fp32 accumulation."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def fc_ref(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None,
           activation: str = "none") -> jax.Array:
    y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    if b is not None:
        y = y + b
    y = _activate(y, activation)
    return y.astype(x.dtype)


def _activate(y: jax.Array, activation: str) -> jax.Array:
    if activation == "relu":
        return jax.nn.relu(y)
    if activation == "sigmoid":
        return jax.nn.sigmoid(y)
    if activation == "tanh":
        return jnp.tanh(y)
    if activation == "softmax":
        return jax.nn.softmax(y, axis=-1)
    if activation == "none":
        return y
    raise ValueError(f"unknown activation {activation}")


def conv2d_ref(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None,
               *, stride: int = 1, padding: int = 0,
               activation: str = "none") -> jax.Array:
    """NHWC input, (OC, IC, KH, KW) filters (paper Table I order)."""
    w_hwio = jnp.transpose(w, (2, 3, 1, 0))  # -> (KH, KW, IC, OC)
    y = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w_hwio.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if b is not None:
        y = y + b
    return _activate(y, activation).astype(x.dtype)


def maxpool_ref(x: jax.Array, *, window: int = 3, stride: int = 2) -> jax.Array:
    return jax.lax.reduce_window(
        x, -jnp.inf if jnp.issubdtype(x.dtype, jnp.floating) else jnp.iinfo(x.dtype).min,
        jax.lax.max, (1, window, window, 1), (1, stride, stride, 1), "VALID")


def avgpool_ref(x: jax.Array, *, window: int = 3, stride: int = 2) -> jax.Array:
    s = jax.lax.reduce_window(
        x.astype(jnp.float32), 0.0, jax.lax.add,
        (1, window, window, 1), (1, stride, stride, 1), "VALID")
    return (s / (window * window)).astype(x.dtype)


def lrn_ref(x: jax.Array, *, local_size: int = 5, alpha: float = 1e-4,
            beta: float = 0.75, k: float = 2.0) -> jax.Array:
    """Across-channel local response normalization (AlexNet / Caffe form):

        y = x / (k + (alpha/n) * sum_{window n} x^2) ** beta

    NHWC; window runs over the channel axis.
    """
    sq = jnp.square(x.astype(jnp.float32))
    half = local_size // 2
    # pad channels and take a windowed sum via shifted adds
    padded = jnp.pad(sq, ((0, 0), (0, 0), (0, 0), (half, half)))
    c = x.shape[-1]
    acc = jnp.zeros_like(sq)
    for i in range(local_size):
        acc = acc + jax.lax.dynamic_slice_in_dim(padded, i, c, axis=3)
    denom = jnp.power(k + (alpha / local_size) * acc, beta)
    return (x.astype(jnp.float32) / denom).astype(x.dtype)


def paged_gather(arena: jax.Array, block_tables: jax.Array,
                 max_seq: int) -> jax.Array:
    """Materialize per-slot KV rows from a block arena.

    arena: (total_blocks(+1), HK, BS, D) — fixed-size physical KV pages;
    block_tables: (B, NB) int32 — slot-major logical->physical block map
    (block j of a slot holds tokens [j*BS, (j+1)*BS)).  Returns
    (B, HK, max_seq, D): the dense rows the block tables describe, trimmed
    to ``max_seq`` (NB*BS may overhang when max_seq % BS != 0).  This is
    the reference the Pallas kernel avoids — it gathers per block inside
    the kernel instead of materializing these rows in HBM.
    """
    b, nb = block_tables.shape
    hk, bs, d = arena.shape[1:]
    rows = arena[block_tables]                      # (B, NB, HK, BS, D)
    rows = rows.transpose(0, 2, 1, 3, 4).reshape(b, hk, nb * bs, d)
    return rows[:, :, :max_seq]


def paged_attention_ref(q: jax.Array, k_arena: jax.Array, v_arena: jax.Array,
                        block_tables: jax.Array, pos: jax.Array, *,
                        max_seq: Optional[int] = None) -> jax.Array:
    """Reference paged decode attention (pure-jnp oracle for the Pallas
    kernel in kernels/paged_attention.py).

    q: (B, HQ, 1, D); arenas: (total_blocks(+1), HK, BS, D);
    block_tables: (B, NB) int32; pos: (B,) absolute position of the
    current token per slot (positions <= pos are attended).  Numerics
    follow :func:`repro.models.attention.decode_attention` exactly — the
    gather-then-attend composition is what keeps the serving engine's
    paged path bit-identical to its dense path.
    """
    from ..models.attention import decode_attention

    if max_seq is None:
        max_seq = block_tables.shape[1] * k_arena.shape[2]
    k = paged_gather(k_arena, block_tables, max_seq)
    v = paged_gather(v_arena, block_tables, max_seq)
    return decode_attention(q, k, v, pos=pos, window=None)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: Optional[int] = None,
                  scale: Optional[float] = None) -> jax.Array:
    """Reference MHA.  q: (B, HQ, S, D); k/v: (B, HK, T, D); GQA by repeat.

    ``window``: sliding-window attention width (each query attends to the
    last `window` keys, inclusive of itself).
    """
    b, hq, s, d = q.shape
    hk = k.shape[1]
    if hk != hq:
        rep = hq // hk
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    t = k.shape[2]
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    logits = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(s)[:, None] + (t - s)   # align ends (decode-friendly)
    kpos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
