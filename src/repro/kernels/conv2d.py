"""Conv2D Pallas kernel — the Conv module (paper Table III, 'Conv Layer').

TPU-native rethink of the FPGA line-buffer + systolic MAC array: instead of
streaming rows through a shift register, we stage the (padded) image in VMEM,
build the im2col patch matrix *in registers* with static strided slices
(one per (kh, kw) tap — the unrolled taps are the analogue of the FPGA's
MAC taps), and feed a single MXU matmul per image:

    patches (OH*OW, KH*KW*IC)  @  filters (KH*KW*IC, OC)

Grid is over the batch dimension; per-image working set for every AlexNet
layer fits in 16 MiB VMEM (largest: Conv2, ~10 MiB fp32).  Padding is applied
in ops.py so the kernel sees only 'VALID' geometry.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv2d_kernel(x_ref, w_ref, b_ref, o_ref, *, kh: int, kw: int,
                   stride: int, oh: int, ow: int, activation: str):
    x = x_ref[...]          # (1, H, W, IC) padded input block
    w = w_ref[...]          # (KH*KW*IC, OC) pre-reshaped filters
    x = x[0]
    ic = x.shape[-1]
    taps = []
    for i in range(kh):          # static unroll: one tap per kernel element
        for j in range(kw):
            lim_h = i + (oh - 1) * stride + 1
            lim_w = j + (ow - 1) * stride + 1
            taps.append(x[i:lim_h:stride, j:lim_w:stride, :])
    # (OH, OW, KH*KW, IC) -> (OH*OW, KH*KW*IC); ordering matches w reshape
    patches = jnp.stack(taps, axis=2).reshape(oh * ow, kh * kw * ic)
    acc = jnp.dot(patches, w, preferred_element_type=jnp.float32)
    acc = acc + b_ref[...].astype(jnp.float32)
    if activation == "relu":
        acc = jnp.maximum(acc, 0.0)
    elif activation == "sigmoid":
        acc = jax.nn.sigmoid(acc)
    elif activation == "tanh":
        acc = jnp.tanh(acc)
    o_ref[...] = acc.reshape(1, oh, ow, -1).astype(o_ref.dtype)


def conv2d_pallas(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    stride: int = 1,
    padding: int = 0,
    activation: str = "none",
    interpret: bool = False,
) -> jax.Array:
    """x: (N, H, W, IC); w: (OC, IC, KH, KW) — Table I layout.  Returns NHWC."""
    n, h, wdt, ic = x.shape
    oc, ic2, kh, kw = w.shape
    assert ic == ic2, (x.shape, w.shape)
    if padding:
        x = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
        h, wdt = h + 2 * padding, wdt + 2 * padding
    oh = (h - kh) // stride + 1
    ow = (wdt - kw) // stride + 1
    # (OC, IC, KH, KW) -> (KH, KW, IC, OC) -> (KH*KW*IC, OC): tap-major rows
    w_mat = jnp.transpose(w, (2, 3, 1, 0)).reshape(kh * kw * ic, oc)
    if bias is None:
        bias = jnp.zeros((oc,), dtype=jnp.float32)

    kernel = functools.partial(
        _conv2d_kernel, kh=kh, kw=kw, stride=stride, oh=oh, ow=ow,
        activation=activation)
    return pl.pallas_call(
        kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, h, wdt, ic), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((kh * kw * ic, oc), lambda i: (0, 0)),
            pl.BlockSpec((oc,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, oh, ow, oc), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, oh, ow, oc), x.dtype),
        interpret=interpret,
    )(x, w_mat, bias)


def conv2d_vmem_bytes(h: int, w: int, ic: int, oc: int, kh: int, kw: int,
                      stride: int, dtype_bytes: int = 4) -> int:
    """Static VMEM working-set estimate for the Table III resource analogue."""
    oh = (h - kh) // stride + 1
    ow = (w - kw) // stride + 1
    x_bytes = h * w * ic * dtype_bytes
    w_bytes = kh * kw * ic * oc * dtype_bytes
    patch_bytes = oh * ow * kh * kw * ic * dtype_bytes
    out_bytes = oh * ow * oc * 4  # fp32 accumulator
    return x_bytes + w_bytes + patch_bytes + out_bytes
