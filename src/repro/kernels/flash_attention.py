"""Flash attention Pallas kernel (beyond-paper engine for the LM archs).

Online-softmax tiling over KV blocks so 32k-token prefill never materializes
the (S, T) score matrix in HBM.  TPU-native choices:

* grid (B*HQ, S/bq, T/bk) with the KV dimension innermost ('arbitrary'),
  running max / denominator / output accumulator in VMEM scratch — the same
  revisiting pattern as the matmul kernel;
* GQA handled in the BlockSpec index maps (each query head reads its
  kv-group's block; KV is never repeated in HBM);
* causal and sliding-window masking by block predicate: blocks entirely
  outside the mask are skipped (`pl.when`), the diagonal blocks mask
  elementwise with broadcasted_iota;
* m/l scratch kept (bq, 128) lane-replicated, the canonical TPU layout.

Used for training and prefill (S == T).  Decode (S == 1) uses the pure-JAX
dot attention in models/ — a 1-row matmul gains nothing from tiling.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import tpu_compiler_params

_NEG_INF = -1e30
_LANES = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  nk: int, bq: int, bk: int, scale: float, causal: bool,
                  window: Optional[int]):
    iq, ik = pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # block-level skip: entirely above the causal diagonal or left of window
    run = jnp.bool_(True)
    if causal:
        run &= ik * bk <= iq * bq + bq - 1
    if window is not None:
        run &= (ik + 1) * bk - 1 >= iq * bq - (window - 1)

    @pl.when(run)
    def _body():
        q = q_ref[...][0].astype(jnp.float32)          # (bq, d)
        k = k_ref[...][0].astype(jnp.float32)          # (bk, d)
        v = v_ref[...][0].astype(jnp.float32)          # (bk, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

        qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), dtype=bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_scr[:, :1]                           # (bq, 1)
        l_prev = l_scr[:, :1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)      # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new) * mask                   # re-mask kills exp(0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_scr[:, :1]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_scr[...] / l_safe)[None].astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, HQ, S, D); k/v: (B, HK, T, D) with HQ % HK == 0.  S % bq == 0,
    T % bk == 0 (ops.py pads otherwise).  Returns (B, HQ, S, D)."""
    b, hq, s, d = q.shape
    _, hk, t, _ = k.shape
    assert hq % hk == 0, (hq, hk)
    group = hq // hk
    bq, bk = min(block_q, s), min(block_k, t)
    assert s % bq == 0 and t % bk == 0, (s, t, bq, bk)
    scale = scale if scale is not None else 1.0 / (d ** 0.5)

    qf = q.reshape(b * hq, s, d)
    kf = k.reshape(b * hk, t, d)
    vf = v.reshape(b * hk, t, d)

    def kv_index(bh, iq, ik):
        batch, qh = bh // hq, bh % hq
        return (batch * hk + qh // group, ik, 0)

    kernel = functools.partial(
        _flash_kernel, nk=t // bk, bq=bq, bk=bk, scale=scale, causal=causal,
        window=window)
    out = pl.pallas_call(
        kernel,
        grid=(b * hq, s // bq, t // bk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, d), kv_index),
            pl.BlockSpec((1, bk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, _LANES), jnp.float32),
            pltpu.VMEM((bq, _LANES), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, s, d)
