"""Checkpoint substrate: sharded, atomic, keep-k, elastic-reshard restore."""
from .manager import CheckpointManager, restore_latest, save_checkpoint  # noqa
