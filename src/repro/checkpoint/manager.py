"""Fault-tolerant checkpointing.

Design (no orbax dependency — built on numpy .npy + json):

* every leaf of the state pytree is written as its own .npy file named by its
  flattened tree path (process 0 gathers; on multi-host deployments each host
  writes its addressable shards — here single-host);
* a manifest.json records step, tree structure, dtypes, PRNG key, data-
  pipeline cursor and the mesh shape the run used;
* writes go to ``step_XXXX.tmp`` then ``os.rename`` → crash-atomic: a
  half-written checkpoint is never visible;
* keep-last-k garbage collection;
* **elastic restore**: arrays are saved unsharded (logical content), so a
  restart may use a *different* mesh — restore re-applies the current run's
  sharding rules via device_put.  This is what makes scale-up/scale-down
  restarts work.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten_with_paths(tree: PyTree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(_path_str(p) for p in path)
        out.append((name, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_checkpoint(directory: str, step: int, state: PyTree,
                    extra: Optional[Dict] = None, keep: int = 3) -> str:
    """Atomically write `state` (arbitrary pytree of arrays) at `step`."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = _flatten_with_paths(state)
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for name, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        fn = name.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"].append(
            {"name": name, "file": fn, "dtype": str(arr.dtype),
             "shape": list(arr.shape)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic publish
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int) -> None:
    steps = sorted(
        (int(m.group(1)), d) for d in os.listdir(directory)
        if (m := _STEP_RE.match(d)))
    for _, d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := _STEP_RE.match(d))]
    return max(steps) if steps else None


def restore_latest(directory: str, template: PyTree,
                   shardings: Optional[PyTree] = None
                   ) -> Optional[Tuple[int, PyTree, Dict]]:
    """Restore into the structure of `template`; if `shardings` is given the
    arrays are device_put with the *current* mesh's sharding (elastic)."""
    step = latest_step(directory)
    if step is None:
        return None
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_name = {l["name"]: l for l in manifest["leaves"]}

    names = [n for n, _ in _flatten_with_paths(template)]
    treedef = jax.tree_util.tree_structure(template)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(names))
    leaves = []
    for name, shard in zip(names, shard_leaves):
        meta = by_name[name]
        arr = np.load(os.path.join(path, meta["file"]))
        if shard is not None:
            leaves.append(jax.device_put(jnp.asarray(arr), shard))
        else:
            leaves.append(jnp.asarray(arr))
    state = jax.tree_util.tree_unflatten(treedef, leaves)
    return step, state, manifest.get("extra", {})


class CheckpointManager:
    """Convenience wrapper used by the train loop."""

    def __init__(self, directory: str, interval: int = 100, keep: int = 3):
        self.directory = directory
        self.interval = interval
        self.keep = keep

    def maybe_save(self, step: int, state: PyTree,
                   extra: Optional[Dict] = None, force: bool = False):
        if force or (self.interval > 0 and step % self.interval == 0
                     and step > 0):
            return save_checkpoint(self.directory, step, state, extra,
                                   self.keep)
        return None

    def restore(self, template: PyTree, shardings: Optional[PyTree] = None):
        return restore_latest(self.directory, template, shardings)
