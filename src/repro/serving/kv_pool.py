"""Slot-based paged KV-cache pool.

The pool manages two resources: *slots* (the batch row a request binds to
for its lifetime) and *blocks* (fixed ``block_size``-token KV pages drawn
from one global free list).  Admission reserves a request's full footprint
in blocks, so the pool can be provisioned for total tokens-in-flight rather
than ``n_slots x max_seq`` worst case (``total_blocks`` < dense is the paged
sharing the vLLM line of work exploits; the ledger also yields the
utilization / fragmentation accounting the batcher and metrics report).

Under the *paged* KV layout (``models.transformer.init_slot_cache_paged``)
the block ledger is physical: each layer's K/V lives in one
``(total_blocks + 1) x n_kv_heads x block_size x head_dim`` arena, and a
request's lease order IS its block table — block ``j`` of the lease holds
tokens ``[j * block_size, (j + 1) * block_size)``.  :meth:`block_table`
exports that mapping as the padded int32 row the decode step gathers
through.  Under the legacy *dense* layout
(``models.transformer.init_slot_cache``) the same ledger is accounting
only, over physically ``max_seq``-long slot rows.

Invariants (property-tested in tests/test_serving.py + tests/test_paged.py):
  * a block belongs to at most one request; free+allocated == total_blocks;
  * a slot belongs to at most one request; double alloc/free raises;
  * utilization = written tokens / (allocated blocks x block_size) <= 1;
  * blocks are interchangeable — fragmentation never blocks an admit whose
    block count fits the free list.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class SlotLease:
    rid: int
    slot: int
    blocks: List[int]                   # logical block ids (global ledger)
    reserved_tokens: int                # footprint reserved at admission
    written_tokens: int = 0             # KV entries actually written


class KVPool:
    def __init__(self, n_slots: int, max_seq: int, *, block_size: int = 16,
                 total_blocks: Optional[int] = None):
        if n_slots <= 0 or max_seq <= 0 or block_size <= 0:
            raise ValueError("n_slots, max_seq, block_size must be positive")
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.block_size = block_size
        self.blocks_per_slot = math.ceil(max_seq / block_size)
        dense = n_slots * self.blocks_per_slot
        self.total_blocks = dense if total_blocks is None else total_blocks
        self._free_slots = list(range(n_slots - 1, -1, -1))
        self._free_blocks = list(range(self.total_blocks - 1, -1, -1))
        self._leases: Dict[int, SlotLease] = {}
        self._block_owner: Dict[int, int] = {}
        # lease-event observer: called as on_event(kind, rid, n_blocks) with
        # kind in {"alloc", "free"}.  The serving loops install a tracer
        # callback here so KV block leases appear as per-request trace
        # instants; None (default) costs one attribute check per event.
        self.on_event = None

    # ---- capacity queries ------------------------------------------------
    def blocks_needed(self, n_tokens: int) -> int:
        return math.ceil(n_tokens / self.block_size)

    @property
    def free_slot_count(self) -> int:
        return len(self._free_slots)

    @property
    def free_block_count(self) -> int:
        return len(self._free_blocks)

    @property
    def allocated_block_count(self) -> int:
        return self.total_blocks - len(self._free_blocks)

    def can_admit(self, n_tokens: int) -> bool:
        if n_tokens > self.max_seq:
            return False                # would overflow the slot row
        return (bool(self._free_slots)
                and self.blocks_needed(n_tokens) <= len(self._free_blocks))

    # ---- alloc / free ----------------------------------------------------
    def alloc(self, rid: int, n_tokens: int) -> int:
        """Reserve a slot + the blocks for the request's full footprint.
        Returns the slot index."""
        if rid in self._leases:
            raise ValueError(f"request {rid} already holds a slot")
        if not self.can_admit(n_tokens):
            raise ValueError(f"pool cannot admit {n_tokens} tokens "
                             f"(free slots={self.free_slot_count}, "
                             f"free blocks={self.free_block_count})")
        slot = self._free_slots.pop()
        blocks = [self._free_blocks.pop()
                  for _ in range(self.blocks_needed(n_tokens))]
        for b in blocks:
            self._block_owner[b] = rid
        self._leases[rid] = SlotLease(rid=rid, slot=slot, blocks=blocks,
                                      reserved_tokens=n_tokens)
        if self.on_event is not None:
            self.on_event("alloc", rid, len(blocks))
        return slot

    def note_write(self, rid: int, n_tokens: int = 1) -> None:
        """Record KV entries written for `rid` (utilization accounting)."""
        lease = self._leases[rid]
        lease.written_tokens += n_tokens
        if lease.written_tokens > lease.reserved_tokens:
            raise ValueError(f"request {rid} wrote past its reservation "
                             f"({lease.written_tokens} > "
                             f"{lease.reserved_tokens})")

    def free(self, rid: int) -> int:
        """Release the request's slot + blocks.  Returns the slot index."""
        lease = self._leases.pop(rid, None)
        if lease is None:
            raise ValueError(f"request {rid} holds no slot (double free?)")
        for b in lease.blocks:
            del self._block_owner[b]
            self._free_blocks.append(b)
        self._free_slots.append(lease.slot)
        if self.on_event is not None:
            self.on_event("free", rid, len(lease.blocks))
        return lease.slot

    def lease(self, rid: int) -> SlotLease:
        return self._leases[rid]

    def block_table(self, rid: int, pad_to: Optional[int] = None
                    ) -> np.ndarray:
        """The request's physical block ids in logical order (block ``j``
        holds tokens ``[j * block_size, (j + 1) * block_size)``), padded
        with 0 to ``pad_to`` entries — the row the paged decode step's
        gather indexes with.  Padding entries are never dereferenced for a
        valid position (the per-slot position mask hides them)."""
        blocks = self._leases[rid].blocks
        n = len(blocks) if pad_to is None else pad_to
        if len(blocks) > n:
            raise ValueError(f"request {rid} holds {len(blocks)} blocks, "
                             f"pad_to={pad_to} is smaller")
        row = np.zeros((n,), np.int32)
        row[:len(blocks)] = blocks
        return row

    # ---- accounting ------------------------------------------------------
    @property
    def written_tokens(self) -> int:
        """KV entries written across all live leases."""
        return sum(l.written_tokens for l in self._leases.values())

    def utilization(self) -> float:
        """Written tokens / capacity of allocated blocks (1 - internal
        fragmentation of partially-filled blocks + unreached reservation)."""
        alloc_tokens = self.allocated_block_count * self.block_size
        if alloc_tokens == 0:
            return 0.0
        return self.written_tokens / alloc_tokens

    def occupancy(self) -> float:
        """Allocated blocks / total blocks (pool pressure for admission)."""
        return self.allocated_block_count / self.total_blocks

    def stats(self) -> Dict[str, float]:
        return {
            "slots_in_use": self.n_slots - self.free_slot_count,
            "blocks_in_use": self.allocated_block_count,
            "total_blocks": self.total_blocks,
            "occupancy": self.occupancy(),
            "utilization": self.utilization(),
        }
