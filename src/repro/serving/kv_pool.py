"""Slot-based paged KV-cache pool with refcounted prefix sharing.

The pool manages two resources: *slots* (the batch row a request binds to
for its lifetime) and *blocks* (fixed ``block_size``-token KV pages drawn
from one global free list).  Admission reserves a request's full footprint
in blocks, so the pool can be provisioned for total tokens-in-flight rather
than ``n_slots x max_seq`` worst case (``total_blocks`` < dense is the paged
sharing the vLLM line of work exploits; the ledger also yields the
utilization / fragmentation accounting the batcher and metrics report).

Under the *paged* KV layout (``models.transformer.init_slot_cache_paged``)
the block ledger is physical: each layer's K/V lives in one
``(total_blocks + 1) x n_kv_heads x block_size x head_dim`` arena, and a
request's lease order IS its block table — block ``j`` of the lease holds
tokens ``[j * block_size, (j + 1) * block_size)``.  :meth:`block_table`
exports that mapping as the padded int32 row the decode step gathers
through.  Under the legacy *dense* layout
(``models.transformer.init_slot_cache``) the same ledger is accounting
only, over physically ``max_seq``-long slot rows.

Prefix sharing (``prefix_sharing=True``) adds a *prefix index*: a
hash-chain over token-id block prefixes.  When a request's prompt fills a
physical block (all ``block_size`` KV entries written, block fully inside
the prompt), the block is *published* under a chain key
``h_j = H(h_{j-1}, tokens_j)``.  A later request whose prompt walks the
same chain maps its matching prefix onto those already-written pages
(refcount incremented, no fresh block, no prefill for those tokens) and
only allocates fresh blocks for the divergent remainder.  Because decode
only ever writes the page holding the *current* position, fully-shared
blocks are read-only by construction; the one write hazard is a partial
tail match (shared length not a multiple of ``block_size``), which is
resolved by copy-on-write: :meth:`alloc` maps the tail onto a fresh block
and records a pending page copy that the engine executes at bind, before
the first divergent write.  Published block content is immutable (positions
only move forward), so sharing is bit-exact: same tokens at same positions
under the same params produce the same KV.

Collision handling: chain keys come from an injectable ``prefix_hash``
(useful for testing); the index buckets entries per key and every lookup
re-verifies parent key and the full token tuple, so a hash collision can
only cause a missed share, never a false one.

Invariants (property-tested in tests/test_serving.py, tests/test_paged.py
and tests/test_prefix.py):
  * every allocated block has refcount >= 1 and refcount equals the number
    of leases holding it (plus pending COW sources);
    free + distinct-allocated == total_blocks;
  * without sharing, a block belongs to at most one request;
  * a slot belongs to at most one request; double alloc/free raises;
  * published blocks are full and never written again (the writer's
    position is already past them);
  * blocks are interchangeable — fragmentation never blocks an admit whose
    fresh-block count fits the free list.

Note on :meth:`utilization` under sharing: written tokens are counted per
lease while physical blocks are counted once, so utilization may exceed
1.0 — that surplus IS the dedup win.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


def default_prefix_hash(parent: Optional[int],
                        tokens: Tuple[int, ...]) -> int:
    """Chain-hash one block of token ids onto its parent key."""
    return hash((parent,) + tokens)


@dataclasses.dataclass
class PrefixEntry:
    """One published block in the prefix index."""
    key: int                            # chain hash at this depth
    parent: Optional[int]               # parent chain key (None at depth 0)
    tokens: Tuple[int, ...]             # the block's token ids (full block)
    block: int                          # physical block id


@dataclasses.dataclass
class SlotLease:
    rid: int
    slot: int
    blocks: List[int]                   # logical order IS the block table
    reserved_tokens: int                # footprint reserved at admission
    written_tokens: int = 0             # KV entries present (incl. shared)
    prompt: Optional[Tuple[int, ...]] = None    # token ids (for publication)
    shared_tokens: int = 0              # prefix mapped onto shared pages
    n_published: int = 0                # full prompt blocks in the index
    chain_keys: List[int] = dataclasses.field(default_factory=list)


class KVPool:
    """Slot + block allocator backing the paged KV cache.

    One pool per :class:`~repro.serving.engine_loop.SlotEngine`.  The
    batcher asks :meth:`can_admit` / :meth:`alloc` at admission, the
    engine reads :meth:`block_table` at bind and calls :meth:`note_write`
    per decode burst; :meth:`free` returns everything on completion.
    """

    def __init__(self, n_slots: int, max_seq: int, *, block_size: int = 16,
                 total_blocks: Optional[int] = None,
                 prefix_sharing: bool = False,
                 prefix_hash: Callable[[Optional[int], Tuple[int, ...]],
                                       int] = default_prefix_hash):
        if n_slots <= 0 or max_seq <= 0 or block_size <= 0:
            raise ValueError("n_slots, max_seq, block_size must be positive")
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.block_size = block_size
        self.blocks_per_slot = math.ceil(max_seq / block_size)
        dense = n_slots * self.blocks_per_slot
        self.total_blocks = dense if total_blocks is None else total_blocks
        self.prefix_sharing = prefix_sharing
        self._hash = prefix_hash
        self._free_slots = list(range(n_slots - 1, -1, -1))
        self._free_blocks = list(range(self.total_blocks - 1, -1, -1))
        self._leases: Dict[int, SlotLease] = {}
        self._block_refs: Dict[int, int] = {}
        # prefix index: chain key -> bucket of verified-on-lookup entries
        # (collisions and duplicate publications share a bucket), plus a
        # reverse map so a freed block's entry can be evicted in O(bucket).
        self._prefix_index: Dict[int, List[PrefixEntry]] = {}
        self._block_entry: Dict[int, PrefixEntry] = {}
        # pending copy-on-write page copies [(src_block, dst_block)] the
        # engine must execute at bind, before the slot's first write.  The
        # source holds an extra ref until consume_cow/free drops it.
        self._pending_cow: Dict[int, List[Tuple[int, int]]] = {}
        # cumulative prefix-sharing counters (stats())
        self.prefix_hits = 0
        self.tokens_prefill_skipped = 0
        self.cow_copies = 0
        self.peak_slots_in_use = 0
        self.peak_blocks_in_use = 0
        # lease-event observer: called as on_event(kind, rid, n_blocks) with
        # kind in {"alloc", "free"}.  The serving loops install a tracer
        # callback here so KV block leases appear as per-request trace
        # instants; None (default) costs one attribute check per event.
        self.on_event = None

    # ---- capacity queries ------------------------------------------------
    def blocks_needed(self, n_tokens: int) -> int:
        return math.ceil(n_tokens / self.block_size)

    @property
    def free_slot_count(self) -> int:
        return len(self._free_slots)

    @property
    def free_block_count(self) -> int:
        return len(self._free_blocks)

    @property
    def allocated_block_count(self) -> int:
        return self.total_blocks - len(self._free_blocks)

    def shared_prefix_tokens(self, prompt: Sequence[int]) -> int:
        """Tokens of ``prompt`` the index can serve from shared pages.

        Capped at ``len(prompt) - 1``: the engine must feed at least the
        last prompt token to produce the first sample."""
        if not self.prefix_sharing or prompt is None or len(prompt) == 0:
            return 0
        matched, _, _ = self._match_prefix(prompt)
        return min(matched, len(prompt) - 1)

    def fresh_blocks_needed(self, n_tokens: int,
                            prompt: Optional[Sequence[int]] = None) -> int:
        """Blocks an admit would draw from the free list (shared full
        blocks excluded; a COW'd tail still costs a fresh block)."""
        shared = self.shared_prefix_tokens(prompt) if prompt is not None \
            else 0
        return self.blocks_needed(n_tokens) - shared // self.block_size

    def can_admit(self, n_tokens: int,
                  prompt: Optional[Sequence[int]] = None) -> bool:
        if n_tokens > self.max_seq:
            return False                # would overflow the slot row
        return (bool(self._free_slots)
                and (self.fresh_blocks_needed(n_tokens, prompt)
                     <= len(self._free_blocks)))

    # ---- prefix index ----------------------------------------------------
    def _find_entry(self, key: int, parent: Optional[int],
                    tokens: Tuple[int, ...]) -> Optional[PrefixEntry]:
        """Bucket scan with full verification — collisions become misses."""
        for e in self._prefix_index.get(key, ()):
            if e.parent == parent and e.tokens == tokens:
                return e
        return None

    def _match_prefix(self, prompt: Sequence[int]
                      ) -> Tuple[int, List[int], List[int]]:
        """Longest indexed prefix of ``prompt``: (matched_tokens, blocks,
        chain_keys_of_full_matches).

        Walks the hash chain block by block; where the full-block walk
        ends (divergence mid-block, or the prompt's own tail), the sibling
        entries under the same parent are scanned for the longest
        token-level common prefix — that entry becomes a shared *tail*
        block (the COW source), so sharing is token-granular even though
        the index is block-granular."""
        bs = self.block_size
        plen = len(prompt)
        blocks: List[int] = []
        keys: List[int] = []
        parent: Optional[int] = None
        j = 0
        while (j + 1) * bs <= plen:
            tokens = tuple(int(t) for t in prompt[j * bs:(j + 1) * bs])
            key = self._hash(parent, tokens)
            entry = self._find_entry(key, parent, tokens)
            if entry is None:
                break
            blocks.append(entry.block)
            keys.append(key)
            parent = key
            j += 1
        # partial tail: buckets cannot serve sub-block lookups (the chain
        # key hashes the full block), so scan this depth's siblings
        seg = tuple(int(t) for t in prompt[j * bs:min(plen, (j + 1) * bs)])
        if seg:
            best, best_d = None, 0
            for bucket in self._prefix_index.values():
                for e in bucket:
                    if e.parent != parent:
                        continue
                    d = 0
                    for a, b in zip(e.tokens, seg):
                        if a != b:
                            break
                        d += 1
                    if d > best_d:
                        best, best_d = e, d
            if best is not None:
                blocks.append(best.block)
                return j * bs + best_d, blocks, keys
        return j * bs, blocks, keys

    def _publish(self, lease: SlotLease) -> None:
        """Insert newly-full prompt blocks into the prefix index.

        A block is publishable once every one of its ``block_size``
        positions lies inside the prompt AND has been written — after that
        the writer's position is past it, so the content is frozen."""
        if not self.prefix_sharing or lease.prompt is None:
            return
        bs = self.block_size
        pub_limit = min(lease.written_tokens, len(lease.prompt)) // bs
        while lease.n_published < pub_limit:
            j = lease.n_published
            tokens = lease.prompt[j * bs:(j + 1) * bs]
            parent = lease.chain_keys[j - 1] if j else None
            key = self._hash(parent, tokens)
            if len(lease.chain_keys) <= j:
                lease.chain_keys.append(key)
            block = lease.blocks[j]
            if block not in self._block_entry:
                entry = PrefixEntry(key=key, parent=parent, tokens=tokens,
                                    block=block)
                self._prefix_index.setdefault(key, []).append(entry)
                self._block_entry[block] = entry
            lease.n_published += 1

    def _deref(self, block: int) -> None:
        self._block_refs[block] -= 1
        if self._block_refs[block] == 0:
            del self._block_refs[block]
            entry = self._block_entry.pop(block, None)
            if entry is not None:
                bucket = self._prefix_index[entry.key]
                bucket.remove(entry)
                if not bucket:
                    del self._prefix_index[entry.key]
            self._free_blocks.append(block)

    # ---- alloc / free ----------------------------------------------------
    def alloc(self, rid: int, n_tokens: int,
              prompt: Optional[Sequence[int]] = None) -> int:
        """Reserve a slot + the blocks for the request's full footprint.

        With ``prefix_sharing`` and a ``prompt``, the longest indexed
        prefix is mapped onto shared pages (refcount++), only the
        remainder draws fresh blocks, and ``lease.shared_tokens`` /
        ``written_tokens`` start past the shared KV.  A partial-tail match
        schedules a COW page copy (see :meth:`consume_cow`).  Returns the
        slot index."""
        if rid in self._leases:
            raise ValueError(f"request {rid} already holds a slot")
        use_sharing = self.prefix_sharing and prompt is not None \
            and len(prompt) > 0
        shared = 0
        mblocks: List[int] = []
        keys: List[int] = []
        if use_sharing:
            matched, mblocks, keys = self._match_prefix(prompt)
            shared = min(matched, len(prompt) - 1)
        if not self.can_admit(n_tokens, prompt if use_sharing else None):
            raise ValueError(f"pool cannot admit {n_tokens} tokens "
                             f"(free slots={self.free_slot_count}, "
                             f"free blocks={self.free_block_count})")
        bs = self.block_size
        shared_full = shared // bs
        fresh_needed = self.blocks_needed(n_tokens) - shared_full
        slot = self._free_slots.pop()
        fresh = [self._free_blocks.pop() for _ in range(fresh_needed)]
        blocks = mblocks[:shared_full] + fresh
        for b in mblocks[:shared_full]:
            self._block_refs[b] += 1
        for b in fresh:
            self._block_refs[b] = 1
        lease = SlotLease(
            rid=rid, slot=slot, blocks=blocks, reserved_tokens=n_tokens,
            written_tokens=shared,
            prompt=tuple(int(t) for t in prompt) if use_sharing else None,
            shared_tokens=shared, n_published=shared_full,
            chain_keys=keys[:shared_full])
        self._leases[rid] = lease
        if shared % bs:
            # partial tail: COW the shared source page into fresh[0]
            # before the first divergent write (position `shared`).  The
            # source keeps an extra ref until the copy is consumed.
            src = mblocks[shared_full]
            self._block_refs[src] += 1
            self._pending_cow.setdefault(rid, []).append((src, fresh[0]))
            self.cow_copies += 1
        if shared:
            self.prefix_hits += 1
            self.tokens_prefill_skipped += shared
        self.peak_slots_in_use = max(self.peak_slots_in_use,
                                     self.n_slots - self.free_slot_count)
        self.peak_blocks_in_use = max(self.peak_blocks_in_use,
                                      self.allocated_block_count)
        if self.on_event is not None:
            self.on_event("alloc", rid, len(blocks))
        return slot

    def consume_cow(self, rid: int) -> List[Tuple[int, int]]:
        """Drain the request's pending COW page copies [(src, dst)].

        The caller (``SlotEngine.bind`` / the disagg import, which lands
        the tail page from the snapshot instead) must materialize the
        copies before any subsequent ``alloc`` — dropping the source's
        extra ref here may return it to the free list."""
        ops = self._pending_cow.pop(rid, [])
        for src, _ in ops:
            self._deref(src)
        return ops

    def note_write(self, rid: int, n_tokens: int = 1) -> None:
        """Record KV entries written for `rid` (utilization accounting;
        publishes newly-full prompt blocks to the prefix index)."""
        lease = self._leases[rid]
        lease.written_tokens += n_tokens
        if lease.written_tokens > lease.reserved_tokens:
            raise ValueError(f"request {rid} wrote past its reservation "
                             f"({lease.written_tokens} > "
                             f"{lease.reserved_tokens})")
        self._publish(lease)

    def free(self, rid: int) -> int:
        """Release the request's slot + block refs.  A block returns to the
        free list (and leaves the prefix index) only at refcount zero.
        Returns the slot index."""
        lease = self._leases.pop(rid, None)
        if lease is None:
            raise ValueError(f"request {rid} holds no slot (double free?)")
        for src, _ in self._pending_cow.pop(rid, []):
            self._deref(src)            # unconsumed COW: drop the src ref
        for b in lease.blocks:
            self._deref(b)
        self._free_slots.append(lease.slot)
        if self.on_event is not None:
            self.on_event("free", rid, len(lease.blocks))
        return lease.slot

    def lease(self, rid: int) -> SlotLease:
        return self._leases[rid]

    def shared_tokens(self, rid: int) -> int:
        """Prefix tokens request `rid` serves from shared pages (0 when
        sharing is off or nothing matched)."""
        return self._leases[rid].shared_tokens

    def block_table(self, rid: int, pad_to: Optional[int] = None
                    ) -> np.ndarray:
        """The request's physical block ids in logical order (block ``j``
        holds tokens ``[j * block_size, (j + 1) * block_size)``), padded
        with 0 to ``pad_to`` entries — the row the paged decode step's
        gather indexes with.  Padding entries are never dereferenced for a
        valid position (the per-slot position mask hides them)."""
        blocks = self._leases[rid].blocks
        n = len(blocks) if pad_to is None else pad_to
        if len(blocks) > n:
            raise ValueError(f"request {rid} holds {len(blocks)} blocks, "
                             f"pad_to={pad_to} is smaller")
        row = np.zeros((n,), np.int32)
        row[:len(blocks)] = blocks
        return row

    # ---- accounting ------------------------------------------------------
    @property
    def written_tokens(self) -> int:
        """KV entries visible across all live leases (shared KV counts once
        per lease — the per-request view, not the physical one)."""
        return sum(l.written_tokens for l in self._leases.values())

    def utilization(self) -> float:
        """Written tokens / capacity of allocated blocks.  Without sharing
        this is <= 1 (1 - internal fragmentation + unreached reservation);
        with sharing it may exceed 1 — the dedup factor."""
        alloc_tokens = self.allocated_block_count * self.block_size
        if alloc_tokens == 0:
            return 0.0
        return self.written_tokens / alloc_tokens

    def occupancy(self) -> float:
        """Allocated blocks / total blocks (pool pressure for admission)."""
        return self.allocated_block_count / self.total_blocks

    def stats(self) -> Dict[str, float]:
        out = {
            "slots_in_use": self.n_slots - self.free_slot_count,
            "blocks_in_use": self.allocated_block_count,
            "total_blocks": self.total_blocks,
            "occupancy": self.occupancy(),
            "utilization": self.utilization(),
            "peak_slots_in_use": self.peak_slots_in_use,
            "peak_blocks_in_use": self.peak_blocks_in_use,
        }
        if self.prefix_sharing:
            out.update({
                "prefix_hits": self.prefix_hits,
                "tokens_prefill_skipped": self.tokens_prefill_skipped,
                "cow_copies": self.cow_copies,
                "shared_tokens_in_use": sum(
                    l.shared_tokens for l in self._leases.values()),
            })
        return out
