"""The programmatic serving API: ``serve(ServeOptions) -> ServeReport``.

Everything ``python -m repro.launch.serve`` can do is driven through one
typed entry point so benchmarks and tests compose serving runs in-process
instead of shelling out and scraping stdout:

    from repro.serving.api import ServeOptions, serve
    opts = ServeOptions()
    opts.workload.arch = "granite_34b"
    opts.speculative.speculate = True
    report = serve(opts)
    print(report.summary["tok_per_s"], report.speculation)

``ServeOptions`` groups the CLI's flags into sub-configs (workload,
engine, pricing, placement, observability, speculative) whose *field
names match the flag names 1:1* — ``--draft-arch`` is
``options.speculative.draft_arch`` — and ``ServeOptions.from_args``
builds the whole tree from a parsed ``argparse`` namespace, so the CLI's
``main()`` is nothing but parse -> from_args -> validate -> serve.

``validate()`` raises ``ValueError`` on every flag interaction that used
to silently no-op (``--shared-frac`` without ``--shared-prefix-len``,
``--misprice`` without ``--watchdog``, ``--slo-ttft-ms`` without
``--slo-report``, disagg-only knobs on a colocated run, ...): an option
the runtime would ignore is a configuration bug, not a default.

Speculative decoding rides the same path: ``speculative.speculate=True``
asks the trade-off analyzer (`placement.choose_speculation`) to price a
draft model against plain decode at the measured-or-prior acceptance
rate and only engages speculation when it wins; ``draft_k`` forces a
depth regardless of price (the CI/identity knob).  The measured
acceptance rate of an engaged run is persisted into the ``--feed-cache``
profile cache (`profiling.acceptance`), so the next run prices on data.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs import registry
from ..launch.mesh import (device_assignment, make_host_mesh,
                           make_production_mesh)
from ..models import sharding as shard_lib
from ..models import transformer as T
from ..obs import Observability, TelemetryFeedback, Tracer, default_clock
from ..obs.export import write_metrics, write_trace
from ..obs.watchdog import AcceptanceTracker
from . import placement as placement_lib
from .disagg import DisaggregatedEngineLoop
from .engine_loop import EngineLoop
from .placement import choose_speculation, place_phases
from .request import prefix_shared_workload, synthetic_workload
from .speculative import (DEFAULT_ACCEPTANCE_PRIOR, DEFAULT_DRAFT_ARCH,
                          SpecPlan, SpeculativeEngineLoop,
                          validate_speculation)

# defaults applied at serve() time for options whose parser default is
# None so validate() can tell "user set it" from "left alone" (the
# no-op-flag audit: --shared-frac without --shared-prefix-len used to
# silently do nothing; now it raises, and the default lives here)
EFFECTIVE_DEFAULTS = {
    "shared_frac": 0.9,
    "calibrated_engine": "xla",
    "misprice_phase": "both",
    "slo_ttft_ms": 2000.0,
    "slo_tpot_ms": 200.0,
    "draft_arch": DEFAULT_DRAFT_ARCH,
}


# ---------------------------------------------------------------------------
# Options tree (field names == CLI flag names, dashes -> underscores)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class WorkloadOptions:
    """What traffic to serve."""
    arch: str = "qwen2_1_5b"
    scale: str = "smoke"
    requests: int = 8
    prompt_len: int = 32
    gen_len: int = 32
    rate: float = 16.0
    shared_prefix_len: Optional[int] = None
    shared_frac: Optional[float] = None          # effective 0.9


@dataclasses.dataclass
class EngineOptions:
    """How the serving engine runs and lays out KV."""
    mesh: str = "host"
    static_batching: bool = False
    batch: int = 4                               # static path only
    slots: int = 8
    kv_layout: str = "paged"
    total_blocks: Optional[int] = None
    prefix_sharing: bool = False
    stream: bool = False


@dataclasses.dataclass
class PricingOptions:
    """Which device model prices admission."""
    step_slo_ms: Optional[float] = None
    device_model: str = "tpu-v5e"
    calibrated_cache: Optional[str] = None
    calibrated_engine: Optional[str] = None      # effective "xla"


@dataclasses.dataclass
class PlacementOptions:
    """Phase placement + disaggregation."""
    placement: str = "colocated"
    placement_objective: str = "latency"
    prefill_engine: Optional[str] = None
    decode_engine: Optional[str] = None
    prefill_slots: Optional[int] = None
    device_assignment: str = "single"
    sync_handoff: bool = False
    handoff_link_bw: Optional[float] = None
    measure_link_bw: Any = None                  # True | path | None


@dataclasses.dataclass
class ObservabilityOptions:
    """Tracing, metrics, telemetry feedback, watchdog, SLO reporting."""
    trace: Optional[str] = None
    metrics_out: Optional[str] = None
    feed_cache: Any = None                       # True | path | None
    persist_curves: Optional[str] = None
    watchdog: bool = False
    drift_gate: Optional[float] = None
    misprice: Optional[float] = None
    misprice_phase: Optional[str] = None         # effective "both"
    slo_report: bool = False
    slo_ttft_ms: Optional[float] = None          # effective 2000.0
    slo_tpot_ms: Optional[float] = None          # effective 200.0


@dataclasses.dataclass
class SpeculativeOptions:
    """Draft-model speculative decoding on the decode phase."""
    speculate: bool = False
    draft_arch: Optional[str] = None             # effective qwen2_1_5b
    draft_k: Optional[int] = None                # None -> analyzer picks


@dataclasses.dataclass
class ServeOptions:
    """Typed configuration for one serving run (1:1 with the serve CLI)."""
    workload: WorkloadOptions = dataclasses.field(
        default_factory=WorkloadOptions)
    engine: EngineOptions = dataclasses.field(default_factory=EngineOptions)
    pricing: PricingOptions = dataclasses.field(
        default_factory=PricingOptions)
    placement: PlacementOptions = dataclasses.field(
        default_factory=PlacementOptions)
    observability: ObservabilityOptions = dataclasses.field(
        default_factory=ObservabilityOptions)
    speculative: SpeculativeOptions = dataclasses.field(
        default_factory=SpeculativeOptions)

    @classmethod
    def groups(cls) -> Tuple[Tuple[str, type], ...]:
        return tuple((f.name, f.type) if isinstance(f.type, type)
                     else (f.name, f.default_factory)
                     for f in dataclasses.fields(cls))

    @classmethod
    def flat_fields(cls) -> Dict[str, str]:
        """Leaf option name -> owning group, for the docs/CLI 1:1 gate."""
        out: Dict[str, str] = {}
        for gname, gcls in cls.groups():
            for f in dataclasses.fields(gcls):
                if f.name in out:
                    raise AssertionError(
                        f"option {f.name!r} appears in both "
                        f"{out[f.name]!r} and {gname!r}")
                out[f.name] = gname
        return out

    @classmethod
    def from_args(cls, args) -> "ServeOptions":
        """Build the options tree from a parsed argparse namespace whose
        dests match the leaf field names (what build_parser produces)."""
        kwargs = {}
        for gname, gcls in cls.groups():
            kwargs[gname] = gcls(**{f.name: getattr(args, f.name)
                                    for f in dataclasses.fields(gcls)})
        return cls(**kwargs)

    @property
    def disagg_requested(self) -> bool:
        pl = self.placement
        return (pl.placement in ("disagg", "auto")
                or bool(pl.prefill_engine) or bool(pl.decode_engine))

    def validate(self) -> "ServeOptions":
        """Raise ValueError on contradictory or silently-no-op options."""
        w, e, p = self.workload, self.engine, self.pricing
        pl, o, s = self.placement, self.observability, self.speculative
        if pl.placement == "auto" and (pl.prefill_engine
                                       or pl.decode_engine):
            raise ValueError(
                "--placement auto chooses the engines; drop "
                "--prefill-engine/--decode-engine or use --placement disagg")
        if e.stream and e.static_batching:
            raise ValueError(
                "--stream needs the continuous engine (the static server "
                "only surfaces tokens at batch end)")
        if e.static_batching and (o.trace or o.metrics_out or o.feed_cache
                                  or o.watchdog or o.slo_report):
            raise ValueError(
                "--trace/--metrics-out/--feed-cache/--watchdog/--slo-report "
                "instrument the continuous engine; drop --static-batching")
        if e.static_batching and (pl.device_assignment != "single"
                                  or pl.sync_handoff or o.persist_curves
                                  or pl.measure_link_bw):
            raise ValueError(
                "--device-assignment/--sync-handoff/--persist-curves/"
                "--measure-link-bw drive the continuous engine; drop "
                "--static-batching")
        if e.prefix_sharing and e.kv_layout == "dense":
            raise ValueError("--prefix-sharing maps physical KV pages; it "
                             "requires --kv-layout paged")
        if e.prefix_sharing and e.static_batching:
            raise ValueError(
                "--prefix-sharing needs the continuous engine's KV pool")
        if w.shared_prefix_len is not None and w.shared_prefix_len <= 0:
            raise ValueError("--shared-prefix-len must be > 0")
        if w.shared_frac is not None and w.shared_prefix_len is None:
            raise ValueError(
                "--shared-frac sizes the --shared-prefix-len workload and "
                "does nothing without it; set both or neither")
        if o.misprice is not None and o.misprice <= 0:
            raise ValueError("--misprice must be > 0")
        if o.misprice_phase is not None and o.misprice is None:
            raise ValueError("--misprice-phase scopes --misprice and does "
                             "nothing without it; add --misprice FACTOR")
        if ((o.misprice is not None or o.drift_gate is not None)
                and not o.watchdog):
            raise ValueError(
                "--misprice/--drift-gate configure the watchdog and do "
                "nothing without it; add --watchdog")
        if ((o.slo_ttft_ms is not None or o.slo_tpot_ms is not None)
                and not o.slo_report):
            raise ValueError(
                "--slo-ttft-ms/--slo-tpot-ms set --slo-report objectives "
                "and do nothing without it; add --slo-report")
        if p.calibrated_engine is not None and p.calibrated_cache is None:
            raise ValueError(
                "--calibrated-engine picks measurements out of "
                "--calibrated-cache and does nothing without it; pass the "
                "cache path too")
        if not self.disagg_requested:
            if pl.sync_handoff:
                raise ValueError(
                    "--sync-handoff tunes the disaggregated hand-off; "
                    "request --placement disagg/auto")
            if pl.prefill_slots is not None:
                raise ValueError(
                    "--prefill-slots sizes the disaggregated prefill pool; "
                    "request --placement disagg/auto")
            if pl.handoff_link_bw is not None:
                raise ValueError(
                    "--handoff-link-bw prices the disaggregated hand-off; "
                    "request --placement disagg/auto")
        if s.speculate:
            if e.static_batching:
                raise ValueError("--speculate drives the continuous "
                                 "engine's paged decode; drop "
                                 "--static-batching")
            if e.prefix_sharing:
                raise ValueError(
                    "--speculate is incompatible with --prefix-sharing "
                    "(rejected verify windows must never land in "
                    "refcounted shared pages)")
            if e.kv_layout == "dense":
                raise ValueError("--speculate verifies against the paged "
                                 "KV arena; it requires --kv-layout paged")
        elif s.draft_arch is not None or s.draft_k is not None:
            raise ValueError("--draft-arch/--draft-k configure speculation "
                             "and do nothing without it; add --speculate")
        if s.draft_k is not None and s.draft_k < 1:
            raise ValueError("--draft-k must be >= 1")
        return self


# ---------------------------------------------------------------------------
# Report
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ServeReport:
    """What one serving run produced and measured."""
    summary: Dict[str, Any]
    metrics: Any = None                  # ServeMetrics (continuous path)
    requests: List[Any] = dataclasses.field(default_factory=list)
    pool_stats: Dict[str, Dict] = dataclasses.field(default_factory=dict)
    admission: List[Dict] = dataclasses.field(default_factory=list)
    handoff: Optional[Dict] = None
    watchdog: Optional[Dict] = None
    slo: Optional[List] = None
    placement: Optional[Dict] = None
    decode_target: Optional[str] = None
    speculation: Optional[Dict] = None
    static_tokens: Optional[List] = None

    @property
    def outputs(self) -> Dict[int, Any]:
        """rid -> generated token list (continuous path)."""
        return {r.rid: r.output for r in self.requests}


# ---------------------------------------------------------------------------
# Building blocks shared with the CLI
# ---------------------------------------------------------------------------
class Server:
    """Legacy static-batching server (the continuous engine's baseline)."""

    def __init__(self, cfg: T.ModelConfig, params, mesh, max_len: int):
        self.cfg = cfg
        self.params = params
        self.mesh = mesh
        self.max_len = max_len
        self._decode = jax.jit(
            lambda p, c, t: T.decode_step(p, cfg, c, t), donate_argnums=(1,))

    def generate(self, prompts: jnp.ndarray, gen_len: int) -> jnp.ndarray:
        """prompts: (B, P) int32.  Returns (B, gen_len)."""
        b, plen = prompts.shape
        # build a max_len cache and replay the prompt through decode steps
        # (keeps the cache layout identical to the dry-run serve_step cells)
        cache = T.init_cache(self.cfg, b, max_seq=self.max_len)
        for i in range(plen):
            step_logits, cache = self._decode(self.params, cache,
                                              prompts[:, i:i + 1])
        next_tok = jnp.argmax(step_logits[:, -1], axis=-1)[:, None]
        out: List[jnp.ndarray] = [next_tok]
        for _ in range(gen_len - 1):
            step_logits, cache = self._decode(self.params, cache, out[-1])
            out.append(jnp.argmax(step_logits[:, -1], axis=-1)[:, None])
        return jnp.concatenate(out, axis=1)


def build_params(cfg: T.ModelConfig, mesh):
    policy = shard_lib.make_policy(cfg, mesh)
    p_shapes = jax.eval_shape(
        functools.partial(T.init_params, cfg=cfg), jax.random.PRNGKey(0))
    p_sh = shard_lib.param_shardings(cfg, policy, p_shapes)
    with mesh:
        return jax.jit(functools.partial(T.init_params, cfg=cfg),
                       out_shardings=p_sh)(jax.random.PRNGKey(0))


def _silent(*args, **kwargs) -> None:
    pass


def _prime_curves(persist_curves: Optional[str], cfg, kv_len: int, batcher,
                  say: Callable) -> None:
    """--persist-curves startup leg: fit the latency(batch) curve from the
    telemetry a previous run fed into the cache and install it as the
    decode batcher's pricing — a restarted server prices from the last
    run's observed curve instead of re-warming through the watchdog."""
    if not persist_curves:
        return
    import os

    from ..obs.curves import curve_points_from_cache, fit_latency_curve
    from ..profiling.cache import ProfileCache
    if not os.path.exists(persist_curves):
        say(f"[serve] curves: {persist_curves} does not exist yet "
            f"(first run warms it)", flush=True)
        return
    cache = ProfileCache.load(persist_curves, strict=False)
    points = curve_points_from_cache(cache, cfg, kv_len=kv_len)
    curve = fit_latency_curve(points, source="cache-curve")
    if curve is None:
        say(f"[serve] curves: {persist_curves} holds "
            f"{len(points)} usable batch point(s) — need >= 2 for a "
            f"curve; pricing stays analytic", flush=True)
        return
    detail = batcher.reprice(curve.predict, source="cache-curve")
    say(f"[serve] curves: primed {batcher.phase} pricing from "
        f"{persist_curves} (batches {list(curve.batches)}, "
        f"token budget {detail['token_budget_old']} -> "
        f"{detail['token_budget']})", flush=True)


def _acceptance_prior(options: ServeOptions) -> Tuple[float, str]:
    """Acceptance rate to price speculation with: a measured rate from
    any cache this run touches (feed-cache, persist-curves, calibrated),
    else the optimistic engagement prior."""
    import os

    from ..profiling.acceptance import cached_acceptance
    from ..profiling.cache import DEFAULT_CACHE_PATH, ProfileCache
    o, p, s = (options.observability, options.pricing, options.speculative)
    draft = s.draft_arch or EFFECTIVE_DEFAULTS["draft_arch"]
    candidates = []
    if o.feed_cache:
        candidates.append(DEFAULT_CACHE_PATH if o.feed_cache is True
                          else o.feed_cache)
    if o.persist_curves:
        candidates.append(o.persist_curves)
    if p.calibrated_cache:
        candidates.append(p.calibrated_cache)
    for path in candidates:
        if not os.path.exists(path):
            continue
        rate = cached_acceptance(
            ProfileCache.load(path, strict=False), draft_arch=draft,
            target_arch=options.workload.arch)
        if rate is not None:
            return rate, f"measured:{path}"
    return DEFAULT_ACCEPTANCE_PRIOR, "prior"


# ---------------------------------------------------------------------------
# serve()
# ---------------------------------------------------------------------------
def serve(options: ServeOptions, *, verbose: bool = False,
          on_delta: Optional[Callable] = None) -> ServeReport:
    """Run one serving run as configured and report what it measured.

    ``verbose`` reproduces the CLI's progress prints; ``on_delta``
    receives :class:`~repro.serving.driver.StreamDelta` objects when
    streaming (passing one implies the per-burst sync even without
    ``options.engine.stream``).  Configuration errors raise
    ``ValueError`` (``validate()`` runs first).
    """
    options.validate()
    w, e, p = options.workload, options.engine, options.pricing
    pl, o, s = (options.placement, options.observability,
                options.speculative)
    say = print if verbose else _silent

    arch = registry.get(w.arch)
    cfg = arch.smoke if w.scale == "smoke" else arch.config
    if cfg is None or cfg.encoder_decoder or cfg.frontend != "none":
        raise ValueError(f"serve supports decoder-only LMs; {w.arch} "
                         f"is not one at scale {w.scale}")
    cfg = dataclasses.replace(cfg, scan_chunk=min(cfg.scan_chunk, 16))
    kv_layout = e.kv_layout
    if kv_layout == "paged" and cfg.attn_window is not None:
        # the paged arena has no rolling-buffer mode yet (ROADMAP follow-on)
        say(f"[serve] {w.arch} uses sliding-window attention "
            f"(window={cfg.attn_window}); paged KV layout does not "
            f"support rolling buffers yet — falling back to dense",
            flush=True)
        kv_layout = "dense"
    if e.prefix_sharing:
        if kv_layout != "paged":
            raise ValueError(f"--prefix-sharing requires the paged KV "
                             f"layout, but {w.arch} fell back to dense "
                             f"(sliding-window attention)")
        if any(t != "attn" for t in cfg.layer_types()):
            raise ValueError(f"--prefix-sharing requires an all-attention "
                             f"config; {w.arch} mixes layer types "
                             f"{sorted(set(cfg.layer_types()))} "
                             f"(recurrent/cross state is slot-local)")

    mesh = (make_host_mesh() if e.mesh == "host" else
            make_production_mesh(multi_pod=e.mesh == "multipod"))
    params = build_params(cfg, mesh)
    max_len = w.prompt_len + w.gen_len

    if e.static_batching:
        server = Server(cfg, params, mesh, max_len=max_len)
        rng = jax.random.PRNGKey(1)
        done = 0
        batches: List = []
        # monotonic clock (shared with the serving loops' timing): wall
        # clock steps under NTP and must not measure intervals
        t0 = default_clock()
        while done < w.requests:
            n = min(e.batch, w.requests - done)
            rng, k = jax.random.split(rng)
            prompts = jax.random.randint(k, (n, w.prompt_len), 0, cfg.vocab)
            with mesh:
                toks = server.generate(prompts, w.gen_len)
            toks.block_until_ready()
            batches.append(toks)
            done += n
            say(f"[serve] batch of {n}: generated {toks.shape} "
                f"first row: {toks[0, :8].tolist()}", flush=True)
        dt = default_clock() - t0
        total_toks = w.requests * w.gen_len
        say(f"served {w.requests} requests, {total_toks} tokens in "
            f"{dt:.1f}s ({total_toks / dt:.1f} tok/s)")
        return ServeReport(
            summary={"requests": w.requests, "tokens": total_toks,
                     "elapsed_s": dt, "tok_per_s": total_toks / dt,
                     "static_batching": True},
            static_tokens=batches)

    # continuous batching: mixed-length open-loop traffic.  With
    # shared_prefix_len the stream front-loads one common prefix onto
    # shared_frac of the requests (prompts grow by the prefix, so the
    # pool's max_seq grows with them)
    gen_lens = (max(w.gen_len // 8, 1), max(w.gen_len // 2, 1), w.gen_len)
    if w.shared_prefix_len is not None:
        shared_frac = (EFFECTIVE_DEFAULTS["shared_frac"]
                       if w.shared_frac is None else w.shared_frac)
        requests = prefix_shared_workload(
            w.requests, rate=w.rate, vocab=cfg.vocab,
            shared_prefix_len=w.shared_prefix_len,
            shared_frac=shared_frac,
            suffix_lens=(max(w.prompt_len // 2, 1), w.prompt_len),
            gen_lens=gen_lens, seed=1)
        max_len += w.shared_prefix_len
    else:
        requests = synthetic_workload(
            w.requests, rate=w.rate, vocab=cfg.vocab,
            prompt_lens=(max(w.prompt_len // 2, 1), w.prompt_len),
            gen_lens=gen_lens, seed=1)
    device_model = None
    if p.calibrated_cache is not None:
        import os

        from ..core.engines import ENGINES_BY_NAME
        from ..profiling import Measurement, ProfileCache, calibrate_engine
        calibrated_engine = (p.calibrated_engine
                             or EFFECTIVE_DEFAULTS["calibrated_engine"])
        if not os.path.exists(p.calibrated_cache):
            raise ValueError(
                f"--calibrated-cache {p.calibrated_cache}: no such file "
                f"(run `python -m repro.launch.profile` first)")
        cache = ProfileCache.load(p.calibrated_cache)
        eng = ENGINES_BY_NAME[calibrated_engine]
        ms = [Measurement.from_dict(d)
              for d in cache.measurements(engine=eng.name)]
        if not ms:
            n_stale = len(cache.measurements(engine=eng.name, stale=True))
            raise ValueError(
                f"{p.calibrated_cache} has no measurements for engine "
                f"{eng.name} under this environment ({n_stale} from other "
                f"jax versions/backends; re-profile here or pass a "
                f"matching cache)")
        device_model = calibrate_engine(eng, ms, register=True)
        say(f"[serve] admission priced on {device_model.name} "
            f"({device_model.n_measurements} measurements, kinds "
            f"{sorted(device_model.throughput)}; other kinds fall back to "
            f"{device_model.base_efficiency:.2f} x peak)")
    else:
        calibrated_engine = EFFECTIVE_DEFAULTS["calibrated_engine"]

    # phase placement: which engine's device model prices each phase
    from ..core.engines import ENGINES_BY_NAME

    def _engine(name: str):
        if name not in ENGINES_BY_NAME:
            raise ValueError(f"unknown engine {name!r} (choose from "
                             f"{', '.join(sorted(ENGINES_BY_NAME))})")
        return ENGINES_BY_NAME[name]

    if on_delta is None and e.stream:
        if verbose:
            def on_delta(d):
                toks = ",".join(str(t) for t in d.tokens)
                tag = " [done]" if d.done else ""
                print(f"[stream] t={d.t:8.3f}s rid={d.rid:>4} "
                      f"+{len(d.tokens)} [{toks}]{tag}", flush=True)
        else:
            on_delta = _silent

    step_slo_s = None if p.step_slo_ms is None else p.step_slo_ms / 1e3

    # device topology: pin the two phase engines onto distinct devices
    # (degrades gracefully to one device when only one is visible)
    assignment = None
    if pl.device_assignment == "auto":
        assignment = device_assignment()
        say(f"[serve] device assignment: {assignment.summary()}",
            flush=True)

    # measured inter-device link bandwidth: an actual device_put of a
    # representative page batch, persisted environment-keyed in the
    # profile cache so place_phases(price="measured") prices hand-offs
    # from it on later runs too
    measured_link_bw = None
    if pl.measure_link_bw:
        from ..profiling import record_link_bw
        from ..profiling.cache import DEFAULT_CACHE_PATH, ProfileCache
        link_cache_path = (DEFAULT_CACHE_PATH
                           if pl.measure_link_bw is True
                           else pl.measure_link_bw)
        devs = assignment if assignment is not None else device_assignment()
        link_cache = ProfileCache.load(link_cache_path, strict=False)
        m = record_link_bw(link_cache, devs.prefill, devs.decode)
        link_cache.save(link_cache_path)
        measured_link_bw = m["link_bw"]
        say(f"[serve] link {m['src']} -> {m['dst']}: "
            f"{measured_link_bw / 1e9:.2f} GB/s "
            f"({m['n_bytes']} bytes in {m['t_median'] * 1e3:.3f} ms) "
            f"-> {link_cache_path}", flush=True)
    handoff_link_bw = (pl.handoff_link_bw if pl.handoff_link_bw is not None
                       else measured_link_bw)
    # one observability bundle for whichever loop runs: tracing only when
    # asked (NullTracer otherwise — near-zero cost), registry always (it
    # backs the hand-off ledger and the metrics dump), feedback only with
    # feed_cache (it syncs each decode burst to time it)
    watchdog = None
    if o.watchdog:
        from ..obs import PerfWatchdog
        watchdog = (PerfWatchdog() if o.drift_gate is None
                    else PerfWatchdog(drift_gate=o.drift_gate))
    obs = Observability(
        tracer=Tracer() if o.trace else None,
        feedback=(TelemetryFeedback(cfg, kv_len=max_len)
                  if o.feed_cache or o.persist_curves else None),
        watchdog=watchdog)

    misprice_phase = (o.misprice_phase
                      or EFFECTIVE_DEFAULTS["misprice_phase"])

    def _misprice(dev, phase=None):
        """Inject an admission-pricing error for watchdog CI/debug runs.
        ``misprice_phase`` scopes it to one phase's device model so
        exactly that stream drifts (the placement-actuation trigger)."""
        if o.misprice is None:
            return dev
        if (phase is not None and misprice_phase != "both"
                and misprice_phase != phase):
            return dev
        from ..core import device_models
        from .placement import drift_scaled_device
        if dev is None:
            dev = device_models.get(p.device_model)
        return drift_scaled_device(dev, o.misprice)

    placement_report = None
    pre_eng = dec_eng = None
    if pl.placement == "auto":
        decision = place_phases(
            cfg, objective=pl.placement_objective,
            prompt_len=w.prompt_len, gen_len=w.gen_len, batch=e.slots,
            price="measured" if p.calibrated_cache else "analytic",
            cache_path=p.calibrated_cache)
        say(f"[serve] {decision.summary()}", flush=True)
        pre_eng = ENGINES_BY_NAME[decision.prefill_engine]
        dec_eng = ENGINES_BY_NAME[decision.decode_engine]
        placement_report = {"mode": "auto",
                            "prefill_engine": decision.prefill_engine,
                            "decode_engine": decision.decode_engine,
                            "objective": pl.placement_objective,
                            "summary": decision.summary()}
    elif pl.placement == "disagg" or pl.prefill_engine or pl.decode_engine:
        pre_eng = _engine(pl.prefill_engine or "xla")
        dec_eng = _engine(pl.decode_engine or "xla")
        placement_report = {"mode": "disagg",
                            "prefill_engine": pre_eng.name,
                            "decode_engine": dec_eng.name}
        for eng, phase in ((pre_eng, "prefill"), (dec_eng, "decode")):
            try:
                c = placement_lib.phase_cost(
                    cfg, eng, phase, prompt_len=w.prompt_len,
                    gen_len=w.gen_len, batch=e.slots)
            except ValueError as err:     # cost-only CNN engine, LM model
                raise ValueError(str(err))
            say(f"[serve] {phase} on {eng.name}: modeled "
                f"{c.time_s*1e3:.3f}ms, {c.energy_j:.4f}J", flush=True)

    def _phase_device(eng):
        """Calibrated model when the cache covers this engine, else its own."""
        if device_model is not None and eng.name == calibrated_engine:
            return device_model
        return eng.device

    # ---- speculative decoding plan ---------------------------------------
    spec_plan = None
    spec_report = None
    if s.speculate:
        draft_arch = s.draft_arch or EFFECTIVE_DEFAULTS["draft_arch"]
        draft_reg = registry.get(draft_arch)
        draft_cfg = (draft_reg.smoke if w.scale == "smoke"
                     else draft_reg.config)
        if draft_cfg is None or draft_cfg.encoder_decoder \
                or draft_cfg.frontend != "none":
            raise ValueError(f"--draft-arch {draft_arch} is not a "
                             f"decoder-only LM at scale {w.scale}")
        draft_cfg = dataclasses.replace(
            draft_cfg, scan_chunk=min(draft_cfg.scan_chunk, 16))
        validate_speculation(cfg, draft_cfg, kv_layout=kv_layout,
                             prefix_sharing=e.prefix_sharing)
        alpha, alpha_src = _acceptance_prior(options)

        def _decide(a: float):
            return choose_speculation(
                cfg, draft_cfg, kv_len=max_len, n_tokens=e.slots,
                acceptance=a, device_name=p.device_model,
                draft_name=draft_arch)

        decision = _decide(alpha)
        forced = s.draft_k is not None
        k = s.draft_k if forced else decision.k
        engaged = forced or decision.use
        if engaged:
            draft_params = build_params(draft_cfg, mesh)
            tracker = AcceptanceTracker(
                decide=None if forced else _decide)
            spec_plan = SpecPlan(draft_cfg, draft_params, k=k,
                                 draft_name=draft_arch, decision=decision,
                                 forced=forced, tracker=tracker)
            say(f"[serve] speculation: draft {draft_arch} k={k} "
                f"acceptance={alpha:.2f} ({alpha_src}) projected "
                f"x{decision.projected_speedup:.2f}"
                f"{' [forced]' if forced else ''}", flush=True)
        else:
            # the analyzer priced speculation worse than plain decode at
            # this acceptance rate — serve plain, record why
            spec_report = {"engaged": False, "priced_fallback": True,
                           "acceptance_prior": alpha,
                           "acceptance_source": alpha_src,
                           "decision": decision.summary()}
            say(f"[serve] speculation: prices worse than plain decode at "
                f"acceptance={alpha:.2f} ({alpha_src}, "
                f"x{decision.projected_speedup:.2f}) — serving plain",
                flush=True)

    # auto placement only disaggregates when the analyzer says the split
    # wins; an explicit --placement disagg always runs the two-engine loop
    # (same-engine disagg measures the bare phase-boundary overhead)
    spec = None
    if pre_eng is not None and (pl.placement == "disagg"
                                or pre_eng.name != dec_eng.name):
        engine = DisaggregatedEngineLoop(
            cfg, params,
            n_prefill_slots=pl.prefill_slots or e.slots,
            n_decode_slots=e.slots, max_seq=max_len,
            kv_layout=kv_layout,
            decode_total_blocks=e.total_blocks,
            prefix_sharing=e.prefix_sharing,
            plan=spec_plan,
            prefill_device=_misprice(_phase_device(pre_eng), "prefill"),
            decode_device=_misprice(_phase_device(dec_eng), "decode"),
            step_slo_s=step_slo_s, obs=obs,
            handoff_link_bw=handoff_link_bw,
            assignment=assignment,
            async_handoff=not pl.sync_handoff,
            placement_engine_name=dec_eng.name,
            prefill_placement_engine_name=pre_eng.name,
            decode_placement_engine_name=dec_eng.name)
        spec = engine.spec
        _prime_curves(o.persist_curves, cfg, max_len,
                      engine.decode_batcher, say)
        if spec_plan is not None and spec_plan.decision is not None \
                and spec_plan.decision.use:
            engine.decode_batcher.reprice(
                lambda n: spec_plan.decision.spec_step_s * n,
                source="speculation")
        with mesh:
            metrics = engine.run(requests, on_delta=on_delta)
        for b in engine.batchers:
            say(f"[serve] {b.phase} token budget {b.token_budget}/"
                f"{b.pool.n_slots} slots (device model {b.device_name})")
        pools = (("prefill", engine.prefill.pool),
                 ("decode", engine.decode.pool))
        batchers = engine.batchers
        handoff_stats = engine.handoff.stats()
        decode_target = engine.decode_target
        for key, v in handoff_stats.items():
            val = f"{v:.4f}" if isinstance(v, float) else str(v)
            say(f"[serve] handoff.{key:>17}: {val}", flush=True)
        say(f"[serve] decode target: {engine.decode_target} engine "
            f"({'async' if not pl.sync_handoff else 'sync'} hand-off)",
            flush=True)
    else:
        if pre_eng is not None:          # colocated by choice of placement
            device_model = _phase_device(pre_eng)
        loop_kwargs = dict(
            n_slots=e.slots, max_seq=max_len, kv_layout=kv_layout,
            total_blocks=e.total_blocks, prefix_sharing=e.prefix_sharing,
            device_name=p.device_model, device_model=_misprice(device_model),
            step_slo_s=step_slo_s, obs=obs)
        if spec_plan is not None:
            engine = SpeculativeEngineLoop(cfg, params, plan=spec_plan,
                                           **loop_kwargs)
            spec = engine.spec
        else:
            engine = EngineLoop(cfg, params, **loop_kwargs)
        _prime_curves(o.persist_curves, cfg, max_len, engine.batcher, say)
        if spec_plan is not None and spec_plan.decision is not None \
                and spec_plan.decision.use:
            engine.batcher.reprice(
                lambda n: spec_plan.decision.spec_step_s * n,
                source="speculation")
        with mesh:
            metrics = engine.run(requests, on_delta=on_delta)
        say(f"[serve] token budget {engine.batcher.token_budget}/"
            f"{e.slots} slots (device model "
            f"{engine.batcher.device_name})")
        pools = (("", engine.pool),)
        batchers = (engine.batcher,)
        handoff_stats = None
        decode_target = None
    summary = metrics.summary()
    for key, v in summary.items():
        val = f"{v:.4f}" if isinstance(v, float) else str(v)
        say(f"[serve] {key:>22}: {val}", flush=True)
    # KV-pool ledger + admission accounting (end-of-run state of the block
    # ledger, plus what the batcher did to the queue over the whole run)
    pool_stats = {}
    for tag, pool in pools:
        prefix = f"kv_pool{'.' + tag if tag else ''}"
        stats = pool.stats()
        pool_stats[tag or "kv_pool"] = stats
        for key, v in stats.items():
            val = f"{v:.4f}" if isinstance(v, float) else str(v)
            say(f"[serve] {prefix}.{key:>15}: {val}", flush=True)
    admission = []
    for b in batchers:
        tag = f" [{b.phase}]" if len(batchers) > 1 else ""
        admission.append({
            "phase": b.phase, "n_admitted": b.n_admitted,
            "n_rejected": b.n_rejected, "n_deferred": b.n_deferred,
            "token_budget": b.token_budget, "n_slots": b.pool.n_slots,
            "device_model": b.device_name, "n_reprices": b.n_reprices,
            "price_source": b.price_source})
        say(f"[serve] admission{tag}: {b.n_admitted} admitted, "
            f"{b.n_rejected} rejected (deadline/oversize), "
            f"{b.n_deferred} deferrals (budget or pool pressure)",
            flush=True)

    # ---- speculation accounting ------------------------------------------
    if spec is not None:
        spec_report = dict(spec.stats())
        spec_report["engaged"] = True
        say(f"[serve] speculation: {spec.n_rounds} rounds, "
            f"{spec.n_committed} committed / {spec.n_proposed} proposed "
            f"(acceptance "
            f"{spec.acceptance_rate if spec.acceptance_rate is not None else float('nan'):.3f})"
            + (" [disabled mid-run: priced worse at measured acceptance]"
               if spec.disabled_midrun else ""), flush=True)

    # ---- watchdog + SLO reporting ----------------------------------------
    watchdog_report = None
    if watchdog is not None:
        watchdog_report = watchdog.report()
        rep = watchdog_report
        say(f"[serve] watchdog: {len(rep['alerts'])} drift alerts, "
            f"{len(rep['reprices'])} re-price events, sync cadence "
            f"{rep['sync_cadence']}", flush=True)
        for a in rep["alerts"]:
            say(f"[serve] watchdog.alert: {a['engine']}/{a['phase']} "
                f"{a['direction']} ewma={a['ewma_ratio']:.2f} "
                f"(priced {a['priced_step_s']*1e3:.2f}ms, observed "
                f"{a['observed_step_s']*1e3:.2f}ms)", flush=True)
        for r in rep["reprices"]:
            say(f"[serve] watchdog.reprice: {r['engine']}/{r['phase']} "
                f"pricing={r.get('pricing')} token_budget "
                f"{r.get('token_budget_old')} -> {r.get('token_budget')}",
                flush=True)
        for b in batchers:
            if b.n_reprices:
                say(f"[serve] admission [{b.phase}] re-priced "
                    f"{b.n_reprices}x ({b.price_source}); final budget "
                    f"{b.token_budget}/{b.pool.n_slots}", flush=True)
    slo_rows = None
    if o.slo_report:
        from ..obs.watchdog import format_slo_report, slo_attainment
        slo_ttft_ms = (EFFECTIVE_DEFAULTS["slo_ttft_ms"]
                       if o.slo_ttft_ms is None else o.slo_ttft_ms)
        slo_tpot_ms = (EFFECTIVE_DEFAULTS["slo_tpot_ms"]
                       if o.slo_tpot_ms is None else o.slo_tpot_ms)
        slo_rows = slo_attainment(requests, ttft_slo_s=slo_ttft_ms / 1e3,
                                  tpot_slo_s=slo_tpot_ms / 1e3)
        say(format_slo_report(slo_rows, ttft_slo_s=slo_ttft_ms / 1e3,
                              tpot_slo_s=slo_tpot_ms / 1e3), flush=True)

    # ---- observability exports -------------------------------------------
    if o.trace:
        path = write_trace(obs.tracer, o.trace)
        say(f"[serve] trace: {len(obs.tracer.events)} events "
            f"({obs.tracer.n_dropped} dropped, {obs.tracer.n_open} "
            f"unclosed) -> {path}", flush=True)
    if o.metrics_out:
        extra = {"summary": summary}
        if watchdog is not None:
            extra["watchdog"] = watchdog_report
        if spec_report is not None:
            extra["speculation"] = spec_report
        path = write_metrics(obs.registry, o.metrics_out,
                             tracer=obs.tracer if o.trace else None,
                             extra=extra)
        say(f"[serve] metrics snapshot -> {path}", flush=True)
    if o.feed_cache:
        from ..profiling.acceptance import record_acceptance
        from ..profiling.cache import DEFAULT_CACHE_PATH, ProfileCache
        cache_path = (DEFAULT_CACHE_PATH if o.feed_cache is True
                      else o.feed_cache)
        cache = ProfileCache.load(cache_path, strict=False)
        n = obs.feedback.flush(cache)
        if spec is not None and spec.n_proposed > 0:
            # persist the measured acceptance so the next run's analyzer
            # prices on data instead of the engagement prior
            record_acceptance(cache, draft_arch=spec.plan.draft_name,
                              target_arch=w.arch, k=spec.plan.k,
                              n_proposed=spec.n_proposed,
                              n_accepted=spec.n_accepted,
                              n_rounds=spec.n_rounds)
            say(f"[serve] acceptance {spec.plan.draft_name} -> {w.arch}: "
                f"{spec.acceptance_rate:.3f} -> {cache_path}", flush=True)
        cache.save(cache_path)
        say(f"[serve] fed {n} telemetry measurements from "
            f"{obs.feedback.n_bursts} bursts (batch sizes "
            f"{obs.feedback.batches}) -> {cache_path}", flush=True)
    if o.persist_curves:
        # persist-curves exit leg: flush this run's burst telemetry so
        # the next serve's _prime_curves finds a fresh curve
        from ..profiling.cache import ProfileCache
        cache = ProfileCache.load(o.persist_curves, strict=False)
        n = obs.feedback.flush(cache)
        cache.save(o.persist_curves)
        say(f"[serve] curves: persisted {n} telemetry measurements "
            f"(batch sizes {obs.feedback.batches}) -> "
            f"{o.persist_curves}", flush=True)

    return ServeReport(
        summary=summary, metrics=metrics, requests=list(requests),
        pool_stats=pool_stats, admission=admission,
        handoff=handoff_stats, watchdog=watchdog_report, slo=slo_rows,
        placement=placement_report, decode_target=decode_target,
        speculation=spec_report)
