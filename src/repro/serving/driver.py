"""Unified open-loop driver for the serving loops + streaming output channel.

Both serving loops — colocated :class:`~repro.serving.engine_loop.EngineLoop`
and phase-disaggregated
:class:`~repro.serving.disagg.DisaggregatedEngineLoop` — used to carry their
own copy of the open-loop scaffolding: drain the arrival stream into the
queue, fast-forward the skew clock across idle gaps, cap bursts while
arrivals are pending, account ``max_steps``, scan completions and aggregate
metrics.  The copies had started to diverge; this module is the single
parameterized driver both loops now instantiate (the "uniform programming
model over heterogeneous engines" discipline the CNN-toolflow line of work
argues for).  A loop provides a small hook surface:

  ``in_flight()``            any admitted/parked work besides the queue
  ``admit(queue, now, m)``   shedding + migration + admission + binding
  ``runnable()``             any engine has an active slot to burst
  ``backlogged(queue)``      loop-specific extra throttle signal (hand-offs)
  ``dispatch(throttle, budget)``  burst the engines, return steps dispatched
  ``sample(m)``              append pool occupancy/utilization samples
  ``scan(clock, m, sink)``   completion scan + stream emission

and the driver owns everything else, so the scaffolding exists in exactly
one place.

Streaming sits on top of the driver: pass ``on_delta`` and the completion
scan syncs each engine's device chain at the burst boundary
(``SlotEngine.pull_outputs``) and emits ``StreamDelta(rid, tokens)`` for
every newly host-readable sample, instead of only pulling a slot's row at
completion.  This is also where the first-token metric gets honest:

  * ``Request.t_first_token`` is stamped when the first sample is actually
    readable on the host — at the burst-boundary sync under streaming, at
    the completion pull otherwise (matching the static server, which also
    only surfaces tokens at batch end).  TTFT therefore measures delivered
    tokens, not dispatch latency.
  * ``Request.t_first_dispatch`` keeps the old stamp (the burst containing
    the first sample has been *dispatched*, CNNLab's per-stage enqueue
    time), so ``ttft - ttft_dispatch`` quantifies the gap the old metric
    hid.  ``ttft_dispatch <= ttft`` holds for every request.

Streaming costs one host sync per burst boundary; the completion-pull path
keeps the fully-pipelined async dispatch chain.  Scheduling is identical
either way — streamed deltas concatenate to exactly the completion-pull
rows (asserted in tests/test_driver.py and benchmarks/bench_serving.py).

Observability: the driver reads the loop's :class:`~repro.obs.Observability`
bundle.  It installs the skew clock into the tracer at run start (every
trace timestamp lives on the offered-load timeline the metrics use),
refreshes the registry's gauges each iteration (KV occupancy, queue depth,
in-flight slots, admission totals) and samples them into the registry's
time series, and mirrors the same values as Perfetto counter tracks when
tracing is on.  ``ServeMetrics`` mirrors its per-request observations into
the registry's histograms so one snapshot carries everything.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from ..obs import NullTracer, Observability, default_clock
from .request import Request

# with arrivals (or hand-offs) pending, bursts stay short so admission and
# migration latency are bounded; otherwise a burst runs to the next
# completion boundary
BURST_CAP_PENDING = 4


def _percentile(xs: List[float], q: float) -> Optional[float]:
    """None (JSON null) when there are no observations — never NaN, which
    json.dump writes as a non-standard token strict parsers reject."""
    return float(np.percentile(np.asarray(xs), q)) if xs else None


@dataclasses.dataclass
class ServeMetrics:
    n_done: int = 0
    n_dropped: int = 0
    n_steps: int = 0
    tokens_out: int = 0
    tokens_in: int = 0
    tokens_streamed: int = 0            # delivered incrementally (streaming)
    n_stream_deltas: int = 0            # StreamDelta emissions
    elapsed_s: float = 0.0
    ttft_s: List[float] = dataclasses.field(default_factory=list)
    ttft_dispatch_s: List[float] = dataclasses.field(default_factory=list)
    tpot_s: List[float] = dataclasses.field(default_factory=list)
    latency_s: List[float] = dataclasses.field(default_factory=list)
    occupancy: List[float] = dataclasses.field(default_factory=list)
    utilization: List[float] = dataclasses.field(default_factory=list)
    # optional obs.MetricsRegistry: per-request observations mirror into
    # its histograms/counters so the registry snapshot carries the same
    # distributions this summary reduces
    registry: Optional[object] = None

    def observe(self, req: Request) -> None:
        self.n_done += 1
        self.tokens_out += len(req.output)
        self.tokens_in += req.prompt_len
        if req.ttft is not None:
            self.ttft_s.append(req.ttft)
        if req.ttft_dispatch is not None:
            self.ttft_dispatch_s.append(req.ttft_dispatch)
        if req.tpot is not None:
            self.tpot_s.append(req.tpot)
        if req.t_done is not None:
            self.latency_s.append(req.t_done - req.arrival)
        reg = self.registry
        if reg is not None:
            reg.counter("requests_done").inc()
            reg.counter("tokens_out").inc(len(req.output))
            reg.counter("tokens_in").inc(req.prompt_len)
            if req.ttft is not None:
                reg.histogram("ttft_s").observe(req.ttft)
            if req.tpot is not None:
                reg.histogram("tpot_s").observe(req.tpot)
            if req.t_done is not None:
                reg.histogram("latency_s").observe(req.t_done - req.arrival)

    def drop(self, n: int = 1) -> None:
        self.n_dropped += n
        if self.registry is not None:
            self.registry.counter("requests_dropped").inc(n)

    def summary(self) -> Dict[str, Optional[float]]:
        dt = max(self.elapsed_s, 1e-9)
        return {
            "requests_done": self.n_done,
            "requests_dropped": self.n_dropped,
            "steps": self.n_steps,
            "tokens_in": self.tokens_in,
            "tokens_out": self.tokens_out,
            "tokens_streamed": self.tokens_streamed,
            "stream_deltas": self.n_stream_deltas,
            "elapsed_s": self.elapsed_s,
            "tok_per_s": self.tokens_out / dt,
            "req_per_s": self.n_done / dt,
            "ttft_p50_s": _percentile(self.ttft_s, 50),
            "ttft_p99_s": _percentile(self.ttft_s, 99),
            "ttft_dispatch_p50_s": _percentile(self.ttft_dispatch_s, 50),
            "ttft_dispatch_p99_s": _percentile(self.ttft_dispatch_s, 99),
            "tpot_p50_s": _percentile(self.tpot_s, 50),
            "tpot_p99_s": _percentile(self.tpot_s, 99),
            "latency_p50_s": _percentile(self.latency_s, 50),
            "latency_p99_s": _percentile(self.latency_s, 99),
            "kv_occupancy_mean": (float(np.mean(self.occupancy))
                                  if self.occupancy else 0.0),
            "kv_utilization_mean": (float(np.mean(self.utilization))
                                    if self.utilization else 0.0),
        }


@dataclasses.dataclass
class StreamDelta:
    """One incremental output emission: `tokens` became host-readable for
    request `rid` at time `t` (offered-load timeline).  ``done`` marks the
    request's final delta (tokens may be empty if everything already
    streamed at an earlier burst boundary)."""

    rid: int
    tokens: List[int]
    t: float
    done: bool = False


class TokenSink:
    """Output channel shared by the loops: incremental (streaming) or
    completion-pull delivery, plus the honest first-token stamping.

    ``drain(engine, clock)`` is the burst-boundary side: it syncs the
    engine's per-slot output buffer (one host sync for the whole engine) and
    emits every newly readable sample as a delta.  ``finish(req, row, t)``
    is the completion side: it installs the request's final output row and,
    under streaming, emits the tail delta with ``done=True``.
    """

    def __init__(self, metrics: ServeMetrics,
                 on_delta: Optional[Callable[[StreamDelta], None]] = None,
                 tracer=None, watchdog=None):
        self.metrics = metrics
        self.on_delta = on_delta
        self.tracer = tracer if tracer is not None else NullTracer()
        self.watchdog = watchdog
        # sync cadence: drain (device sync + delta emission) only every
        # k-th boundary per engine.  1 = every boundary (PR 6 behavior);
        # the driver stretches it from the watchdog's sync-cost pressure
        self.sync_every = 1
        self._boundaries: Dict[str, int] = {}   # per-engine boundary count

    @property
    def streaming(self) -> bool:
        return self.on_delta is not None

    def drain(self, engine, clock: Callable[[], float]) -> None:
        """Sync `engine`'s outputs at the burst boundary and emit deltas."""
        if self.on_delta is None:
            return                       # completion-pull: keep async chain
        n = self._boundaries.get(engine.name, 0) + 1
        self._boundaries[engine.name] = n
        if n % max(self.sync_every, 1) != 0:
            return                       # skipped boundary: tokens ride the
            #                              next drain (or the finish() tail)
        h = (self.tracer.begin("sync", track=f"engine:{engine.name}",
                               cat="engine", args={"kind": "drain"})
             if self.tracer.enabled else None)
        t0 = self.tracer.now() if self.watchdog is not None else 0.0
        rows = engine.pull_outputs()     # host sync: burst results land
        if self.watchdog is not None:
            self.watchdog.observe_sync(self.tracer.now() - t0)
        if h is not None:
            self.tracer.end(h)
        t = clock()                      # stamped AFTER materialization
        for s, req in enumerate(engine.slots):
            if req is not None:
                self._emit(req, rows[s], req.samples_ready, t, done=False)

    def finish(self, req: Request, row: np.ndarray, t: float) -> None:
        """Completion pull: install the final output row (and stream the
        tail).  `row` is already trimmed to ``max_new_tokens``."""
        req.output = row.tolist()
        if self.on_delta is not None:
            self._emit(req, row, req.max_new_tokens, t, done=True)
        if req.t_first_token is None:
            # completion-pull delivery: the first token became host-visible
            # just now, with the rest of the row
            req.t_first_token = t
            self._first_token_instant(req, t)

    def _first_token_instant(self, req: Request, t: float) -> None:
        if self.tracer.enabled:
            self.tracer.instant("first_token", track="requests", tid=req.rid,
                                cat="request", t=t,
                                args={"ttft_s": req.ttft,
                                      "ttft_dispatch_s": req.ttft_dispatch})

    def _emit(self, req: Request, row: np.ndarray, n_ready: int, t: float,
              done: bool) -> None:
        new = ([] if n_ready <= req.n_streamed
               else [int(x) for x in row[req.n_streamed:n_ready]])
        if not new and not done:
            return
        if new and req.t_first_token is None:
            req.t_first_token = t        # first sample host-visible
            self._first_token_instant(req, t)
        req.n_streamed = max(req.n_streamed, n_ready)
        self.metrics.tokens_streamed += len(new)
        self.metrics.n_stream_deltas += 1
        self.on_delta(StreamDelta(rid=req.rid, tokens=new, t=t, done=done))


def burst_size(remaining: int, *, throttle: bool,
               budget: Optional[int]) -> int:
    """Pending-aware burst capping + ``max_steps`` accounting (the one
    shared implementation): run to the next completion boundary
    (`remaining`), capped while arrivals/hand-offs wait, capped at the
    remaining step budget."""
    burst = remaining
    if throttle:
        burst = min(burst, BURST_CAP_PENDING)
    if budget is not None:
        burst = min(burst, max(budget, 0))
    return burst


def sample_pools(pools) -> tuple:
    """Aggregate (occupancy, utilization) over one or more KV pools.

    Pools can differ in capacity, so the means are weighted: occupancy by
    each pool's ``total_blocks`` (block-weighted pressure == total allocated
    / total capacity) and utilization by each pool's allocated-block token
    capacity (written / allocated capacity).  With one pool this reduces to
    ``pool.occupancy(), pool.utilization()`` exactly.
    """
    total = sum(p.total_blocks for p in pools)
    alloc = sum(p.allocated_block_count for p in pools)
    occupancy = alloc / total if total else 0.0
    cap = sum(p.allocated_block_count * p.block_size for p in pools)
    written = sum(p.written_tokens for p in pools)
    utilization = written / cap if cap else 0.0
    return occupancy, utilization


class OpenLoopDriver:
    """The shared open-loop serving driver.

    Owns the arrival drain, the idle fast-forward skew clock, the
    throttle/budget plumbing into :func:`burst_size`, the per-iteration
    metric sampling and the run-level metrics; the loop owns the engines.

    Invariants: all time comes from the injected ``now_fn`` — the driver
    installs the derived skew clock into the tracer at run start, so every
    metric stamp and trace timestamp lives on one timeline and tests can
    drive the whole loop on a virtual clock (no hidden wall-time reads).
    Scheduling decisions (admission order, burst sizes, throttles,
    re-prices) affect only timing: per-request greedy outputs depend on
    the prompt and the model alone, streamed deltas concatenate to exactly
    the completion-pull rows, and ``ttft_dispatch <= ttft`` holds for
    every request.
    """

    def __init__(self, loop):
        self.loop = loop
        self.obs: Observability = (getattr(loop, "obs", None)
                                   or Observability())

    def run(self, requests: List[Request], *,
            now_fn: Callable[[], float] = default_clock,
            max_steps: Optional[int] = None,
            on_delta: Optional[Callable[[StreamDelta], None]] = None
            ) -> ServeMetrics:
        """Serve `requests` (an arrival-stamped open-loop stream) to
        completion; returns the aggregate metrics.  With ``on_delta`` the
        run streams: every burst boundary syncs the device chain and emits
        newly readable ``(rid, tokens)`` deltas."""
        loop = self.loop
        obs = self.obs
        metrics = ServeMetrics(registry=obs.registry)
        sink = TokenSink(metrics, on_delta, tracer=obs.tracer,
                         watchdog=obs.watchdog)
        pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
        queue: List[Request] = []
        loop.start_run()
        t0 = now_fn()
        skew = 0.0                       # idle fast-forward (see below)
        clock = lambda: now_fn() - t0 + skew
        # every trace timestamp shares the metrics' offered-load timeline
        obs.tracer.set_clock(clock)

        while pending or queue or loop.in_flight():
            now = clock()
            # open-loop arrivals: everything whose arrival time has passed
            # joins the queue
            while pending and pending[0].arrival <= now:
                queue.append(pending.pop(0))
            if not queue and not loop.in_flight():
                # fully idle with the next arrival in the future: fast-
                # forward the clock to it instead of busy-waiting, so
                # timestamps stay on the offered-load timeline (TTFT and
                # latency remain >= 0)
                skew += pending[0].arrival - now
                continue
            loop.admit(queue, now, metrics)
            if not loop.runnable():
                continue                 # nothing admissible (pool pressure)
            throttle = bool(pending) or loop.backlogged(queue)
            budget = (None if max_steps is None
                      else max_steps - metrics.n_steps)
            metrics.n_steps += loop.dispatch(throttle, budget)
            loop.sample(metrics)
            loop.scan(clock, metrics, sink)
            self._act_on_watchdog(sink)
            self._observe_iteration(metrics, queue, pending, clock())
            if max_steps is not None and metrics.n_steps >= max_steps:
                break
        metrics.elapsed_s = clock()
        return metrics

    def _act_on_watchdog(self, sink: TokenSink) -> None:
        """Burst-boundary watchdog hook: hand pending drift alerts to the
        loop's ``on_drift`` action leg (admission re-pricing + placement
        re-run) and apply the current sync-cadence advice to the streaming
        sink.  All of it is scheduling/pricing policy — per-request greedy
        outputs are schedule-independent, so acting never changes them."""
        wd = self.obs.watchdog
        if wd is None:
            return
        on_drift = getattr(self.loop, "on_drift", None)
        if on_drift is not None:
            for alert in wd.pending_actions():
                on_drift(alert, wd)
        sink.sync_every = wd.sync_cadence()

    def _observe_iteration(self, metrics: ServeMetrics, queue: List[Request],
                           pending: List[Request], now: float) -> None:
        """Refresh the registry's gauges from this iteration's state and
        sample them into the time series (+ Perfetto counter tracks)."""
        loop, reg = self.loop, self.obs.registry
        occ = metrics.occupancy[-1] if metrics.occupancy else 0.0
        util = metrics.utilization[-1] if metrics.utilization else 0.0
        in_flight = loop.n_active
        reg.gauge("kv_occupancy").set(occ)
        reg.gauge("kv_utilization").set(util)
        reg.gauge("queue_depth").set(len(queue))
        reg.gauge("pending_arrivals").set(len(pending))
        reg.gauge("slots_in_flight").set(in_flight)
        batchers = loop.batchers
        reg.gauge("admitted_total").set(sum(b.n_admitted for b in batchers))
        reg.gauge("rejected_total").set(sum(b.n_rejected for b in batchers))
        reg.gauge("deferred_total").set(
            sum(b.n_deferred for b in batchers))
        reg.sample(now)
        tracer = self.obs.tracer
        if tracer.enabled:
            tracer.counter("kv", {"occupancy": occ, "utilization": util},
                           track="server", t=now)
            tracer.counter("load", {"queue_depth": len(queue),
                                    "pending_arrivals": len(pending),
                                    "slots_in_flight": in_flight},
                           track="server", t=now)
