"""Phase placement: price prefill and decode separately over engines.

CNNLab's middleware prices every network stage on every accelerator and
offloads each stage where the user's objective wins (§III.A, Fig. 6).
Serving decomposes into exactly two stages with opposite rooflines —
prefill (seq-long matmuls, compute-bound) and decode (one token against a
long KV cache, memory-bound) — so the same design-space exploration
applies: enumerate (prefill engine, decode engine) pairs, price each
phase with ``core/cost_model.py`` on that engine's device model, price
the phase-boundary hand-off with the offload-overhead model
(``transfer_cost``: KV rows + recurrent state at link bandwidth), and
pick the pair minimizing the objective.  Colocated pairs pay no hand-off,
so the analyzer chooses colocation exactly when the boundary overhead
dominates the per-phase wins — the same force that kept whole CNNs on one
board in the paper when PCIe sync ate the speedup.

``price="measured"`` swaps each *buildable* engine's analytic model for a
profiling-calibrated one when the profile cache holds measurements for it
(``repro.profiling``), degrading per-engine to analytic otherwise.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

from ..core import cost_model
from ..core.cost_model import TransferCost, layer_cost, transfer_cost
from ..core.engines import PLACEMENT_ENGINES, ExecutionEngine
from ..core.layer_model import NetworkSpec
from ..models.transformer import ModelConfig
from .batcher import phase_network_spec

OBJECTIVES = ("latency", "energy", "edp", "perf_density")


@dataclasses.dataclass(frozen=True)
class PhaseCost:
    """One phase priced on one engine's device model."""

    phase: str                           # "prefill" | "decode"
    engine: str
    device: str
    time_s: float
    energy_j: float
    flops: int
    peak_power_w: float


@dataclasses.dataclass(frozen=True)
class PairScore:
    """One (prefill engine, decode engine) candidate, fully priced."""

    prefill: PhaseCost
    decode: PhaseCost
    handoff: TransferCost
    objective: str

    @property
    def colocated(self) -> bool:
        return self.prefill.engine == self.decode.engine

    @property
    def total_time_s(self) -> float:
        return self.prefill.time_s + self.handoff.t_transfer + self.decode.time_s

    @property
    def total_energy_j(self) -> float:
        return (self.prefill.energy_j + self.handoff.energy_j
                + self.decode.energy_j)

    @property
    def total_flops(self) -> int:
        return self.prefill.flops + self.decode.flops

    @property
    def value(self) -> float:
        """Objective value — lower is better, like cost_model.objective_value."""
        if self.objective == "latency":
            return self.total_time_s
        if self.objective == "energy":
            return self.total_energy_j
        if self.objective == "edp":
            return self.total_energy_j * self.total_time_s
        if self.objective == "perf_density":
            # maximize GFLOP/J -> minimize its inverse (joules per GFLOP)
            return self.total_energy_j / (self.total_flops / 1e9)
        raise ValueError(f"unknown placement objective: {self.objective!r}")


@dataclasses.dataclass(frozen=True)
class PlacementDecision:
    """The DSE result: the winning pair plus the ranked alternatives."""

    objective: str
    pricing: str
    best: PairScore
    ranked: Tuple[PairScore, ...]        # all candidates, best first

    @property
    def prefill_engine(self) -> str:
        return self.best.prefill.engine

    @property
    def decode_engine(self) -> str:
        return self.best.decode.engine

    @property
    def colocated(self) -> bool:
        return self.best.colocated

    def summary(self) -> str:
        rows = [f"phase placement ({self.objective}, {self.pricing} pricing)",
                f"{'prefill':<14} {'decode':<14} {'prefill':>11} "
                f"{'handoff':>11} {'decode':>11} {'value':>12}"]
        for p in self.ranked:
            mark = " <- chosen" if p is self.ranked[0] else ""
            rows.append(
                f"{p.prefill.engine:<14} {p.decode.engine:<14} "
                f"{p.prefill.time_s*1e3:>9.3f}ms "
                f"{p.handoff.t_transfer*1e3:>9.3f}ms "
                f"{p.decode.time_s*1e3:>9.3f}ms {p.value:>12.4g}{mark}")
        b = self.best
        rows.append(
            f"chosen: prefill={b.prefill.engine} "
            f"(t={b.prefill.time_s*1e3:.3f}ms, e={b.prefill.energy_j:.4f}J) "
            f"decode={b.decode.engine} "
            f"(t={b.decode.time_s*1e3:.3f}ms, e={b.decode.energy_j:.4f}J) "
            f"handoff={b.handoff.bytes_moved}B/"
            f"{b.handoff.t_transfer*1e3:.3f}ms "
            f"[{'colocated' if b.colocated else 'disaggregated'}]")
        return "\n".join(rows)


# ---------------------------------------------------------------------------
# Phase workloads + hand-off payload
# ---------------------------------------------------------------------------
def prefill_network_spec(cfg: ModelConfig, prompt_len: int) -> NetworkSpec:
    """The prefill phase's workload: the whole prompt in one causal pass."""
    return phase_network_spec(cfg, seq=prompt_len, kv_len=prompt_len)


def handoff_payload_bytes(cfg: ModelConfig, prompt_len: int, *,
                          dtype_bytes: int = 2,
                          slot_len: Optional[int] = None) -> int:
    """Analytic per-request phase-boundary payload: the prompt's KV rows
    for every attention layer, recurrent states for SSM layers, and the
    d_model activation / sampled-token hand-off.

    ``slot_len`` prices what THIS implementation's transport moves: the
    SlotEngine migrates whole physical slot rows (``max_seq`` KV positions
    plus the int32 prompt/output buffers), not just the logical prefix —
    so placement decisions for the disaggregated loop must be priced at
    the padded size or they under-charge the boundary ~(max_seq /
    prompt_len)x near the split/colocate crossover.  ``None`` prices the
    logical payload (what an ideal block-paged transport would move)."""
    total = cfg.d_model * dtype_bytes
    di = cfg.ssm_expand * cfg.d_model
    kv_rows = slot_len or prompt_len
    for btype in cfg.layer_types():
        if btype in ("attn", "xattn"):
            t = min(cfg.attn_window or kv_rows, kv_rows)
            total += 2 * cfg.n_kv_heads * cfg.hd * t * dtype_bytes
        elif btype == "rec":
            total += di * dtype_bytes
        elif btype == "mamba":
            total += (di * cfg.ssm_state + di * cfg.ssm_conv) * dtype_bytes
    if slot_len is not None:
        total += 2 * slot_len * 4        # int32 prompt row + output row
    return total


def phase_cost(cfg: ModelConfig, engine: ExecutionEngine, phase: str, *,
               prompt_len: int, gen_len: int, batch: int = 1,
               dtype_bytes: int = 2, device=None) -> PhaseCost:
    """Price one serving phase on one engine.

    Prefill is the full-sequence pass; decode is ``gen_len`` per-token
    steps, each priced at the worst-case context the phase serves
    (``prompt_len + gen_len``).  ``device`` overrides the engine's own
    model (a profiling-calibrated one, placement's measured pricing).
    """
    device = device or engine.device
    if phase == "prefill":
        net = prefill_network_spec(cfg, prompt_len)
        steps = 1
    elif phase == "decode":
        net = phase_network_spec(cfg, seq=1, kv_len=prompt_len + gen_len)
        steps = max(gen_len - 1, 0)      # the first sample lands in prefill
    else:
        raise ValueError(f"unknown phase: {phase!r}")
    t = e = 0.0
    flops = 0
    peak = 0.0
    eff = engine.efficiency if device.analytic else 1.0
    for spec in net:
        if not engine.supports(spec):
            raise ValueError(
                f"engine {engine.name} does not run {spec.kind} "
                f"(needed by {cfg.name}'s {phase} phase)")
        c = layer_cost(spec, device, batch=batch, dtype_bytes=dtype_bytes,
                       mxu_efficiency=eff)
        t += c.t_total
        e += c.energy_j
        flops += c.flops
        peak = max(peak, c.power_w)
    return PhaseCost(phase=phase, engine=engine.name, device=device.name,
                     time_s=t * steps, energy_j=e * steps,
                     flops=flops * steps, peak_power_w=peak)


def drift_scaled_device(device, ratio: float):
    """De-rate (ratio > 1) or up-rate (ratio < 1) a device model by an
    observed/priced time ratio.

    This is how the watchdog re-enters the placement DSE mid-run: a phase
    whose observed step cost runs ``ratio``x its price behaves like a
    device whose every rate is ``1/ratio`` of nominal, so the DSE re-prices
    the pair against what the hardware is actually delivering."""
    if ratio <= 0.0:
        raise ValueError("drift ratio must be > 0")
    return dataclasses.replace(
        device,
        name=f"{device.name}-drift{ratio:.3g}x",
        peak_flops=device.peak_flops / ratio,
        mem_bw=device.mem_bw / ratio,
        throughput={k: v / ratio for k, v in device.throughput.items()},
        throughput_bwd={k: v / ratio
                        for k, v in device.throughput_bwd.items()})


# ---------------------------------------------------------------------------
# The DSE itself
# ---------------------------------------------------------------------------
def _measured_devices(engines: Sequence[ExecutionEngine],
                      cache_path: Optional[str]) -> Dict[str, object]:
    """Per-engine calibrated device models from the profile cache, for the
    engines it holds current-environment measurements for.  Missing /
    empty caches degrade cleanly to {} (analytic for everyone)."""
    from ..profiling import Measurement, ProfileCache, calibrate_engine
    from ..profiling.cache import DEFAULT_CACHE_PATH
    cache = ProfileCache.load(cache_path or DEFAULT_CACHE_PATH, strict=False)
    out: Dict[str, object] = {}
    for eng in engines:
        if not eng.buildable:
            continue                     # nothing measurable to calibrate
        ms = [Measurement.from_dict(d)
              for d in cache.measurements(engine=eng.name)]
        if ms:
            out[eng.name] = calibrate_engine(eng, ms)
    return out


def _measured_link_bw(cache_path: Optional[str]) -> Optional[float]:
    """The profile cache's measured inter-device copy bandwidth (bytes/s)
    for this environment, or None when none was recorded — the measured
    counterpart of ``transfer_cost``'s datasheet fallback."""
    from ..profiling import ProfileCache, cached_link_bw
    from ..profiling.cache import DEFAULT_CACHE_PATH
    cache = ProfileCache.load(cache_path or DEFAULT_CACHE_PATH, strict=False)
    return cached_link_bw(cache)


def place_phases(
    cfg: ModelConfig,
    engines: Optional[Sequence[ExecutionEngine]] = None,
    *,
    objective: str = "latency",
    prompt_len: int,
    gen_len: int,
    batch: int = 1,
    dtype_bytes: int = 2,
    price: str = "analytic",
    cache_path: Optional[str] = None,
    link_bw: Optional[float] = None,
    device_overrides: Optional[Dict[str, object]] = None,
) -> PlacementDecision:
    """Enumerate (prefill, decode) engine pairs and pick per objective.

    ``engines`` defaults to ``core.engines.PLACEMENT_ENGINES`` (the
    buildable XLA engine plus the paper boards' roofline twins).  Engines
    that cannot run one of the model's layer kinds are skipped for that
    phase.  ``price="measured"`` hooks into ``repro.profiling``: buildable
    engines with cached measurements are priced on calibrated models, and
    the hand-off is priced at the cache's measured inter-device copy rate
    when one was recorded (:mod:`repro.profiling.transfer`) — an explicit
    ``link_bw`` still wins over both.
    ``device_overrides`` maps engine name -> device model and wins over
    the measured calibration — the watchdog re-runs the DSE mid-run with
    the drifted engine's device de-rated (:func:`drift_scaled_device`).
    """
    if objective not in OBJECTIVES:
        raise ValueError(f"unknown placement objective: {objective!r} "
                         f"(pick from {OBJECTIVES})")
    if price not in ("analytic", "measured"):
        raise ValueError(f"unknown pricing source: {price!r}")
    engines = tuple(engines if engines is not None else PLACEMENT_ENGINES)
    overrides = dict(_measured_devices(engines, cache_path)
                     if price == "measured" else {})
    if device_overrides:
        overrides.update(device_overrides)
    if link_bw is None and price == "measured":
        link_bw = _measured_link_bw(cache_path)

    needed_kinds = {spec.kind
                    for spec in phase_network_spec(cfg, seq=1, kv_len=2)}
    per_phase: Dict[str, Dict[str, PhaseCost]] = {"prefill": {}, "decode": {}}
    for eng in engines:
        if not needed_kinds.issubset(eng.kinds):
            continue                     # engine lacks a needed layer kind
        for phase in ("prefill", "decode"):
            per_phase[phase][eng.name] = phase_cost(
                cfg, eng, phase, prompt_len=prompt_len, gen_len=gen_len,
                batch=batch, dtype_bytes=dtype_bytes,
                device=overrides.get(eng.name))
    if not per_phase["prefill"] or not per_phase["decode"]:
        raise ValueError(f"no candidate engine runs {cfg.name}'s layer kinds")

    by_name = {e.name: e for e in engines}
    # priced at the slot-row size the disaggregated loop actually migrates
    payload = handoff_payload_bytes(
        cfg, prompt_len, dtype_bytes=dtype_bytes,
        slot_len=prompt_len + gen_len) * batch
    scores = []
    for p_name, pc in per_phase["prefill"].items():
        for d_name, dc in per_phase["decode"].items():
            src = overrides.get(p_name) or by_name[p_name].device
            dst = overrides.get(d_name) or by_name[d_name].device
            hand = transfer_cost(0 if p_name == d_name else payload,
                                 src, dst, link_bw=link_bw)
            scores.append(PairScore(prefill=pc, decode=dc, handoff=hand,
                                    objective=objective))
    # deterministic tie-break: objective value, colocation first, names
    scores.sort(key=lambda s: (s.value, not s.colocated,
                               s.prefill.engine, s.decode.engine))
    return PlacementDecision(objective=objective, pricing=price,
                             best=scores[0], ranked=tuple(scores))


# ---------------------------------------------------------------------------
# Speculative decoding: whether, with which draft, and how deep
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SpeculationDecision:
    """Outcome of pricing draft-model speculation against plain decode.

    Per-committed-token wall times at the given decode shape; ``use`` is
    True when the best (draft, k) candidate prices below plain decode.
    """
    use: bool
    draft: str
    k: int                       # best candidate depth (even when not used)
    acceptance: float            # the alpha the decision priced on
    plain_step_s: float          # plain decode, per token
    spec_step_s: float           # best speculative candidate, per token
    table: Tuple[Tuple[int, float], ...]   # (k, per-token s) per candidate

    @property
    def projected_speedup(self) -> float:
        return (self.plain_step_s / self.spec_step_s
                if self.spec_step_s > 0 else float("inf"))

    def summary(self) -> Dict:
        """JSON-safe decision record (bench / trace / ServeReport)."""
        return {"use": self.use, "draft": self.draft, "k": self.k,
                "acceptance": self.acceptance,
                "plain_step_s": self.plain_step_s,
                "spec_step_s": self.spec_step_s,
                "projected_speedup": self.projected_speedup,
                "table": [[k, t] for k, t in self.table]}


def choose_speculation(target_cfg: ModelConfig, draft_cfg: ModelConfig, *,
                       kv_len: int, n_tokens: int, acceptance: float,
                       device_name: str = "tpu-v5e",
                       target_device=None, draft_device=None,
                       k_candidates: Sequence[int] = (1, 2, 3, 4),
                       draft_name: str = "draft") -> SpeculationDecision:
    """Price speculative decoding against plain decode and pick the depth.

    The paper's trade-off analysis applied to the decode hot path: one
    plain step commits ``n_tokens`` tokens (one per slot) in
    ``t_plain``; one speculative round spends k+1 draft steps plus a
    single (k+1)-position verify step on the target and commits
    ``E[c] * n_tokens`` tokens.  The verify step is priced as a target
    step carrying ``n_tokens * (k+1)`` tokens — batch scaling amortizes
    the weight reads exactly the way the multi-position step does.
    ``acceptance`` comes from the profiling cache
    (:func:`repro.profiling.cached_acceptance`), a prior, or the
    watchdog's online EWMA; ``target_device``/``draft_device`` override
    the registry lookup (calibrated or drift-scaled models).
    """
    from .batcher import step_time_model
    t_plain = step_time_model(target_cfg, kv_len, n_tokens,
                              device_name, device=target_device)
    t_draft = step_time_model(draft_cfg, kv_len, n_tokens,
                              device_name, device=draft_device)
    table = []
    for k in k_candidates:
        t_verify = step_time_model(target_cfg, kv_len,
                                   n_tokens * (k + 1),
                                   device_name, device=target_device)
        e = cost_model.expected_tokens_per_round(acceptance, k)
        per_tok = ((k + 1) * t_draft + t_verify) / (e * n_tokens)
        table.append((int(k), per_tok))
    best_k, best_t = min(table, key=lambda kt: kt[1])
    return SpeculationDecision(
        use=best_t < t_plain / n_tokens, draft=draft_name, k=best_k,
        acceptance=float(acceptance), plain_step_s=t_plain / n_tokens,
        spec_step_s=best_t, table=tuple(table))
