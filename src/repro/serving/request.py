"""Request lifecycle + synthetic open-loop arrival process.

A request moves QUEUED -> PREFILL -> DECODE -> DONE (or DROPPED if its
deadline passes while still queued).  Prefill here is *decode-replay*: the
engine feeds prompt tokens through the same slot-decode step the static
server uses, one token per engine iteration, so per-request greedy outputs
are bit-identical between the two paths (tests/test_serving.py asserts it).

The arrival process is the standard open-loop serving model: exponential
interarrival times at an offered load of ``rate`` requests/second, with
prompt/generation lengths drawn from small discrete mixes — the mixed-length
traffic that makes static batching pay head-of-line blocking.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional, Sequence

import numpy as np


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"
    DROPPED = "dropped"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (P,) int32 token ids
    max_new_tokens: int
    arrival: float = 0.0                # seconds since stream start
    priority: int = 0                   # lower = more urgent
    deadline: Optional[float] = None    # absolute; queued past it -> DROPPED

    state: RequestState = RequestState.QUEUED
    slot: Optional[int] = None
    # prompt tokens served from shared KV pages (prefix sharing): prefill
    # for these is skipped by binding at an offset, and n_fed counts them
    # as fed so samples_ready stays engine-independent
    shared_tokens: int = 0
    n_fed: int = 0                      # engine steps fed so far (all phases)
    n_streamed: int = 0                 # samples already delivered as deltas
    output: List[int] = dataclasses.field(default_factory=list)
    t_admitted: Optional[float] = None
    # host-visible first token (burst-boundary sync under streaming, the
    # completion pull otherwise) — what TTFT honestly measures
    t_first_token: Optional[float] = None
    # dispatch-time stamp: the burst containing the first sample has been
    # enqueued on the device (the pre-streaming TTFT; kept so the bench can
    # quantify the dispatch-vs-delivery gap)
    t_first_dispatch: Optional[float] = None
    t_done: Optional[float] = None
    # modeled per-step cost at admission time (what the batcher's token
    # budget priced this request against); the tracer pairs it with the
    # observed per-step time in the decode span
    priced_step_s: Optional[float] = None

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def total_tokens(self) -> int:
        """Full KV footprint the request will ever need (reservation unit)."""
        return self.prompt_len + self.max_new_tokens

    @property
    def samples_ready(self) -> int:
        """Samples present in the slot's output row after ``n_fed`` engine
        steps: the step fed at position p writes sample p - prompt_len + 1
        (valid once the final prompt token has been fed), so ``n_fed`` steps
        leave ``n_fed - prompt_len + 1`` samples, clamped to the request's
        generation length.  Engine-independent: ``n_fed`` counts steps
        across phases, so the formula holds colocated and disaggregated."""
        return min(max(self.n_fed - self.prompt_len + 1, 0),
                   self.max_new_tokens)

    # ---- metrics ---------------------------------------------------------
    @property
    def ttft(self) -> Optional[float]:
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.arrival

    @property
    def ttft_dispatch(self) -> Optional[float]:
        """Dispatch-stamped TTFT (the old metric); <= ttft always."""
        if self.t_first_dispatch is None:
            return None
        return self.t_first_dispatch - self.arrival

    @property
    def tpot(self) -> Optional[float]:
        """Time per output token after the first (decode-phase latency)."""
        if self.t_done is None or self.t_first_token is None:
            return None
        n = max(len(self.output) - 1, 1)
        return (self.t_done - self.t_first_token) / n


def synthetic_workload(
    n_requests: int,
    *,
    rate: float,
    vocab: int,
    prompt_lens: Sequence[int] = (8, 16),
    gen_lens: Sequence[int] = (4, 8, 16, 48),
    seed: int = 0,
    deadline_s: Optional[float] = None,
) -> List[Request]:
    """Open-loop Poisson arrivals with mixed prompt/generation lengths."""
    rng = np.random.default_rng(seed)
    t = 0.0
    reqs = []
    for rid in range(n_requests):
        t += float(rng.exponential(1.0 / rate)) if rate > 0 else 0.0
        plen = int(rng.choice(prompt_lens))
        glen = int(rng.choice(gen_lens))
        prompt = rng.integers(0, vocab, size=(plen,), dtype=np.int32)
        reqs.append(Request(
            rid=rid, prompt=prompt, max_new_tokens=glen, arrival=t,
            deadline=None if deadline_s is None else t + deadline_s))
    return reqs


def prefix_shared_workload(
    n_requests: int,
    *,
    rate: float,
    vocab: int,
    shared_prefix_len: int,
    shared_frac: float = 0.9,
    suffix_lens: Sequence[int] = (8, 16),
    gen_lens: Sequence[int] = (4, 8, 16),
    seed: int = 0,
    deadline_s: Optional[float] = None,
) -> List[Request]:
    """Open-loop arrivals where ``shared_frac`` of requests front-load one
    common ``shared_prefix_len``-token prompt prefix (the chat/agent
    system-prompt pattern prefix sharing exploits); the rest are fully
    unique.  Every suffix is unique, so sharers still diverge after the
    prefix."""
    rng = np.random.default_rng(seed)
    common = rng.integers(0, vocab, size=(shared_prefix_len,),
                          dtype=np.int32)
    t = 0.0
    reqs = []
    for rid in range(n_requests):
        t += float(rng.exponential(1.0 / rate)) if rate > 0 else 0.0
        slen = int(rng.choice(suffix_lens))
        glen = int(rng.choice(gen_lens))
        suffix = rng.integers(0, vocab, size=(slen,), dtype=np.int32)
        if rng.random() < shared_frac:
            prompt = np.concatenate([common, suffix])
        else:
            unique = rng.integers(0, vocab, size=(shared_prefix_len,),
                                  dtype=np.int32)
            prompt = np.concatenate([unique, suffix])
        reqs.append(Request(
            rid=rid, prompt=prompt, max_new_tokens=glen, arrival=t,
            deadline=None if deadline_s is None else t + deadline_s))
    return reqs
