"""Token-budgeted continuous batcher with engine-aware admission.

Every engine iteration processes one token per active slot, so the step's
token count == the number of active slots.  The batcher decides how many
slots may be active by pricing a decode step with ``core/cost_model.py`` on
the target device model — the same trade-off machinery the layer scheduler
uses to pick engines (CNNLab §III.A), applied to traffic instead of layers:
admission stops at the largest batch whose modeled step time still meets the
per-step latency objective (decode SLO), and at the KV pool's free blocks.

Eviction is deadline shedding: queued requests whose deadline has passed are
DROPPED rather than admitted (they would miss their SLO anyway and only
steal pool blocks from live traffic).

When the pool runs prefix sharing, admission prices a request's *fresh*
footprint: blocks the prefix index already serves are not drawn from the
free list, and the skipped prefill tokens shorten the request's engine
residency — so a shared prefix makes admission cheaper and more slots fit
the same KV budget.

Invariant: admission and re-pricing are pure *scheduling* policy.  Greedy
per-request outputs depend only on the prompt and the model, never on the
admission order, the token budget, or a mid-run re-price — every bench
section gates this bit-identity.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from ..core import device_models
from ..core.cost_model import layer_cost
from ..core.layer_model import (AttentionSpec, MLPSpec, MoESpec, NetworkSpec,
                                SSMSpec)
from ..models.transformer import ModelConfig
from .kv_pool import KVPool
from .request import Request, RequestState


def phase_network_spec(cfg: ModelConfig, *, seq: int,
                       kv_len: int) -> NetworkSpec:
    """Declarative layer-tuple spec for one serving-phase call of `cfg`:
    ``seq`` tokens attending over ``kv_len`` cached positions.  ``seq=1``
    is a decode step; ``seq=prompt_len, kv_len=prompt_len`` is prefill —
    the two workloads phase placement prices against each other."""
    layers = []
    for i, btype in enumerate(cfg.layer_types()):
        if btype in ("attn", "xattn"):
            layers.append(AttentionSpec(
                f"L{i}.attn", d_model=cfg.d_model, n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads, seq=seq, kv_len=kv_len,
                causal=True, window=cfg.attn_window, qkv_bias=cfg.qkv_bias,
                cross=btype == "xattn"))
        elif btype == "rec":
            layers.append(SSMSpec(f"L{i}.rglru", d_model=cfg.d_model,
                                  d_state=cfg.ssm_state, d_conv=cfg.ssm_conv,
                                  expand=cfg.ssm_expand, seq=seq,
                                  variant="rglru"))
        elif btype == "mamba":
            layers.append(SSMSpec(f"L{i}.mamba", d_model=cfg.d_model,
                                  d_state=cfg.ssm_state, d_conv=cfg.ssm_conv,
                                  expand=cfg.ssm_expand, seq=seq,
                                  variant="mamba1"))
        if btype != "mamba":            # mamba blocks have no separate MLP
            if cfg.n_experts > 0:
                layers.append(MoESpec(f"L{i}.moe", d_model=cfg.d_model,
                                      d_ff=cfg.d_ff, seq=seq,
                                      n_experts=cfg.n_experts,
                                      top_k=cfg.moe_top_k,
                                      gated=cfg.gated_mlp))
            else:
                layers.append(MLPSpec(f"L{i}.mlp", d_model=cfg.d_model,
                                      d_ff=cfg.d_ff, seq=seq,
                                      gated=cfg.gated_mlp))
    tag = "decode-step" if seq == 1 else f"prefill{seq}"
    return NetworkSpec(f"{cfg.name}-{tag}", tuple(layers))


def decode_network_spec(cfg: ModelConfig, kv_len: int) -> NetworkSpec:
    """Per-token decode-step spec — what admission prices (one engine
    iteration carries one token per active slot)."""
    return phase_network_spec(cfg, seq=1, kv_len=kv_len)


def step_time_model(cfg: ModelConfig, kv_len: int, n_tokens: int,
                    device_name: str = "tpu-v5e",
                    dtype_bytes: int = 2,
                    device: Optional[device_models.DeviceModel] = None
                    ) -> float:
    """Modeled wall time of one engine step carrying `n_tokens` tokens.

    ``device`` overrides the registry lookup — this is how admission prices
    on a profiling-calibrated model (``repro.profiling.calibrate``) instead
    of the nominal constants."""
    if device is None:
        device = device_models.get(device_name)
    net = decode_network_spec(cfg, kv_len)
    return sum(layer_cost(l, device, batch=n_tokens,
                          dtype_bytes=dtype_bytes).t_total for l in net)


def token_budget_for_slo(cfg: ModelConfig, kv_len: int, n_slots: int,
                         step_slo_s: float,
                         device_name: str = "tpu-v5e",
                         device: Optional[device_models.DeviceModel] = None
                         ) -> int:
    """Largest per-step token count whose modeled step time meets the SLO
    (always >= 1: a budget that admits nothing serves nothing)."""
    budget = 1
    for k in range(2, n_slots + 1):
        if step_time_model(cfg, kv_len, k, device_name,
                           device=device) > step_slo_s:
            break
        budget = k
    return budget


@dataclasses.dataclass
class AdmissionDecision:
    admitted: List[Request]
    dropped: List[Request]


class ContinuousBatcher:
    """Admits QUEUED requests into pool slots against the token budget.

    One batcher governs one (phase, engine) pair: its token budget is
    priced on *its* device model, so a disaggregated deployment runs two —
    a prefill batcher budgeted on the prefill engine and a decode batcher
    budgeted on the decode engine (``phase`` labels which this is)."""

    def __init__(self, cfg: ModelConfig, pool: KVPool, *,
                 device_name: str = "tpu-v5e",
                 device_model: Optional[device_models.DeviceModel] = None,
                 step_slo_s: Optional[float] = None,
                 token_budget: Optional[int] = None,
                 phase: str = "decode"):
        self.cfg = cfg
        self.pool = pool
        self.phase = phase
        self.device_name = (device_model.name if device_model is not None
                            else device_name)
        self.device_model = device_model
        # kept so a mid-run re-price can refit the token budget against the
        # same objective admission was originally sized for
        self.step_slo_s = step_slo_s
        # installed by reprice(): a fitted latency(batch) curve (or
        # ratio-scaled analytic model) that replaces the analytic pricing
        self._price_override = None
        self._price_source = "analytic"
        self.n_reprices = 0
        if token_budget is None:
            if step_slo_s is None:
                token_budget = pool.n_slots
            else:
                token_budget = token_budget_for_slo(
                    cfg, pool.max_seq, pool.n_slots, step_slo_s, device_name,
                    device=device_model)
        if token_budget <= 0:
            raise ValueError("token_budget must be >= 1 (a budget that "
                             "admits nothing serves nothing)")
        self.token_budget = min(token_budget, pool.n_slots)
        # cumulative admission accounting (surfaced by launch/serve.py)
        self.n_admitted = 0
        self.n_rejected = 0              # dropped: deadline passed / never fits
        # rids currently deferred and not yet admitted/dropped.  Bounded by
        # the live queue length: a rid is discarded the moment its request
        # resolves, so a long-lived stream doesn't leak a set entry per
        # request.  The ever-deferred total lives in the monotone counter.
        self._deferred_rids: set = set()
        self._n_deferred_total = 0

    @property
    def price_source(self) -> str:
        """Where the current pricing came from: ``analytic`` until a
        watchdog re-price installs ``fitted-curve`` or ``scaled-analytic``
        telemetry pricing."""
        return self._price_source

    @property
    def n_deferred(self) -> int:
        """Distinct requests ever left queued by an admit pass (budget or
        pool pressure) — comparable to the admitted/rejected counts.
        Monotone counter; re-deferrals of a still-queued request count
        once."""
        return self._n_deferred_total

    def note_resolved(self, rid: int) -> None:
        """Forget a deferred rid whose request left the queue outside an
        admit pass (e.g. the disaggregated loop's pre-admission shedding),
        keeping the deferred set bounded by the live queue."""
        self._deferred_rids.discard(rid)

    def priced_step_s(self, n_tokens: int) -> float:
        """This batcher's modeled per-step wall time at ``n_tokens`` tokens
        per step — the cost its token budget prices admission against, on
        its own device model.  The tracer stamps it into admission spans so
        traces carry priced-vs-observed cost side by side.  After a
        watchdog re-price this is the installed telemetry curve instead of
        the analytic model."""
        if self._price_override is not None:
            return self._price_override(max(int(n_tokens), 1))
        return self.analytic_step_s(n_tokens)

    def analytic_step_s(self, n_tokens: int) -> float:
        """The pure analytic price, ignoring any installed override — the
        shape a re-price scales when telemetry has only fixed one point."""
        return step_time_model(self.cfg, self.pool.max_seq,
                               max(int(n_tokens), 1), self.device_name,
                               device=self.device_model)

    def reprice(self, step_time_fn, *, source: str = "telemetry") -> dict:
        """Install ``step_time_fn`` (tokens -> seconds) as this batcher's
        pricing and refit the token budget against the stored step SLO.

        This is the watchdog's action leg: observed step costs replace the
        analytic model, so subsequent admission (and the ``priced_step_s``
        stamped into traces) reflects what the hardware actually does.
        Returns a JSON-safe event describing the change.
        """
        old_budget = self.token_budget
        self._price_override = step_time_fn
        self._price_source = source
        if self.step_slo_s is not None:
            budget = 1
            for k in range(2, self.pool.n_slots + 1):
                if step_time_fn(k) > self.step_slo_s:
                    break
                budget = k
            self.token_budget = min(budget, self.pool.n_slots)
        self.n_reprices += 1
        return {"pricing": source,
                "token_budget_old": int(old_budget),
                "token_budget": int(self.token_budget),
                "step_slo_s": self.step_slo_s,
                "priced_step_s_at_budget":
                    float(self.priced_step_s(self.token_budget))}

    def admit(self, queue: List[Request], n_active: int,
              now: float) -> AdmissionDecision:
        """Pop admissible requests from `queue` (mutated in place).

        Priority order: (priority, arrival).  A request that does not fit
        the pool right now blocks lower-priority requests behind it only if
        they would also not fit (no starvation of big requests, but small
        ones may backfill free blocks).
        """
        admitted: List[Request] = []
        dropped: List[Request] = []
        queue.sort(key=lambda r: (r.priority, r.arrival, r.rid))
        i = 0
        while i < len(queue):
            req = queue[i]
            never_fits = (req.total_tokens > self.pool.max_seq
                          or self.pool.blocks_needed(req.total_tokens)
                          > self.pool.total_blocks)
            if never_fits or (req.deadline is not None and now > req.deadline):
                req.state = RequestState.DROPPED
                dropped.append(queue.pop(i))
                continue
            if n_active + len(admitted) >= self.token_budget:
                break
            # prefix sharing makes admission cheaper: only the blocks the
            # prefix index cannot serve are drawn from the free list, so a
            # mostly-shared request fits where a dense one would defer
            prompt = req.prompt if self.pool.prefix_sharing else None
            if not self.pool.can_admit(req.total_tokens, prompt):
                i += 1                   # try to backfill a smaller request
                continue
            req.slot = self.pool.alloc(req.rid, req.total_tokens,
                                       prompt=prompt)
            req.state = RequestState.PREFILL
            req.t_admitted = now
            admitted.append(queue.pop(i))
        self.n_admitted += len(admitted)
        self.n_rejected += len(dropped)
        for r in admitted:
            self._deferred_rids.discard(r.rid)
        for r in dropped:
            self._deferred_rids.discard(r.rid)
        for r in queue:
            if r.rid not in self._deferred_rids:
                self._deferred_rids.add(r.rid)
                self._n_deferred_total += 1
        return AdmissionDecision(admitted=admitted, dropped=dropped)
