"""Continuous-batching serving runtime (the CNNLab middleware idea applied
to traffic): request lifecycle + arrivals, slot-based paged KV pool,
cost-model-priced admission, the jitted engine loop with serving metrics
(TTFT / TPOT / tok-s / p50 / p99), the unified open-loop driver with the
streaming output channel (`driver` — both loops instantiate it; streamed
deltas are bit-identical to completion pulls), and phase-disaggregated
serving — prefill and decode placed on separate engines by the trade-off
analyzer (`placement`), with an explicitly-priced KV hand-off
(`disagg`), draft-model speculative decoding priced by the same analyzer
(`speculative`), and the typed programmatic entry point
(`api.serve(ServeOptions) -> ServeReport`) the CLI, benchmarks, and
tests all drive."""
from .api import ServeOptions, ServeReport, serve
from .batcher import (ContinuousBatcher, decode_network_spec,
                      phase_network_spec, step_time_model,
                      token_budget_for_slo)
from .disagg import DisaggregatedEngineLoop, HandoffLedger
from .driver import (OpenLoopDriver, ServeMetrics, StreamDelta, TokenSink,
                     sample_pools)
from .engine_loop import EngineLoop, SlotEngine
from .kv_pool import KVPool
from .placement import (PhaseCost, PlacementDecision, SpeculationDecision,
                        choose_speculation, handoff_payload_bytes,
                        phase_cost, place_phases, prefill_network_spec)
from .request import (Request, RequestState, prefix_shared_workload,
                      synthetic_workload)
from .speculative import (SpecPlan, SpeculativeDecoder,
                          SpeculativeEngineLoop, validate_speculation)

__all__ = [
    "ContinuousBatcher", "DisaggregatedEngineLoop", "EngineLoop",
    "HandoffLedger", "KVPool", "OpenLoopDriver", "PhaseCost",
    "PlacementDecision", "Request", "RequestState", "ServeMetrics",
    "ServeOptions", "ServeReport", "SlotEngine", "SpecPlan",
    "SpeculationDecision", "SpeculativeDecoder", "SpeculativeEngineLoop",
    "StreamDelta", "TokenSink", "choose_speculation",
    "decode_network_spec", "handoff_payload_bytes", "phase_cost",
    "phase_network_spec", "place_phases", "prefill_network_spec",
    "prefix_shared_workload", "sample_pools", "serve", "step_time_model",
    "synthetic_workload", "token_budget_for_slo", "validate_speculation",
]
