"""Continuous-batching serving runtime (the CNNLab middleware idea applied
to traffic): request lifecycle + arrivals, slot-based paged KV pool,
cost-model-priced admission, and the jitted engine loop with serving
metrics (TTFT / TPOT / tok-s / p50 / p99)."""
from .batcher import (ContinuousBatcher, decode_network_spec,
                      step_time_model, token_budget_for_slo)
from .engine_loop import EngineLoop, ServeMetrics
from .kv_pool import KVPool
from .request import Request, RequestState, synthetic_workload

__all__ = [
    "ContinuousBatcher", "EngineLoop", "KVPool", "Request", "RequestState",
    "ServeMetrics", "decode_network_spec", "step_time_model",
    "synthetic_workload", "token_budget_for_slo",
]
