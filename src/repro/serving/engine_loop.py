"""Continuous-batching engine loop + serving metrics.

One jitted step (`decode_step_slots`) advances every active slot by one
token per iteration — prefilling slots consume their next prompt token,
decoding slots consume their previously sampled token — so prefill work
interleaves with the running decode batch instead of stalling it, and a
finished request's slot is refilled at the next completion boundary (no
inter-batch idle, no head-of-line blocking on the longest generation).

Token feeding is device-resident: the fused step selects each slot's next
token from an uploaded prompt buffer (while ``pos < prompt_len``) or from
the previous argmax, and scatters sampled tokens into a per-slot output
buffer.  The host never syncs per step — request completion is
deterministic in step count (greedy decoding, known lengths), so the loop
dispatches a *burst* of steps up to the next completion boundary and only
then pulls the finished slots' output rows.  This keeps per-step overhead
at dispatch cost, matching the static server's async decode chain.

The slot state + burst machinery lives in :class:`SlotEngine` so one
deployment can run several engines: :class:`EngineLoop` composes a single
SlotEngine (colocated serving), while
:class:`~repro.serving.disagg.DisaggregatedEngineLoop` composes two — a
prefill engine and a decode engine — and migrates slots between them
(`export_slot`/`import_slot`) at the phase boundary.

The open-loop scaffolding (arrival drain, idle fast-forward skew clock,
pending-aware burst capping, completion scan, metrics, streaming channel)
lives in :mod:`repro.serving.driver`; this module provides the loop hooks
the driver calls.  The loop is driven by a clock function so tests can run
it reproducibly; the CLI and benchmark use wall time, which is what the
open-loop arrival process (request.synthetic_workload) is offered against.
"""
from __future__ import annotations

import functools
import math
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer as T
from ..obs import Observability, default_clock
from .batcher import ContinuousBatcher
from .driver import (OpenLoopDriver, ServeMetrics, StreamDelta, TokenSink,
                     burst_size, sample_pools)
from .kv_pool import KVPool
from .request import Request, RequestState

__all__ = ["EngineLoop", "ServeMetrics", "SlotEngine", "StreamDelta",
           "TokenSink"]


# ---- shared trace instrumentation (colocated + disaggregated loops) -------

def wire_pool_events(pool: KVPool, tracer) -> None:
    """Surface the pool's block-lease events as per-request trace instants
    (``kv_alloc``/``kv_free`` on the request's own tid)."""
    if not tracer.enabled:
        return

    def on_event(kind, rid, n_blocks):
        tracer.instant("kv_" + kind, track="requests", tid=rid, cat="kv",
                       args={"blocks": n_blocks})

    pool.on_event = on_event


def trace_admission(obs, batcher, decision, n_active: int) -> None:
    """Close each admitted request's ``queued`` span (arrival -> admission)
    with the priced cost the batcher admitted it against, and mark drops."""
    tracer = obs.tracer
    if not tracer.enabled:
        return
    if decision.admitted:
        priced = batcher.priced_step_s(n_active)
        for req in decision.admitted:
            req.priced_step_s = priced
            tracer.span("queued", req.arrival, req.t_admitted,
                        track="requests", tid=req.rid, cat="request",
                        args={"priced_step_s": priced,
                              "token_budget": batcher.token_budget,
                              "phase": batcher.phase,
                              "blocks": batcher.pool.blocks_needed(
                                  req.total_tokens),
                              "shared_tokens": req.shared_tokens})
    for req in decision.dropped:
        tracer.instant("dropped", track="requests", tid=req.rid,
                       cat="request",
                       args={"reason": "deadline-or-never-fits"})


def trace_phase_flip(tracer, req, now: float) -> None:
    """Prefill span: admission -> the first decode burst's dispatch."""
    if tracer.enabled:
        tracer.span("prefill", req.t_admitted, now, track="requests",
                    tid=req.rid, cat="request",
                    args={"prompt_len": req.prompt_len})


def trace_completion(tracer, req) -> None:
    """Decode span (dispatch -> done, priced vs observed per-step cost) +
    the ``done`` instant."""
    if not tracer.enabled:
        return
    if req.t_first_dispatch is not None and req.t_done is not None:
        dur = req.t_done - req.t_first_dispatch
        steps = max(req.max_new_tokens - 1, 1)
        tracer.span("decode", req.t_first_dispatch, req.t_done,
                    track="requests", tid=req.rid, cat="request",
                    args={"priced_step_s": req.priced_step_s,
                          "observed_step_s": dur / steps,
                          "tokens": len(req.output)})
    tracer.instant("done", track="requests", tid=req.rid, cat="request",
                   t=req.t_done,
                   args={"latency_s": (None if req.t_done is None
                                       else req.t_done - req.arrival)})


def _fused_step(step_fn, params, cfg, cache, prompts, plens, last_tok,
                out_buf, active):
    """Device-side feed + step + sample + output scatter.

    prompts: (B, P_max) int32; plens/last_tok: (B,) int32; out_buf:
    (B, G_max) int32; active: (B,) bool.  cache["pos"] counts tokens fed
    per slot, so pos < plen selects the prompt, else the last sample.
    ``step_fn`` is the layout's slot step (`decode_step_slots` dense,
    `decode_step_slots_paged` paged) — same contract, bit-identical
    outputs."""
    b = prompts.shape[0]
    pos = cache["pos"]
    prompt_tok = prompts[jnp.arange(b), jnp.minimum(pos, prompts.shape[1] - 1)]
    tok = jnp.where(pos < plens, prompt_tok, last_tok)
    logits, cache = step_fn(params, cfg, cache, tok[:, None], active)
    nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    # the sample is output index (pos - plen + 1); valid once the final
    # prompt token has been fed (same schedule as the static replay path)
    idx = pos - plens + 1
    write = active & (idx >= 0) & (idx < out_buf.shape[1])
    safe_idx = jnp.clip(idx, 0, out_buf.shape[1] - 1)
    row = out_buf[jnp.arange(b), safe_idx]
    out_buf = out_buf.at[jnp.arange(b), safe_idx].set(
        jnp.where(write, nxt, row))
    last_tok = jnp.where(active, nxt, last_tok)
    return cache, last_tok, out_buf


class SlotEngine:
    """Device-resident slot state + jitted burst machinery for one engine.

    Owns the slot cache, the prompt/output buffers, the per-slot step
    schedule and the compiled burst buckets.  The per-slot math is exactly
    `decode_step`'s, so outputs are bit-identical whether a request lives
    its whole life in one SlotEngine (colocated) or is exported from a
    prefill engine and imported into a decode engine mid-flight.

    Invariants: under the paged layout the pool's lease order IS the block
    table — :meth:`bind` uploads ``KVPool.block_table`` verbatim, so
    logical block ``j`` of a slot always resolves through lease entry
    ``j`` (prefix sharing changes *which* physical pages a lease maps, not
    this contract).  The engine writes KV only at each slot's current
    position, so pages behind ``pos`` are immutable — what makes published
    prefix pages safe to share — and pending COW copies are materialized
    in :meth:`bind` before the slot's first write.  The engine never reads
    the host clock: burst timing is the caller's concern (injected
    clocks), and :meth:`sync` is a pure wait that cannot change outputs.

    ``device`` pins the engine to one physical device: params, the KV
    arenas and every per-slot buffer are committed there, so jitted
    bursts run on that device and two SlotEngines on distinct devices
    execute concurrently (the disaggregated loop's throughput win).
    ``device=None`` keeps the legacy behaviour — everything on jax's
    default device, nothing committed.
    """

    # largest scanned burst compiled; bounds compile count (power-of-two
    # buckets 1..MAX_BUCKET)
    MAX_BUCKET = 32

    def __init__(self, cfg: T.ModelConfig, params, pool: KVPool, *,
                 kv_layout: str = "dense", name: str = "engine",
                 device=None):
        if kv_layout not in ("dense", "paged"):
            raise ValueError(f"unknown kv_layout {kv_layout!r}")
        self.cfg = cfg
        self.device = device
        self.params = (params if device is None
                       else jax.device_put(params, device))
        self.pool = pool
        self.kv_layout = kv_layout
        self.name = name                 # labels this engine's trace track
        n_slots = pool.n_slots
        if kv_layout == "paged":
            self.cache = T.init_slot_cache_paged(
                cfg, n_slots, pool.max_seq, block_size=pool.block_size,
                total_blocks=pool.total_blocks)
            self._step_fn = functools.partial(T.decode_step_slots_paged,
                                              max_seq=pool.max_seq)
        else:
            self.cache = T.init_slot_cache(cfg, n_slots, pool.max_seq)
            self._step_fn = T.decode_step_slots
        if device is not None:
            self.cache = jax.device_put(self.cache, device)
        self.max_prompt = pool.max_seq
        self.max_gen = pool.max_seq
        self._prompts = jnp.zeros((n_slots, self.max_prompt), jnp.int32)
        self._plens = jnp.zeros((n_slots,), jnp.int32)
        self._last_tok = jnp.zeros((n_slots,), jnp.int32)
        self._out_buf = jnp.zeros((n_slots, self.max_gen), jnp.int32)
        if device is not None:
            (self._prompts, self._plens, self._last_tok, self._out_buf) = \
                jax.device_put((self._prompts, self._plens, self._last_tok,
                                self._out_buf), device)
        self._burst_fns: Dict[int, Callable] = {}
        self.slots: List[Optional[Request]] = [None] * n_slots
        # host-side schedule state: active steps done / total per slot, plus
        # the dispatch mask (bind sets, release clears)
        self.steps_done = np.zeros((n_slots,), np.int64)
        self.steps_total = np.zeros((n_slots,), np.int64)
        self.active = np.zeros((n_slots,), bool)

    def _burst_fn(self, k: int) -> Callable:
        """Jitted scan of k fused steps — one dispatch per bucket instead of
        per token, so burst cost is dominated by device compute."""
        fn = self._burst_fns.get(k)
        if fn is None:
            cfg = self.cfg
            step_fn = self._step_fn

            def burst(p, c, pr, pl, lt, ob, a):
                def body(carry, _):
                    c, lt, ob = carry
                    return (_fused_step(step_fn, p, cfg, c, pr, pl, lt, ob,
                                        a), None)
                (c, lt, ob), _ = jax.lax.scan(body, (c, lt, ob), None,
                                              length=k)
                return c, lt, ob

            fn = jax.jit(burst, donate_argnums=(1, 4, 5))
            self._burst_fns[k] = fn
        return fn

    def warmup(self) -> None:
        """Compile every burst bucket.  An all-inactive step leaves
        positions, live KV state and buffers bit-identical (the paged
        layout's trash page is the only thing written, and it is never
        read), so this is state-neutral."""
        idle = jnp.zeros((self.pool.n_slots,), bool)
        b = 1
        while b <= self.MAX_BUCKET:
            (self.cache, self._last_tok, self._out_buf) = self._burst_fn(b)(
                self.params, self.cache, self._prompts, self._plens,
                self._last_tok, self._out_buf, idle)
            b *= 2

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self.slots)

    def active_requests(self):
        return (r for r in self.slots if r is not None)

    def copy_page(self, src: int, dst: int) -> None:
        """Copy-on-write: duplicate one physical KV page (every attention
        layer's K and V arena) so a writer diverging inside a shared tail
        page gets a private copy before its first write."""
        if self.kv_layout != "paged":
            raise ValueError("copy_page needs the paged KV layout")

        def one(c, stacked):
            if isinstance(c, dict) and "k" in c:
                if stacked:
                    return jax.tree.map(
                        lambda a: a.at[:, dst].set(a[:, src]), c)
                return jax.tree.map(lambda a: a.at[dst].set(a[src]), c)
            return c

        blocks, rem = self.cache["layers"]
        cache = dict(self.cache)
        cache["layers"] = (tuple(one(c, True) for c in blocks),
                           tuple(one(c, False) for c in rem))
        self.cache = cache

    def bind(self, req: Request, *, steps_total: int,
             start_pos: int = 0) -> None:
        """Upload the request's prompt into its slot and reset per-request
        state (position counter + recurrent SSM states; attention KV rows
        need no clearing — per-slot position masks hide stale entries).
        ``steps_total`` is the number of engine steps this request runs on
        THIS engine (plen + gen - 1 colocated; plen for a prefill phase —
        each minus the shared prefix under prefix sharing).

        ``start_pos`` > 0 binds at an offset (prefix sharing): positions
        ``[0, start_pos)`` are already served by shared pages in the
        slot's block table, so the first fed token is
        ``prompt[start_pos]`` and prefill for the shared prefix is
        skipped.  Pending COW page copies are materialized here, before
        the slot's first write."""
        if start_pos and self.kv_layout != "paged":
            raise ValueError("bind at an offset (prefix sharing) requires "
                             "the paged KV layout")
        s = req.slot
        row = np.zeros((self.max_prompt,), np.int32)
        row[:req.prompt_len] = req.prompt
        self._prompts = self._prompts.at[s].set(jnp.asarray(row))
        self._plens = self._plens.at[s].set(req.prompt_len)
        if self.kv_layout == "paged":
            for src, dst in self.pool.consume_cow(req.rid):
                self.copy_page(src, dst)
            # upload the slot's logical->physical page map (lease order IS
            # the block table)
            table = self.pool.block_table(
                req.rid, pad_to=self.cache["block_tables"].shape[1])
            cache = dict(self.cache)
            cache["block_tables"] = cache["block_tables"].at[s].set(
                jnp.asarray(table))
            self.cache = cache
        self.cache = T.reset_slot_state(self.cfg, self.cache, s)
        if start_pos:
            cache = dict(self.cache)
            cache["pos"] = cache["pos"].at[s].set(start_pos)
            self.cache = cache
        self.slots[s] = req
        self.steps_done[s] = 0
        self.steps_total[s] = steps_total
        self.active[s] = True

    def dispatch(self, burst: int, active_np: np.ndarray) -> None:
        """Dispatch `burst` fused steps over the active slots (bucketed
        power-of-two scans, no host sync)."""
        active_dev = jnp.asarray(active_np)
        k = burst
        while k > 0:
            b = min(self.MAX_BUCKET, 1 << (k.bit_length() - 1))
            (self.cache, self._last_tok, self._out_buf) = self._burst_fn(b)(
                self.params, self.cache, self._prompts, self._plens,
                self._last_tok, self._out_buf, active_dev)
            k -= b
        self.steps_done[active_np] += burst
        for s, req in enumerate(self.slots):
            if req is not None and active_np[s]:
                self.pool.note_write(req.rid, burst)

    def sync(self) -> None:
        """Block until every dispatched burst has executed.  Waits only —
        nothing is read or written — so outputs are bit-identical with or
        without the sync; the telemetry feedback path calls this so burst
        timings measure device wall time, not enqueue time."""
        jax.block_until_ready((self.cache, self._last_tok, self._out_buf))

    def pull_output(self, slot: int) -> np.ndarray:
        """Sync and read one slot's sampled-token row."""
        return np.asarray(self._out_buf[slot])

    def pull_outputs(self) -> np.ndarray:
        """Sync and read the whole (n_slots, max_gen) output buffer — one
        host sync per burst boundary, shared by every streaming slot."""
        return np.asarray(self._out_buf)

    def release(self, req: Request) -> None:
        """Free the request's slot + pool lease on this engine."""
        self.pool.free(req.rid)
        self.slots[req.slot] = None
        self.active[req.slot] = False

    # ---- slot hand-off (phase disaggregation) ----------------------------
    def _layer_take(self, take_slot, take_arena):
        """Map the layout-appropriate extractor over each layer cache:
        paged attention layers carry block arenas (page-granular take),
        everything else is slot-major (slot-granular take)."""
        blocks, rem = self.cache["layers"]

        def one(c, stacked):
            if (self.kv_layout == "paged" and isinstance(c, dict)
                    and "k" in c):
                return jax.tree.map(lambda a: take_arena(a, stacked), c)
            take = take_slot[1] if stacked else take_slot[0]
            return jax.tree.map(take, c)

        return (tuple(one(c, True) for c in blocks),
                tuple(one(c, False) for c in rem))

    def export_slot(self, s: int) -> Dict:
        """Snapshot every per-slot tensor a request needs to resume on
        another engine: KV state / recurrent states / position, the
        per-slot cross-attention features (vision/enc-dec caches), the
        prompt row + feed state, and the sampled-output row.  This is the
        payload the placement analyzer prices with the offload-overhead
        model.

        Dense layout ships the slot's whole ``max_seq`` KV rows; the paged
        layout ships only the pages that actually hold written tokens
        (``kv_tokens`` of them), so the hand-off payload scales with the
        prompt, not the reservation."""
        # slot-invariant entries (no slot axis) must be COPIED, not
        # aliased: the async hand-off holds snapshots across bursts, and
        # the burst donates the engine's buffers — an alias would be a
        # deleted buffer by adoption time.  Slices already allocate fresh
        # buffers; only the passthrough branches alias.
        snap = lambda a: a.copy() if hasattr(a, "ndim") else a
        take_r = lambda a: a[s] if getattr(a, "ndim", 0) >= 1 else snap(a)
        take_b = lambda a: a[:, s] if getattr(a, "ndim", 0) >= 2 else snap(a)
        state = {
            "layout": self.kv_layout,
            "pos": self.cache["pos"][s],
            "cross": None,
            "prompt": self._prompts[s],
            "plen": self._plens[s],
            "last_tok": self._last_tok[s],
            "out_row": self._out_buf[s],
        }
        cross = self.cache.get("cross")
        if cross is not None:
            state["cross"] = cross[s]
        if self.kv_layout == "paged":
            req = self.slots[s]
            lease = self.pool.lease(req.rid)
            n_used = math.ceil(lease.written_tokens / self.pool.block_size)
            phys = jnp.asarray(np.asarray(lease.blocks[:n_used], np.int32))
            take_arena = lambda a, stacked: (a[:, phys] if stacked
                                             else a[phys])
            state["kv_tokens"] = lease.written_tokens
        else:
            take_arena = None
        state["blocks"], state["rem"] = self._layer_take(
            (take_r, take_b), take_arena)
        return state

    def import_slot(self, s: int, state: Dict, *,
                    dest_blocks: Optional[List[int]] = None,
                    skip_blocks: int = 0) -> None:
        """Install an exported slot snapshot into slot ``s`` (bit-exact:
        the imported request decodes the same tokens it would have
        produced had it stayed on the exporting engine).

        The cache is rebuilt by copy-and-update of ``self.cache`` so every
        key the layout carries survives the migration (a literal rebuild
        used to silently drop unknown keys), and per-slot cross-attention
        rows are migrated rather than shared.  Paged layout: the shipped
        pages land in this engine's arena at ``dest_blocks`` (the slot's
        new lease, logical order) and the slot's block table is rebuilt
        from that lease — physical page ids never migrate across engines.
        ``skip_blocks`` leading logical pages are NOT landed (prefix
        sharing: the destination lease already maps them onto shared
        pages holding bit-identical content, which must not be written).
        """
        if self.device is not None:
            # commit the snapshot here before any at[].set — mixing arrays
            # committed to different devices in one op is an error, and a
            # snapshot that already finished its async device_put makes
            # this a no-op
            state = state_to_device(state, self.device)
        layout = state.get("layout", "dense")
        if layout != self.kv_layout:
            raise ValueError(
                f"exported slot uses the {layout!r} KV layout but the "
                f"importing engine runs {self.kv_layout!r} — phase engines "
                f"must share a layout for exact migration")
        cross = self.cache.get("cross")
        if cross is not None and state.get("cross") is None:
            raise ValueError(
                "cross-attention cache present on the importing engine "
                "but the exported slot carries no cross row — the "
                "exporting engine was built for a different config")
        if cross is None and state.get("cross") is not None:
            raise ValueError(
                "exported slot carries a cross-attention row but the "
                "importing engine has no cross cache — silently dropping "
                "it would corrupt the migrated request (engines built for "
                "different configs)")
        set_b = lambda a, v: (a.at[:, s].set(v)
                              if getattr(a, "ndim", 0) >= 2 else a)
        set_r = lambda a, v: (a.at[s].set(v)
                              if getattr(a, "ndim", 0) >= 1 else a)
        if self.kv_layout == "paged":
            if dest_blocks is None:
                raise ValueError("paged import needs dest_blocks (the "
                                 "slot's lease on this engine)")
            n_used = math.ceil(int(state["kv_tokens"])
                               / self.pool.block_size)
            if n_used > len(dest_blocks):
                raise ValueError(
                    f"snapshot carries {n_used} written pages but the "
                    f"destination lease holds {len(dest_blocks)} blocks")
            skip = min(skip_blocks, n_used)
            phys = jnp.asarray(np.asarray(dest_blocks[skip:n_used],
                                          np.int32))
            set_arena = {
                True: lambda a, v: a.at[:, phys].set(v[:, skip:n_used]),
                False: lambda a, v: a.at[phys].set(v[skip:n_used]),
            }
        else:
            set_arena = None

        def set_layer(c, v, stacked):
            if (self.kv_layout == "paged" and isinstance(c, dict)
                    and "k" in c):
                return jax.tree.map(set_arena[stacked], c, v)
            return jax.tree.map(set_b if stacked else set_r, c, v)

        blocks, rem = self.cache["layers"]
        cache = dict(self.cache)
        cache["layers"] = (
            tuple(set_layer(c, v, True)
                  for c, v in zip(blocks, state["blocks"])),
            tuple(set_layer(c, v, False)
                  for c, v in zip(rem, state["rem"])))
        cache["pos"] = self.cache["pos"].at[s].set(state["pos"])
        if cross is not None:
            cache["cross"] = cross.at[s].set(state["cross"])
        if self.kv_layout == "paged":
            table = np.zeros((cache["block_tables"].shape[1],), np.int32)
            table[:len(dest_blocks)] = dest_blocks
            cache["block_tables"] = cache["block_tables"].at[s].set(
                jnp.asarray(table))
        self.cache = cache
        self._prompts = self._prompts.at[s].set(state["prompt"])
        self._plens = self._plens.at[s].set(state["plen"])
        self._last_tok = self._last_tok.at[s].set(state["last_tok"])
        self._out_buf = self._out_buf.at[s].set(state["out_row"])

    def adopt(self, req: Request, state: Dict, *, steps_total: int,
              skip_blocks: int = 0) -> None:
        """Take over a migrated request: install its snapshot into the slot
        the pool already assigned (``req.slot``) and reset the per-slot
        schedule for the steps this engine owes.  ``skip_blocks`` passes
        through to :meth:`import_slot` (prefix-shared leading pages)."""
        s = req.slot
        dest = (self.pool.lease(req.rid).blocks
                if self.kv_layout == "paged" else None)
        self.import_slot(s, state, dest_blocks=dest,
                         skip_blocks=skip_blocks)
        self.slots[s] = req
        self.steps_done[s] = 0
        self.steps_total[s] = steps_total
        self.active[s] = True

    @staticmethod
    def state_nbytes(state: Dict) -> int:
        """Byte size of an exported slot snapshot (the hand-off payload).
        Non-array metadata (layout tag, written-token count) is free."""
        return sum(int(leaf.nbytes) for leaf in jax.tree.leaves(state)
                   if hasattr(leaf, "nbytes"))


def state_to_device(state: Dict, device) -> Dict:
    """Commit every array leaf of an exported slot snapshot to ``device``
    (non-array metadata — layout tag, token counts — passes through).

    ``jax.device_put`` *dispatches* the copy and returns immediately, so
    calling this right after :meth:`SlotEngine.export_slot` starts the
    cross-device transfer in the background: the exporting engine can keep
    computing while the bytes drain, and the adopting engine blocks only
    on whatever is still in flight (the async hand-off).  Re-committing an
    array already on ``device`` is a no-op, so the defensive call inside
    :meth:`SlotEngine.import_slot` costs nothing on the fast path."""
    return jax.tree.map(
        lambda x: (jax.device_put(x, device)
                   if isinstance(x, jax.Array) else x), state)


def snapshot_ready(state: Dict) -> bool:
    """True when every array in a dispatched snapshot has resolved on its
    destination device (non-blocking — the overlap probe)."""
    return all(leaf.is_ready() for leaf in jax.tree.leaves(state)
               if isinstance(leaf, jax.Array))


def snapshot_wait(state: Dict) -> None:
    """Block until a dispatched snapshot's transfer completes (the stall
    the hand-off ledger charges to the adopting engine)."""
    for leaf in jax.tree.leaves(state):
        if isinstance(leaf, jax.Array):
            leaf.block_until_ready()


class EngineLoop:
    """Colocated serving: one SlotEngine runs both phases of every request.

    The open-loop scaffolding lives in :class:`~repro.serving.driver.
    OpenLoopDriver`; this class provides the colocated hook implementations
    (admission binds both phases onto the one engine, completion pulls the
    whole output row).
    """

    def __init__(self, cfg: T.ModelConfig, params, *, n_slots: int,
                 max_seq: int, block_size: int = 16,
                 total_blocks: Optional[int] = None,
                 kv_layout: str = "paged",
                 device_name: str = "tpu-v5e",
                 device_model=None,
                 step_slo_s: Optional[float] = None,
                 token_budget: Optional[int] = None,
                 prefix_sharing: bool = False,
                 obs: Optional[Observability] = None):
        if prefix_sharing:
            if kv_layout != "paged":
                raise ValueError("prefix sharing maps physical pages — it "
                                 "requires kv_layout='paged'")
            if any(t != "attn" for t in cfg.layer_types()):
                raise ValueError(
                    "prefix sharing requires an all-attention config: "
                    "recurrent/cross layer state is slot-local and cannot "
                    "be reconstructed from shared KV pages")
        self.cfg = cfg
        self.kv_layout = kv_layout
        self.prefix_sharing = prefix_sharing
        self.obs = obs if obs is not None else Observability()
        self.pool = KVPool(n_slots, max_seq, block_size=block_size,
                           total_blocks=total_blocks,
                           prefix_sharing=prefix_sharing)
        self.batcher = ContinuousBatcher(
            cfg, self.pool, device_name=device_name,
            device_model=device_model, step_slo_s=step_slo_s,
            token_budget=token_budget)
        self.engine = SlotEngine(cfg, params, self.pool,
                                 kv_layout=kv_layout, name="colocated")
        wire_pool_events(self.pool, self.obs.tracer)

    def warmup(self) -> None:
        self.engine.warmup()

    @property
    def n_active(self) -> int:
        return self.engine.n_active

    @property
    def batchers(self):
        """Admission batchers, uniform with the disaggregated loop's."""
        return (self.batcher,)

    def run(self, requests: List[Request], *,
            now_fn: Callable[[], float] = default_clock,
            max_steps: Optional[int] = None,
            on_delta: Optional[Callable[[StreamDelta], None]] = None
            ) -> ServeMetrics:
        """Serve `requests` (an arrival-stamped open-loop stream) to
        completion via the shared open-loop driver.  Returns the aggregate
        metrics; ``on_delta`` streams newly readable tokens at burst
        boundaries."""
        return OpenLoopDriver(self).run(requests, now_fn=now_fn,
                                        max_steps=max_steps,
                                        on_delta=on_delta)

    # ---- OpenLoopDriver hooks --------------------------------------------
    def start_run(self) -> None:
        pass                             # all per-run state lives on engines

    def in_flight(self) -> bool:
        return self.engine.n_active > 0

    def runnable(self) -> bool:
        return self.engine.n_active > 0

    def backlogged(self, queue: List[Request]) -> bool:
        return False                     # only pending arrivals throttle

    def admit(self, queue: List[Request], now: float,
              metrics: ServeMetrics) -> None:
        decision = self.batcher.admit(queue, self.engine.n_active, now)
        metrics.drop(len(decision.dropped))
        for req in decision.admitted:
            # greedy decoding with known lengths: completion is
            # deterministic — the final sample lands after
            # plen + gen - 1 active steps (minus any prefix-shared
            # tokens, whose prefill is skipped by binding at an offset)
            shared = self.pool.shared_tokens(req.rid)
            req.shared_tokens = shared
            self.engine.bind(
                req, start_pos=shared,
                steps_total=(req.prompt_len - shared
                             + req.max_new_tokens - 1))
        trace_admission(self.obs, self.batcher, decision,
                        self.engine.n_active)
        return decision

    def dispatch(self, throttle: bool, budget: Optional[int]) -> int:
        # burst: dispatch steps to the next completion boundary without
        # any host sync; the device chain pipelines behind dispatch
        eng = self.engine
        remaining = eng.steps_total - eng.steps_done
        burst = burst_size(int(remaining[eng.active].min()),
                           throttle=throttle, budget=budget)
        if burst <= 0:
            return 0
        tracer, fb, wd = self.obs.tracer, self.obs.feedback, self.obs.watchdog
        n_active = eng.n_active
        h = (tracer.begin("burst", track=f"engine:{eng.name}", cat="engine",
                          args={"steps": burst, "n_active": n_active})
             if tracer.enabled else None)
        timed = fb is not None or wd is not None
        t0 = tracer.now() if timed else 0.0
        eng.dispatch(burst, eng.active)
        if timed:
            # telemetry feedback / the watchdog want device wall time per
            # step, so wait for the burst (a pure wait: outputs stay
            # bit-identical)
            eng.sync()
            dt = tracer.now() - t0
            if fb is not None:
                fb.observe_burst(n_active, burst, dt)
            if wd is not None:
                wd.observe_burst(
                    eng.name, self.batcher.phase, n_tokens=n_active,
                    steps=burst, elapsed_s=dt,
                    priced_step_s=self.batcher.priced_step_s(n_active))
        if h is not None:
            tracer.end(h, args={"synced": timed})
        return burst

    def on_drift(self, alert, watchdog) -> None:
        """Watchdog action leg: re-price admission from observed telemetry.

        Installs the best pricing the watchdog can offer — a fitted
        latency(batch) curve once >= 2 batch sizes were observed, the
        analytic shape scaled by the observed divergence ratio otherwise —
        and refits the token budget against the stored step SLO.  Pure
        admission policy: per-request greedy outputs are schedule-
        independent, so re-pricing never changes what is generated.
        """
        fn, source = watchdog.step_time_fn(
            alert.engine, alert.phase, self.batcher.analytic_step_s)
        if source == "analytic":
            return                       # nothing observed: keep the model
        detail = self.batcher.reprice(fn, source=source)
        watchdog.note_reprice(alert, detail)

    def sample(self, metrics: ServeMetrics) -> None:
        occ, util = sample_pools((self.pool,))
        metrics.occupancy.append(occ)
        metrics.utilization.append(util)

    def scan(self, clock: Callable[[], float], metrics: ServeMetrics,
             sink: TokenSink) -> None:
        eng = self.engine
        tracer = self.obs.tracer
        now = clock()
        for s, req in enumerate(eng.slots):
            if req is None:
                continue
            # shared-prefix tokens count as fed: the KV exists and the
            # feed pointer started past them
            req.n_fed = int(eng.steps_done[s]) + req.shared_tokens
            if (req.state is RequestState.PREFILL
                    and req.n_fed >= req.prompt_len):
                # the burst containing the first sample has been dispatched
                # (host-visible stamping happens in the sink)
                req.state = RequestState.DECODE
                req.t_first_dispatch = now
                trace_phase_flip(tracer, req, now)
        sink.drain(eng, clock)           # streaming: burst-boundary sync
        for s, req in enumerate(eng.slots):
            if req is None:
                continue
            if eng.steps_done[s] >= eng.steps_total[s]:
                # completion boundary: sync and pull this slot's tokens
                h = (tracer.begin("sync", track=f"engine:{eng.name}",
                                  cat="engine", args={"kind": "completion"})
                     if tracer.enabled else None)
                row = eng.pull_output(s)
                if h is not None:
                    tracer.end(h)
                req.state = RequestState.DONE
                req.t_done = clock()
                sink.finish(req, row[:req.max_new_tokens], req.t_done)
                eng.release(req)
                metrics.observe(req)
                trace_completion(tracer, req)
