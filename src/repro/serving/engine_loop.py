"""Continuous-batching engine loop + serving metrics.

One jitted step (`decode_step_slots`) advances every active slot by one
token per iteration — prefilling slots consume their next prompt token,
decoding slots consume their previously sampled token — so prefill work
interleaves with the running decode batch instead of stalling it, and a
finished request's slot is refilled at the next completion boundary (no
inter-batch idle, no head-of-line blocking on the longest generation).

Token feeding is device-resident: the fused step selects each slot's next
token from an uploaded prompt buffer (while ``pos < prompt_len``) or from
the previous argmax, and scatters sampled tokens into a per-slot output
buffer.  The host never syncs per step — request completion is
deterministic in step count (greedy decoding, known lengths), so the loop
dispatches a *burst* of steps up to the next completion boundary and only
then pulls the finished slots' output rows.  This keeps per-step overhead
at dispatch cost, matching the static server's async decode chain.

The slot state + burst machinery lives in :class:`SlotEngine` so one
deployment can run several engines: :class:`EngineLoop` composes a single
SlotEngine (colocated serving), while
:class:`~repro.serving.disagg.DisaggregatedEngineLoop` composes two — a
prefill engine and a decode engine — and migrates slots between them
(`export_slot`/`import_slot`) at the phase boundary.

The loop is driven by a clock function so tests can run it reproducibly;
the CLI and benchmark use wall time, which is what the open-loop arrival
process (request.synthetic_workload) is offered against.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer as T
from .batcher import ContinuousBatcher
from .kv_pool import KVPool
from .request import Request, RequestState


def _percentile(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else float("nan")


@dataclasses.dataclass
class ServeMetrics:
    n_done: int = 0
    n_dropped: int = 0
    n_steps: int = 0
    tokens_out: int = 0
    tokens_in: int = 0
    elapsed_s: float = 0.0
    ttft_s: List[float] = dataclasses.field(default_factory=list)
    tpot_s: List[float] = dataclasses.field(default_factory=list)
    latency_s: List[float] = dataclasses.field(default_factory=list)
    occupancy: List[float] = dataclasses.field(default_factory=list)
    utilization: List[float] = dataclasses.field(default_factory=list)

    def observe(self, req: Request) -> None:
        self.n_done += 1
        self.tokens_out += len(req.output)
        self.tokens_in += req.prompt_len
        if req.ttft is not None:
            self.ttft_s.append(req.ttft)
        if req.tpot is not None:
            self.tpot_s.append(req.tpot)
        if req.t_done is not None:
            self.latency_s.append(req.t_done - req.arrival)

    def summary(self) -> Dict[str, float]:
        dt = max(self.elapsed_s, 1e-9)
        return {
            "requests_done": self.n_done,
            "requests_dropped": self.n_dropped,
            "steps": self.n_steps,
            "tokens_in": self.tokens_in,
            "tokens_out": self.tokens_out,
            "elapsed_s": self.elapsed_s,
            "tok_per_s": self.tokens_out / dt,
            "req_per_s": self.n_done / dt,
            "ttft_p50_s": _percentile(self.ttft_s, 50),
            "ttft_p99_s": _percentile(self.ttft_s, 99),
            "tpot_p50_s": _percentile(self.tpot_s, 50),
            "tpot_p99_s": _percentile(self.tpot_s, 99),
            "latency_p50_s": _percentile(self.latency_s, 50),
            "latency_p99_s": _percentile(self.latency_s, 99),
            "kv_occupancy_mean": (float(np.mean(self.occupancy))
                                  if self.occupancy else 0.0),
            "kv_utilization_mean": (float(np.mean(self.utilization))
                                    if self.utilization else 0.0),
        }


def _fused_step(params, cfg, cache, prompts, plens, last_tok, out_buf,
                active):
    """Device-side feed + step + sample + output scatter.

    prompts: (B, P_max) int32; plens/last_tok: (B,) int32; out_buf:
    (B, G_max) int32; active: (B,) bool.  cache["pos"] counts tokens fed
    per slot, so pos < plen selects the prompt, else the last sample."""
    b = prompts.shape[0]
    pos = cache["pos"]
    prompt_tok = prompts[jnp.arange(b), jnp.minimum(pos, prompts.shape[1] - 1)]
    tok = jnp.where(pos < plens, prompt_tok, last_tok)
    logits, cache = T.decode_step_slots(params, cfg, cache, tok[:, None],
                                        active)
    nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    # the sample is output index (pos - plen + 1); valid once the final
    # prompt token has been fed (same schedule as the static replay path)
    idx = pos - plens + 1
    write = active & (idx >= 0) & (idx < out_buf.shape[1])
    safe_idx = jnp.clip(idx, 0, out_buf.shape[1] - 1)
    row = out_buf[jnp.arange(b), safe_idx]
    out_buf = out_buf.at[jnp.arange(b), safe_idx].set(
        jnp.where(write, nxt, row))
    last_tok = jnp.where(active, nxt, last_tok)
    return cache, last_tok, out_buf


class SlotEngine:
    """Device-resident slot state + jitted burst machinery for one engine.

    Owns the slot cache, the prompt/output buffers, the per-slot step
    schedule and the compiled burst buckets.  The per-slot math is exactly
    `decode_step`'s, so outputs are bit-identical whether a request lives
    its whole life in one SlotEngine (colocated) or is exported from a
    prefill engine and imported into a decode engine mid-flight.
    """

    # largest scanned burst compiled; bounds compile count (power-of-two
    # buckets 1..MAX_BUCKET)
    MAX_BUCKET = 32

    def __init__(self, cfg: T.ModelConfig, params, pool: KVPool):
        self.cfg = cfg
        self.params = params
        self.pool = pool
        n_slots = pool.n_slots
        self.cache = T.init_slot_cache(cfg, n_slots, pool.max_seq)
        self.max_prompt = pool.max_seq
        self.max_gen = pool.max_seq
        self._prompts = jnp.zeros((n_slots, self.max_prompt), jnp.int32)
        self._plens = jnp.zeros((n_slots,), jnp.int32)
        self._last_tok = jnp.zeros((n_slots,), jnp.int32)
        self._out_buf = jnp.zeros((n_slots, self.max_gen), jnp.int32)
        self._burst_fns: Dict[int, Callable] = {}
        self.slots: List[Optional[Request]] = [None] * n_slots
        # host-side schedule state: active steps done / total per slot
        self.steps_done = np.zeros((n_slots,), np.int64)
        self.steps_total = np.zeros((n_slots,), np.int64)

    def _burst_fn(self, k: int) -> Callable:
        """Jitted scan of k fused steps — one dispatch per bucket instead of
        per token, so burst cost is dominated by device compute."""
        fn = self._burst_fns.get(k)
        if fn is None:
            cfg = self.cfg

            def burst(p, c, pr, pl, lt, ob, a):
                def body(carry, _):
                    c, lt, ob = carry
                    return _fused_step(p, cfg, c, pr, pl, lt, ob, a), None
                (c, lt, ob), _ = jax.lax.scan(body, (c, lt, ob), None,
                                              length=k)
                return c, lt, ob

            fn = jax.jit(burst, donate_argnums=(1, 4, 5))
            self._burst_fns[k] = fn
        return fn

    def warmup(self) -> None:
        """Compile every burst bucket.  An all-inactive step leaves cache,
        positions and buffers bit-identical, so this is state-neutral."""
        idle = jnp.zeros((self.pool.n_slots,), bool)
        b = 1
        while b <= self.MAX_BUCKET:
            (self.cache, self._last_tok, self._out_buf) = self._burst_fn(b)(
                self.params, self.cache, self._prompts, self._plens,
                self._last_tok, self._out_buf, idle)
            b *= 2

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self.slots)

    def active_requests(self):
        return (r for r in self.slots if r is not None)

    def bind(self, req: Request, *, steps_total: int) -> None:
        """Upload the request's prompt into its slot and reset per-request
        state (position counter + recurrent SSM states; attention KV rows
        need no clearing — per-slot position masks hide stale entries).
        ``steps_total`` is the number of engine steps this request runs on
        THIS engine (plen + gen - 1 colocated; plen for a prefill phase)."""
        s = req.slot
        row = np.zeros((self.max_prompt,), np.int32)
        row[:req.prompt_len] = req.prompt
        self._prompts = self._prompts.at[s].set(jnp.asarray(row))
        self._plens = self._plens.at[s].set(req.prompt_len)
        self.cache = T.reset_slot_state(self.cfg, self.cache, s)
        self.slots[s] = req
        self.steps_done[s] = 0
        self.steps_total[s] = steps_total

    def dispatch(self, burst: int, active_np: np.ndarray) -> None:
        """Dispatch `burst` fused steps over the active slots (bucketed
        power-of-two scans, no host sync)."""
        active_dev = jnp.asarray(active_np)
        k = burst
        while k > 0:
            b = min(self.MAX_BUCKET, 1 << (k.bit_length() - 1))
            (self.cache, self._last_tok, self._out_buf) = self._burst_fn(b)(
                self.params, self.cache, self._prompts, self._plens,
                self._last_tok, self._out_buf, active_dev)
            k -= b
        self.steps_done[active_np] += burst
        for s, req in enumerate(self.slots):
            if req is not None and active_np[s]:
                self.pool.note_write(req.rid, burst)

    def pull_output(self, slot: int) -> np.ndarray:
        """Sync and read one slot's sampled-token row."""
        return np.asarray(self._out_buf[slot])

    def release(self, req: Request) -> None:
        """Free the request's slot + pool lease on this engine."""
        self.pool.free(req.rid)
        self.slots[req.slot] = None

    # ---- slot hand-off (phase disaggregation) ----------------------------
    def export_slot(self, s: int) -> Dict:
        """Snapshot every per-slot tensor a request needs to resume on
        another engine: KV rows / recurrent states / position, the prompt
        row + feed state, and the sampled-output row.  This is the payload
        the placement analyzer prices with the offload-overhead model."""
        blocks, rem = self.cache["layers"]
        take_b = lambda a: a[:, s] if getattr(a, "ndim", 0) >= 2 else a
        take_r = lambda a: a[s] if getattr(a, "ndim", 0) >= 1 else a
        return {
            "blocks": jax.tree.map(take_b, blocks),
            "rem": jax.tree.map(take_r, rem),
            "pos": self.cache["pos"][s],
            "prompt": self._prompts[s],
            "plen": self._plens[s],
            "last_tok": self._last_tok[s],
            "out_row": self._out_buf[s],
        }

    def import_slot(self, s: int, state: Dict) -> None:
        """Install an exported slot snapshot into slot ``s`` (bit-exact:
        the imported request decodes the same tokens it would have
        produced had it stayed on the exporting engine)."""
        blocks, rem = self.cache["layers"]
        set_b = lambda a, v: (a.at[:, s].set(v)
                              if getattr(a, "ndim", 0) >= 2 else a)
        set_r = lambda a, v: (a.at[s].set(v)
                              if getattr(a, "ndim", 0) >= 1 else a)
        self.cache = {
            "layers": (jax.tree.map(set_b, blocks, state["blocks"]),
                       jax.tree.map(set_r, rem, state["rem"])),
            "pos": self.cache["pos"].at[s].set(state["pos"]),
            "cross": self.cache.get("cross"),
        }
        self._prompts = self._prompts.at[s].set(state["prompt"])
        self._plens = self._plens.at[s].set(state["plen"])
        self._last_tok = self._last_tok.at[s].set(state["last_tok"])
        self._out_buf = self._out_buf.at[s].set(state["out_row"])

    @staticmethod
    def state_nbytes(state: Dict) -> int:
        """Byte size of an exported slot snapshot (the hand-off payload)."""
        return sum(int(leaf.nbytes) for leaf in jax.tree.leaves(state))


class EngineLoop:
    """Colocated serving: one SlotEngine runs both phases of every request."""

    # with arrivals pending, bursts stay short so admission latency is
    # bounded; otherwise a burst runs to the next completion boundary
    BURST_CAP_PENDING = 4

    def __init__(self, cfg: T.ModelConfig, params, *, n_slots: int,
                 max_seq: int, block_size: int = 16,
                 total_blocks: Optional[int] = None,
                 device_name: str = "tpu-v5e",
                 device_model=None,
                 step_slo_s: Optional[float] = None,
                 token_budget: Optional[int] = None):
        self.cfg = cfg
        self.pool = KVPool(n_slots, max_seq, block_size=block_size,
                           total_blocks=total_blocks)
        self.batcher = ContinuousBatcher(
            cfg, self.pool, device_name=device_name,
            device_model=device_model, step_slo_s=step_slo_s,
            token_budget=token_budget)
        self.engine = SlotEngine(cfg, params, self.pool)

    def warmup(self) -> None:
        self.engine.warmup()

    @property
    def n_active(self) -> int:
        return self.engine.n_active

    def run(self, requests: List[Request], *,
            now_fn: Callable[[], float] = time.perf_counter,
            max_steps: Optional[int] = None) -> ServeMetrics:
        """Serve `requests` (an arrival-stamped open-loop stream) to
        completion.  Returns the aggregate metrics."""
        eng = self.engine
        metrics = ServeMetrics()
        pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
        queue: List[Request] = []
        active_np = np.zeros((self.pool.n_slots,), bool)
        t0 = now_fn()
        skew = 0.0                       # idle fast-forward (see below)
        clock = lambda: now_fn() - t0 + skew

        while pending or queue or eng.n_active:
            now = clock()
            # open-loop arrivals: everything whose arrival time has passed
            # joins the queue
            while pending and pending[0].arrival <= now:
                queue.append(pending.pop(0))
            if not queue and not eng.n_active:
                # fully idle with the next arrival in the future: fast-
                # forward the clock to it instead of busy-waiting, so
                # timestamps stay on the offered-load timeline (TTFT and
                # latency remain >= 0)
                skew += pending[0].arrival - now
                continue
            decision = self.batcher.admit(queue, eng.n_active, now)
            metrics.n_dropped += len(decision.dropped)
            for req in decision.admitted:
                # greedy decoding with known lengths: completion is
                # deterministic — the final sample lands after
                # plen + gen - 1 active steps
                eng.bind(req, steps_total=(req.prompt_len
                                           + req.max_new_tokens - 1))
                active_np[req.slot] = True

            if eng.n_active == 0:
                continue                 # nothing admissible (pool pressure)

            # burst: dispatch steps to the next completion boundary without
            # any host sync; the device chain pipelines behind dispatch
            remaining = eng.steps_total - eng.steps_done
            burst = int(remaining[active_np].min())
            if pending:
                burst = min(burst, self.BURST_CAP_PENDING)
            if max_steps is not None:
                burst = min(burst, max_steps - metrics.n_steps)
            eng.dispatch(burst, active_np)
            metrics.n_steps += burst
            metrics.occupancy.append(self.pool.occupancy())
            metrics.utilization.append(self.pool.utilization())

            now = clock()
            for s, req in enumerate(eng.slots):
                if req is None:
                    continue
                req.n_fed = int(eng.steps_done[s])
                if (req.state is RequestState.PREFILL
                        and req.n_fed >= req.prompt_len):
                    # first sample landed inside this burst (dispatch-time
                    # stamp; completion below syncs the chain)
                    req.state = RequestState.DECODE
                    req.t_first_token = now
                if eng.steps_done[s] >= eng.steps_total[s]:
                    # completion boundary: sync and pull this slot's tokens
                    row = eng.pull_output(s)
                    req.output = row[:req.max_new_tokens].tolist()
                    req.state = RequestState.DONE
                    req.t_done = clock()
                    eng.release(req)
                    active_np[s] = False
                    metrics.observe(req)
            if max_steps is not None and metrics.n_steps >= max_steps:
                break
        metrics.elapsed_s = clock()
        return metrics
