"""Draft-model speculative decoding on the paged serving path.

The paper's trade-off move — price the same work on a cheap and an
expensive engine and offload by measured trade-off — applied to the
decode hot path: a small *draft* model proposes k tokens per slot, the
*target* verifies all k in ONE multi-token step over its paged KV cache
(`decode_multi_step_slots_paged`), and only the accepted prefix commits.

Round math (greedy verification, per slot, from committed position pos0
whose chain head token is ``last_tok``):

* the draft autoregressively proposes d_1..d_k (k+1 sequential draft
  steps — the extra feed writes draft KV for d_k so a fully-accepted
  round leaves the draft cache one rollback away from the new head);
* the target feeds [last_tok, d_1..d_k] at positions pos0..pos0+k in one
  step, producing greedy continuations g_1..g_{k+1} where g_j conditions
  on the window prefix up to input j;
* accepted a = longest prefix with d_i == g_i; committed
  c = min(a + 1, rem) — the +1 is the target's own token (the correction
  after a rejection, the bonus token after full acceptance);
* pos += c, the new chain head is g_c, and both caches roll their
  position back to the committed prefix.  Positions pos0+c..pos0+k hold
  *stale* K/V from the rejected tail — harmless, because every later
  feed starts at the committed position and rewrites forward before
  attention ever reads them (attention masks kv_slot <= query position).

Every committed token is a target greedy continuation of the same
committed chain plain decode walks, so outputs are BIT-IDENTICAL to
non-speculative decode by construction; expected committed tokens per
round is sum_{i=1..k} alpha^i + 1 for per-token acceptance rate alpha
(`core.cost_model.expected_tokens_per_round`).

Safety gate: a slot only enters a round while ``rem >= k`` (rem = steps
still owed), which pins the verify window's top position pos0+k inside
the slot's page lease (pos + rem == total_tokens - 1 <= max_seq - 1).
The pool's block table pads with physical page 0, so an overflow write
would corrupt another request's pages — the gate makes that impossible
instead of masking it.  Tail slots (rem < k) finish via plain bursts.

The draft engine needs no KVPool: it is provisioned dense-equivalently
(slot s statically owns pages [s*bps, (s+1)*bps)), its cache is a
throwaway mirror of the committed chain, and a rejection rollback is a
position move.  Draft slot indices equal target slot indices, so the
loops' active masks line up.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer as T
from .engine_loop import EngineLoop

DEFAULT_DRAFT_ARCH = "qwen2_1_5b"
DEFAULT_DRAFT_K = 2
# acceptance prior used before any measurement exists for a (draft,
# target) pair — optimistic enough to let speculation engage so the
# online tracker can measure the real rate and veto it
DEFAULT_ACCEPTANCE_PRIOR = 0.8


def validate_speculation(target_cfg, draft_cfg, *, kv_layout: str,
                         prefix_sharing: bool) -> None:
    """Raise on serving configurations speculation cannot run under."""
    if kv_layout != "paged":
        raise ValueError("speculative decoding verifies k+1 positions "
                         "against the block-paged cache — it requires "
                         "kv_layout='paged'")
    if prefix_sharing:
        raise ValueError(
            "speculative decoding is incompatible with prefix sharing: "
            "shared-offset binds break the draft's committed-chain replay "
            "and a rejected window must never land in refcounted pages")
    for role, cfg in (("target", target_cfg), ("draft", draft_cfg)):
        if any(t != "attn" for t in cfg.layer_types()):
            raise ValueError(
                f"speculative decoding requires an all-attention {role} "
                f"config ({cfg.name!r}): recurrent/SSM state has no "
                f"multi-token verify or rollback")
    if target_cfg.vocab != draft_cfg.vocab:
        raise ValueError(
            f"draft vocab {draft_cfg.vocab} != target vocab "
            f"{target_cfg.vocab}: proposals would not be target tokens")


@dataclasses.dataclass
class SpecPlan:
    """Everything the serving loop needs to speculate: the draft model,
    the depth, and how the decision was made (forced CLI depth vs the
    trade-off analyzer's `choose_speculation`)."""
    draft_cfg: T.ModelConfig
    draft_params: object
    k: int = DEFAULT_DRAFT_K
    draft_name: str = DEFAULT_DRAFT_ARCH
    decision: object = None      # placement.SpeculationDecision | None
    forced: bool = False         # --draft-k: speculate regardless of price
    tracker: object = None       # obs.watchdog.AcceptanceTracker | None


class DraftEngine:
    """The draft model's paged slot cache, dense-equivalently provisioned.

    Mirrors the target engine's committed chain per slot: ``sync_to``
    replays chain tokens the draft has not seen (prompt tokens from the
    target's prompt buffer, committed generations from its output buffer
    — both device-resident, so catch-up never syncs the host) in
    power-of-two multi-token chunks; ``propose`` runs k+1 sequential
    draft steps; ``rollback`` moves positions back to the committed
    prefix after verification.
    """

    def __init__(self, cfg: T.ModelConfig, params, *, n_slots: int,
                 max_seq: int, block_size: int = 16, device=None):
        self.cfg = cfg
        self.max_seq = max_seq
        self.params = (params if device is None
                       else jax.device_put(params, device))
        cache = T.init_slot_cache_paged(cfg, n_slots, max_seq,
                                        block_size=block_size)
        bps = cache["block_tables"].shape[1]
        cache = dict(cache)
        cache["block_tables"] = jnp.asarray(
            np.arange(n_slots * bps, dtype=np.int32).reshape(n_slots, bps))
        if device is not None:
            cache = jax.device_put(cache, device)
        self.cache = cache
        # host view of each draft slot's position (== cache["pos"], kept
        # in lockstep so eligibility checks never pull the device)
        self.pos = np.zeros((n_slots,), np.int64)
        self._sync_fns: Dict[int, Callable] = {}
        self._propose_fns: Dict[int, Callable] = {}
        self._rollback_fn: Optional[Callable] = None

    def reset_slot(self, slot: int) -> None:
        self.cache = T.reset_slot_state(self.cfg, self.cache, slot)
        self.pos[slot] = 0

    def _sync_fn(self, m: int) -> Callable:
        fn = self._sync_fns.get(m)
        if fn is None:
            cfg, ms = self.cfg, self.max_seq

            def sync(params, cache, prompts, plens, out_buf, start, a):
                # committed chain: prompt tokens, then generated tokens
                # (out_buf[x] holds the token at absolute position
                # plen + x — see engine_loop._fused_step's scatter)
                cols = jnp.arange(prompts.shape[1])[None, :]
                gen_idx = jnp.clip(cols - plens[:, None], 0,
                                   out_buf.shape[1] - 1)
                chain = jnp.where(cols < plens[:, None], prompts,
                                  jnp.take_along_axis(out_buf, gen_idx,
                                                      axis=1))
                chunk = jax.lax.dynamic_slice(
                    chain, (0, start), (chain.shape[0], m))
                _, cache = T.decode_multi_step_slots_paged(
                    params, cfg, cache, chunk, a, max_seq=ms, advance=True)
                return cache

            fn = jax.jit(sync)
            self._sync_fns[m] = fn
        return fn

    def sync_to(self, slot: int, target_pos: int, *, prompts, plens,
                out_buf) -> None:
        """Feed the draft cache chain tokens [pos, target_pos) for one
        slot — initial enrollment (pos 0 -> plen) and catch-up after
        plain bursts advanced the target without the draft."""
        start = int(self.pos[slot])
        delta = int(target_pos) - start
        if delta <= 0:
            return
        onehot = np.zeros((self.pos.shape[0],), bool)
        onehot[slot] = True
        a = jnp.asarray(onehot)
        while delta > 0:
            m = 1 << (delta.bit_length() - 1)
            self.cache = self._sync_fn(m)(
                self.params, self.cache, prompts, plens, out_buf,
                jnp.int32(start), a)
            start += m
            delta -= m
        self.pos[slot] = int(target_pos)

    def _propose_fn(self, k: int) -> Callable:
        fn = self._propose_fns.get(k)
        if fn is None:
            cfg, ms = self.cfg, self.max_seq

            def propose(params, cache, last_tok, a):
                def body(carry, _):
                    c, tok = carry
                    logits, c = T.decode_step_slots_paged(
                        params, cfg, c, tok[:, None], a, max_seq=ms)
                    nxt = jnp.argmax(logits[:, -1], axis=-1).astype(
                        jnp.int32)
                    return (c, jnp.where(a, nxt, tok)), nxt

                # k+1 steps: the last feed writes draft KV for d_k, so a
                # fully-accepted round's rollback lands on a cache that
                # already holds the whole committed window
                (cache, _), toks = jax.lax.scan(
                    body, (cache, last_tok), None, length=k + 1)
                return cache, toks[:k].T           # proposals (B, k)

            fn = jax.jit(propose)
            self._propose_fns[k] = fn
        return fn

    def propose(self, k: int, last_tok, active) -> jax.Array:
        self.cache, toks = self._propose_fn(k)(
            self.params, self.cache, last_tok, active)
        return toks

    def rollback(self, k: int, commit, active) -> None:
        """After verify: active slots sit at pos0 + k + 1; move them back
        to the committed head pos0 + c (on-device — ``commit`` stays a
        device array, no host round-trip)."""
        if self._rollback_fn is None:
            def rb(cache, delta, a):
                cache = dict(cache)
                cache["pos"] = jnp.where(a, cache["pos"] + delta,
                                         cache["pos"])
                return cache

            self._rollback_fn = jax.jit(rb)
        self.cache = self._rollback_fn(self.cache, commit - (k + 1), active)


class SpeculativeDecoder:
    """One target SlotEngine's speculative decode state: the draft
    engine, the jitted verify step, per-run acceptance accounting, and
    the online veto (an `AcceptanceTracker` re-runs the trade-off
    decision as measured acceptance drifts; a negative decision disables
    speculation for the rest of the run and the loop re-prices admission
    back to plain decode).

    ``propose_override(round_index, proposals) -> proposals`` lets tests
    corrupt the draft's proposals deterministically (forcing rejection at
    a chosen window offset); it sees/returns host arrays, so it costs a
    sync and exists for tests only.
    """

    def __init__(self, engine, plan: SpecPlan, *,
                 propose_override: Optional[Callable] = None):
        if engine.kv_layout != "paged":
            raise ValueError("speculative decoding requires a paged engine")
        validate_speculation(engine.cfg, plan.draft_cfg,
                             kv_layout=engine.kv_layout,
                             prefix_sharing=engine.pool.prefix_sharing)
        self.eng = engine
        self.plan = plan
        self.draft = DraftEngine(
            plan.draft_cfg, plan.draft_params,
            n_slots=engine.pool.n_slots, max_seq=engine.pool.max_seq,
            block_size=engine.pool.block_size, device=engine.device)
        self.propose_override = propose_override
        self._verify_fns: Dict[int, Callable] = {}
        self.enabled = True
        self.disabled_midrun = False
        self._veto_handled = True
        self.n_rounds = 0
        self.n_proposed = 0
        self.n_accepted = 0
        self.n_committed = 0

    def reset_slot(self, slot: int) -> None:
        self.draft.reset_slot(slot)

    def sync_drafts(self, pos: np.ndarray, mask: np.ndarray) -> None:
        """Bring every masked slot's draft cache up to the target's
        committed position (no-op for already-synced slots)."""
        eng = self.eng
        for s in np.flatnonzero(mask):
            if self.draft.pos[s] != pos[s]:
                self.draft.sync_to(int(s), int(pos[s]),
                                   prompts=eng._prompts, plens=eng._plens,
                                   out_buf=eng._out_buf)

    def _verify_fn(self, k: int) -> Callable:
        fn = self._verify_fns.get(k)
        if fn is None:
            cfg, ms = self.eng.cfg, self.eng.pool.max_seq

            def verify(params, cache, draft_toks, last_tok, plens, out_buf,
                       a, rem):
                pos0 = cache["pos"]
                toks = jnp.concatenate([last_tok[:, None], draft_toks],
                                       axis=1)
                logits, cache = T.decode_multi_step_slots_paged(
                    params, cfg, cache, toks, a, max_seq=ms, advance=False)
                greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                # accepted = longest agreeing draft prefix; committed adds
                # the target's own next token, clamped to the steps owed
                match = (draft_toks == greedy[:, :k]).astype(jnp.int32)
                acc = jnp.sum(jnp.cumprod(match, axis=1), axis=1)
                commit = jnp.where(
                    a, jnp.minimum(acc + 1, jnp.maximum(rem, 1)), 0)
                # scatter committed tokens: greedy[:, j] is the chain
                # token at absolute position pos0 + j + 1, stored at
                # out_buf[pos0 + j + 1 - plen] (same layout as
                # engine_loop._fused_step)
                b, g = out_buf.shape
                j = jnp.arange(k + 1)[None, :]
                idx = pos0[:, None] + j - plens[:, None] + 1
                write = (a[:, None] & (j < commit[:, None])
                         & (idx >= 0) & (idx < g))
                safe = jnp.clip(idx, 0, g - 1)
                rows = out_buf[jnp.arange(b)[:, None], safe]
                out_buf = out_buf.at[jnp.arange(b)[:, None], safe].set(
                    jnp.where(write, greedy, rows))
                last = jnp.take_along_axis(
                    greedy, jnp.clip(commit - 1, 0, k)[:, None],
                    axis=1)[:, 0]
                last_tok = jnp.where(a, last, last_tok)
                cache = dict(cache)
                cache["pos"] = jnp.where(a, pos0 + commit, pos0)
                return cache, last_tok, out_buf, commit, acc

            fn = jax.jit(verify)
            self._verify_fns[k] = fn
        return fn

    def round(self, mask: np.ndarray, rem: np.ndarray) -> np.ndarray:
        """One speculative round over the masked slots (drafts must be
        synced).  Returns per-slot committed-token counts — the round's
        single host pull."""
        eng, k = self.eng, self.plan.k
        a = jnp.asarray(mask)
        proposals = self.draft.propose(k, eng._last_tok, a)
        if self.propose_override is not None:
            proposals = jnp.asarray(
                self.propose_override(self.n_rounds,
                                      np.asarray(proposals)),
                dtype=jnp.int32)
        remd = jnp.asarray(rem.astype(np.int32))
        (eng.cache, eng._last_tok, eng._out_buf, commit,
         acc) = self._verify_fn(k)(
            eng.params, eng.cache, proposals, eng._last_tok, eng._plens,
            eng._out_buf, a, remd)
        self.draft.rollback(k, commit, a)
        c = np.asarray(commit).astype(np.int64)
        acc_h = np.asarray(acc)
        self.draft.pos[mask] += c[mask]
        n = int(mask.sum())
        accepted = int(acc_h[mask].sum())
        self.n_rounds += 1
        self.n_proposed += k * n
        self.n_accepted += accepted
        self.n_committed += int(c[mask].sum())
        tracker = self.plan.tracker
        if tracker is not None and self.enabled:
            tracker.observe_round(k * n, accepted)
            if tracker.disabled:
                self.enabled = False
                self.disabled_midrun = True
                self._veto_handled = False
        return c

    def take_veto(self) -> bool:
        """True exactly once, when the tracker just vetoed speculation —
        the loop reacts by re-pricing admission back to plain decode."""
        if self._veto_handled:
            return False
        self._veto_handled = True
        return True

    @property
    def acceptance_rate(self) -> Optional[float]:
        if self.n_proposed <= 0:
            return None
        return self.n_accepted / self.n_proposed

    def stats(self) -> Dict:
        """JSON-safe per-run speculation accounting."""
        d = {"draft": self.plan.draft_name, "k": self.plan.k,
             "forced": self.plan.forced, "n_rounds": self.n_rounds,
             "n_proposed": self.n_proposed, "n_accepted": self.n_accepted,
             "n_committed": self.n_committed,
             "acceptance_rate": self.acceptance_rate,
             "enabled": self.enabled,
             "disabled_midrun": self.disabled_midrun}
        if self.plan.tracker is not None:
            d["tracker"] = self.plan.tracker.report()
        if self.plan.decision is not None:
            d["decision"] = self.plan.decision.summary()
        return d


def spec_dispatch(spec: SpeculativeDecoder, eng, pool, batcher, obs, *,
                  mask: np.ndarray, pos: np.ndarray, rem: np.ndarray,
                  budget: Optional[int]) -> int:
    """One speculative round under the serving loops' dispatch/telemetry
    contract (burst span, synced feedback/watchdog observation, pool
    write accounting).  Returns the step count credited to the driver:
    the maximum committed tokens across the round's slots."""
    if budget is not None and budget <= 0:
        return 0
    tracer, fb, wd = obs.tracer, obs.feedback, obs.watchdog
    spec.sync_drafts(pos, mask)
    n_active = int(mask.sum())
    h = (tracer.begin("burst", track=f"engine:{eng.name}", cat="engine",
                      args={"steps": spec.plan.k + 1, "n_active": n_active,
                            "speculative": True})
         if tracer.enabled else None)
    timed = fb is not None or wd is not None
    t0 = tracer.now() if timed else 0.0
    c = spec.round(mask, rem)
    committed = int(c[mask].sum())
    eng.steps_done[mask] += c[mask]
    for s in np.flatnonzero(mask):
        req = eng.slots[s]
        if req is not None and c[s] > 0:
            pool.note_write(req.rid, int(c[s]))
    if timed:
        eng.sync()
        dt = tracer.now() - t0
        # per-slot committed tokens this round, as fractional "steps": the
        # watchdog/feedback contract is wall time per step per token
        steps = committed / max(n_active, 1)
        if fb is not None:
            fb.observe_burst(n_active, steps, dt)
        if wd is not None:
            wd.observe_burst(eng.name, batcher.phase, n_tokens=n_active,
                             steps=steps, elapsed_s=dt,
                             priced_step_s=batcher.priced_step_s(n_active))
    if h is not None:
        tracer.end(h, args={"synced": timed, "committed": committed})
    if spec.take_veto():
        # measured acceptance re-priced speculation worse than plain
        # decode: admission returns to the analytic plain-step model
        detail = batcher.reprice(batcher.analytic_step_s,
                                 source="speculation-disabled")
        if tracer.enabled:
            tracer.instant("speculation_disabled", track="server",
                           cat="watchdog", args=detail)
    return int(c[mask].max()) if n_active else 0


class SpeculativeEngineLoop(EngineLoop):
    """Colocated serving with draft-model speculation on the decode phase.

    Dispatch policy per driver iteration: when every burstable slot is
    decode-phase with ``rem >= k`` (the page-lease safety gate), run one
    speculative round — drafts are first synced to each slot's committed
    chain, which covers both initial enrollment at the phase flip and
    catch-up after plain bursts advanced the target alone.  Any other mix
    (prefilling slots, rem < k tails) falls back to the plain burst path
    unchanged, so scheduling stays simple and the identity contract rides
    entirely on the verify math.
    """

    def __init__(self, cfg, params, *, plan: SpecPlan,
                 propose_override: Optional[Callable] = None, **kwargs):
        super().__init__(cfg, params, **kwargs)
        self.spec = SpeculativeDecoder(self.engine, plan,
                                       propose_override=propose_override)

    def admit(self, queue, now, metrics):
        decision = super().admit(queue, now, metrics)
        for req in decision.admitted:
            self.spec.reset_slot(req.slot)
        return decision

    def dispatch(self, throttle: bool, budget: Optional[int]) -> int:
        eng = self.engine
        if self.spec.enabled:
            burstable = eng.active & (eng.steps_done < eng.steps_total)
            if burstable.any():
                plens = np.array([0 if r is None else r.prompt_len
                                  for r in eng.slots], np.int64)
                pos = eng.steps_done     # prefix sharing excluded: offset 0
                rem = eng.steps_total - eng.steps_done
                eligible = (burstable & (pos >= plens)
                            & (rem >= self.plan.k))
                if eligible[burstable].all():
                    return spec_dispatch(
                        self.spec, eng, self.pool, self.batcher, self.obs,
                        mask=burstable, pos=pos, rem=rem, budget=budget)
        return super().dispatch(throttle, budget)

    @property
    def plan(self) -> SpecPlan:
        return self.spec.plan
