"""Phase-disaggregated serving: prefill and decode on separate engines.

CNNLab offloads each network stage to the accelerator where its trade-off
wins (§III.A/IV); serving has exactly two stages — compute-bound prefill
and memory-bound decode — so the same split applies: a *prefill engine*
ingests prompts, and at the phase boundary each request's per-slot state
(KV rows, recurrent states, feed position, first sampled token) is
exported and imported into a *decode engine* that carries the generation.
The hand-off is the paper's offload overhead (PCIe sync, Fig. 5 step 4)
applied to the phase boundary: the loop meters the actual bytes it moves
and prices them with ``core.cost_model.transfer_cost`` on the two phases'
device models — the same model ``serving.placement`` uses to decide
whether the split is worth it at all.  Under the paged KV layout (the
default) the migrated snapshot is block-granular — only the pages holding
the prefilled tokens ship, not the slot's full ``max_seq`` reservation —
so the metered hand-off bytes scale with the prompt.

Each phase owns its own KV pool and its own :class:`ContinuousBatcher`,
so admission and migration are budgeted per (phase, engine) pair: queued
requests enter prefill against the prefill engine's token budget; prefill-
complete requests migrate only when the decode engine's budget and pool
admit them (until then they hold their prefill slot — natural back-
pressure on admission).

Per-request outputs are bit-identical to the colocated
:class:`~repro.serving.engine_loop.EngineLoop` (and therefore to the
static server): the migrated snapshot is exact, and the per-slot step math
is engine-independent.  ``tests/test_placement.py`` asserts it.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

from ..core import device_models
from ..core.cost_model import transfer_cost
from ..models import transformer as T
from ..obs import MetricsRegistry, Observability, default_clock
from .batcher import ContinuousBatcher
from .driver import (OpenLoopDriver, ServeMetrics, StreamDelta, TokenSink,
                     burst_size, sample_pools)
from .engine_loop import (SlotEngine, trace_admission, trace_completion,
                          trace_phase_flip, wire_pool_events)
from .kv_pool import KVPool
from .request import Request, RequestState


class HandoffLedger:
    """What the phase boundary actually moved, plus its modeled price.

    A thin view over the metrics registry's ``handoff_*`` counters: the
    loop's ``.handoff`` attribute keeps its historical read surface
    (``n_handoffs``, ``bytes_moved``, ``modeled_s``, ``modeled_energy_j``,
    ``stats()``) while the values themselves live in the same registry
    snapshot/time-series stream as KV occupancy and queue depth instead of
    a parallel ad-hoc ledger."""

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        if registry is None:
            registry = MetricsRegistry()  # standalone view (tests)
        self._n = registry.counter("handoff_n")
        self._bytes = registry.counter("handoff_bytes")
        self._modeled_s = registry.counter("handoff_modeled_s")
        self._energy_j = registry.counter("handoff_modeled_energy_j")

    def record(self, n_bytes: int, price) -> None:
        """Account one hand-off: metered bytes + its transfer-cost price."""
        self._n.inc()
        self._bytes.inc(n_bytes)
        self._modeled_s.inc(price.t_transfer)
        self._energy_j.inc(price.energy_j)

    @property
    def n_handoffs(self) -> int:
        return int(self._n.value)

    @property
    def bytes_moved(self) -> int:
        return int(self._bytes.value)

    @property
    def modeled_s(self) -> float:
        return self._modeled_s.value

    @property
    def modeled_energy_j(self) -> float:
        return self._energy_j.value

    def stats(self) -> Dict[str, float]:
        return {
            "n_handoffs": self.n_handoffs,
            "bytes_moved": self.bytes_moved,
            "modeled_s": self.modeled_s,
            "modeled_energy_j": self.modeled_energy_j,
        }


class DisaggregatedEngineLoop:
    """Two SlotEngines (prefill + decode) with explicit slot migration.

    The open-loop scaffolding lives in :class:`~repro.serving.driver.
    OpenLoopDriver` (shared with the colocated loop); this class provides
    the two-engine hook implementations: admission binds the prefill phase
    only, the completion scan detects the phase boundary, and migration at
    admission passes carries slots onto the decode engine.
    """

    def __init__(self, cfg: T.ModelConfig, params, *, n_prefill_slots: int,
                 n_decode_slots: int, max_seq: int, block_size: int = 16,
                 kv_layout: str = "paged",
                 prefill_total_blocks: Optional[int] = None,
                 decode_total_blocks: Optional[int] = None,
                 prefill_device_name: str = "tpu-v5e",
                 decode_device_name: str = "tpu-v5e",
                 prefill_device: Optional[device_models.DeviceModel] = None,
                 decode_device: Optional[device_models.DeviceModel] = None,
                 step_slo_s: Optional[float] = None,
                 handoff_link_bw: Optional[float] = None,
                 placement_engine_name: str = "xla",
                 prefix_sharing: bool = False,
                 obs: Optional[Observability] = None):
        if prefix_sharing:
            if kv_layout != "paged":
                raise ValueError("prefix sharing maps physical pages — it "
                                 "requires kv_layout='paged'")
            if any(t != "attn" for t in cfg.layer_types()):
                raise ValueError(
                    "prefix sharing requires an all-attention config: "
                    "recurrent/cross layer state is slot-local and cannot "
                    "be reconstructed from shared KV pages")
        self.cfg = cfg
        self.kv_layout = kv_layout
        self.prefix_sharing = prefix_sharing
        self.obs = obs if obs is not None else Observability()
        # each phase pool runs its own prefix index: the prefill index
        # serves admission (prefill skipping), the decode index dedupes
        # migrated prompts so sharers land only their unique pages
        prefill_pool = KVPool(n_prefill_slots, max_seq, block_size=block_size,
                              total_blocks=prefill_total_blocks,
                              prefix_sharing=prefix_sharing)
        decode_pool = KVPool(n_decode_slots, max_seq, block_size=block_size,
                             total_blocks=decode_total_blocks,
                             prefix_sharing=prefix_sharing)
        self.prefill = SlotEngine(cfg, params, prefill_pool,
                                  kv_layout=kv_layout, name="prefill")
        self.decode = SlotEngine(cfg, params, decode_pool,
                                 kv_layout=kv_layout, name="decode")
        wire_pool_events(prefill_pool, self.obs.tracer)
        wire_pool_events(decode_pool, self.obs.tracer)
        self.prefill_batcher = ContinuousBatcher(
            cfg, prefill_pool, phase="prefill",
            device_name=prefill_device_name, device_model=prefill_device,
            step_slo_s=step_slo_s)
        self.decode_batcher = ContinuousBatcher(
            cfg, decode_pool, phase="decode",
            device_name=decode_device_name, device_model=decode_device,
            step_slo_s=step_slo_s)
        self._prefill_dev = (prefill_device
                             or device_models.get(prefill_device_name))
        self._decode_dev = (decode_device
                            or device_models.get(decode_device_name))
        self._handoff_link_bw = handoff_link_bw
        # the DSE candidate the in-process SlotEngines actually execute on;
        # the watchdog's mid-run placement re-run de-rates this engine
        self._placement_engine_name = placement_engine_name
        self.handoff = HandoffLedger(registry=self.obs.registry)
        # prefill-complete requests awaiting migration (reset per run)
        self._ready: List[Request] = []

    def warmup(self) -> None:
        self.prefill.warmup()
        self.decode.warmup()

    @property
    def batchers(self):
        return (self.prefill_batcher, self.decode_batcher)

    @property
    def n_active(self) -> int:
        """Slots bound across both phase engines (parked ready slots
        included) — uniform with the colocated loop's ``n_active``."""
        return self.prefill.n_active + self.decode.n_active

    # ---- migration -------------------------------------------------------
    def _migrate(self, req: Request) -> bool:
        """Move a prefill-complete request onto the decode engine.  Returns
        False (leaving the request parked in its prefill slot) when the
        decode engine's token budget or pool cannot take it yet."""
        if self.decode.n_active >= self.decode_batcher.token_budget:
            return False
        prompt = req.prompt if self.decode.pool.prefix_sharing else None
        if not self.decode.pool.can_admit(req.total_tokens, prompt):
            return False
        tracer = self.obs.tracer
        h = (tracer.begin("handoff", track="requests", tid=req.rid,
                          cat="request")
             if tracer.enabled else None)
        state = self.prefill.export_slot(req.slot)
        written = self.prefill.pool.lease(req.rid).written_tokens
        self.prefill.release(req)
        req.slot = self.decode.pool.alloc(req.rid, req.total_tokens,
                                          prompt=prompt)
        # prefix coherence at the hand-off: blocks the decode-side index
        # already serves are shared (refcounted) rather than re-imported —
        # the snapshot's pages for them are dropped (bit-identical content
        # by the index's token verification) and a dest-side COW tail takes
        # its content from the snapshot page itself, so the pending pool
        # copy is consumed without a device copy.
        dst_lease = self.decode.pool.lease(req.rid)
        skip = dst_lease.shared_tokens // self.decode.pool.block_size
        self.decode.pool.consume_cow(req.rid)
        # the prefill engine already produced the first sample; the decode
        # engine owes the remaining gen - 1 steps
        self.decode.adopt(req, state, steps_total=req.max_new_tokens - 1,
                          skip_blocks=skip)
        # carry the KV-write accounting into the decode pool's ledger
        # (the lease already counts its shared tokens as written)
        self.decode.pool.note_write(
            req.rid,
            min(written, req.total_tokens) - dst_lease.written_tokens)
        req.state = RequestState.DECODE
        self.decode_batcher.n_admitted += 1      # migration ledger

        n_bytes = SlotEngine.state_nbytes(state)
        price = transfer_cost(n_bytes, self._prefill_dev, self._decode_dev,
                              link_bw=self._handoff_link_bw)
        self.handoff.record(n_bytes, price)
        if h is not None:
            tracer.end(h, args={"bytes": n_bytes,
                                "modeled_s": price.t_transfer,
                                "modeled_energy_j": price.energy_j})
        return True

    # ---- main loop -------------------------------------------------------
    def run(self, requests: List[Request], *,
            now_fn: Callable[[], float] = default_clock,
            max_steps: Optional[int] = None,
            on_delta: Optional[Callable[[StreamDelta], None]] = None
            ) -> ServeMetrics:
        """Serve `requests` via the shared open-loop driver.  ``on_delta``
        streams: the prefill engine emits each request's first sample at its
        phase boundary, the decode engine the rest."""
        return OpenLoopDriver(self).run(requests, now_fn=now_fn,
                                        max_steps=max_steps,
                                        on_delta=on_delta)

    # ---- OpenLoopDriver hooks --------------------------------------------
    def start_run(self) -> None:
        self._ready = []

    def in_flight(self) -> bool:
        return bool(self._ready or self.prefill.n_active
                    or self.decode.n_active)

    def runnable(self) -> bool:
        return bool(self.prefill.n_active or self.decode.n_active)

    def backlogged(self, queue: List[Request]) -> bool:
        # bursts stay short while hand-offs or queued arrivals wait so
        # migration latency is bounded
        return bool(queue or self._ready)

    def admit(self, queue: List[Request], now: float,
              metrics: ServeMetrics) -> None:
        # requests that can never fit the DECODE pool would park in a
        # prefill slot forever: shed them before admission
        i = 0
        while i < len(queue):
            r = queue[i]
            if (r.total_tokens > self.decode.pool.max_seq
                    or self.decode.pool.blocks_needed(r.total_tokens)
                    > self.decode.pool.total_blocks):
                r.state = RequestState.DROPPED
                metrics.drop()
                self.prefill_batcher.note_resolved(r.rid)
                if self.obs.tracer.enabled:
                    self.obs.tracer.instant(
                        "dropped", track="requests", tid=r.rid,
                        cat="request", args={"reason": "never-fits-decode"})
                queue.pop(i)
                continue
            i += 1

        # migrate phase-boundary requests (decode budget + pool gated)
        self._ready = [req for req in self._ready if not self._migrate(req)]

        # admit new arrivals into the prefill engine; ready requests
        # still hold prefill slots, so n_active covers them
        decision = self.prefill_batcher.admit(
            queue, self.prefill.n_active, now)
        metrics.drop(len(decision.dropped))
        for req in decision.admitted:
            # the first sample lands after plen steps (minus any
            # prefix-shared tokens, skipped by binding at an offset); the
            # rest of the generation belongs to the decode engine
            shared = self.prefill.pool.shared_tokens(req.rid)
            req.shared_tokens = shared
            self.prefill.bind(req, start_pos=shared,
                              steps_total=req.prompt_len - shared)
        trace_admission(self.obs, self.prefill_batcher, decision,
                        self.prefill.n_active)

    def dispatch(self, throttle: bool, budget: Optional[int]) -> int:
        # one burst per engine per driver iteration; parked (phase-boundary)
        # prefill slots are active but not burstable
        tracer, fb, wd = self.obs.tracer, self.obs.feedback, self.obs.watchdog
        n = 0
        for eng, batcher in ((self.prefill, self.prefill_batcher),
                             (self.decode, self.decode_batcher)):
            mask = eng.active & (eng.steps_done < eng.steps_total)
            if not mask.any():
                continue
            remaining = (eng.steps_total - eng.steps_done)[mask]
            burst = burst_size(
                int(remaining.min()), throttle=throttle,
                budget=None if budget is None else budget - n)
            if burst > 0:
                n_burst = int(mask.sum())
                h = (tracer.begin("burst", track=f"engine:{eng.name}",
                                  cat="engine",
                                  args={"steps": burst,
                                        "n_active": n_burst})
                     if tracer.enabled else None)
                # only decode bursts feed the cache: they run the per-token
                # decode network admission prices; prefill bursts do too
                # mathematically, but attributing them to the decode batch
                # size would double-count mixed iterations.  The watchdog
                # watches BOTH phases — each stream is keyed by its own
                # (engine, phase) batcher pricing, so there is no mixing
                feed = fb is not None and eng is self.decode
                timed = feed or wd is not None
                t0 = tracer.now() if timed else 0.0
                eng.dispatch(burst, mask)
                if timed:
                    eng.sync()
                    dt = tracer.now() - t0
                    if feed:
                        fb.observe_burst(n_burst, burst, dt)
                    if wd is not None:
                        wd.observe_burst(
                            eng.name, batcher.phase, n_tokens=n_burst,
                            steps=burst, elapsed_s=dt,
                            priced_step_s=batcher.priced_step_s(n_burst))
                if h is not None:
                    tracer.end(h, args={"synced": timed})
                n += burst
        return n

    def on_drift(self, alert, watchdog) -> None:
        """Watchdog action leg, disaggregated: re-price the drifted phase's
        admission AND re-run the placement DSE with that phase's device
        de-rated by the observed divergence.

        Both phase SlotEngines live in one process, so the fresh
        :func:`~repro.serving.placement.place_phases` decision is recorded
        as *advice* (trace ``reprice`` args + the watchdog report) rather
        than a hot engine swap; what actually changes mid-run is the
        batcher's pricing and token budget.
        """
        batcher = {"prefill": self.prefill_batcher,
                   "decode": self.decode_batcher}.get(alert.phase)
        if batcher is None:
            return
        fn, source = watchdog.step_time_fn(
            alert.engine, alert.phase, batcher.analytic_step_s)
        if source == "analytic":
            return
        detail = batcher.reprice(fn, source=source)
        detail.update(self._replace_placement(alert))
        watchdog.note_reprice(alert, detail)

    def _replace_placement(self, alert) -> Dict:
        """Re-run ``place_phases`` with the drifted device de-rated by the
        observed ratio; returns JSON-safe advice for the re-price event."""
        from .placement import drift_scaled_device, place_phases
        dev = (self._prefill_dev if alert.phase == "prefill"
               else self._decode_dev)
        try:
            scaled = drift_scaled_device(dev, alert.ewma_ratio)
            pool = self.decode.pool
            prompt_len = max(pool.max_seq // 2, 1)
            decision = place_phases(
                self.cfg, objective="latency", prompt_len=prompt_len,
                gen_len=max(pool.max_seq - prompt_len, 1),
                batch=pool.n_slots, link_bw=self._handoff_link_bw,
                device_overrides={self._placement_engine_name: scaled})
            return {"placement_advice": {
                        "prefill_engine": decision.prefill_engine,
                        "decode_engine": decision.decode_engine,
                        "colocated": decision.colocated,
                        "objective": decision.objective,
                        "value": float(decision.best.value)},
                    "drifted_device": scaled.name}
        except Exception as e:             # advice must never kill the run
            return {"placement_advice": None,
                    "placement_error": repr(e)}

    def sample(self, metrics: ServeMetrics) -> None:
        # capacity-weighted across the two pools: occupancy by total_blocks,
        # utilization by allocated-block capacity (an unweighted mean
        # misreports pressure when --prefill-slots != --slots)
        occ, util = sample_pools((self.prefill.pool, self.decode.pool))
        metrics.occupancy.append(occ)
        metrics.utilization.append(util)

    def scan(self, clock: Callable[[], float], metrics: ServeMetrics,
             sink: TokenSink) -> None:
        now = clock()
        # prefill completions -> phase boundary
        ready_rids = {r.rid for r in self._ready}
        for s, req in enumerate(self.prefill.slots):
            if req is None or req.rid in ready_rids:
                continue
            req.n_fed = int(self.prefill.steps_done[s]) + req.shared_tokens
            if self.prefill.steps_done[s] >= self.prefill.steps_total[s]:
                # the burst containing the first sample has been dispatched
                req.state = RequestState.DECODE
                req.t_first_dispatch = now
                trace_phase_flip(self.obs.tracer, req, now)
                self._ready.append(req)
        for s, req in enumerate(self.decode.slots):
            if req is not None:
                req.n_fed = req.prompt_len + int(self.decode.steps_done[s])
        # streaming: burst-boundary sync per engine — the prefill engine
        # emits first samples (including parked slots), the decode engine
        # the rest of each generation
        sink.drain(self.prefill, clock)
        sink.drain(self.decode, clock)
        # decode completions
        tracer = self.obs.tracer
        for s, req in enumerate(self.decode.slots):
            if req is None:
                continue
            if self.decode.steps_done[s] >= self.decode.steps_total[s]:
                h = (tracer.begin("sync", track="engine:decode",
                                  cat="engine", args={"kind": "completion"})
                     if tracer.enabled else None)
                row = self.decode.pull_output(s)
                if h is not None:
                    tracer.end(h)
                req.state = RequestState.DONE
                req.t_done = clock()
                sink.finish(req, row[:req.max_new_tokens], req.t_done)
                self.decode.release(req)
                metrics.observe(req)
                trace_completion(tracer, req)
