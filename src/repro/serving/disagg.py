"""Phase-disaggregated serving: prefill and decode on separate engines.

CNNLab offloads each network stage to the accelerator where its trade-off
wins (§III.A/IV); serving has exactly two stages — compute-bound prefill
and memory-bound decode — so the same split applies: a *prefill engine*
ingests prompts, and at the phase boundary each request's per-slot state
(KV rows, recurrent states, feed position, first sampled token) is
exported and imported into a *decode engine* that carries the generation.
The hand-off is the paper's offload overhead (PCIe sync, Fig. 5 step 4)
applied to the phase boundary: the loop meters the actual bytes it moves
and prices them with ``core.cost_model.transfer_cost`` on the two phases'
device models — the same model ``serving.placement`` uses to decide
whether the split is worth it at all.  Under the paged KV layout (the
default) the migrated snapshot is block-granular — only the pages holding
the prefilled tokens ship, not the slot's full ``max_seq`` reservation —
so the metered hand-off bytes scale with the prompt.

With a :class:`~repro.launch.mesh.DeviceAssignment` the two phase engines
are *physically* split: each engine's params, KV arenas and slot buffers
are committed to its assigned device, and the hand-off becomes an actual
inter-device copy.  That copy is **asynchronous and double-buffered**:
the prefill side exports the snapshot, dispatches ``jax.device_put``
toward the decode device (which returns immediately) and goes straight
back to bursting its next prompts, while the decode side adopts the slot
once the transfer resolves — at most :data:`MAX_PENDING_HANDOFFS`
transfers ride in flight.  The :class:`HandoffLedger` meters both sides
of the overlap: ``stall_s`` is the time adoption actually blocked on an
unresolved transfer, ``overlap_s`` the dispatch-to-adoption window the
copy had to hide in.  Setting ``async_handoff=False`` adopts immediately
after dispatch — the synchronous baseline whose stall is the full
transfer, which the multidevice benchmark compares against.

Each phase owns its own KV pool and its own :class:`ContinuousBatcher`,
so admission and migration are budgeted per (phase, engine) pair: queued
requests enter prefill against the prefill engine's token budget; prefill-
complete requests migrate only when the decode engine's budget and pool
admit them (until then they hold their prefill slot — natural back-
pressure on admission).

The PR 7 watchdog's placement advice can also **actuate** here: when the
two phases price on distinct DSE engines, a drift alert re-runs
``place_phases`` with the drifted device de-rated, and if the fresh
decision moves the decode phase onto the *other* hosted engine the loop
switches its decode target mid-run — in-flight decode slots live-migrate
through the same export/import machinery (capacity-permitting; the rest
finish where they are), and later phase boundaries flip in place instead
of handing off.  All of it is scheduling: per-request greedy outputs are
engine- and schedule-independent.

Per-request outputs are bit-identical to the colocated
:class:`~repro.serving.engine_loop.EngineLoop` (and therefore to the
static server): the migrated snapshot is exact, and the per-slot step math
is engine-independent.  ``tests/test_placement.py`` asserts it.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from ..core import device_models
from ..core.cost_model import transfer_cost
from ..launch.mesh import DeviceAssignment
from ..models import transformer as T
from ..obs import MetricsRegistry, Observability, default_clock
from .batcher import ContinuousBatcher
from .driver import (OpenLoopDriver, ServeMetrics, StreamDelta, TokenSink,
                     burst_size, sample_pools)
from .engine_loop import (SlotEngine, snapshot_ready, snapshot_wait,
                          state_to_device, trace_admission, trace_completion,
                          trace_phase_flip, wire_pool_events)
from .kv_pool import KVPool
from .request import Request, RequestState
from .speculative import SpecPlan, SpeculativeDecoder, spec_dispatch

# double-buffering bound: at most this many dispatched-but-unadopted
# hand-offs ride in flight before the next dispatch blocks on the oldest
MAX_PENDING_HANDOFFS = 2


class HandoffLedger:
    """What the phase boundary actually moved, plus its modeled price.

    A thin view over the metrics registry's ``handoff_*`` counters: the
    loop's ``.handoff`` attribute keeps its historical read surface
    (``n_handoffs``, ``bytes_moved``, ``modeled_s``, ``modeled_energy_j``,
    ``stats()``) while the values themselves live in the same registry
    snapshot/time-series stream as KV occupancy and queue depth instead of
    a parallel ad-hoc ledger.

    The async hand-off adds the overlap accounting: ``stall_s`` sums the
    time adoptions actually blocked waiting on an in-flight transfer,
    ``overlap_s`` the dispatch-to-adoption windows the transfers had to
    hide in (synchronous hand-offs stall for the whole copy and overlap
    ~nothing — the measured baseline).  ``n_live_migrations`` counts
    hand-offs that moved an *in-flight decode* slot between engines (the
    watchdog's placement actuation) rather than a phase boundary.
    """

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        if registry is None:
            registry = MetricsRegistry()  # standalone view (tests)
        self._n = registry.counter("handoff_n")
        self._bytes = registry.counter("handoff_bytes")
        self._modeled_s = registry.counter("handoff_modeled_s")
        self._energy_j = registry.counter("handoff_modeled_energy_j")
        self._stall_s = registry.counter("handoff_stall_s")
        self._overlap_s = registry.counter("handoff_overlap_s")
        self._live = registry.counter("handoff_live_migrations")

    def record(self, n_bytes: int, price, *, stall_s: float = 0.0,
               overlap_s: float = 0.0, live: bool = False) -> None:
        """Account one hand-off: metered bytes + its transfer-cost price,
        plus the measured stall/overlap split of the actual copy."""
        self._n.inc()
        self._bytes.inc(n_bytes)
        self._modeled_s.inc(price.t_transfer)
        self._energy_j.inc(price.energy_j)
        self._stall_s.inc(max(stall_s, 0.0))
        self._overlap_s.inc(max(overlap_s, 0.0))
        if live:
            self._live.inc()

    @property
    def n_handoffs(self) -> int:
        return int(self._n.value)

    @property
    def bytes_moved(self) -> int:
        return int(self._bytes.value)

    @property
    def modeled_s(self) -> float:
        return self._modeled_s.value

    @property
    def modeled_energy_j(self) -> float:
        return self._energy_j.value

    @property
    def stall_s(self) -> float:
        return self._stall_s.value

    @property
    def overlap_s(self) -> float:
        return self._overlap_s.value

    @property
    def n_live_migrations(self) -> int:
        return int(self._live.value)

    def stats(self) -> Dict[str, float]:
        return {
            "n_handoffs": self.n_handoffs,
            "bytes_moved": self.bytes_moved,
            "modeled_s": self.modeled_s,
            "modeled_energy_j": self.modeled_energy_j,
            "stall_s": self.stall_s,
            "overlap_s": self.overlap_s,
            "n_live_migrations": self.n_live_migrations,
        }


@dataclasses.dataclass
class _PendingHandoff:
    """One dispatched-but-unadopted phase hand-off: the snapshot is (or
    may still be) in flight toward the decode device; the decode pool
    lease already exists (``req.slot``), the prefill slot is released."""

    req: Request
    state: Dict
    written: int                 # src-lease written tokens at export
    dst_written0: int            # dst-lease pre-adoption (shared) tokens
    skip_blocks: int             # prefix-shared leading pages, not landed
    steps_total: int             # decode steps the adopting engine owes
    t_dispatch: float            # tracer-clock stamp at dispatch
    span: Optional[object]       # open "handoff" tracer span


class DisaggregatedEngineLoop:
    """Two SlotEngines (prefill + decode) with explicit slot migration.

    The open-loop scaffolding lives in :class:`~repro.serving.driver.
    OpenLoopDriver` (shared with the colocated loop); this class provides
    the two-engine hook implementations: admission binds the prefill phase
    only, the completion scan detects the phase boundary, and migration at
    admission passes carries slots onto the decode engine.
    """

    def __init__(self, cfg: T.ModelConfig, params, *, n_prefill_slots: int,
                 n_decode_slots: int, max_seq: int, block_size: int = 16,
                 kv_layout: str = "paged",
                 prefill_total_blocks: Optional[int] = None,
                 decode_total_blocks: Optional[int] = None,
                 prefill_device_name: str = "tpu-v5e",
                 decode_device_name: str = "tpu-v5e",
                 prefill_device: Optional[device_models.DeviceModel] = None,
                 decode_device: Optional[device_models.DeviceModel] = None,
                 step_slo_s: Optional[float] = None,
                 handoff_link_bw: Optional[float] = None,
                 placement_engine_name: str = "xla",
                 prefill_placement_engine_name: Optional[str] = None,
                 decode_placement_engine_name: Optional[str] = None,
                 assignment: Optional[DeviceAssignment] = None,
                 async_handoff: bool = True,
                 prefix_sharing: bool = False,
                 plan: Optional[SpecPlan] = None,
                 propose_override: Optional[Callable] = None,
                 obs: Optional[Observability] = None):
        if prefix_sharing:
            if kv_layout != "paged":
                raise ValueError("prefix sharing maps physical pages — it "
                                 "requires kv_layout='paged'")
            if any(t != "attn" for t in cfg.layer_types()):
                raise ValueError(
                    "prefix sharing requires an all-attention config: "
                    "recurrent/cross layer state is slot-local and cannot "
                    "be reconstructed from shared KV pages")
        self.cfg = cfg
        self.kv_layout = kv_layout
        self.prefix_sharing = prefix_sharing
        self.assignment = assignment
        self.obs = obs if obs is not None else Observability()
        # each phase pool runs its own prefix index: the prefill index
        # serves admission (prefill skipping), the decode index dedupes
        # migrated prompts so sharers land only their unique pages
        prefill_pool = KVPool(n_prefill_slots, max_seq, block_size=block_size,
                              total_blocks=prefill_total_blocks,
                              prefix_sharing=prefix_sharing)
        decode_pool = KVPool(n_decode_slots, max_seq, block_size=block_size,
                             total_blocks=decode_total_blocks,
                             prefix_sharing=prefix_sharing)
        self.prefill = SlotEngine(
            cfg, params, prefill_pool, kv_layout=kv_layout, name="prefill",
            device=None if assignment is None else assignment.prefill)
        self.decode = SlotEngine(
            cfg, params, decode_pool, kv_layout=kv_layout, name="decode",
            device=None if assignment is None else assignment.decode)
        wire_pool_events(prefill_pool, self.obs.tracer)
        wire_pool_events(decode_pool, self.obs.tracer)
        self.prefill_batcher = ContinuousBatcher(
            cfg, prefill_pool, phase="prefill",
            device_name=prefill_device_name, device_model=prefill_device,
            step_slo_s=step_slo_s)
        self.decode_batcher = ContinuousBatcher(
            cfg, decode_pool, phase="decode",
            device_name=decode_device_name, device_model=decode_device,
            step_slo_s=step_slo_s)
        self._prefill_dev = (prefill_device
                             or device_models.get(prefill_device_name))
        self._decode_dev = (decode_device
                            or device_models.get(decode_device_name))
        self._handoff_link_bw = handoff_link_bw
        # speculative decoding rides the decode engine only (prefill has
        # no decode-phase slots); while speculating, placement actuation
        # and live migration are disabled — the draft engine's cache is
        # pinned to the decode engine and a mid-round migration would
        # orphan it
        self.spec = (SpeculativeDecoder(self.decode, plan,
                                        propose_override=propose_override)
                     if plan is not None else None)
        # the DSE candidates the in-process SlotEngines actually execute
        # on; the watchdog's mid-run placement re-run de-rates the drifted
        # phase's engine.  With one shared name the decision stays advice;
        # with distinct per-phase names it ACTUATES (_actuate_placement)
        self._placement_engine_name = placement_engine_name
        self._prefill_placement_name = (prefill_placement_engine_name
                                        or placement_engine_name)
        self._decode_placement_name = (decode_placement_engine_name
                                       or placement_engine_name)
        self._async_handoff = async_handoff
        # which hosted engine currently serves the decode phase: "decode"
        # (hand-off at the boundary) or "prefill" (flip in place) — the
        # watchdog's placement actuation switches this mid-run
        self._decode_target = "decode"
        self.handoff = HandoffLedger(registry=self.obs.registry)
        # prefill-complete requests awaiting migration (reset per run)
        self._ready: List[Request] = []
        # dispatched hand-offs whose transfer may still be in flight
        self._pending: List[_PendingHandoff] = []
        # rid -> n_fed at live-migration export: steps_done restarts at 0
        # on the adopting engine, so fed accounting resumes from this base
        self._fed_base: Dict[int, int] = {}

    def warmup(self) -> None:
        self.prefill.warmup()
        self.decode.warmup()

    @property
    def batchers(self):
        return (self.prefill_batcher, self.decode_batcher)

    @property
    def n_active(self) -> int:
        """Slots bound across both phase engines (parked ready slots and
        in-flight hand-offs included) — uniform with the colocated loop's
        ``n_active``."""
        return (self.prefill.n_active + self.decode.n_active
                + len(self._pending))

    @property
    def decode_target(self) -> str:
        """Which hosted engine currently serves the decode phase."""
        return self._decode_target

    # ---- migration -------------------------------------------------------
    def _dispatch_handoff(self, req: Request) -> bool:
        """Start moving a prefill-complete request onto the decode engine.
        Returns False (leaving the request parked in its prefill slot) when
        the decode engine's token budget or pool cannot take it yet.

        This is the *dispatch* half of the hand-off: export the snapshot,
        start the ``device_put`` toward the decode device (returns
        immediately) and release the prefill slot — the prefill engine goes
        straight back to bursting.  Adoption happens in :meth:`_adopt` once
        the transfer resolves (or immediately, when ``async_handoff`` is
        off).  At most :data:`MAX_PENDING_HANDOFFS` dispatched transfers
        ride in flight; past that the oldest is adopted first (blocking) —
        the double-buffering bound."""
        if (self.decode.n_active + len(self._pending)
                >= self.decode_batcher.token_budget):
            return False
        prompt = req.prompt if self.decode.pool.prefix_sharing else None
        if not self.decode.pool.can_admit(req.total_tokens, prompt):
            return False
        while len(self._pending) >= MAX_PENDING_HANDOFFS:
            self._adopt(self._pending.pop(0))
        tracer = self.obs.tracer
        h = (tracer.begin("handoff", track="requests", tid=req.rid,
                          cat="request")
             if tracer.enabled else None)
        state = self.prefill.export_slot(req.slot)
        written = self.prefill.pool.lease(req.rid).written_tokens
        self.prefill.release(req)
        req.slot = self.decode.pool.alloc(req.rid, req.total_tokens,
                                          prompt=prompt)
        # prefix coherence at the hand-off: blocks the decode-side index
        # already serves are shared (refcounted) rather than re-imported —
        # the snapshot's pages for them are dropped (bit-identical content
        # by the index's token verification) and a dest-side COW tail takes
        # its content from the snapshot page itself, so the pending pool
        # copy is consumed without a device copy.
        dst_lease = self.decode.pool.lease(req.rid)
        skip = dst_lease.shared_tokens // self.decode.pool.block_size
        self.decode.pool.consume_cow(req.rid)
        if self.decode.device is not None:
            # async dispatch: device_put returns immediately; the copy
            # drains toward the decode device while prefill keeps bursting
            state = state_to_device(state, self.decode.device)
        # the prefill engine already produced the first sample; the decode
        # engine owes the remaining gen - 1 steps
        self._pending.append(_PendingHandoff(
            req=req, state=state, written=written,
            dst_written0=dst_lease.written_tokens, skip_blocks=skip,
            steps_total=req.max_new_tokens - 1,
            t_dispatch=tracer.now(), span=h))
        if not self._async_handoff:
            self._adopt(self._pending.pop())
        return True

    def _adopt(self, ph: _PendingHandoff) -> None:
        """Adoption half of the hand-off: wait out whatever part of the
        transfer is still in flight (the measured *stall*), install the
        snapshot into the decode slot and account the hand-off."""
        tracer = self.obs.tracer
        t0 = tracer.now()
        snapshot_wait(ph.state)
        stall = tracer.now() - t0
        # the window the copy had to hide in: dispatch -> adoption start
        overlap = max(t0 - ph.t_dispatch, 0.0)
        req = ph.req
        self.decode.adopt(req, ph.state, steps_total=ph.steps_total,
                          skip_blocks=ph.skip_blocks)
        if self.spec is not None:
            # fresh draft mirror for the adopted slot; the draft replays
            # the committed chain from the imported prompt/output buffers
            # at its first speculative round
            self.spec.reset_slot(req.slot)
        # carry the KV-write accounting into the decode pool's ledger
        # (the lease already counts its shared tokens as written)
        self.decode.pool.note_write(
            req.rid, min(ph.written, req.total_tokens) - ph.dst_written0)
        req.state = RequestState.DECODE
        self.decode_batcher.n_admitted += 1      # migration ledger
        n_bytes = SlotEngine.state_nbytes(ph.state)
        price = transfer_cost(n_bytes, self._prefill_dev, self._decode_dev,
                              link_bw=self._handoff_link_bw)
        self.handoff.record(n_bytes, price, stall_s=stall, overlap_s=overlap)
        if ph.span is not None:
            tracer.end(ph.span, args={"bytes": n_bytes,
                                      "modeled_s": price.t_transfer,
                                      "modeled_energy_j": price.energy_j,
                                      "stall_s": stall,
                                      "overlap_s": overlap,
                                      "async": self._async_handoff})

    def _drain_handoffs(self, *, force_all: bool = False) -> None:
        """Adopt dispatched hand-offs, oldest first: every one whose
        transfer has resolved, plus (blocking) while the pipeline is over
        the double-buffer bound, the decode engine sits idle, or the
        caller forces a full drain."""
        while self._pending:
            must = (force_all or len(self._pending) > MAX_PENDING_HANDOFFS
                    or self.decode.n_active == 0)
            if not must and not snapshot_ready(self._pending[0].state):
                break
            self._adopt(self._pending.pop(0))

    def _live_migrate(self, target: str) -> int:
        """Move in-flight DECODE slots onto the ``target`` engine through
        the same export/import machinery the phase boundary uses —
        synchronously, so the request resumes immediately.  Slots the
        destination cannot take (budget/pool) finish where they are.
        Returns the number of slots moved."""
        src = self.decode if target == "prefill" else self.prefill
        dst = self.prefill if target == "prefill" else self.decode
        dst_batcher = (self.prefill_batcher if target == "prefill"
                       else self.decode_batcher)
        src_dev = (self._decode_dev if target == "prefill"
                   else self._prefill_dev)
        dst_dev = (self._prefill_dev if target == "prefill"
                   else self._decode_dev)
        skip_rids = ({r.rid for r in self._ready}
                     | {ph.req.rid for ph in self._pending})
        tracer = self.obs.tracer
        moved = 0
        for s, req in enumerate(list(src.slots)):
            if (req is None or req.state is not RequestState.DECODE
                    or req.rid in skip_rids):
                continue
            remaining = int(src.steps_total[s] - src.steps_done[s])
            if remaining <= 0:
                continue                 # completes where it is
            if dst.n_active >= dst_batcher.token_budget:
                continue                 # budget-limited: finish in place
            prompt = req.prompt if dst.pool.prefix_sharing else None
            if not dst.pool.can_admit(req.total_tokens, prompt):
                continue                 # pool-limited: finish in place
            h = (tracer.begin("handoff", track="requests", tid=req.rid,
                              cat="request")
                 if tracer.enabled else None)
            # fed accounting resumes from the steps already run here
            base = self._fed_base.get(req.rid)
            if src is self.decode:
                fed_base = ((req.prompt_len if base is None else base)
                            + int(src.steps_done[s]))
            else:
                fed_base = ((req.shared_tokens if base is None else base)
                            + int(src.steps_done[s]))
            state = src.export_slot(s)
            written = src.pool.lease(req.rid).written_tokens
            src.release(req)
            req.slot = dst.pool.alloc(req.rid, req.total_tokens,
                                      prompt=prompt)
            dst_lease = dst.pool.lease(req.rid)
            skip = dst_lease.shared_tokens // dst.pool.block_size
            dst.pool.consume_cow(req.rid)
            if dst.device is not None:
                state = state_to_device(state, dst.device)
            t0 = tracer.now()
            snapshot_wait(state)
            stall = tracer.now() - t0
            dst.adopt(req, state, steps_total=remaining, skip_blocks=skip)
            dst.pool.note_write(
                req.rid,
                min(written, req.total_tokens) - dst_lease.written_tokens)
            self._fed_base[req.rid] = fed_base
            n_bytes = SlotEngine.state_nbytes(state)
            price = transfer_cost(n_bytes, src_dev, dst_dev,
                                  link_bw=self._handoff_link_bw)
            self.handoff.record(n_bytes, price, stall_s=stall, live=True)
            moved += 1
            if h is not None:
                tracer.end(h, args={"bytes": n_bytes,
                                    "modeled_s": price.t_transfer,
                                    "kind": "live-migration",
                                    "from": src.name, "to": dst.name,
                                    "remaining_steps": remaining})
        return moved

    # ---- main loop -------------------------------------------------------
    def run(self, requests: List[Request], *,
            now_fn: Callable[[], float] = default_clock,
            max_steps: Optional[int] = None,
            on_delta: Optional[Callable[[StreamDelta], None]] = None
            ) -> ServeMetrics:
        """Serve `requests` via the shared open-loop driver.  ``on_delta``
        streams: the prefill engine emits each request's first sample at its
        phase boundary, the decode engine the rest."""
        return OpenLoopDriver(self).run(requests, now_fn=now_fn,
                                        max_steps=max_steps,
                                        on_delta=on_delta)

    # ---- OpenLoopDriver hooks --------------------------------------------
    def start_run(self) -> None:
        self._ready = []
        self._pending = []
        self._fed_base = {}

    def in_flight(self) -> bool:
        return bool(self._ready or self._pending or self.prefill.n_active
                    or self.decode.n_active)

    def runnable(self) -> bool:
        return bool(self.prefill.n_active or self.decode.n_active)

    def backlogged(self, queue: List[Request]) -> bool:
        # bursts stay short while hand-offs or queued arrivals wait so
        # migration latency is bounded
        return bool(queue or self._ready or self._pending)

    def admit(self, queue: List[Request], now: float,
              metrics: ServeMetrics) -> None:
        # requests that can never fit the DECODE pool would park in a
        # prefill slot forever: shed them before admission
        i = 0
        while i < len(queue):
            r = queue[i]
            if (r.total_tokens > self.decode.pool.max_seq
                    or self.decode.pool.blocks_needed(r.total_tokens)
                    > self.decode.pool.total_blocks):
                r.state = RequestState.DROPPED
                metrics.drop()
                self.prefill_batcher.note_resolved(r.rid)
                if self.obs.tracer.enabled:
                    self.obs.tracer.instant(
                        "dropped", track="requests", tid=r.rid,
                        cat="request", args={"reason": "never-fits-decode"})
                queue.pop(i)
                continue
            i += 1

        # adopt resolved in-flight hand-offs before dispatching new ones
        self._drain_handoffs()

        # migrate phase-boundary requests (decode budget + pool gated) —
        # or, when placement actuation moved the decode phase onto the
        # prefill engine, resume them in place (colocated step math)
        if self._decode_target == "prefill":
            for req in self._ready:
                self.prefill.steps_total[req.slot] += req.max_new_tokens - 1
            self._ready = []
        else:
            self._ready = [req for req in self._ready
                           if not self._dispatch_handoff(req)]

        # admit new arrivals into the prefill engine; ready requests
        # still hold prefill slots, so n_active covers them
        decision = self.prefill_batcher.admit(
            queue, self.prefill.n_active, now)
        metrics.drop(len(decision.dropped))
        for req in decision.admitted:
            # the first sample lands after plen steps (minus any
            # prefix-shared tokens, skipped by binding at an offset); the
            # rest of the generation belongs to the decode engine
            shared = self.prefill.pool.shared_tokens(req.rid)
            req.shared_tokens = shared
            self.prefill.bind(req, start_pos=shared,
                              steps_total=req.prompt_len - shared)
        trace_admission(self.obs, self.prefill_batcher, decision,
                        self.prefill.n_active)

    def dispatch(self, throttle: bool, budget: Optional[int]) -> int:
        # one burst per engine per driver iteration; parked (phase-boundary)
        # prefill slots are active but not burstable
        tracer, fb, wd = self.obs.tracer, self.obs.feedback, self.obs.watchdog
        n = 0
        for eng, batcher in ((self.prefill, self.prefill_batcher),
                             (self.decode, self.decode_batcher)):
            mask = eng.active & (eng.steps_done < eng.steps_total)
            if not mask.any():
                continue
            if (eng is self.decode and self.spec is not None
                    and self.spec.enabled):
                rem = eng.steps_total - eng.steps_done
                if (rem[mask] >= self.spec.plan.k).all():
                    # every burstable decode slot clears the page-lease
                    # gate: one speculative round instead of a plain burst
                    plens = np.array([0 if r is None else r.prompt_len
                                      for r in eng.slots], np.int64)
                    n += spec_dispatch(
                        self.spec, eng, eng.pool, batcher, self.obs,
                        mask=mask, pos=plens + eng.steps_done, rem=rem,
                        budget=None if budget is None else budget - n)
                    continue
            remaining = (eng.steps_total - eng.steps_done)[mask]
            burst = burst_size(
                int(remaining.min()), throttle=throttle,
                budget=None if budget is None else budget - n)
            if burst > 0:
                n_burst = int(mask.sum())
                h = (tracer.begin("burst", track=f"engine:{eng.name}",
                                  cat="engine",
                                  args={"steps": burst,
                                        "n_active": n_burst})
                     if tracer.enabled else None)
                # only decode bursts feed the cache: they run the per-token
                # decode network admission prices; prefill bursts do too
                # mathematically, but attributing them to the decode batch
                # size would double-count mixed iterations.  The watchdog
                # watches BOTH phases — each stream is keyed by its own
                # (engine, phase) batcher pricing, so there is no mixing
                feed = fb is not None and eng is self.decode
                timed = feed or wd is not None
                t0 = tracer.now() if timed else 0.0
                eng.dispatch(burst, mask)
                if timed:
                    eng.sync()
                    dt = tracer.now() - t0
                    if feed:
                        fb.observe_burst(n_burst, burst, dt)
                    if wd is not None:
                        wd.observe_burst(
                            eng.name, batcher.phase, n_tokens=n_burst,
                            steps=burst, elapsed_s=dt,
                            priced_step_s=batcher.priced_step_s(n_burst))
                if h is not None:
                    tracer.end(h, args={"synced": timed})
                n += burst
        return n

    def on_drift(self, alert, watchdog) -> None:
        """Watchdog action leg, disaggregated: re-price the drifted phase's
        admission AND re-run the placement DSE with that phase's device
        de-rated by the observed divergence.

        When both phases price on one DSE engine the fresh
        :func:`~repro.serving.placement.place_phases` decision is recorded
        as *advice* (trace ``reprice`` args + the watchdog report); with
        distinct per-phase engine names the decision ACTUATES — if it
        moves the decode phase onto the other hosted engine, the loop
        switches its decode target and live-migrates in-flight slots
        (:meth:`_live_migrate`).
        """
        batcher = {"prefill": self.prefill_batcher,
                   "decode": self.decode_batcher}.get(alert.phase)
        if batcher is None:
            return
        fn, source = watchdog.step_time_fn(
            alert.engine, alert.phase, batcher.analytic_step_s)
        if source == "analytic":
            return
        detail = batcher.reprice(fn, source=source)
        detail.update(self._replace_placement(alert))
        watchdog.note_reprice(alert, detail)

    def _replace_placement(self, alert) -> Dict:
        """Re-run ``place_phases`` with the drifted device de-rated by the
        observed ratio; returns JSON-safe advice for the re-price event
        (plus what, if anything, was actuated)."""
        from .placement import drift_scaled_device, place_phases
        drifted_phase = ("prefill" if alert.phase == "prefill" else "decode")
        name = (self._prefill_placement_name if drifted_phase == "prefill"
                else self._decode_placement_name)
        dev = (self._prefill_dev if drifted_phase == "prefill"
               else self._decode_dev)
        try:
            scaled = drift_scaled_device(dev, alert.ewma_ratio)
            # both hosted engines enter the DSE on their actual device
            # models, the drifted one de-rated
            overrides = {self._prefill_placement_name: self._prefill_dev,
                         self._decode_placement_name: self._decode_dev}
            overrides[name] = scaled
            # with distinct per-phase engines the decision is meant to
            # actuate, so the DSE is restricted to the hosted pair — a
            # third engine we cannot run on would turn every decision
            # into unactionable advice
            engines = None
            if (self._prefill_placement_name
                    != self._decode_placement_name):
                from ..core.engines import ENGINES_BY_NAME
                hosted = [ENGINES_BY_NAME[n]
                          for n in (self._prefill_placement_name,
                                    self._decode_placement_name)
                          if n in ENGINES_BY_NAME]
                engines = hosted if len(hosted) == 2 else None
            pool = self.decode.pool
            prompt_len = max(pool.max_seq // 2, 1)
            decision = place_phases(
                self.cfg, engines, objective="latency",
                prompt_len=prompt_len,
                gen_len=max(pool.max_seq - prompt_len, 1),
                batch=pool.n_slots, link_bw=self._handoff_link_bw,
                device_overrides=overrides)
            advice = {"placement_advice": {
                          "prefill_engine": decision.prefill_engine,
                          "decode_engine": decision.decode_engine,
                          "colocated": decision.colocated,
                          "objective": decision.objective,
                          "value": float(decision.best.value)},
                      "drifted_device": scaled.name}
            advice.update(self._actuate_placement(decision))
            return advice
        except Exception as e:             # advice must never kill the run
            return {"placement_advice": None,
                    "placement_error": repr(e)}

    def _actuate_placement(self, decision) -> Dict:
        """Turn a fresh placement decision into a mid-run engine switch.

        Only possible when the two phases price on *distinct* DSE engine
        names (otherwise the decision cannot be mapped onto the hosted
        engines and stays advice).  If the decision's decode engine is one
        of the hosted pair and differs from the current decode target: the
        pipeline drains, the target flips, and in-flight decode slots
        live-migrate (capacity-permitting)."""
        if self.spec is not None:
            return {"actuated": False,
                    "reason": "speculative decoding pins the decode engine"}
        if self._prefill_placement_name == self._decode_placement_name:
            return {"actuated": False, "reason": "single-engine placement"}
        target = {self._decode_placement_name: "decode",
                  self._prefill_placement_name: "prefill"}.get(
                      decision.decode_engine)
        if target is None:
            return {"actuated": False,
                    "reason": f"decode engine {decision.decode_engine!r} "
                              f"is not hosted"}
        if target == self._decode_target:
            return {"actuated": False, "decode_target": target}
        self._drain_handoffs(force_all=True)
        self._decode_target = target
        moved = self._live_migrate(target)
        if self.obs.tracer.enabled:
            self.obs.tracer.instant(
                "placement_actuated", track="server", cat="watchdog",
                args={"decode_target": target, "live_migrations": moved})
        return {"actuated": True, "decode_target": target,
                "live_migrations": moved}

    def sample(self, metrics: ServeMetrics) -> None:
        # capacity-weighted across the two pools: occupancy by total_blocks,
        # utilization by allocated-block capacity (an unweighted mean
        # misreports pressure when --prefill-slots != --slots)
        occ, util = sample_pools((self.prefill.pool, self.decode.pool))
        metrics.occupancy.append(occ)
        metrics.utilization.append(util)

    def scan(self, clock: Callable[[], float], metrics: ServeMetrics,
             sink: TokenSink) -> None:
        now = clock()
        # prefill completions -> phase boundary (or in-place flip when the
        # decode target is the prefill engine itself)
        ready_rids = {r.rid for r in self._ready}
        for s, req in enumerate(self.prefill.slots):
            if req is None or req.rid in ready_rids:
                continue
            base = self._fed_base.get(req.rid)
            if base is not None:         # live-migrated decode slot here
                req.n_fed = base + int(self.prefill.steps_done[s])
            else:
                req.n_fed = int(self.prefill.steps_done[s]) \
                    + req.shared_tokens
            if (req.state is not RequestState.DECODE
                    and self.prefill.steps_done[s]
                    >= self.prefill.steps_total[s]):
                # the burst containing the first sample has been dispatched
                req.state = RequestState.DECODE
                req.t_first_dispatch = now
                trace_phase_flip(self.obs.tracer, req, now)
                if self._decode_target == "prefill":
                    # actuated placement: the prefill engine carries the
                    # decode phase in place (colocated step math — no
                    # hand-off, bit-identical by construction)
                    self.prefill.steps_total[s] += req.max_new_tokens - 1
                else:
                    self._ready.append(req)
                    ready_rids.add(req.rid)
        for s, req in enumerate(self.decode.slots):
            if req is not None:
                base = self._fed_base.get(req.rid, req.prompt_len)
                req.n_fed = base + int(self.decode.steps_done[s])
        # streaming: burst-boundary sync per engine — the prefill engine
        # emits first samples (including parked slots), the decode engine
        # the rest of each generation
        sink.drain(self.prefill, clock)
        sink.drain(self.decode, clock)
        # decode completions — on whichever engine carries the slot now
        tracer = self.obs.tracer
        for eng in (self.decode, self.prefill):
            for s, req in enumerate(eng.slots):
                if (req is None or req.state is not RequestState.DECODE
                        or req.rid in ready_rids):
                    continue
                if eng.steps_done[s] >= eng.steps_total[s]:
                    h = (tracer.begin("sync", track=f"engine:{eng.name}",
                                      cat="engine",
                                      args={"kind": "completion"})
                         if tracer.enabled else None)
                    row = eng.pull_output(s)
                    if h is not None:
                        tracer.end(h)
                    req.state = RequestState.DONE
                    req.t_done = clock()
                    sink.finish(req, row[:req.max_new_tokens], req.t_done)
                    eng.release(req)
                    self._fed_base.pop(req.rid, None)
                    metrics.observe(req)
                    trace_completion(tracer, req)
