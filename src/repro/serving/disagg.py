"""Phase-disaggregated serving: prefill and decode on separate engines.

CNNLab offloads each network stage to the accelerator where its trade-off
wins (§III.A/IV); serving has exactly two stages — compute-bound prefill
and memory-bound decode — so the same split applies: a *prefill engine*
ingests prompts, and at the phase boundary each request's per-slot state
(KV rows, recurrent states, feed position, first sampled token) is
exported and imported into a *decode engine* that carries the generation.
The hand-off is the paper's offload overhead (PCIe sync, Fig. 5 step 4)
applied to the phase boundary: the loop meters the actual bytes it moves
and prices them with ``core.cost_model.transfer_cost`` on the two phases'
device models — the same model ``serving.placement`` uses to decide
whether the split is worth it at all.

Each phase owns its own KV pool and its own :class:`ContinuousBatcher`,
so admission and migration are budgeted per (phase, engine) pair: queued
requests enter prefill against the prefill engine's token budget; prefill-
complete requests migrate only when the decode engine's budget and pool
admit them (until then they hold their prefill slot — natural back-
pressure on admission).

Per-request outputs are bit-identical to the colocated
:class:`~repro.serving.engine_loop.EngineLoop` (and therefore to the
static server): the migrated snapshot is exact, and the per-slot step math
is engine-independent.  ``tests/test_placement.py`` asserts it.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from ..core import device_models
from ..core.cost_model import transfer_cost
from ..models import transformer as T
from .batcher import ContinuousBatcher
from .engine_loop import ServeMetrics, SlotEngine
from .kv_pool import KVPool
from .request import Request, RequestState


@dataclasses.dataclass
class HandoffLedger:
    """What the phase boundary actually moved, plus its modeled price."""

    n_handoffs: int = 0
    bytes_moved: int = 0
    modeled_s: float = 0.0
    modeled_energy_j: float = 0.0

    def stats(self) -> Dict[str, float]:
        return {
            "n_handoffs": self.n_handoffs,
            "bytes_moved": self.bytes_moved,
            "modeled_s": self.modeled_s,
            "modeled_energy_j": self.modeled_energy_j,
        }


class DisaggregatedEngineLoop:
    """Two SlotEngines (prefill + decode) with explicit slot migration."""

    BURST_CAP_PENDING = 4

    def __init__(self, cfg: T.ModelConfig, params, *, n_prefill_slots: int,
                 n_decode_slots: int, max_seq: int, block_size: int = 16,
                 prefill_device_name: str = "tpu-v5e",
                 decode_device_name: str = "tpu-v5e",
                 prefill_device: Optional[device_models.DeviceModel] = None,
                 decode_device: Optional[device_models.DeviceModel] = None,
                 step_slo_s: Optional[float] = None,
                 handoff_link_bw: Optional[float] = None):
        self.cfg = cfg
        prefill_pool = KVPool(n_prefill_slots, max_seq, block_size=block_size)
        decode_pool = KVPool(n_decode_slots, max_seq, block_size=block_size)
        self.prefill = SlotEngine(cfg, params, prefill_pool)
        self.decode = SlotEngine(cfg, params, decode_pool)
        self.prefill_batcher = ContinuousBatcher(
            cfg, prefill_pool, phase="prefill",
            device_name=prefill_device_name, device_model=prefill_device,
            step_slo_s=step_slo_s)
        self.decode_batcher = ContinuousBatcher(
            cfg, decode_pool, phase="decode",
            device_name=decode_device_name, device_model=decode_device,
            step_slo_s=step_slo_s)
        self._prefill_dev = (prefill_device
                             or device_models.get(prefill_device_name))
        self._decode_dev = (decode_device
                            or device_models.get(decode_device_name))
        self._handoff_link_bw = handoff_link_bw
        self.handoff = HandoffLedger()

    def warmup(self) -> None:
        self.prefill.warmup()
        self.decode.warmup()

    @property
    def batchers(self):
        return (self.prefill_batcher, self.decode_batcher)

    # ---- migration -------------------------------------------------------
    def _migrate(self, req: Request, prefill_active: np.ndarray,
                 decode_active: np.ndarray) -> bool:
        """Move a prefill-complete request onto the decode engine.  Returns
        False (leaving the request parked in its prefill slot) when the
        decode engine's token budget or pool cannot take it yet."""
        if self.decode.n_active >= self.decode_batcher.token_budget:
            return False
        if not self.decode.pool.can_admit(req.total_tokens):
            return False
        state = self.prefill.export_slot(req.slot)
        written = self.prefill.pool.lease(req.rid).written_tokens
        prefill_active[req.slot] = False
        self.prefill.release(req)
        req.slot = self.decode.pool.alloc(req.rid, req.total_tokens)
        self.decode.import_slot(req.slot, state)
        self.decode.slots[req.slot] = req
        self.decode.steps_done[req.slot] = 0
        # the prefill engine already produced the first sample; the decode
        # engine owes the remaining gen - 1 steps
        self.decode.steps_total[req.slot] = req.max_new_tokens - 1
        # carry the KV-write accounting into the decode pool's ledger
        self.decode.pool.note_write(req.rid, min(written, req.total_tokens))
        decode_active[req.slot] = True
        req.state = RequestState.DECODE
        self.decode_batcher.n_admitted += 1      # migration ledger

        n_bytes = SlotEngine.state_nbytes(state)
        price = transfer_cost(n_bytes, self._prefill_dev, self._decode_dev,
                              link_bw=self._handoff_link_bw)
        self.handoff.n_handoffs += 1
        self.handoff.bytes_moved += n_bytes
        self.handoff.modeled_s += price.t_transfer
        self.handoff.modeled_energy_j += price.energy_j
        return True

    # ---- main loop -------------------------------------------------------
    def run(self, requests: List[Request], *,
            now_fn: Callable[[], float] = time.perf_counter,
            max_steps: Optional[int] = None) -> ServeMetrics:
        metrics = ServeMetrics()
        pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
        queue: List[Request] = []
        ready: List[Request] = []        # prefill done, awaiting migration
        pre_active = np.zeros((self.prefill.pool.n_slots,), bool)
        dec_active = np.zeros((self.decode.pool.n_slots,), bool)
        t0 = now_fn()
        skew = 0.0
        clock = lambda: now_fn() - t0 + skew

        def busy() -> bool:
            return bool(queue or ready or self.prefill.n_active
                        or self.decode.n_active)

        while pending or busy():
            now = clock()
            while pending and pending[0].arrival <= now:
                queue.append(pending.pop(0))
            if not busy():
                skew += pending[0].arrival - now
                continue

            # requests that can never fit the DECODE pool would park in a
            # prefill slot forever: shed them before admission
            i = 0
            while i < len(queue):
                r = queue[i]
                if (r.total_tokens > self.decode.pool.max_seq
                        or self.decode.pool.blocks_needed(r.total_tokens)
                        > self.decode.pool.total_blocks):
                    r.state = RequestState.DROPPED
                    metrics.n_dropped += 1
                    queue.pop(i)
                    continue
                i += 1

            # migrate phase-boundary requests (decode budget + pool gated)
            ready = [req for req in ready
                     if not self._migrate(req, pre_active, dec_active)]

            # admit new arrivals into the prefill engine; ready requests
            # still hold prefill slots, so n_active covers them
            decision = self.prefill_batcher.admit(
                queue, self.prefill.n_active, now)
            metrics.n_dropped += len(decision.dropped)
            for req in decision.admitted:
                # the first sample lands after plen steps; the rest of the
                # generation belongs to the decode engine
                self.prefill.bind(req, steps_total=req.prompt_len)
                pre_active[req.slot] = True

            if not self.prefill.n_active and not self.decode.n_active:
                continue                 # nothing runnable (pool pressure)

            # one burst per engine; both stay short while hand-offs or
            # arrivals are waiting so migration latency is bounded
            throttle = bool(pending or queue or ready)
            pre_burstable = pre_active & (self.prefill.steps_done
                                          < self.prefill.steps_total)
            if pre_burstable.any():
                remaining = (self.prefill.steps_total
                             - self.prefill.steps_done)[pre_burstable]
                burst = int(remaining.min())
                if throttle:
                    burst = min(burst, self.BURST_CAP_PENDING)
                if max_steps is not None:
                    burst = min(burst, max(max_steps - metrics.n_steps, 0))
                if burst:
                    self.prefill.dispatch(burst, pre_burstable)
                    metrics.n_steps += burst
            dec_burstable = dec_active & (self.decode.steps_done
                                          < self.decode.steps_total)
            if dec_burstable.any():
                remaining = (self.decode.steps_total
                             - self.decode.steps_done)[dec_burstable]
                burst = int(remaining.min())
                if throttle:
                    burst = min(burst, self.BURST_CAP_PENDING)
                if max_steps is not None:
                    burst = min(burst, max(max_steps - metrics.n_steps, 0))
                if burst:
                    self.decode.dispatch(burst, dec_burstable)
                    metrics.n_steps += burst
            metrics.occupancy.append(
                (self.prefill.pool.occupancy()
                 + self.decode.pool.occupancy()) / 2)
            metrics.utilization.append(
                (self.prefill.pool.utilization()
                 + self.decode.pool.utilization()) / 2)

            now = clock()
            # prefill completions -> phase boundary
            ready_rids = {r.rid for r in ready}
            for s, req in enumerate(self.prefill.slots):
                if req is None or req.rid in ready_rids:
                    continue
                req.n_fed = int(self.prefill.steps_done[s])
                if self.prefill.steps_done[s] >= self.prefill.steps_total[s]:
                    # first sample landed inside this burst
                    req.state = RequestState.DECODE
                    req.t_first_token = now
                    ready.append(req)
            # decode completions
            for s, req in enumerate(self.decode.slots):
                if req is None:
                    continue
                req.n_fed = req.prompt_len + int(self.decode.steps_done[s])
                if self.decode.steps_done[s] >= self.decode.steps_total[s]:
                    row = self.decode.pull_output(s)
                    req.output = row[:req.max_new_tokens].tolist()
                    req.state = RequestState.DONE
                    req.t_done = clock()
                    self.decode.release(req)
                    dec_active[s] = False
                    metrics.observe(req)
            if max_steps is not None and metrics.n_steps >= max_steps:
                break
        metrics.elapsed_s = clock()
        return metrics
