"""CNNLab cost model: per-layer time / power / energy / performance density.

This is the quantity the paper's middleware optimizes during design-space
exploration (§III.A "trade-off analysis"), generalized to the TPU roofline:

    t_compute    = FLOPs / (chips x achieved FLOP/s)
    t_memory     = bytes  / (chips x HBM bandwidth)
    t_collective = collective bytes / (chips x link bandwidth)
    t_total      = max(t_compute, t_memory, t_collective)   (overlap model)

For empirical device models (K40/DE5, calibrated from the paper's
measurements) only the compute term is used — the measurement already folds
in memory behaviour.

Derived metrics exactly as §IV.B defines them:
    throughput        = FLOPs / t_total              (FLOP/s)
    power             = device watts for the kind    (W)
    energy            = t_total x power              (J)
    perf density (1)  = throughput / power           (FLOPS/W)
    perf density (2)  = FLOPs / energy               (FLOP/J)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from .device_models import DeviceModel
from .layer_model import LayerSpec, NetworkSpec


def piecewise_interp(xs: Sequence[float], ys: Sequence[float], x: float) -> float:
    """Piecewise-linear interpolation through measured (x, y) knots.

    The analytic model above prices a step as a sum of per-layer roofline
    terms that scale linearly in FLOPs between any two batch sizes; measured
    latency(batch) curves do not obey that (kernel launch floors, cache
    cliffs, bucket re-jits).  When telemetry supplies real knots, interpolate
    between them instead of assuming linear-FLOP scaling — outside the
    measured range, extrapolate along the nearest segment's slope, clamped
    non-negative.

    ``xs`` must be strictly increasing with at least two knots; shorter
    inputs have no interior to interpolate and callers fall back to the
    analytic model.
    """
    if len(xs) != len(ys) or len(xs) < 2:
        raise ValueError("piecewise_interp needs >= 2 matching knots")
    if x <= xs[0]:
        lo, hi = 0, 1
    elif x >= xs[-1]:
        lo, hi = len(xs) - 2, len(xs) - 1
    else:
        hi = next(i for i, v in enumerate(xs) if v >= x)
        lo = hi - 1
    span = xs[hi] - xs[lo]
    if span <= 0:
        raise ValueError("piecewise_interp knots must be strictly increasing")
    frac = (x - xs[lo]) / span
    return max(ys[lo] + frac * (ys[hi] - ys[lo]), 0.0)


@dataclasses.dataclass(frozen=True)
class CostBreakdown:
    layer: str
    kind: str
    device: str
    flops: int
    bytes_moved: int
    collective_bytes: int
    t_compute: float
    t_memory: float
    t_collective: float
    power_w: float

    @property
    def t_total(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def throughput(self) -> float:
        t = self.t_total
        return self.flops / t if t > 0 else 0.0

    @property
    def energy_j(self) -> float:
        return self.t_total * self.power_w

    @property
    def gflops_per_watt(self) -> float:
        return self.throughput / 1e9 / self.power_w if self.power_w else 0.0

    @property
    def gflop_per_joule(self) -> float:
        e = self.energy_j
        return self.flops / 1e9 / e if e > 0 else 0.0


def layer_cost(
    spec: LayerSpec,
    device: DeviceModel,
    *,
    batch: int = 1,
    dtype_bytes: int = 4,
    n_chips: int = 1,
    collective_bytes: int = 0,
    direction: str = "fwd",
    mxu_efficiency: float = 1.0,
) -> CostBreakdown:
    """Cost one layer on one device model.

    ``collective_bytes`` is per-chip traffic attributable to this layer's
    sharding (0 for single-device); the caller (scheduler / roofline reader)
    supplies it either analytically or parsed from compiled HLO.
    """
    flops = spec.flops(batch) if direction == "fwd" else spec.bwd_flops(batch)
    bytes_moved = (
        spec.activation_bytes(batch, dtype_bytes) + spec.param_bytes(dtype_bytes)
    )
    if direction == "bwd":
        bytes_moved *= 2  # re-read activations + write grads (rough model)

    kind = spec.kind
    if device.analytic_for(kind):
        eff_peak = (device.peak_flops * mxu_efficiency
                    * device.roofline_efficiency(kind))
        t_c = flops / (n_chips * eff_peak)
        t_m = bytes_moved / (n_chips * device.mem_bw)
        t_x = (
            collective_bytes / device.link_bw if device.link_bw and collective_bytes else 0.0
        )
        power = device.power_active
    else:
        t_c = flops / (n_chips * device.achieved_flops(kind, direction))
        t_m = 0.0
        t_x = 0.0
        power = device.watts(kind, direction)
    return CostBreakdown(
        layer=spec.name,
        kind=kind,
        device=device.name,
        flops=flops,
        bytes_moved=bytes_moved,
        collective_bytes=collective_bytes,
        t_compute=t_c,
        t_memory=t_m,
        t_collective=t_x,
        power_w=power,
    )


def network_cost(
    net: NetworkSpec,
    device: DeviceModel,
    *,
    batch: int = 1,
    dtype_bytes: int = 4,
    n_chips: int = 1,
    direction: str = "fwd",
) -> list:
    return [
        layer_cost(
            l,
            device,
            batch=batch,
            dtype_bytes=dtype_bytes,
            n_chips=n_chips,
            direction=direction,
        )
        for l in net
    ]


# ---------------------------------------------------------------------------
# Offload overhead (the paper's PCIe sync, Fig. 5 step 4)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TransferCost:
    """Cost of moving bytes between two engines' devices.

    The paper's runtime pays a host-mediated synchronization whenever
    adjacent stages run on different boards; we price it as the byte
    payload at the slower of the two devices' link bandwidths (falling
    back to memory bandwidth for devices that declare no interconnect).
    Energy charges both devices at idle for the transfer — neither is
    computing while the hand-off drains.
    """

    src: str
    dst: str
    bytes_moved: int
    link_bw: float
    t_transfer: float
    energy_j: float
    # where link_bw came from: "assumed-mem-bw" (datasheet fallback),
    # "provided" (caller passed one, e.g. the profiling runtime's measured
    # inter-device copy rate), or "colocated" (same device, free)
    link_source: str = "assumed-mem-bw"


def transfer_cost(
    n_bytes: int,
    src: DeviceModel,
    dst: DeviceModel,
    *,
    link_bw: Optional[float] = None,
) -> TransferCost:
    """Price an engine-switch hand-off of ``n_bytes`` from ``src`` to ``dst``.

    Same device -> free (XLA's shared 'virtual memory space', plan.py).
    ``link_bw`` overrides the derived bandwidth — pass the measured rate
    from :func:`repro.profiling.transfer.measure_link_bandwidth` where one
    exists; the no-argument fallback (slower endpoint's declared link or
    memory bandwidth) is a datasheet *assumption*, and the result records
    which of the two priced the hand-off in ``link_source``.
    """
    if src.name == dst.name:
        return TransferCost(src=src.name, dst=dst.name, bytes_moved=0,
                            link_bw=float("inf"), t_transfer=0.0,
                            energy_j=0.0, link_source="colocated")
    source = "provided" if link_bw is not None else "assumed-mem-bw"
    if link_bw is None:
        link_bw = min(src.link_bw or src.mem_bw, dst.link_bw or dst.mem_bw)
    t = n_bytes / link_bw if link_bw > 0 else float("inf")
    return TransferCost(
        src=src.name, dst=dst.name, bytes_moved=n_bytes, link_bw=link_bw,
        t_transfer=t, energy_j=t * (src.power_idle + dst.power_idle),
        link_source=source)


# ---------------------------------------------------------------------------
# Objectives (what the user asks the middleware to optimize, §III.A)
# ---------------------------------------------------------------------------
def objective_value(cost: CostBreakdown, objective: str) -> float:
    """Lower is better for every objective."""
    if objective == "latency":
        return cost.t_total
    if objective == "energy":
        return cost.energy_j
    if objective == "edp":  # energy-delay product
        return cost.energy_j * cost.t_total
    if objective == "power":
        return cost.power_w
    if objective == "perf_density":  # maximize GFLOPS/W -> minimize inverse
        d = cost.gflops_per_watt
        return 1.0 / d if d > 0 else float("inf")
    raise ValueError(f"unknown objective: {objective}")


OBJECTIVES = ("latency", "energy", "edp", "power", "perf_density")


# ---------------------------------------------------------------------------
# Speculative decoding (draft/verify on the decode path)
# ---------------------------------------------------------------------------
def expected_tokens_per_round(acceptance: float, k: int) -> float:
    """Expected committed tokens of one speculative round at draft depth k.

    With per-token acceptance rate ``alpha`` (i.i.d. across window
    offsets, the standard speculative-decoding model), the accepted draft
    prefix has expected length sum_{i=1..k} alpha^i and the target always
    commits one more token of its own (the correction after a rejection,
    the bonus after full acceptance):

        E[c] = alpha (1 - alpha^k) / (1 - alpha) + 1        (alpha < 1)
             = k + 1                                        (alpha = 1)
    """
    if k < 1:
        raise ValueError(f"draft depth k must be >= 1, got {k}")
    a = min(max(float(acceptance), 0.0), 1.0)
    if a >= 1.0:
        return float(k + 1)
    return a * (1.0 - a ** k) / (1.0 - a) + 1.0


def speculative_decode_cost(t_draft_step_s: float, t_verify_s: float,
                            acceptance: float, k: int) -> float:
    """Modeled wall time per *committed* token of speculative decoding.

    One round runs k+1 sequential draft steps (the last writes the draft
    KV for its own final proposal) plus one multi-position verify step on
    the target, and commits :func:`expected_tokens_per_round` tokens:

        t_spec = ((k + 1) t_draft + t_verify) / E[c]

    Compare against the plain per-token time (one target step) to decide
    whether speculation prices better — the paper's offload trade-off
    applied to the decode hot path.
    """
    e = expected_tokens_per_round(acceptance, k)
    return ((k + 1) * t_draft_step_s + t_verify_s) / e
