"""Trade-off analysis (paper §IV): the quantitative study CNNLab performs.

`analyze` regenerates the paper's Fig. 6 table — per layer, per device:
execution time, throughput, power, energy, GFLOPS/W, GFLOP/J — from the cost
model.  `check_paper_claims` validates the reproduction against the paper's
own reported numbers (DESIGN.md C1–C7).

Energy normalization: the paper reports joules per (unstated) measurement
workload.  Ratios are therefore the validation target; we additionally pick
the single workload constant (109 images) that reproduces the paper's
absolute GPU conv energy, and report absolute joules under it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

from .cost_model import CostBreakdown, layer_cost
from .device_models import DE5, K40, K40_CUBLAS, K40_CUDNN, DeviceModel
from .layer_model import NetworkSpec, alexnet_spec

# workload constant reproducing the paper's absolute GPU conv energy (see
# module docstring); claims are checked on ratios, not on this constant.
PAPER_WORKLOAD_IMAGES = 109


@dataclasses.dataclass(frozen=True)
class TradeoffRow:
    layer: str
    kind: str
    device: str
    time_s: float
    throughput_gflops: float
    power_w: float
    energy_j: float
    gflops_per_watt: float
    gflop_per_joule: float

    @staticmethod
    def from_cost(c: CostBreakdown) -> "TradeoffRow":
        return TradeoffRow(
            layer=c.layer, kind=c.kind, device=c.device, time_s=c.t_total,
            throughput_gflops=c.throughput / 1e9, power_w=c.power_w,
            energy_j=c.energy_j, gflops_per_watt=c.gflops_per_watt,
            gflop_per_joule=c.gflop_per_joule)


def analyze(
    net: NetworkSpec,
    devices: Sequence[DeviceModel],
    *,
    batch: int = 1,
    dtype_bytes: int = 4,
    direction: str = "fwd",
) -> List[TradeoffRow]:
    rows = []
    for dev in devices:
        for spec in net:
            c = layer_cost(spec, dev, batch=batch, dtype_bytes=dtype_bytes,
                           direction=direction)
            rows.append(TradeoffRow.from_cost(c))
    return rows


def _mean(xs):
    xs = list(xs)
    return sum(xs) / len(xs) if xs else 0.0


def check_paper_claims(batch: int = PAPER_WORKLOAD_IMAGES) -> Dict[str, dict]:
    """Validate DESIGN.md claims C1–C7 against the paper's reported values.

    Returns {claim: {"value": ..., "expected": ..., "ok": bool, "note": str}}.
    """
    net = alexnet_spec()
    rows_gpu = {r.layer: r for r in analyze(net, [K40], batch=batch)}
    rows_fpga = {r.layer: r for r in analyze(net, [DE5], batch=batch)}
    convs = [l.name for l in net if l.kind == "conv"]
    fcs = [l.name for l in net if l.kind == "fc"]

    out: Dict[str, dict] = {}

    # C1: GPU ~100x faster overall; up to ~1000x on FC layers
    fc_speedups = [rows_fpga[n].time_s / rows_gpu[n].time_s for n in fcs]
    conv_speedups = [rows_fpga[n].time_s / rows_gpu[n].time_s for n in convs]
    out["C1"] = {
        "value": {"fc_speedup_max": max(fc_speedups),
                  "conv_speedup_mean": _mean(conv_speedups)},
        "expected": "conv ~60-100x, FC up to ~1000x",
        "ok": max(fc_speedups) > 300 and 20 < _mean(conv_speedups) < 200,
    }

    # C2: peak throughputs — GPU 1632 GFLOPS (conv), FPGA 25.56 GFLOPS (conv)
    out["C2"] = {
        "value": {"gpu_conv_peak": max(rows_gpu[n].throughput_gflops for n in convs),
                  "fpga_conv_peak": max(rows_fpga[n].throughput_gflops for n in convs)},
        "expected": {"gpu_conv_peak": 1632.0, "fpga_conv_peak": 25.56},
        "ok": abs(max(rows_gpu[n].throughput_gflops for n in convs) - 1632) < 5
        and abs(max(rows_fpga[n].throughput_gflops for n in convs) - 25.56) < 0.5,
    }

    # C3: FPGA ~50x more power-efficient (97 W vs 2.23 W)
    p_ratio = _mean(r.power_w for r in rows_gpu.values()) / _mean(
        r.power_w for r in rows_fpga.values())
    out["C3"] = {"value": {"power_ratio": p_ratio},
                 "expected": "~43x (97/2.23)", "ok": 30 < p_ratio < 60}

    # C4: conv energy similar (paper: 10.24 J FPGA vs 8.67 J GPU, ratio 1.18);
    #     FC energy GPU far better (12.24 J vs 0.64 J, ratio ~19)
    e_conv_gpu = _mean(rows_gpu[n].energy_j for n in convs)
    e_conv_fpga = _mean(rows_fpga[n].energy_j for n in convs)
    e_fc_gpu = _mean(rows_gpu[n].energy_j for n in fcs)
    e_fc_fpga = _mean(rows_fpga[n].energy_j for n in fcs)
    out["C4"] = {
        "value": {"conv_ratio_fpga_over_gpu": e_conv_fpga / e_conv_gpu,
                  "fc_ratio_fpga_over_gpu": e_fc_fpga / e_fc_gpu,
                  "gpu_conv_energy_j": e_conv_gpu,
                  "fpga_conv_energy_j": e_conv_fpga},
        "expected": {"conv_ratio": 10.24 / 8.67, "fc_ratio": 12.24 / 0.64},
        "ok": 0.5 < (e_conv_fpga / e_conv_gpu) < 3.0
        and 8 < (e_fc_fpga / e_fc_gpu) < 40,
    }

    # C5: density — conv: GPU 14.12 vs FPGA 10.58 GFLOPS/W (similar);
    #     FC: GPU 14.20 vs FPGA 0.82 GFLOPS/W
    d_conv_gpu = _mean(rows_gpu[n].gflops_per_watt for n in convs)
    d_conv_fpga = _mean(rows_fpga[n].gflops_per_watt for n in convs)
    d_fc_gpu = _mean(rows_gpu[n].gflops_per_watt for n in fcs)
    d_fc_fpga = _mean(rows_fpga[n].gflops_per_watt for n in fcs)
    out["C5"] = {
        "value": {"conv": (d_conv_gpu, d_conv_fpga), "fc": (d_fc_gpu, d_fc_fpga)},
        "expected": {"conv": (14.12, 10.58), "fc": (14.20, 0.82)},
        "ok": abs(d_fc_gpu - 14.20) < 0.5 and abs(d_fc_fpga - 0.82) < 0.1
        and 0.4 < d_conv_gpu / 14.12 < 1.5 and 0.4 < d_conv_fpga / 10.58 < 1.5,
    }

    # C6: exact FLOP counts, Table II
    fc6 = next(l for l in net if l.name == "FC6")
    fc7 = next(l for l in net if l.name == "FC7")
    fc8 = next(l for l in net if l.name == "FC8")
    vals = {
        "FC6_fwd": fc6.flops(1), "FC7_fwd": fc7.flops(1), "FC8_fwd": fc8.flops(1),
        "FC6_bwd": fc6.bwd_flops(1), "FC7_bwd": fc7.bwd_flops(1),
        "FC8_bwd": fc8.bwd_flops(1),
    }
    expect = {"FC6_fwd": 75497472, "FC7_fwd": 33554432, "FC8_fwd": 8192000,
              "FC6_bwd": 150994944, "FC7_bwd": 67108864, "FC8_bwd": 16384000}
    out["C6"] = {"value": vals, "expected": expect,
                 "ok": all(vals[k] == expect[k] for k in expect)}

    # C7: cuBLAS vs cuDNN — 1.69x fwd speedup, 24.89x bwd; bwd power
    # 78.77 W vs 123.40 W; bwd energy ratio ~44x (31.19/0.70)
    fc_net = NetworkSpec("fc-only", tuple(l for l in net if l.kind == "fc"))
    def total_time(dev, direction):
        return sum(layer_cost(l, dev, batch=batch, direction=direction).t_total
                   for l in fc_net)
    fwd_speedup = total_time(K40_CUDNN, "fwd") / total_time(K40_CUBLAS, "fwd")
    bwd_speedup = total_time(K40_CUDNN, "bwd") / total_time(K40_CUBLAS, "bwd")
    e_cudnn_bwd = sum(layer_cost(l, K40_CUDNN, batch=batch,
                                 direction="bwd").energy_j for l in fc_net)
    e_cublas_bwd = sum(layer_cost(l, K40_CUBLAS, batch=batch,
                                  direction="bwd").energy_j for l in fc_net)
    out["C7"] = {
        "value": {"fwd_speedup": fwd_speedup, "bwd_speedup": bwd_speedup,
                  "bwd_power": (K40_CUDNN.power_bwd["fc"], K40_CUBLAS.power_bwd["fc"]),
                  "bwd_energy_ratio": e_cudnn_bwd / e_cublas_bwd},
        "expected": {"fwd_speedup": 1.69, "bwd_speedup": 24.89,
                     "bwd_power": (123.40, 78.77),
                     "bwd_energy_ratio": 31.19 / 0.70},
        "ok": abs(fwd_speedup - 1.69) < 0.05 and abs(bwd_speedup - 24.89) < 0.5
        and 30 < (e_cudnn_bwd / e_cublas_bwd) < 60,
        "note": ("paper's BP *throughput* claim (cuDNN 1.57x higher) is "
                 "inconsistent with its 24.89x time speedup for identical "
                 "FLOPs (Table II); we validate the time/power/energy claims"),
    }
    return out
