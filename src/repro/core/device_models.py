"""Analytic device models for the CNNLab scheduler.

The paper's middleware holds per-accelerator knowledge (it measured the K40
and DE5 boards); ours holds analytic/calibrated models.  Two flavours:

* ``analytic=True`` (TPU v5e): time is the 3-term roofline
  max(compute, memory, collective) from first principles.  This drives the
  real scheduler and the §Roofline analysis.

* ``analytic=False`` (K40, DE5, and the K40 cuDNN/cuBLAS library variants):
  *empirical* models whose per-layer-kind achieved throughput and power are
  calibrated from the paper's own measurements (§IV.B/C, Tables II-III).
  These exist so the trade-off analysis of Fig. 6 / Figs. 7-8 can be
  regenerated and the paper's claims validated (DESIGN.md C1-C7).

Calibration sources (all from the paper):
  K40  : 4.29 TFLOPS fp32 peak, 288 GB/s, avg power 97 W;
         conv eff. set so conv throughput = 1632 GFLOPS (peak claim, Conv4);
         FC throughput = 14.20 GFLOPS/W x 97 W = 1377 GFLOPS (density claim).
  DE5  : Table III module freqs + DSP counts; measured conv peak 25.56 GFLOPS
         (Conv2), FC density 0.82 GFLOPS/W at 2.23 W -> ~1.8 GFLOPS.
  cuDNN/cuBLAS: Fig. 7-8 speedups (1.69x fwd, 24.89x bwd) and powers
         (fwd 79.12/78.73 W, bwd 123.40/78.77 W).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

GiB = 1024 ** 3
MiB = 1024 ** 2


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    name: str
    peak_flops: float                    # FLOP/s (target precision)
    mem_bw: float                        # bytes/s HBM (or DDR/BRAM aggregate)
    link_bw: float = 0.0                 # bytes/s per ICI link
    vmem_bytes: int = 0                  # on-chip scratch (VMEM / BRAM)
    analytic: bool = True
    # kind -> achieved FLOP/s (calibrated; used when analytic=False)
    throughput: Mapping[str, float] = dataclasses.field(default_factory=dict)
    # kind -> watts while running that kind (falls back to `power_active`)
    power: Mapping[str, float] = dataclasses.field(default_factory=dict)
    power_active: float = 100.0
    power_idle: float = 10.0
    # backward-pass throughput overrides (kind -> FLOP/s); default = fwd
    throughput_bwd: Mapping[str, float] = dataclasses.field(default_factory=dict)
    power_bwd: Mapping[str, float] = dataclasses.field(default_factory=dict)
    frequency_hz: float = 0.0

    def achieved_flops(self, kind: str, direction: str = "fwd") -> float:
        if direction == "bwd" and kind in self.throughput_bwd:
            return self.throughput_bwd[kind]
        if kind in self.throughput:
            return self.throughput[kind]
        return self.peak_flops

    def analytic_for(self, kind: str) -> bool:
        """Whether `kind` is priced with the first-principles roofline
        (subclasses with partial empirical coverage override per kind)."""
        return self.analytic

    def roofline_efficiency(self, kind: str) -> float:
        """Model-intrinsic achieved-fraction multiplier for the roofline
        compute term (1.0 here; calibrated models carry the engine's
        nominal efficiency for their unmeasured kinds)."""
        return 1.0

    def watts(self, kind: str, direction: str = "fwd") -> float:
        if direction == "bwd" and kind in self.power_bwd:
            return self.power_bwd[kind]
        return self.power.get(kind, self.power_active)


# ---------------------------------------------------------------------------
# TPU v5e — the target platform (constants given by the assignment brief).
# ---------------------------------------------------------------------------
TPU_V5E = DeviceModel(
    name="tpu-v5e",
    peak_flops=197e12,          # bf16
    mem_bw=819e9,               # HBM
    link_bw=50e9,               # per ICI link
    vmem_bytes=16 * MiB,
    analytic=True,
    power_active=200.0,         # modeled envelope (no meter on target)
    power_idle=60.0,
)

# ---------------------------------------------------------------------------
# Nvidia K40 — the paper's GPU (§IV.A), empirical model.
# ---------------------------------------------------------------------------
_K40_PEAK = 4.29e12
K40 = DeviceModel(
    name="nvidia-k40",
    peak_flops=_K40_PEAK,
    mem_bw=288e9,
    vmem_bytes=12288 * MiB,     # device memory (paper: 12,288 MB)
    analytic=False,
    throughput={
        "conv": 1632e9,          # C2: peak GPU throughput, Conv4
        "fc": 1377e9,            # C5: 14.20 GFLOPS/W x 97 W
        "norm": 300e9,
        "pool": 200e9,
    },
    power={"conv": 97.0, "fc": 97.0, "norm": 97.0, "pool": 97.0},
    power_active=97.0,           # C3: average GPU power
    power_idle=20.0,
)

# cuDNN / cuBLAS library variants of the same board (§IV.C, Figs. 7-8).
# cuBLAS is the fast library; cuDNN fwd = cublas/1.69, bwd = cublas/24.89.
_CUBLAS_FC_FWD = 1377e9
_CUBLAS_FC_BWD = 1377e9
K40_CUBLAS = dataclasses.replace(
    K40,
    name="k40-cublas",
    throughput={**K40.throughput, "fc": _CUBLAS_FC_FWD},
    throughput_bwd={"fc": _CUBLAS_FC_BWD},
    power={"fc": 78.73},
    power_bwd={"fc": 78.77},
)
K40_CUDNN = dataclasses.replace(
    K40,
    name="k40-cudnn",
    throughput={**K40.throughput, "fc": _CUBLAS_FC_FWD / 1.69},
    throughput_bwd={"fc": _CUBLAS_FC_BWD / 24.89},
    power={"fc": 79.12},
    power_bwd={"fc": 123.40},
)

# ---------------------------------------------------------------------------
# Altera DE5 — the paper's FPGA (§IV.A, Table III), empirical per-module model.
# Peak theoretical per module = DSPs x 2 FLOP x module clock.
# ---------------------------------------------------------------------------
_DE5_MODULES = {  # kind: (DSPs, freq MHz) — Table III
    "conv": (162, 171.29),
    "norm": (3, 269.02),
    "fc": (130, 216.16),
    "pool": (0, 304.50),
}
DE5 = DeviceModel(
    name="altera-de5",
    peak_flops=162 * 2 * 171.29e6,     # conv module theoretical: ~55.5 GFLOPS
    mem_bw=25.6e9,                     # 2x DDR3-1600 channels on DE5
    vmem_bytes=52_428_800 // 8,        # 52,428,800 memory *bits* (Table III)
    analytic=False,
    throughput={
        "conv": 25.56e9,               # C2: peak FPGA throughput, Conv2
        "fc": 1.83e9,                  # C5: 0.82 GFLOPS/W x 2.23 W
        "norm": 1.6e9,                 # LRN module: 3 DSPs @ 269 MHz (+LUT math)
        "pool": 2.4e9,                 # comparator tree @ 304.5 MHz (no DSPs)
    },
    power={"conv": 2.23, "fc": 2.23, "norm": 2.23, "pool": 2.23},
    power_active=2.23,                 # C3: FPGA conv-module power
    power_idle=0.5,
    frequency_hz=171.29e6,
)

# ---------------------------------------------------------------------------
# Roofline variants of the paper boards.  The empirical K40/DE5 models only
# know the CNN kinds the paper measured; for layer kinds the paper never ran
# (attention, MLP, MoE, SSM — the serving phases) we price the same silicon
# from first principles instead: peak FLOPs vs memory bandwidth, the 3-term
# roofline the TPU model uses.  These are what phase placement
# (repro.serving.placement) studies the paper's GPU/FPGA split on.
# ---------------------------------------------------------------------------
K40_ROOFLINE = dataclasses.replace(K40, name="nvidia-k40-roofline",
                                   analytic=True)
DE5_ROOFLINE = dataclasses.replace(DE5, name="altera-de5-roofline",
                                   analytic=True)

REGISTRY = {m.name: m for m in (TPU_V5E, K40, K40_CUBLAS, K40_CUDNN, DE5,
                                K40_ROOFLINE, DE5_ROOFLINE)}


def get(name: str) -> DeviceModel:
    return REGISTRY[name]


def register(model: DeviceModel, *, overwrite: bool = False) -> DeviceModel:
    """Add a model (e.g. a profiling-calibrated one) to the registry so
    name-keyed consumers — the serving batcher's ``device_name`` — can
    price on it."""
    if model.name in REGISTRY and not overwrite:
        raise ValueError(f"device model {model.name!r} already registered")
    REGISTRY[model.name] = model
    return model


def fpga_module_peak(kind: str) -> float:
    """Theoretical module peak from Table III (DSPs x 2 x clock)."""
    dsps, mhz = _DE5_MODULES[kind]
    return dsps * 2 * mhz * 1e6
