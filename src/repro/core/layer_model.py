"""CNNLab layer abstraction (paper §III.B).

Every network layer is a declarative tuple of parameters, decoupled from any
backend.  The paper defines four tuples:

    Conv  ⟨M_I, M_K, M_O, S, T⟩          (Eq. 5)
    Norm  ⟨M_I, T, S, α, β⟩              (Eq. 6)
    Pool  ⟨M_I, M_O, T, S, N⟩            (Eq. 7)
    FC    ⟨M_I, K_O⟩                     (Eq. 8)

We keep those exactly, and extend the same idea to the transformer-era layer
types our assigned architectures need (attention, MoE, SSM, norm, embedding).
Each spec knows its own FLOP count, parameter bytes and activation bytes, so
the cost model (core/cost_model.py) and the scheduler (core/scheduler.py) can
reason about it analytically — this is what lets the middleware do DSE before
anything is compiled.

FLOP conventions: 1 multiply-accumulate = 2 FLOPs (matches the paper's
Table II exactly: FC6 fwd over 256x6x6 -> 4096 is 2*9216*4096 = 75,497,472).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

Shape3 = Tuple[int, int, int]  # height, width, channels (paper: h x w x dim)


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """Base class: a declaratively-specified layer (one CNNLab tuple)."""

    name: str

    # ---- accounting interface ---------------------------------------
    def flops(self, batch: int = 1) -> int:
        """Forward FLOPs per batch of `batch` inputs."""
        raise NotImplementedError

    def bwd_flops(self, batch: int = 1) -> int:
        """Backward FLOPs.  Paper's Table II uses exactly 2x forward."""
        return 2 * self.flops(batch)

    def param_count(self) -> int:
        return 0

    def param_bytes(self, dtype_bytes: int = 4) -> int:
        return self.param_count() * dtype_bytes

    def activation_bytes(self, batch: int = 1, dtype_bytes: int = 4) -> int:
        """Bytes read + written for the forward pass (I/O traffic)."""
        raise NotImplementedError

    @property
    def kind(self) -> str:
        return type(self).__name__.replace("Spec", "").lower()


# ----------------------------------------------------------------------
# The paper's four tuples (§III.B, Eqs. 5-8)
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ConvSpec(LayerSpec):
    """Convolutional layer ⟨M_I, M_K, M_O, S, T⟩ (Eq. 5)."""

    m_i: Shape3        # input  (h, w, c_in)
    m_k: Tuple[int, int, int, int]  # kernel (c_out, c_in, kh, kw) — Table I order
    m_o: Shape3        # output (h, w, c_out)
    stride: int = 1
    nonlinearity: str = "relu"   # T ∈ {sigmoid, tanh, relu, none}
    padding: int = 0

    def flops(self, batch: int = 1) -> int:
        oh, ow, oc = self.m_o
        _, ic, kh, kw = self.m_k
        macs = oh * ow * oc * ic * kh * kw
        return batch * 2 * macs

    def param_count(self) -> int:
        oc, ic, kh, kw = self.m_k
        return oc * ic * kh * kw + oc  # + bias

    def activation_bytes(self, batch: int = 1, dtype_bytes: int = 4) -> int:
        return batch * (_prod(self.m_i) + _prod(self.m_o)) * dtype_bytes


@dataclasses.dataclass(frozen=True)
class NormSpec(LayerSpec):
    """Normalization layer ⟨M_I, T, S, α, β⟩ (Eq. 6).  T='lrn' is the paper's
    LRN; we also admit 'layernorm'/'rmsnorm' for the transformer archs."""

    m_i: Shape3
    norm_type: str = "lrn"
    local_size: int = 5
    alpha: float = 1e-4
    beta: float = 0.75

    def flops(self, batch: int = 1) -> int:
        n = _prod(self.m_i)
        if self.norm_type == "lrn":
            # square, windowed sum over `local_size` channels, scale, pow, div
            return batch * n * (2 * self.local_size + 4)
        # layernorm / rmsnorm: mean/var + normalize + affine ≈ 6 ops/elem
        return batch * n * 6

    def param_count(self) -> int:
        if self.norm_type in ("layernorm", "rmsnorm"):
            h, w, c = self.m_i
            return c * (2 if self.norm_type == "layernorm" else 1)
        return 0

    def activation_bytes(self, batch: int = 1, dtype_bytes: int = 4) -> int:
        return batch * 2 * _prod(self.m_i) * dtype_bytes


@dataclasses.dataclass(frozen=True)
class PoolSpec(LayerSpec):
    """Pooling layer ⟨M_I, M_O, T, S, N⟩ (Eq. 7)."""

    m_i: Shape3
    m_o: Shape3
    pool_type: str = "max"   # T ∈ {max, avg}
    stride: int = 2
    num_kernels: int = 1     # N
    window: int = 3

    def flops(self, batch: int = 1) -> int:
        # one compare/add per window element per output element
        return batch * _prod(self.m_o) * self.window * self.window

    def activation_bytes(self, batch: int = 1, dtype_bytes: int = 4) -> int:
        return batch * (_prod(self.m_i) + _prod(self.m_o)) * dtype_bytes


@dataclasses.dataclass(frozen=True)
class FCSpec(LayerSpec):
    """Fully-connected layer ⟨M_I, K_O⟩ (Eq. 8).

    m_i may be a 3-tuple (flattened internally, like FC6's 256x6x6) or an int.
    """

    m_i: Tuple[int, ...] = (1,)
    k_o: int = 1
    activation: str = "none"   # dropout applied outside; softmax for FC8

    @property
    def n_in(self) -> int:
        return _prod(self.m_i)

    def flops(self, batch: int = 1) -> int:
        return batch * 2 * self.n_in * self.k_o   # == paper Table II exactly

    def param_count(self) -> int:
        return self.n_in * self.k_o + self.k_o

    def activation_bytes(self, batch: int = 1, dtype_bytes: int = 4) -> int:
        return batch * (self.n_in + self.k_o) * dtype_bytes


# ----------------------------------------------------------------------
# Transformer-era extensions (same declarative idea, new layer kinds)
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class EmbeddingSpec(LayerSpec):
    vocab: int = 32000
    d_model: int = 4096
    tied_output: bool = False

    def flops(self, batch: int = 1) -> int:
        return 0  # gather

    def param_count(self) -> int:
        return self.vocab * self.d_model

    def activation_bytes(self, batch: int = 1, dtype_bytes: int = 4) -> int:
        return batch * self.d_model * dtype_bytes


@dataclasses.dataclass(frozen=True)
class AttentionSpec(LayerSpec):
    """Self/cross attention with GQA.  seq/kv_len are per-call lengths."""

    d_model: int = 4096
    n_heads: int = 32
    n_kv_heads: int = 8
    seq: int = 4096
    kv_len: int = 4096
    causal: bool = True
    window: Optional[int] = None      # sliding-window attention if set
    qkv_bias: bool = False
    cross: bool = False

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def _eff_kv(self) -> int:
        kv = self.kv_len
        if self.window is not None:
            kv = min(kv, self.window)
        return kv

    def flops(self, batch: int = 1) -> int:
        d, h, hk, hd = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim
        proj = 2 * self.seq * d * (h * hd + 2 * hk * hd) + 2 * self.seq * d * d
        kv = self._eff_kv()
        if self.causal and self.kv_len == self.seq and self.window is None:
            scores = 2 * 2 * h * hd * self.seq * self.seq // 2  # causal half
        else:
            scores = 2 * 2 * h * hd * self.seq * kv
        return batch * (proj + scores)

    def param_count(self) -> int:
        d, h, hk, hd = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim
        p = d * h * hd + 2 * d * hk * hd + h * hd * d
        if self.qkv_bias:
            p += h * hd + 2 * hk * hd
        return p

    def activation_bytes(self, batch: int = 1, dtype_bytes: int = 4) -> int:
        kv = self._eff_kv()
        io = self.seq * self.d_model * 2 + 2 * kv * self.n_kv_heads * self.head_dim
        return batch * io * dtype_bytes


@dataclasses.dataclass(frozen=True)
class MLPSpec(LayerSpec):
    """Gated (SwiGLU-style, 3 matrices) or plain (2 matrices) FFN."""

    d_model: int = 4096
    d_ff: int = 14336
    seq: int = 4096
    gated: bool = True

    def flops(self, batch: int = 1) -> int:
        mats = 3 if self.gated else 2
        return batch * 2 * self.seq * self.d_model * self.d_ff * mats

    def param_count(self) -> int:
        mats = 3 if self.gated else 2
        return mats * self.d_model * self.d_ff

    def activation_bytes(self, batch: int = 1, dtype_bytes: int = 4) -> int:
        return batch * self.seq * (2 * self.d_model + self.d_ff) * dtype_bytes


@dataclasses.dataclass(frozen=True)
class MoESpec(LayerSpec):
    """Mixture-of-experts FFN; active FLOPs = top_k experts per token."""

    d_model: int = 4096
    d_ff: int = 14336
    seq: int = 4096
    n_experts: int = 8
    top_k: int = 2
    gated: bool = True

    def flops(self, batch: int = 1) -> int:
        mats = 3 if self.gated else 2
        expert = 2 * self.seq * self.d_model * self.d_ff * mats * self.top_k
        router = 2 * self.seq * self.d_model * self.n_experts
        return batch * (expert + router)

    def param_count(self) -> int:
        mats = 3 if self.gated else 2
        return self.n_experts * mats * self.d_model * self.d_ff + self.d_model * self.n_experts

    def activation_bytes(self, batch: int = 1, dtype_bytes: int = 4) -> int:
        return batch * self.seq * (2 * self.d_model + self.top_k * self.d_ff) * dtype_bytes


@dataclasses.dataclass(frozen=True)
class SSMSpec(LayerSpec):
    """Mamba-1 style selective-SSM block (falcon-mamba) or RG-LRU block."""

    d_model: int = 4096
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    seq: int = 4096
    variant: str = "mamba1"    # or "rglru"

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    def flops(self, batch: int = 1) -> int:
        di, n, L, d = self.d_inner, self.d_state, self.seq, self.d_model
        if self.variant == "mamba1":
            proj = 2 * L * d * (2 * di) + 2 * L * di * d       # in_proj, out_proj
            conv = 2 * L * di * self.d_conv
            dbc = 2 * L * di * (self.d_state * 2 + math.ceil(d / 16))
            scan = L * di * n * 6                               # recurrence ops
            return batch * (proj + conv + dbc + scan)
        # RG-LRU: gates (2 matmuls di x di) + elementwise recurrence
        proj = 2 * L * d * (2 * di) + 2 * L * di * d
        gates = 2 * 2 * L * di * di
        rec = L * di * 8
        return batch * (proj + gates + rec)

    def param_count(self) -> int:
        di, n, d = self.d_inner, self.d_state, self.d_model
        if self.variant == "mamba1":
            dt_rank = math.ceil(d / 16)
            return (d * 2 * di + di * d + di * self.d_conv
                    + di * (dt_rank + 2 * n) + dt_rank * di + di * n + di)
        return d * 2 * di + di * d + 2 * di * di + 2 * di

    def activation_bytes(self, batch: int = 1, dtype_bytes: int = 4) -> int:
        return batch * self.seq * (2 * self.d_model + self.d_inner) * dtype_bytes


# ----------------------------------------------------------------------
# Network = ordered list of layer specs (the paper's "decomposed layers")
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class NetworkSpec:
    name: str
    layers: Tuple[LayerSpec, ...]

    def flops(self, batch: int = 1) -> int:
        return sum(l.flops(batch) for l in self.layers)

    def param_count(self) -> int:
        return sum(l.param_count() for l in self.layers)

    def __iter__(self):
        return iter(self.layers)

    def __len__(self):
        return len(self.layers)


def alexnet_spec() -> NetworkSpec:
    """The paper's experimental network, Table I, verbatim."""
    L = (
        # padding=2 reconciles Table I's 224 -> 55 geometry (the classic
        # AlexNet off-by-one; FLOPs use M_O so counts are unaffected)
        ConvSpec("Conv1", m_i=(224, 224, 3), m_k=(96, 3, 11, 11),
                 m_o=(55, 55, 96), stride=4, padding=2, nonlinearity="relu"),
        ConvSpec("Conv2", m_i=(27, 27, 96), m_k=(256, 96, 5, 5),
                 m_o=(27, 27, 256), stride=1, padding=2, nonlinearity="relu"),
        ConvSpec("Conv3", m_i=(13, 13, 256), m_k=(384, 256, 3, 3),
                 m_o=(13, 13, 384), stride=1, padding=1, nonlinearity="relu"),
        ConvSpec("Conv4", m_i=(13, 13, 384), m_k=(384, 384, 3, 3),
                 m_o=(13, 13, 384), stride=1, padding=1, nonlinearity="relu"),
        ConvSpec("Conv5", m_i=(13, 13, 384), m_k=(256, 384, 3, 3),
                 m_o=(13, 13, 256), stride=1, padding=1, nonlinearity="relu"),
        FCSpec("FC6", m_i=(256, 6, 6), k_o=4096, activation="relu"),
        FCSpec("FC7", m_i=(4096,), k_o=4096, activation="relu"),
        FCSpec("FC8", m_i=(4096,), k_o=1000, activation="softmax"),
    )
    return NetworkSpec("alexnet-table1", L)


def alexnet_full_spec() -> NetworkSpec:
    """Table I network with the LRN + pooling layers that sit between the
    convs in the real AlexNet (the paper's FPGA has LRN/Pool modules,
    Table III, so CNNLab schedules them too)."""
    L = (
        ConvSpec("Conv1", m_i=(224, 224, 3), m_k=(96, 3, 11, 11),
                 m_o=(55, 55, 96), stride=4, padding=2),
        NormSpec("LRN1", m_i=(55, 55, 96), norm_type="lrn", local_size=5),
        PoolSpec("Pool1", m_i=(55, 55, 96), m_o=(27, 27, 96), pool_type="max",
                 stride=2, window=3),
        ConvSpec("Conv2", m_i=(27, 27, 96), m_k=(256, 96, 5, 5),
                 m_o=(27, 27, 256), stride=1, padding=2),
        NormSpec("LRN2", m_i=(27, 27, 256), norm_type="lrn", local_size=5),
        PoolSpec("Pool2", m_i=(27, 27, 256), m_o=(13, 13, 256), pool_type="max",
                 stride=2, window=3),
        ConvSpec("Conv3", m_i=(13, 13, 256), m_k=(384, 256, 3, 3),
                 m_o=(13, 13, 384), stride=1, padding=1),
        ConvSpec("Conv4", m_i=(13, 13, 384), m_k=(384, 384, 3, 3),
                 m_o=(13, 13, 384), stride=1, padding=1),
        ConvSpec("Conv5", m_i=(13, 13, 384), m_k=(256, 384, 3, 3),
                 m_o=(13, 13, 256), stride=1, padding=1),
        PoolSpec("Pool5", m_i=(13, 13, 256), m_o=(6, 6, 256), pool_type="max",
                 stride=2, window=3),
        FCSpec("FC6", m_i=(256, 6, 6), k_o=4096, activation="relu"),
        FCSpec("FC7", m_i=(4096,), k_o=4096, activation="relu"),
        FCSpec("FC8", m_i=(4096,), k_o=1000, activation="softmax"),
    )
    return NetworkSpec("alexnet-full", L)
