"""ExecutionPlan → runnable JAX program.

The paper's Fig. 4: the API forwards requests via the scheduling middleware;
host code offloads threads to CUDA or OpenCL kernels sharing a virtual
memory space.  Here the compiled plan is a single jit program whose per-layer
callables come from whichever engine the scheduler picked — buffers flow
between engines with no copies (XLA owns the 'virtual memory space').
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from .engines import ENGINES_BY_NAME, ExecutionEngine, init_layer_params
from .layer_model import NetworkSpec
from .scheduler import ExecutionPlan, schedule


def init_network_params(net: NetworkSpec, key: jax.Array,
                        dtype=jnp.float32) -> List[Dict[str, jax.Array]]:
    keys = jax.random.split(key, len(net))
    return [init_layer_params(spec, k, dtype) for spec, k in zip(net, keys)]


def reprice_plan(
    plan: ExecutionPlan,
    *,
    engines: Optional[Sequence[ExecutionEngine]] = None,
    price: str = "measured",
    pricer=None,
    batch: Optional[int] = None,
    dtype_bytes: Optional[int] = None,
) -> ExecutionPlan:
    """Re-run the DSE for a plan's network under a different pricing source
    (the paper's profile-then-offload: the analytic plan is a hypothesis;
    the measured plan is what the runtime actually commits to).

    The operating point (batch / dtype) defaults to the one the plan was
    scheduled at.  Candidate engines default to the plan's own engine set
    *plus every buildable engine* — measurement exists precisely to
    reconsider runnable candidates the analytic model dismissed, so a plan
    that analytically collapsed onto one engine can still move."""
    net = NetworkSpec(plan.network, tuple(a.spec for a in plan.assignments))
    if engines is None:
        names = dict.fromkeys(a.engine for a in plan.assignments)
        names.update((e.name, None) for e in ENGINES_BY_NAME.values()
                     if e.buildable)
        engines = tuple(ENGINES_BY_NAME[n] for n in names)
    return schedule(net, engines, objective=plan.objective,
                    batch=plan.batch if batch is None else batch,
                    dtype_bytes=(plan.dtype_bytes if dtype_bytes is None
                                 else dtype_bytes),
                    price=price, pricer=pricer)


def compile_plan(
    plan: ExecutionPlan,
    *,
    engines: Optional[Sequence[ExecutionEngine]] = None,
    fallback: str = "xla",
    price: Optional[str] = None,
    pricer=None,
    batch: Optional[int] = None,
    dtype_bytes: Optional[int] = None,
):
    """Build `f(x, params) -> y` chaining the per-layer engine callables.

    Cost-only engines (the paper's K40/DE5 models) fall back to `fallback`
    for execution — the plan's *analysis* stays on the modeled device, which
    is how the benchmarks replay the paper's numbers while still producing
    real outputs.

    ``price="measured"`` re-prices the plan through the profiling runtime
    before building (no-op if the plan was already measured-priced), so the
    compiled program follows measurements rather than the analytic
    hypothesis.  The plan actually built — re-priced or not — is attached
    to the returned callable as ``.plan``.
    """
    if price is not None and price != plan.pricing:
        plan = reprice_plan(plan, engines=engines, price=price,
                            pricer=pricer, batch=batch,
                            dtype_bytes=dtype_bytes)
    by_name = dict(ENGINES_BY_NAME)
    if engines:
        by_name.update({e.name: e for e in engines})

    fns = []
    for a in plan.assignments:
        eng = by_name[a.engine]
        if not eng.buildable:
            eng = by_name[fallback]
        fns.append(eng.build(a.spec))

    def apply(x: jax.Array, params: List[Dict[str, jax.Array]]) -> jax.Array:
        for fn, p in zip(fns, params):
            x = fn(x, p)
        return x

    apply.plan = plan
    return apply
