"""ExecutionPlan → runnable JAX program.

The paper's Fig. 4: the API forwards requests via the scheduling middleware;
host code offloads threads to CUDA or OpenCL kernels sharing a virtual
memory space.  Here the compiled plan is a single jit program whose per-layer
callables come from whichever engine the scheduler picked — buffers flow
between engines with no copies (XLA owns the 'virtual memory space').
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from .engines import ENGINES_BY_NAME, ExecutionEngine, init_layer_params
from .layer_model import NetworkSpec
from .scheduler import ExecutionPlan


def init_network_params(net: NetworkSpec, key: jax.Array,
                        dtype=jnp.float32) -> List[Dict[str, jax.Array]]:
    keys = jax.random.split(key, len(net))
    return [init_layer_params(spec, k, dtype) for spec, k in zip(net, keys)]


def compile_plan(
    plan: ExecutionPlan,
    *,
    engines: Optional[Sequence[ExecutionEngine]] = None,
    fallback: str = "xla",
):
    """Build `f(x, params) -> y` chaining the per-layer engine callables.

    Cost-only engines (the paper's K40/DE5 models) fall back to `fallback`
    for execution — the plan's *analysis* stays on the modeled device, which
    is how the benchmarks replay the paper's numbers while still producing
    real outputs.
    """
    by_name = dict(ENGINES_BY_NAME)
    if engines:
        by_name.update({e.name: e for e in engines})

    fns = []
    for a in plan.assignments:
        eng = by_name[a.engine]
        if not eng.buildable:
            eng = by_name[fallback]
        fns.append(eng.build(a.spec))

    def apply(x: jax.Array, params: List[Dict[str, jax.Array]]) -> jax.Array:
        for fn, p in zip(fns, params):
            x = fn(x, p)
        return x

    return apply
