"""Execution-engine registry — the paper's 'resource pool' (§III.A, Fig. 2).

Each engine couples (a) a device/cost model the scheduler prices layers on,
and (b) an optional builder that turns a LayerSpec into a runnable JAX
callable ``f(x, params) -> y``.  Two engines are buildable on this target:

* ``xla``    — jnp/lax implementations (kernels/ref.py); XLA fuses them.
* ``pallas`` — the Pallas TPU kernels (kernels/ops.py) with explicit
               BlockSpec VMEM tiling.

The paper's own boards are registered as *cost-only* engines (no builder):
``k40-cudnn``, ``k40-cublas``, ``de5-opencl``.  The scheduler can plan onto
them — that is exactly how benchmarks/bench_fig6 regenerates the paper's
trade-off study — but `plan.compile_plan` requires buildable engines.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops, ref
from . import device_models as dm
from .layer_model import (AttentionSpec, ConvSpec, EmbeddingSpec, FCSpec,
                          LayerSpec, MLPSpec, MoESpec, NormSpec, PoolSpec,
                          SSMSpec)

LayerFn = Callable[..., jax.Array]


@dataclasses.dataclass(frozen=True)
class ExecutionEngine:
    name: str
    device: dm.DeviceModel
    kinds: Tuple[str, ...]                       # layer kinds it can run
    builder: Optional[Callable[[LayerSpec], LayerFn]] = None
    # scheduler hint: fraction of device peak this engine typically reaches
    # (cuDNN vs cuBLAS showed the library matters — §IV.C)
    efficiency: float = 1.0

    def supports(self, spec: LayerSpec) -> bool:
        return spec.kind in self.kinds

    @property
    def buildable(self) -> bool:
        return self.builder is not None

    def build(self, spec: LayerSpec) -> LayerFn:
        if not self.buildable:
            raise ValueError(
                f"engine {self.name} is cost-only (paper device); cannot build")
        if not self.supports(spec):
            raise ValueError(f"engine {self.name} does not support {spec.kind}")
        return self.builder(spec)


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------
def _build_xla(spec: LayerSpec) -> LayerFn:
    if isinstance(spec, ConvSpec):
        return functools.partial(
            _conv_apply, impl=ref.conv2d_ref, stride=spec.stride,
            padding=spec.padding, activation=spec.nonlinearity)
    if isinstance(spec, FCSpec):
        return functools.partial(_fc_apply, impl=ref.fc_ref,
                                 activation=spec.activation)
    if isinstance(spec, PoolSpec):
        impl = ref.maxpool_ref if spec.pool_type == "max" else ref.avgpool_ref
        return lambda x, params: impl(x, window=spec.window, stride=spec.stride)
    if isinstance(spec, NormSpec) and spec.norm_type == "lrn":
        return lambda x, params: ref.lrn_ref(
            x, local_size=spec.local_size, alpha=spec.alpha, beta=spec.beta)
    raise NotImplementedError(f"xla builder: {type(spec).__name__}")


def _build_pallas(spec: LayerSpec) -> LayerFn:
    if isinstance(spec, ConvSpec):
        return functools.partial(
            _conv_apply, impl=ops.conv2d, stride=spec.stride,
            padding=spec.padding, activation=spec.nonlinearity)
    if isinstance(spec, FCSpec):
        return functools.partial(_fc_apply, impl=ops.fc,
                                 activation=spec.activation)
    if isinstance(spec, PoolSpec):
        return lambda x, params: ops.pool(
            x, window=spec.window, stride=spec.stride, pool_type=spec.pool_type)
    if isinstance(spec, NormSpec) and spec.norm_type == "lrn":
        return lambda x, params: ops.lrn(
            x, local_size=spec.local_size, alpha=spec.alpha, beta=spec.beta)
    raise NotImplementedError(f"pallas builder: {type(spec).__name__}")


def _conv_apply(x, params, *, impl, stride, padding, activation):
    return impl(x, params["w"], params.get("b"), stride=stride,
                padding=padding, activation=activation)


def _fc_apply(x, params, *, impl, activation):
    if x.ndim > 2:
        x = x.reshape(x.shape[0], -1)
    return impl(x, params["w"], params.get("b"), activation=activation)


# ---------------------------------------------------------------------------
# Parameter init (specs are declarative; engines share one param layout)
# ---------------------------------------------------------------------------
def init_layer_params(spec: LayerSpec, key: jax.Array,
                      dtype=jnp.float32) -> Dict[str, jax.Array]:
    if isinstance(spec, ConvSpec):
        oc, ic, kh, kw = spec.m_k
        fan_in = ic * kh * kw
        w = jax.random.normal(key, (oc, ic, kh, kw), dtype) * (2.0 / fan_in) ** 0.5
        return {"w": w, "b": jnp.zeros((oc,), dtype)}
    if isinstance(spec, FCSpec):
        w = jax.random.normal(key, (spec.n_in, spec.k_o), dtype) * (
            2.0 / spec.n_in) ** 0.5
        return {"w": w, "b": jnp.zeros((spec.k_o,), dtype)}
    return {}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_CNN_KINDS = ("conv", "fc", "pool", "norm")

XLA_ENGINE = ExecutionEngine(
    name="xla", device=dm.TPU_V5E, kinds=_CNN_KINDS + (
        "attention", "mlp", "moe", "ssm", "embedding"),
    builder=_build_xla, efficiency=0.55)
PALLAS_ENGINE = ExecutionEngine(
    name="pallas", device=dm.TPU_V5E, kinds=_CNN_KINDS + ("attention",),
    builder=_build_pallas, efficiency=0.75)

# cost-only paper devices
K40_CUDNN_ENGINE = ExecutionEngine(
    name="k40-cudnn", device=dm.K40_CUDNN, kinds=_CNN_KINDS)
K40_CUBLAS_ENGINE = ExecutionEngine(
    name="k40-cublas", device=dm.K40_CUBLAS, kinds=_CNN_KINDS)
K40_ENGINE = ExecutionEngine(name="k40", device=dm.K40, kinds=_CNN_KINDS)
DE5_ENGINE = ExecutionEngine(name="de5-opencl", device=dm.DE5, kinds=_CNN_KINDS)

DEFAULT_ENGINES = (XLA_ENGINE, PALLAS_ENGINE)
PAPER_ENGINES = (K40_ENGINE, DE5_ENGINE)
ALL_ENGINES = DEFAULT_ENGINES + PAPER_ENGINES + (
    K40_CUDNN_ENGINE, K40_CUBLAS_ENGINE)

ENGINES_BY_NAME = {e.name: e for e in ALL_ENGINES}
