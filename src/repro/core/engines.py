"""Execution-engine registry — the paper's 'resource pool' (§III.A, Fig. 2).

Each engine couples (a) a device/cost model the scheduler prices layers on,
and (b) an optional builder that turns a LayerSpec into a runnable JAX
callable ``f(x, params) -> y``.  Two engines are buildable on this target:

* ``xla``    — jnp/lax implementations (kernels/ref.py); XLA fuses them.
* ``pallas`` — the Pallas TPU kernels (kernels/ops.py) with explicit
               BlockSpec VMEM tiling.

The paper's own boards are registered as *cost-only* engines (no builder):
``k40-cudnn``, ``k40-cublas``, ``de5-opencl``.  The scheduler can plan onto
them — that is exactly how benchmarks/bench_fig6 regenerates the paper's
trade-off study — but `plan.compile_plan` requires buildable engines.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops, ref
from . import device_models as dm
from .layer_model import (AttentionSpec, ConvSpec, FCSpec, LayerSpec,
                          MLPSpec, MoESpec, NormSpec, PoolSpec, SSMSpec)

LayerFn = Callable[..., jax.Array]


@dataclasses.dataclass(frozen=True)
class ExecutionEngine:
    name: str
    device: dm.DeviceModel
    kinds: Tuple[str, ...]                       # layer kinds it can run
    builder: Optional[Callable[[LayerSpec], LayerFn]] = None
    # scheduler hint: fraction of device peak this engine typically reaches
    # (cuDNN vs cuBLAS showed the library matters — §IV.C)
    efficiency: float = 1.0

    def supports(self, spec: LayerSpec) -> bool:
        return spec.kind in self.kinds

    @property
    def buildable(self) -> bool:
        return self.builder is not None

    def build(self, spec: LayerSpec) -> LayerFn:
        if not self.buildable:
            raise ValueError(
                f"engine {self.name} is cost-only (paper device); cannot build")
        if not self.supports(spec):
            raise ValueError(f"engine {self.name} does not support {spec.kind}")
        return self.builder(spec)


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------
def _build_xla(spec: LayerSpec) -> LayerFn:
    if isinstance(spec, ConvSpec):
        return functools.partial(
            _conv_apply, impl=ref.conv2d_ref, stride=spec.stride,
            padding=spec.padding, activation=spec.nonlinearity)
    if isinstance(spec, FCSpec):
        return functools.partial(_fc_apply, impl=ref.fc_ref,
                                 activation=spec.activation)
    if isinstance(spec, PoolSpec):
        impl = ref.maxpool_ref if spec.pool_type == "max" else ref.avgpool_ref
        return lambda x, params: impl(x, window=spec.window, stride=spec.stride)
    if isinstance(spec, NormSpec) and spec.norm_type == "lrn":
        return lambda x, params: ref.lrn_ref(
            x, local_size=spec.local_size, alpha=spec.alpha, beta=spec.beta)
    if isinstance(spec, AttentionSpec):
        return functools.partial(_attention_apply, spec=spec)
    if isinstance(spec, MLPSpec):
        return functools.partial(_mlp_apply, gated=spec.gated)
    if isinstance(spec, MoESpec):
        return functools.partial(_moe_apply, top_k=spec.top_k,
                                 gated=spec.gated)
    if isinstance(spec, SSMSpec):
        return functools.partial(_ssm_apply, spec=spec)
    raise NotImplementedError(f"xla builder: {type(spec).__name__}")


def _build_pallas(spec: LayerSpec) -> LayerFn:
    if isinstance(spec, ConvSpec):
        return functools.partial(
            _conv_apply, impl=ops.conv2d, stride=spec.stride,
            padding=spec.padding, activation=spec.nonlinearity)
    if isinstance(spec, FCSpec):
        return functools.partial(_fc_apply, impl=ops.fc,
                                 activation=spec.activation)
    if isinstance(spec, PoolSpec):
        return lambda x, params: ops.pool(
            x, window=spec.window, stride=spec.stride, pool_type=spec.pool_type)
    if isinstance(spec, NormSpec) and spec.norm_type == "lrn":
        return lambda x, params: ops.lrn(
            x, local_size=spec.local_size, alpha=spec.alpha, beta=spec.beta)
    raise NotImplementedError(f"pallas builder: {type(spec).__name__}")


def _conv_apply(x, params, *, impl, stride, padding, activation):
    return impl(x, params["w"], params.get("b"), stride=stride,
                padding=padding, activation=activation)


def _fc_apply(x, params, *, impl, activation):
    if x.ndim > 2:
        x = x.reshape(x.shape[0], -1)
    return impl(x, params["w"], params.get("b"), activation=activation)


# ---------------------------------------------------------------------------
# Decode-step builders (attention / mlp / moe / ssm).
#
# These run the serving phases' layer kinds as standalone callables so the
# profiling harness can *measure* what admission and phase placement price
# (ROADMAP: "profile the decode-step spec kinds").  Each mirrors the FLOP
# structure its spec declares: attention scores q against a KV cache of
# ``kv_len`` entries held in params; MoE routes each token to top_k experts;
# SSM advances the recurrence over ``seq`` steps from a zero state.
# ---------------------------------------------------------------------------
def _attention_apply(x, params, *, spec):
    # x: (B, S, D).  Cached K/V live in params (per-call lengths are part of
    # the spec tuple) and are shared across the batch — the projection,
    # score and output FLOPs match AttentionSpec.flops.
    b, s, _ = x.shape
    h, hk, hd = spec.n_heads, spec.n_kv_heads, spec.head_dim
    q = jnp.einsum("bsd,dk->bsk", x, params["wq"])
    k_new = jnp.einsum("bsd,dk->bsk", x, params["wk"])
    v_new = jnp.einsum("bsd,dk->bsk", x, params["wv"])
    if spec.qkv_bias:
        q = q + params["bq"]
        k_new = k_new + params["bk"]
        v_new = v_new + params["bv"]
    q = q.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k_new = k_new.reshape(b, s, hk, hd).transpose(0, 2, 1, 3)
    v_new = v_new.reshape(b, s, hk, hd).transpose(0, 2, 1, 3)
    kv = spec._eff_kv()
    if kv > s:
        # prepend the cached prefix so the freshly projected K/V stay live
        # (the decode step both reads the cache and appends to it)
        pre_k = jnp.broadcast_to(params["k_cache"][None, :, :kv - s],
                                 (b, hk, kv - s, hd))
        pre_v = jnp.broadcast_to(params["v_cache"][None, :, :kv - s],
                                 (b, hk, kv - s, hd))
        k = jnp.concatenate([pre_k, k_new], axis=2)
        v = jnp.concatenate([pre_v, v_new], axis=2)
    else:
        k, v = k_new[:, :, :kv], v_new[:, :, :kv]
    if h != hk:
        k = jnp.repeat(k, h // hk, axis=1)
        v = jnp.repeat(v, h // hk, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / (hd ** 0.5)
    if spec.causal and s > 1:
        mask = jnp.tril(jnp.ones((s, kv), bool), k=kv - s)
        scores = jnp.where(mask, scores, jnp.finfo(scores.dtype).min)
    out = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(scores, axis=-1), v)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    return jnp.einsum("bsk,kd->bsd", out, params["wo"])


def _mlp_apply(x, params, *, gated):
    if gated:
        hmid = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    else:
        hmid = jax.nn.gelu(x @ params["w_up"])
    return hmid @ params["w_down"]


def _moe_apply(x, params, *, top_k, gated):
    b, s, d = x.shape
    flat = x.reshape(b * s, d)
    logits = flat @ params["w_router"]                    # (T, E)
    weights, idx = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), top_k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    up = params["w_up"][idx]                              # (T, k, d, f)
    down = params["w_down"][idx]                          # (T, k, f, d)
    if gated:
        gate = params["w_gate"][idx]
        hmid = jax.nn.silu(jnp.einsum("td,tkdf->tkf", flat, gate)) * \
            jnp.einsum("td,tkdf->tkf", flat, up)
    else:
        hmid = jax.nn.gelu(jnp.einsum("td,tkdf->tkf", flat, up))
    out = jnp.einsum("tkf,tkfd->tkd", hmid, down)
    out = jnp.sum(out * weights[..., None], axis=1)
    return out.reshape(b, s, d)


def _ssm_apply(x, params, *, spec):
    b, s, _ = x.shape
    di = spec.d_inner
    xz = x @ params["in_proj"]                            # (B, S, 2*di)
    xs, z = xz[..., :di], xz[..., di:]
    if spec.variant == "mamba1":
        n = spec.d_state
        # causal depthwise conv over the sequence
        pad = jnp.pad(xs, ((0, 0), (spec.d_conv - 1, 0), (0, 0)))
        conv = sum(pad[:, i:i + s] * params["conv_w"][:, i]
                   for i in range(spec.d_conv))
        u = jax.nn.silu(conv)
        dbc = u @ params["x_proj"]                        # (B, S, dt+2n)
        dt_rank = params["dt_proj"].shape[0]
        dt = jax.nn.softplus(dbc[..., :dt_rank] @ params["dt_proj"])
        bmat, cmat = dbc[..., dt_rank:dt_rank + n], dbc[..., dt_rank + n:]
        a = -jnp.exp(params["a_log"])                     # (di, n)

        def step(hstate, inputs):
            u_t, dt_t, b_t, c_t = inputs
            da = jnp.exp(dt_t[..., None] * a)             # (B, di, n)
            hstate = da * hstate + (dt_t * u_t)[..., None] * b_t[:, None]
            return hstate, jnp.einsum("bdn,bn->bd", hstate, c_t)

        h0 = jnp.zeros((b, di, n), x.dtype)
        xs_t = (u.transpose(1, 0, 2), dt.transpose(1, 0, 2),
                bmat.transpose(1, 0, 2), cmat.transpose(1, 0, 2))
        _, ys = jax.lax.scan(step, h0, xs_t)
        y = ys.transpose(1, 0, 2) + u * params["d_skip"]
    else:                                                 # rglru
        r = jax.nn.sigmoid(xs @ params["w_r"])
        i = jax.nn.sigmoid(xs @ params["w_i"])
        log_a = -8.0 * jax.nn.softplus(params["a_param"]) * r

        def step(hstate, inputs):
            x_t, la_t, i_t = inputs
            a_t = jnp.exp(la_t)
            hstate = a_t * hstate + jnp.sqrt(
                jnp.maximum(1.0 - a_t * a_t, 0.0)) * (i_t * x_t)
            return hstate, hstate

        h0 = jnp.zeros((b, di), x.dtype)
        xs_t = (xs.transpose(1, 0, 2), log_a.transpose(1, 0, 2),
                i.transpose(1, 0, 2))
        _, ys = jax.lax.scan(step, h0, xs_t)
        y = ys.transpose(1, 0, 2)
    return (y * jax.nn.silu(z)) @ params["out_proj"]


# ---------------------------------------------------------------------------
# Parameter init (specs are declarative; engines share one param layout)
# ---------------------------------------------------------------------------
def init_layer_params(spec: LayerSpec, key: jax.Array,
                      dtype=jnp.float32) -> Dict[str, jax.Array]:
    def dense(k, shape, fan_in=None):
        fan_in = fan_in or shape[0]
        return jax.random.normal(k, shape, dtype) * (2.0 / fan_in) ** 0.5

    if isinstance(spec, ConvSpec):
        oc, ic, kh, kw = spec.m_k
        w = dense(key, (oc, ic, kh, kw), fan_in=ic * kh * kw)
        return {"w": w, "b": jnp.zeros((oc,), dtype)}
    if isinstance(spec, FCSpec):
        return {"w": dense(key, (spec.n_in, spec.k_o)),
                "b": jnp.zeros((spec.k_o,), dtype)}
    if isinstance(spec, AttentionSpec):
        ks = jax.random.split(key, 6)
        d, h, hk, hd = (spec.d_model, spec.n_heads, spec.n_kv_heads,
                        spec.head_dim)
        p = {"wq": dense(ks[0], (d, h * hd)),
             "wk": dense(ks[1], (d, hk * hd)),
             "wv": dense(ks[2], (d, hk * hd)),
             "wo": dense(ks[3], (h * hd, d)),
             "k_cache": jax.random.normal(ks[4], (hk, spec._eff_kv(), hd),
                                          dtype),
             "v_cache": jax.random.normal(ks[5], (hk, spec._eff_kv(), hd),
                                          dtype)}
        if spec.qkv_bias:
            p["bq"] = jnp.zeros((h * hd,), dtype)
            p["bk"] = jnp.zeros((hk * hd,), dtype)
            p["bv"] = jnp.zeros((hk * hd,), dtype)
        return p
    if isinstance(spec, MLPSpec):
        ks = jax.random.split(key, 3)
        d, f = spec.d_model, spec.d_ff
        p = {"w_up": dense(ks[0], (d, f)), "w_down": dense(ks[1], (f, d))}
        if spec.gated:
            p["w_gate"] = dense(ks[2], (d, f))
        return p
    if isinstance(spec, MoESpec):
        ks = jax.random.split(key, 4)
        d, f, e = spec.d_model, spec.d_ff, spec.n_experts
        p = {"w_router": dense(ks[0], (d, e)),
             "w_up": dense(ks[1], (e, d, f), fan_in=d),
             "w_down": dense(ks[2], (e, f, d), fan_in=f)}
        if spec.gated:
            p["w_gate"] = dense(ks[3], (e, d, f), fan_in=d)
        return p
    if isinstance(spec, SSMSpec):
        ks = jax.random.split(key, 8)
        d, di, n = spec.d_model, spec.d_inner, spec.d_state
        p = {"in_proj": dense(ks[0], (d, 2 * di)),
             "out_proj": dense(ks[1], (di, d))}
        if spec.variant == "mamba1":
            dt_rank = -(-d // 16)        # ceil(d / 16), matches SSMSpec.flops
            p.update({
                "conv_w": dense(ks[2], (di, spec.d_conv), fan_in=spec.d_conv),
                "x_proj": dense(ks[3], (di, dt_rank + 2 * n)),
                "dt_proj": dense(ks[4], (dt_rank, di)),
                "a_log": jnp.log(jnp.broadcast_to(
                    jnp.arange(1, n + 1, dtype=dtype), (di, n))),
                "d_skip": jnp.ones((di,), dtype),
            })
        else:
            p.update({"w_r": dense(ks[2], (di, di)),
                      "w_i": dense(ks[3], (di, di)),
                      "a_param": jnp.ones((di,), dtype)})
        return p
    return {}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_CNN_KINDS = ("conv", "fc", "pool", "norm")
_LM_KINDS = ("attention", "mlp", "moe", "ssm", "embedding")

XLA_ENGINE = ExecutionEngine(
    name="xla", device=dm.TPU_V5E, kinds=_CNN_KINDS + _LM_KINDS,
    builder=_build_xla, efficiency=0.55)
PALLAS_ENGINE = ExecutionEngine(
    name="pallas", device=dm.TPU_V5E, kinds=_CNN_KINDS + ("attention",),
    builder=_build_pallas, efficiency=0.75)

# cost-only paper devices
K40_CUDNN_ENGINE = ExecutionEngine(
    name="k40-cudnn", device=dm.K40_CUDNN, kinds=_CNN_KINDS)
K40_CUBLAS_ENGINE = ExecutionEngine(
    name="k40-cublas", device=dm.K40_CUBLAS, kinds=_CNN_KINDS)
K40_ENGINE = ExecutionEngine(name="k40", device=dm.K40, kinds=_CNN_KINDS)
DE5_ENGINE = ExecutionEngine(name="de5-opencl", device=dm.DE5, kinds=_CNN_KINDS)

# cost-only roofline variants of the paper boards covering the LM kinds —
# the engine set phase placement (repro.serving.placement) prices the
# prefill/decode split on (the paper's GPU/FPGA stage split, applied to the
# two serving phases)
K40_LM_ENGINE = ExecutionEngine(
    name="k40-roofline", device=dm.K40_ROOFLINE, kinds=_CNN_KINDS + _LM_KINDS)
DE5_LM_ENGINE = ExecutionEngine(
    name="de5-roofline", device=dm.DE5_ROOFLINE, kinds=_CNN_KINDS + _LM_KINDS)

DEFAULT_ENGINES = (XLA_ENGINE, PALLAS_ENGINE)
PAPER_ENGINES = (K40_ENGINE, DE5_ENGINE)
# the candidate set for per-phase serving placement; NOT part of ALL_ENGINES
# so the paper-replay DSE benchmarks keep scheduling on the boards as
# measured, not their idealized roofline twins
PLACEMENT_ENGINES = (XLA_ENGINE, K40_LM_ENGINE, DE5_LM_ENGINE)
ALL_ENGINES = DEFAULT_ENGINES + PAPER_ENGINES + (
    K40_CUDNN_ENGINE, K40_CUBLAS_ENGINE)

ENGINES_BY_NAME = {e.name: e for e in ALL_ENGINES + (K40_LM_ENGINE,
                                                     DE5_LM_ENGINE)}
