"""CNNLab core: the paper primary contribution in JAX.

Layer tuples (III.B) -> device models -> cost model -> engine registry ->
DSE scheduler -> execution plan -> trade-off analysis (IV).
"""
