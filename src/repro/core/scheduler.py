"""CNNLab runtime scheduler: design-space exploration with trade-off analysis.

The paper (§III.A): "the structure of the NN input model will undergo the
design space exploration and trade-off analysis in the middleware support
... this process yields a succession of hardware mappings of the NN model
onto the particular FPGA-based or GPU-based platforms".

Here: for every layer tuple, enumerate candidate (engine) mappings, price
each with the cost model, and pick per the user's objective.  Because layer
costs are independent given the engine set (layers execute in sequence,
§II), per-layer argmin IS the global optimum for separable objectives —
`tests/test_scheduler.py` proves this against exhaustive search.  For the
non-separable power-capped objective we schedule cheapest-under-cap.

A plan also carries per-layer *offload overhead* (the paper's PCIe sync,
Fig. 5 step 4): switching engines between adjacent layers costs the
activation transfer at link bandwidth.  This is what makes "all FC on GPU,
all conv wherever" style plans emerge exactly as the paper observed.

Pricing sources: ``price="analytic"`` (default) uses the static device
models; ``price="measured"`` is the paper's profile-then-offload runtime
flow — candidates are priced from the empirical profile cache
(``repro.profiling``), measuring on miss, and fall back to analytic for
anything unmeasurable (cost-only paper devices, backward passes).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Optional, Sequence, Tuple

from .cost_model import CostBreakdown, layer_cost, objective_value
from .engines import ExecutionEngine
from .layer_model import LayerSpec, NetworkSpec


@dataclasses.dataclass(frozen=True)
class Assignment:
    spec: LayerSpec
    engine: str
    cost: CostBreakdown


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    network: str
    objective: str
    assignments: Tuple[Assignment, ...]
    pricing: str = "analytic"            # "analytic" | "measured"
    # the operating point the plan was priced at (re-pricing preserves it)
    batch: int = 1
    dtype_bytes: int = 4

    @property
    def total_time(self) -> float:
        return sum(a.cost.t_total for a in self.assignments)

    @property
    def total_energy(self) -> float:
        return sum(a.cost.energy_j for a in self.assignments)

    @property
    def peak_power(self) -> float:
        return max((a.cost.power_w for a in self.assignments), default=0.0)

    def total_objective(self) -> float:
        return sum(objective_value(a.cost, self.objective)
                   for a in self.assignments)

    def engine_of(self, layer_name: str) -> str:
        for a in self.assignments:
            if a.spec.name == layer_name:
                return a.engine
        raise KeyError(layer_name)

    def offload_overhead(self, engines_by_name=None):
        """Per-boundary engine-switch costs (the paper's PCIe sync, Fig. 5
        step 4): wherever adjacent layers run on different engines, the
        producer's output activation crosses at link bandwidth.  Returns
        ``[(layer_a, layer_b, TransferCost), ...]`` for the switching
        boundaries; total extra seconds = sum of ``t_transfer``."""
        from .cost_model import transfer_cost
        from .engines import ENGINES_BY_NAME
        by_name = engines_by_name or ENGINES_BY_NAME
        out = []
        for a, b in zip(self.assignments, self.assignments[1:]):
            if a.engine == b.engine:
                continue
            n_bytes = a.spec.activation_bytes(
                self.batch, self.dtype_bytes) // 2   # producer's output half
            out.append((a.spec.name, b.spec.name, transfer_cost(
                n_bytes, by_name[a.engine].device, by_name[b.engine].device)))
        return out

    def summary(self) -> str:
        rows = [f"{'layer':<8} {'kind':<6} {'engine':<12} "
                f"{'time(ms)':>10} {'GFLOPS':>9} {'W':>7} {'mJ':>9}"]
        for a in self.assignments:
            c = a.cost
            rows.append(
                f"{a.spec.name:<8} {c.kind:<6} {a.engine:<12} "
                f"{c.t_total*1e3:>10.4f} {c.throughput/1e9:>9.1f} "
                f"{c.power_w:>7.2f} {c.energy_j*1e3:>9.4f}")
        rows.append(f"total: {self.total_time*1e3:.3f} ms, "
                    f"{self.total_energy:.4f} J, peak {self.peak_power:.1f} W")
        return "\n".join(rows)


def _candidate_costs(
    spec: LayerSpec,
    engines: Sequence[ExecutionEngine],
    *,
    batch: int,
    dtype_bytes: int,
    n_chips: int,
    direction: str,
    pricer=None,
) -> Dict[str, CostBreakdown]:
    out = {}
    for eng in engines:
        if not eng.supports(spec):
            continue
        cost = None
        if pricer is not None:
            cost = pricer.price(spec, eng, batch=batch,
                                dtype_bytes=dtype_bytes, n_chips=n_chips,
                                direction=direction)
        if cost is None:                 # analytic model (or pricer declined)
            eff = eng.efficiency if eng.device.analytic else 1.0
            cost = layer_cost(
                spec, eng.device, batch=batch, dtype_bytes=dtype_bytes,
                n_chips=n_chips, direction=direction, mxu_efficiency=eff)
        out[eng.name] = cost
    if not out:
        raise ValueError(f"no engine supports layer {spec.name} ({spec.kind})")
    return out


def _resolve_pricer(price: str, pricer):
    if price not in ("analytic", "measured"):
        raise ValueError(f"unknown pricing source: {price!r}")
    if price == "analytic":
        return None
    if pricer is None:
        from ..profiling.pricer import MeasuredPricer  # avoid import cycle
        pricer = MeasuredPricer()
    return pricer


def schedule(
    net: NetworkSpec,
    engines: Sequence[ExecutionEngine],
    *,
    objective: str = "latency",
    batch: int = 1,
    dtype_bytes: int = 4,
    n_chips: int = 1,
    direction: str = "fwd",
    power_cap_w: Optional[float] = None,
    price: str = "analytic",
    pricer=None,
) -> ExecutionPlan:
    """Per-layer DSE.  `power_cap_w` adds the paper's motivating constraint
    ("data centers quite power consuming"): only engines whose running power
    fits the cap are eligible; if none fit, the lowest-power engine wins.

    ``price="measured"`` prices buildable candidates from the profiling
    runtime (cache-on-hit, measure-on-miss); pass a configured
    ``repro.profiling.MeasuredPricer`` as ``pricer`` to control the cache
    location / measurement budget, else a default one is built.
    """
    pricer = _resolve_pricer(price, pricer)
    assignments = []
    for spec in net:
        cands = _candidate_costs(spec, engines, batch=batch,
                                 dtype_bytes=dtype_bytes, n_chips=n_chips,
                                 direction=direction, pricer=pricer)
        pool = cands
        if power_cap_w is not None:
            capped = {n: c for n, c in cands.items() if c.power_w <= power_cap_w}
            pool = capped or {min(cands, key=lambda n: cands[n].power_w):
                              cands[min(cands, key=lambda n: cands[n].power_w)]}
        best = min(pool, key=lambda n: objective_value(pool[n], objective))
        assignments.append(Assignment(spec, best, pool[best]))
    return ExecutionPlan(net.name, objective, tuple(assignments),
                         pricing=price, batch=batch, dtype_bytes=dtype_bytes)


def schedule_exhaustive(
    net: NetworkSpec,
    engines: Sequence[ExecutionEngine],
    *,
    objective: str = "latency",
    batch: int = 1,
    dtype_bytes: int = 4,
    n_chips: int = 1,
    direction: str = "fwd",
) -> ExecutionPlan:
    """Brute-force over the full engine-assignment product.  Exponential —
    test/validation use only (proves the greedy scheduler optimal for
    separable objectives)."""
    per_layer = [
        _candidate_costs(s, engines, batch=batch, dtype_bytes=dtype_bytes,
                         n_chips=n_chips, direction=direction)
        for s in net
    ]
    best_plan, best_val = None, float("inf")
    for combo in itertools.product(*[sorted(c) for c in per_layer]):
        val = sum(objective_value(per_layer[i][name], objective)
                  for i, name in enumerate(combo))
        if val < best_val:
            best_val = val
            best_plan = combo
    assignments = tuple(
        Assignment(spec, name, per_layer[i][name])
        for i, (spec, name) in enumerate(zip(net, best_plan)))
    return ExecutionPlan(net.name, objective, assignments)
