"""Chrome trace-event / Perfetto JSON export + flat metrics dump.

The tracer records seconds on the run timeline; Chrome trace-event wants
microseconds, per-track ``process_name`` metadata, and *strict* JSON (the
``chrome://tracing`` and Perfetto loaders reject the non-standard ``NaN``
token, so both writers pass ``allow_nan=False`` — a NaN reaching export is
a bug upstream, not something to paper over).

Track mapping: each :meth:`Tracer.track` name becomes one pid with an
``M``/``process_name`` record (``server``, ``requests``,
``engine:<name>``); tids within a track are request ids (``requests``) or
slot 0 (engine tracks).  Request lifecycle spans carry their args
(priced vs observed cost, block lease sizes) through to Perfetto's span
detail pane.
"""
from __future__ import annotations

import json
from typing import Optional

__all__ = ["chrome_trace", "trace_health", "write_metrics", "write_trace"]

_US = 1e6


def _safe(v):
    """JSON-strict coercion for span args: numpy scalars -> python, floats
    that cannot serialize (nan/inf) -> None, unknown objects -> repr."""
    if isinstance(v, dict):
        return {str(k): _safe(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_safe(x) for x in v]
    if isinstance(v, bool) or v is None or isinstance(v, (int, str)):
        return v
    if hasattr(v, "item"):            # numpy scalar
        v = v.item()
    if isinstance(v, float):
        return v if v == v and abs(v) != float("inf") else None
    if isinstance(v, int):
        return v
    return repr(v)


def chrome_trace(tracer) -> dict:
    """The tracer's buffer as a Chrome trace-event object (JSON-safe)."""
    events = []
    for name, pid in tracer.tracks.items():
        events.append({"name": "process_name", "ph": "M", "pid": pid,
                       "tid": 0, "args": {"name": name}})
    for ev in tracer.events:
        rec = {"name": ev.name, "ph": ev.ph, "cat": ev.cat,
               "ts": round(ev.ts * _US, 3), "pid": ev.pid, "tid": ev.tid}
        if ev.ph == "X":
            rec["dur"] = round((ev.dur or 0.0) * _US, 3)
        elif ev.ph == "i":
            rec["s"] = "t"               # instant scope: thread
        if ev.args:
            rec["args"] = _safe(ev.args)
        events.append(rec)
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"n_dropped": tracer.n_dropped,
                          "n_open": tracer.n_open}}


def write_trace(tracer, path: str) -> str:
    """Dump the trace as strict JSON (loads in Perfetto /
    ``chrome://tracing``)."""
    with open(path, "w") as f:
        json.dump(chrome_trace(tracer), f, allow_nan=False)
    return path


def trace_health(tracer) -> dict:
    """Ring-buffer accounting for the metrics snapshot.

    A saturated ring silently drops the oldest spans, so an exported trace
    can *look* complete while missing the run's start; surfacing
    ``n_dropped`` (and still-open span count) next to the metrics makes
    the truncation visible without opening the trace itself.
    """
    return {"n_events": len(tracer.events),
            "n_dropped": tracer.n_dropped,
            "n_open": tracer.n_open,
            "enabled": bool(tracer.enabled)}


def write_metrics(registry, path: str, *, tracer=None,
                  extra: Optional[dict] = None) -> str:
    """Dump the registry snapshot (counters, gauges, histogram summaries,
    sampled time series) as strict JSON; ``tracer`` adds its ring-buffer
    health under ``trace``; ``extra`` merges top-level keys (e.g. the
    run's ServeMetrics summary)."""
    data = registry.snapshot()
    if tracer is not None:
        data["trace"] = trace_health(tracer)
    if extra:
        data.update(_safe(extra))
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True, allow_nan=False)
    return path
