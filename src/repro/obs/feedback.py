"""Telemetry feedback: serving burst timings -> profiling-cache entries.

ROADMAP's "online recalibration" starts here: every decode burst the
serving loop dispatches is a free measurement of the decode network at
``batch = n_active`` — the exact quantity ``ContinuousBatcher`` prices at
admission and ``schedule(..., price="measured")`` looks up.  This module
turns those observations into :class:`~repro.profiling.bench.Measurement`
-shaped cache entries so ``MeasuredPricer`` learns from production traffic
without a dedicated profiling run.

Apportioning: a burst observes the *whole* decode step (all layers fused
into one scanned dispatch), but the cache is keyed per layer spec.  The
observed per-step time is split across
:func:`~repro.serving.batcher.decode_network_spec`'s layers by FLOP share
— the same weighting the analytic cost model uses — so per-layer entries
sum back to the observed step and each carries a correct
``achieved_flops``.  Zero-FLOP layers (embedding gather) are skipped; the
pricer could never use a zero-time entry anyway.

Keying: entries are fingerprinted with the profiling cache's own
:func:`~repro.profiling.cache.fingerprint` (spec + batch + dtype) under
the current (jax version, backend) environment and ``engine="xla"`` — the
engine that actually executed the burst — so lookups hit if and only if
they ask for what serving ran.  A ``"source": "serving-telemetry"`` field
distinguishes fed points from bench-harness ones (extra fields survive
the cache schema; ``Measurement.from_dict`` ignores them).

Timing hygiene: burst dispatch is async, so the loop syncs the engine
before stamping the burst end — the observation is device wall time, not
host enqueue time.  The sync only *waits* (it never changes what was
computed), so feeding the cache preserves output bit-identity.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

__all__ = ["TelemetryFeedback"]


class TelemetryFeedback:
    """Accumulates per-burst step timings; flushes cache entries."""

    def __init__(self, cfg, *, kv_len: int, engine: str = "xla",
                 dtype: str = "float32"):
        """``kv_len`` should be the KV pool's ``max_seq`` — the length
        admission prices with (``decode_network_spec(cfg, pool.max_seq)``),
        so fed entries answer the same lookups pricing makes."""
        self.cfg = cfg
        self.kv_len = int(kv_len)
        self.engine = engine
        self.dtype = dtype
        # batch (= tokens per step) -> observed per-step seconds
        self._step_s: Dict[int, List[float]] = {}
        self.n_bursts = 0

    def observe_burst(self, n_tokens: int, steps: int,
                      elapsed_s: float) -> None:
        """One synced decode burst: ``steps`` engine iterations carrying
        ``n_tokens`` tokens each took ``elapsed_s`` of wall time."""
        if n_tokens <= 0 or steps <= 0 or elapsed_s <= 0:
            return
        self._step_s.setdefault(int(n_tokens), []).append(elapsed_s / steps)
        self.n_bursts += 1

    @property
    def batches(self) -> List[int]:
        """Token-per-step batch sizes observed so far."""
        return sorted(self._step_s)

    def measurements(self) -> List[dict]:
        """Cache-entry dicts for every observed batch size."""
        # lazy imports: keep repro.obs importable without jax/serving
        from ..profiling import cache as cache_lib
        from ..serving.batcher import decode_network_spec

        net = decode_network_spec(self.cfg, self.kv_len)
        env = cache_lib.environment()
        out: List[dict] = []
        for batch, times in sorted(self._step_s.items()):
            xs = np.asarray(times)
            q25, q50, q75 = np.percentile(xs, (25, 50, 75))
            flops = [l.flops(batch) for l in net]
            total = sum(flops)
            if total <= 0:
                continue
            for spec, fl in zip(net, flops):
                if fl <= 0:
                    continue             # gather layers: nothing to price
                share = fl / total
                if float(q50) * share <= 0.0:
                    # a tiny FLOP share can underflow the apportioned time
                    # to 0.0; a 0-cost cache entry would price the layer as
                    # free everywhere MeasuredPricer looks it up — skip it
                    continue
                out.append({
                    "layer": spec.name, "kind": spec.kind,
                    "engine": self.engine, "batch": int(batch),
                    "dtype": self.dtype, "repeats": len(times),
                    "t_median": float(q50) * share,
                    "t_iqr": float(q75 - q25) * share,
                    "t_min": float(xs.min()) * share,
                    "t_mean": float(xs.mean()) * share,
                    "flops": int(fl),
                    "fingerprint": cache_lib.fingerprint(
                        spec, batch, self.dtype),
                    "jax_version": env["jax_version"],
                    "backend": env["backend"],
                    "source": "serving-telemetry",
                })
        return out

    def flush(self, cache) -> int:
        """Write all accumulated measurements into ``cache`` (a
        :class:`~repro.profiling.cache.ProfileCache`).  Returns the number
        of entries written.  Does not save — the caller owns persistence."""
        ms = self.measurements()
        for m in ms:
            cache.put(m)
        return len(ms)
