"""Fitted latency(batch) curves from serving telemetry.

The admission batcher prices a decode step with the analytic roofline sum
(`serving.batcher.step_time_model`), which scales linearly in FLOPs between
batch sizes.  Production telemetry — the per-burst timings `PerfWatchdog`
collects, or the `source=serving-telemetry` entries `TelemetryFeedback`
writes into the profile cache — gives real (batch, step seconds) points.
This module turns those points into a monotone piecewise-linear curve the
batcher can price against instead.

Latency(batch) on real hardware is non-decreasing, but raw medians from a
live run need not be (noise, bucket re-jits).  The fit enforces monotonicity
with pool-adjacent-violators isotonic regression and reports per-knot
residuals so the export can show how far the raw points were pulled.

Fewer than two distinct batch sizes is not a curve: ``fit_latency_curve``
returns ``None`` and callers fall back to the analytic model (possibly
scaled by the watchdog's observed divergence ratio).
"""
from __future__ import annotations

import dataclasses
import statistics
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from ..core.cost_model import piecewise_interp

MIN_CURVE_POINTS = 2


def isotonic_fit(ys: Sequence[float]) -> List[float]:
    """Pool-adjacent-violators: least-squares non-decreasing fit of ``ys``."""
    # each block: [level, weight] — merge backwards while out of order
    blocks: List[List[float]] = []
    for y in ys:
        blocks.append([float(y), 1.0])
        while len(blocks) > 1 and blocks[-2][0] > blocks[-1][0]:
            level, w = blocks.pop()
            plevel, pw = blocks.pop()
            tot = w + pw
            blocks.append([(level * w + plevel * pw) / tot, tot])
    out: List[float] = []
    for level, w in blocks:
        out.extend([level] * int(round(w)))
    return out


@dataclasses.dataclass(frozen=True)
class LatencyCurve:
    """Monotone piecewise-linear step-seconds(batch) fitted from telemetry."""

    batches: Tuple[int, ...]          # strictly increasing knot batch sizes
    step_s: Tuple[float, ...]         # isotonic-fitted seconds per knot
    raw_step_s: Tuple[float, ...]     # observed medians before the fit
    source: str = "serving-telemetry"

    @property
    def n_points(self) -> int:
        return len(self.batches)

    def predict(self, n_tokens: int) -> float:
        """Step seconds at ``n_tokens``, interpolating between fitted knots."""
        return piecewise_interp(
            [float(b) for b in self.batches], list(self.step_s),
            float(max(int(n_tokens), 1)))

    def residuals(self) -> Dict[int, float]:
        """Per-knot relative residual |fitted - observed| / observed."""
        out: Dict[int, float] = {}
        for b, fit, raw in zip(self.batches, self.step_s, self.raw_step_s):
            out[b] = abs(fit - raw) / raw if raw > 0 else 0.0
        return out

    def max_batch_within(self, slo_s: float, n_slots: int) -> int:
        """Largest batch (1..n_slots) whose predicted step fits the SLO."""
        budget = 1
        for k in range(2, max(int(n_slots), 1) + 1):
            if self.predict(k) > slo_s:
                break
            budget = k
        return budget

    def summary(self) -> dict:
        """JSON-safe description for the metrics snapshot / watchdog report."""
        return {
            "batches": list(self.batches),
            "step_s": [float(v) for v in self.step_s],
            "raw_step_s": [float(v) for v in self.raw_step_s],
            "residuals": {str(b): float(r)
                          for b, r in sorted(self.residuals().items())},
            "source": self.source,
        }


def fit_latency_curve(points: Mapping[int, float], *,
                      source: str = "serving-telemetry",
                      ) -> Optional[LatencyCurve]:
    """Fit a monotone curve through ``{batch: median step seconds}``.

    Returns ``None`` when fewer than :data:`MIN_CURVE_POINTS` distinct
    batches carry a positive timing — a single point fixes a scale but not
    a shape, so the caller keeps the analytic model.
    """
    clean = sorted((int(b), float(t)) for b, t in points.items()
                   if int(b) >= 1 and float(t) > 0.0)
    if len(clean) < MIN_CURVE_POINTS:
        return None
    batches = tuple(b for b, _ in clean)
    raw = tuple(t for _, t in clean)
    fitted = tuple(isotonic_fit(raw))
    return LatencyCurve(batches=batches, step_s=fitted, raw_step_s=raw,
                        source=source)


def median_points(samples: Mapping[int, Sequence[float]]) -> Dict[int, float]:
    """Collapse per-batch step-seconds samples to per-batch medians."""
    return {int(b): float(statistics.median(xs))
            for b, xs in samples.items() if len(xs) > 0}


def curve_points_from_cache(cache, cfg, *, kv_len: int, engine: str = "xla",
                            dtype: str = "float32") -> Dict[int, float]:
    """Reconstruct {batch: step seconds} from fed profile-cache entries.

    `TelemetryFeedback.flush` apportions each observed decode step across
    the decode network's layers and tags the entries
    ``source=serving-telemetry``; summing the per-layer medians back up per
    batch recovers the observed step time that batch actually cost —
    feedable straight into :func:`fit_latency_curve` on a later run.
    """
    # serving imports pull in jax; keep `repro.obs` importable without it
    from ..serving.batcher import decode_network_spec

    net = decode_network_spec(cfg, kv_len)
    fed = cache.measurements(engine=engine, source="serving-telemetry")
    batches = sorted({int(m["batch"]) for m in fed})
    points: Dict[int, float] = {}
    for batch in batches:
        total = 0.0
        complete = True
        for spec in net:
            m = cache.get(spec, engine, batch=batch, dtype=dtype)
            if m is not None and m.get("source") == "serving-telemetry":
                total += float(m["t_median"])
            elif spec.flops(batch) > 0:
                complete = False  # a priced layer is missing: partial step
                break
        if complete and total > 0.0:
            points[batch] = total
    return points
