"""Serving-wide observability: tracing, metrics, export, telemetry feedback.

One :class:`Observability` bundle travels through the serving stack
(driver, engine loops, pools) so every layer instruments against the same
tracer, metrics registry, and — when enabled — telemetry feedback and the
:class:`~repro.obs.watchdog.PerfWatchdog` that re-prices admission when
observed step costs drift from the admission price:

    obs = Observability(tracer=Tracer(), feedback=TelemetryFeedback(...))
    loop = EngineLoop(cfg, params, pool, obs=obs)
    driver.run(requests)
    write_trace(obs.tracer, "trace.json")      # -> Perfetto
    write_metrics(obs.registry, "metrics.json")
    obs.feedback.flush(profile_cache)          # -> price="measured"

The default bundle is inert: a :class:`~repro.obs.trace.NullTracer` (every
instrumentation site guards on ``tracer.enabled``), a live-but-unexported
:class:`~repro.obs.metrics.MetricsRegistry`, and no feedback — so
uninstrumented callers pay near-zero cost and no call site needs
``if obs is not None``.

This package must stay importable without jax or the serving stack
(feedback lazy-imports both): the launch CLIs read
:func:`~repro.obs.trace.default_clock` before configuring XLA.
"""
from __future__ import annotations

from typing import Optional

from .curves import LatencyCurve, fit_latency_curve
from .feedback import TelemetryFeedback
from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .trace import NullTracer, TraceEvent, Tracer, default_clock
from .watchdog import DriftAlert, PerfWatchdog

__all__ = [
    "Counter", "DriftAlert", "Gauge", "Histogram", "LatencyCurve",
    "MetricsRegistry", "NullTracer", "Observability", "PerfWatchdog",
    "TelemetryFeedback", "TraceEvent", "Tracer", "default_clock",
    "fit_latency_curve",
]


class Observability:
    """The bundle every serving layer instruments against."""

    def __init__(self, tracer=None, registry: Optional[MetricsRegistry] = None,
                 feedback: Optional[TelemetryFeedback] = None,
                 watchdog: Optional[PerfWatchdog] = None):
        self.tracer = tracer if tracer is not None else NullTracer()
        self.registry = registry if registry is not None else MetricsRegistry()
        self.feedback = feedback
        self.watchdog = watchdog
        if watchdog is not None:
            watchdog.bind(self.registry, self.tracer)
