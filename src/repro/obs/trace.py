"""Request-lifecycle span/event tracer for the serving runtime.

CNNLab's contribution is *quantitative*: per-stage time measured on real
accelerators, not modeled.  The serving loops, by contrast, only reported
end-of-run aggregates — nobody could see where a request spent its time
across admission, prefill, the disaggregation hand-off and its decode
bursts.  This module is the measurement substrate: a tracer that records

  * **spans** — named intervals on a (track, tid) pair.  Request lifecycle
    spans live on the ``requests`` track with ``tid = rid`` (``queued``,
    ``prefill``, ``handoff``, ``decode``); engine-level spans live on one
    track per :class:`~repro.serving.engine_loop.SlotEngine` (``burst``
    dispatches, ``sync`` host waits).
  * **instants** — point events (``first_token`` host visibility,
    ``kv_alloc``/``kv_free`` block-lease events, ``done``/``dropped``).
  * **counters** — sampled value series (KV occupancy, queue depth) that
    Perfetto renders as counter tracks.

Clock discipline: the tracer never calls ``time.*`` directly — it reads an
injected ``clock`` callable, and the open-loop driver installs its own skew
clock (``now_fn - t0 + idle fast-forward``) at run start, so every trace
timestamp lives on the same offered-load timeline as the serving metrics
(TTFT, latency).  Tests inject deterministic clocks and get golden traces.

Cost discipline: events append to a bounded ring buffer (old events drop,
``n_dropped`` counts them — a long-lived server never grows without bound),
and :class:`NullTracer` implements the same surface as no-ops with
``enabled = False`` so every instrumentation site can guard its argument
construction and tracing-off stays near-zero cost.

The Chrome-trace/Perfetto JSON serialization lives in
:mod:`repro.obs.export`; this module is dependency-free (no jax) so the
launch CLIs can import its clock before touching jax.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Dict, List, Optional

__all__ = ["NullTracer", "TraceEvent", "Tracer", "default_clock"]


def default_clock() -> float:
    """The one monotonic clock the runtime times with (`time.perf_counter`).

    Everything that stamps or measures time — the serving driver, the
    tracer, the launch CLIs — routes through this (or an injected override)
    so durations are never computed across mixed clock domains.
    ``time.time()`` is NOT monotonic (NTP steps it) and must not be used
    for intervals.
    """
    return time.perf_counter()


@dataclasses.dataclass
class TraceEvent:
    """One trace record.  ``ph`` follows the Chrome trace-event phases the
    exporter emits: ``X`` complete span, ``i`` instant, ``C`` counter."""

    name: str
    ph: str
    ts: float                       # seconds on the run timeline
    pid: int                        # track id (see Tracer.track)
    tid: int
    dur: Optional[float] = None     # seconds; X spans only
    cat: str = "span"
    args: Optional[dict] = None


class Tracer:
    """Span/event recorder with an injected clock and a bounded buffer."""

    enabled = True

    def __init__(self, clock: Optional[Callable[[], float]] = None, *,
                 capacity: int = 65536):
        self._clock = clock or default_clock
        self.capacity = capacity
        self.events: deque = deque(maxlen=capacity)
        self.n_dropped = 0
        # track registry: name -> pid, in registration order (the exporter
        # turns this into process_name metadata)
        self.tracks: Dict[str, int] = {}
        # open begin()-spans awaiting end(); handle -> (name, t0, pid, tid,
        # cat, args)
        self._open: Dict[int, tuple] = {}
        self._next_handle = 0

    # ---- clock -----------------------------------------------------------
    def set_clock(self, clock: Callable[[], float]) -> None:
        """Install the run's clock (the driver's skew clock) so events
        stamped with ``t=None`` land on the offered-load timeline."""
        self._clock = clock

    def now(self) -> float:
        return self._clock()

    # ---- tracks ----------------------------------------------------------
    def track(self, name: str) -> int:
        """Stable pid for a named track (``server``, ``requests``,
        ``engine:<name>``), registering it on first use."""
        pid = self.tracks.get(name)
        if pid is None:
            pid = len(self.tracks) + 1
            self.tracks[name] = pid
        return pid

    # ---- emission --------------------------------------------------------
    def _emit(self, ev: TraceEvent) -> None:
        if len(self.events) == self.capacity:
            self.n_dropped += 1          # ring: the oldest event falls out
        self.events.append(ev)

    def span(self, name: str, t0: float, t1: float, *, track: str,
             tid: int = 0, cat: str = "span",
             args: Optional[dict] = None) -> None:
        """Record a complete span with known endpoints (the lifecycle
        stamps the serving loop already carries: arrival, admission,
        phase boundary, completion)."""
        self._emit(TraceEvent(name=name, ph="X", ts=t0,
                              dur=max(t1 - t0, 0.0),
                              pid=self.track(track), tid=tid, cat=cat,
                              args=args))

    def begin(self, name: str, *, track: str, tid: int = 0,
              cat: str = "span", args: Optional[dict] = None,
              t: Optional[float] = None) -> int:
        """Open a span now; :meth:`end` closes it.  Returns a handle.
        Used where the interval is the instrumented code itself (burst
        dispatch, host syncs) rather than recorded stamps."""
        h = self._next_handle
        self._next_handle += 1
        self._open[h] = (name, self.now() if t is None else t,
                         self.track(track), tid, cat, args)
        return h

    def end(self, handle: int, *, args: Optional[dict] = None,
            t: Optional[float] = None) -> None:
        name, t0, pid, tid, cat, a0 = self._open.pop(handle)
        if args:
            a0 = {**(a0 or {}), **args}
        t1 = self.now() if t is None else t
        self._emit(TraceEvent(name=name, ph="X", ts=t0,
                              dur=max(t1 - t0, 0.0), pid=pid, tid=tid,
                              cat=cat, args=a0))

    @property
    def n_open(self) -> int:
        """Spans begun but not yet ended (0 after a well-formed run)."""
        return len(self._open)

    def instant(self, name: str, *, track: str, tid: int = 0,
                cat: str = "event", args: Optional[dict] = None,
                t: Optional[float] = None) -> None:
        self._emit(TraceEvent(name=name, ph="i",
                              ts=self.now() if t is None else t,
                              pid=self.track(track), tid=tid, cat=cat,
                              args=args))

    def counter(self, name: str, values: Dict[str, float], *, track: str,
                t: Optional[float] = None) -> None:
        """Sample a counter series (Perfetto renders one stacked counter
        track per name; ``values`` are its series)."""
        self._emit(TraceEvent(name=name, ph="C",
                              ts=self.now() if t is None else t,
                              pid=self.track(track), tid=0, cat="counter",
                              args={k: float(v) for k, v in values.items()}))

    def spans(self, name: Optional[str] = None) -> List[TraceEvent]:
        """Recorded complete spans, optionally filtered by name."""
        return [e for e in self.events
                if e.ph == "X" and (name is None or e.name == name)]

    def __len__(self) -> int:
        return len(self.events)


class NullTracer:
    """No-op tracer: the same surface, nothing recorded, near-zero cost.

    ``enabled = False`` lets instrumentation sites skip building span
    arguments entirely; the methods themselves are safe to call
    unconditionally.  ``now()`` still works (it reads the injected clock)
    so code that times an interval for a *different* consumer — e.g. the
    telemetry feedback path — can share one time source with the tracer.
    """

    enabled = False
    events: tuple = ()
    tracks: dict = {}
    n_dropped = 0
    n_open = 0

    def __init__(self, clock: Optional[Callable[[], float]] = None):
        self._clock = clock or default_clock

    def set_clock(self, clock: Callable[[], float]) -> None:
        self._clock = clock

    def now(self) -> float:
        return self._clock()

    def track(self, name: str) -> int:
        return 0

    def span(self, *a, **k) -> None:
        pass

    def begin(self, *a, **k) -> int:
        return 0

    def end(self, *a, **k) -> None:
        pass

    def instant(self, *a, **k) -> None:
        pass

    def counter(self, *a, **k) -> None:
        pass

    def spans(self, name: Optional[str] = None) -> list:
        return []

    def __len__(self) -> int:
        return 0
