"""Online performance watchdog: priced-vs-observed drift detection + re-pricing.

PR 6 landed the measurement leg: every decode span records the admission
price (`priced_step_s`) next to the observed per-step cost, and burst
timings flow through `TelemetryFeedback` into the profile cache.  The
watchdog is the control leg — it subscribes to the same burst stream,
maintains a per-(engine, phase) EWMA of the observed/priced step-time
ratio, fits :mod:`~repro.obs.curves` latency(batch) curves from the
accumulated points, and raises structured :class:`DriftAlert` events once
warm divergence clears the gate.

The watchdog only *detects*; acting is the serving loop's job.  The driver
drains :meth:`PerfWatchdog.pending_actions` at burst boundaries and hands
each alert to the loop's ``on_drift`` hook, which re-prices the matching
`ContinuousBatcher` (fitted curve when >= 2 batch sizes were observed,
ratio-scaled analytic otherwise) and — disaggregated — re-runs
`place_phases` with the drifted device de-rated.  The loop reports what it
did via :meth:`note_reprice`, which re-arms the detector so pricing must
drift *again* (relative to the new price) before the next alert.

Everything the watchdog sees and does lands in the registry (counters +
per-phase drift gauges), the trace (``drift_alert``/``reprice`` instants,
a ``drift`` counter track) and the exported metrics snapshot's
``watchdog`` section.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

from .curves import LatencyCurve, fit_latency_curve, median_points

# drift gate: alert when EWMA(observed / priced) leaves [1/gate, gate]
DEFAULT_DRIFT_GATE = 1.5
DEFAULT_EWMA_ALPHA = 0.4
DEFAULT_WARMUP = 4
# cold-start skip: the first burst per (engine, phase, batch bucket)
# includes jit compilation — the engine compiles one program per
# power-of-two batch bucket, so every first visit to a new bucket (the
# very first burst, and the first burst after a re-price raises the
# budget) would poison the EWMA (alpha-decay keeps a seconds-long compile
# visible for many bursts against a sub-millisecond price) and plant a
# compile-polluted knot in the fitted curve
DEFAULT_SKIP_FIRST = 1


def _bucket(n_tokens: int) -> int:
    """Power-of-two batch bucket (mirrors the engine's jit bucketing)."""
    b = 1
    while b < n_tokens:
        b <<= 1
    return b
# sync-cadence pressure: drain-sync cost above this fraction of burst cost
# stretches the streaming sync cadence (k), bounded by MAX_SYNC_EVERY
DEFAULT_SYNC_BUDGET_FRAC = 0.25
MAX_SYNC_EVERY = 4


@dataclasses.dataclass(frozen=True)
class DriftAlert:
    """One gate crossing for one (engine, phase) pricing stream."""

    engine: str
    phase: str
    t: float                  # trace-clock time of the triggering burst
    ewma_ratio: float         # EWMA of observed/priced at trigger
    priced_step_s: float      # price of the triggering burst
    observed_step_s: float    # observed per-step cost of that burst
    n_obs: int                # observations since the last re-price
    batch: int                # tokens in flight at trigger
    direction: str            # "slow": observed > priced; "fast": priced > observed

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class _PhaseState:
    """Per-(engine, phase) detector state."""

    __slots__ = ("ewma", "n_obs", "seen", "samples", "alert_active",
                 "n_alerts")

    def __init__(self) -> None:
        self.ewma: Optional[float] = None
        self.n_obs = 0                       # observations since last action
        self.seen: Dict[int, int] = {}       # bucket -> bursts seen (incl. skips)
        self.samples: Dict[int, List[float]] = {}   # batch -> step seconds
        self.alert_active = False
        self.n_alerts = 0


class PerfWatchdog:
    """Detects priced-vs-observed drift and brokers the re-pricing loop.

    Invariants: the watchdog is detection-only — it never touches engine
    state or request outputs, and the re-pricing it brokers is pure
    admission policy (greedy outputs are schedule-independent, so a
    re-price can change *when* requests run but never *what* they decode;
    the bench's adaptive section gates that bit-identity).  It holds no
    clock of its own: every timing it sees arrives as an ``elapsed_s``
    measured by the serving loop on the injected run clock, so tests can
    drive it deterministically and trace timestamps stay on the run's
    timeline.  The only runtime cost it adds is the per-burst device sync
    the loop performs to time bursts honestly — a pure wait,
    output-neutral by construction."""

    def __init__(self, *, ewma_alpha: float = DEFAULT_EWMA_ALPHA,
                 drift_gate: float = DEFAULT_DRIFT_GATE,
                 warmup: int = DEFAULT_WARMUP,
                 skip_first: int = DEFAULT_SKIP_FIRST,
                 sync_budget_frac: float = DEFAULT_SYNC_BUDGET_FRAC,
                 max_sync_every: int = MAX_SYNC_EVERY):
        if not 0.0 < ewma_alpha <= 1.0:
            raise ValueError("ewma_alpha must be in (0, 1]")
        if drift_gate <= 1.0:
            raise ValueError("drift_gate must be > 1")
        self.ewma_alpha = ewma_alpha
        self.drift_gate = drift_gate
        self.warmup = max(int(warmup), 1)
        self.skip_first = max(int(skip_first), 0)
        self.sync_budget_frac = sync_budget_frac
        self.max_sync_every = max(int(max_sync_every), 1)

        self._states: Dict[Tuple[str, str], _PhaseState] = {}
        self.alerts: List[DriftAlert] = []
        self.reprices: List[dict] = []
        self._pending: List[DriftAlert] = []
        self._sync_ewma: Optional[float] = None
        self._burst_ewma: Optional[float] = None
        self._registry = None
        self._tracer = None

    def bind(self, registry, tracer) -> None:
        """Attach the run's metrics registry + tracer (Observability does)."""
        self._registry = registry
        self._tracer = tracer

    # ---- observation ------------------------------------------------------
    def _state(self, engine: str, phase: str) -> _PhaseState:
        return self._states.setdefault((engine, phase), _PhaseState())

    def _ewma_update(self, prev: Optional[float], x: float) -> float:
        if prev is None:
            return x
        return (1.0 - self.ewma_alpha) * prev + self.ewma_alpha * x

    def observe_burst(self, engine: str, phase: str, *, n_tokens: int,
                      steps: int, elapsed_s: float,
                      priced_step_s: float) -> Optional[DriftAlert]:
        """Feed one synced burst; returns the alert if this one crossed."""
        if n_tokens <= 0 or steps <= 0 or elapsed_s <= 0.0:
            return None
        st = self._state(engine, phase)
        bucket = _bucket(int(n_tokens))
        st.seen[bucket] = st.seen.get(bucket, 0) + 1
        if st.seen[bucket] <= self.skip_first:
            return None              # cold-start burst at this batch bucket:
                                     # elapsed includes jit compilation
        observed = elapsed_s / steps
        st.samples.setdefault(int(n_tokens), []).append(observed)
        self._burst_ewma = self._ewma_update(self._burst_ewma, elapsed_s)

        if priced_step_s <= 0.0:
            return None
        ratio = observed / priced_step_s
        st.ewma = self._ewma_update(st.ewma, ratio)
        st.n_obs += 1

        reg, tracer = self._registry, self._tracer
        if reg is not None:
            reg.counter("watchdog_observations").inc()
            reg.gauge(f"drift_{engine}_{phase}").set(st.ewma)
        if tracer is not None and tracer.enabled:
            tracer.counter("drift", {f"{engine}/{phase}": st.ewma},
                           track="watchdog")

        gated = st.ewma > self.drift_gate or st.ewma < 1.0 / self.drift_gate
        if st.alert_active or st.n_obs < self.warmup or not gated:
            return None
        t = tracer.now() if tracer is not None else 0.0
        alert = DriftAlert(
            engine=engine, phase=phase, t=t, ewma_ratio=st.ewma,
            priced_step_s=priced_step_s, observed_step_s=observed,
            n_obs=st.n_obs, batch=int(n_tokens),
            direction="slow" if st.ewma > 1.0 else "fast")
        st.alert_active = True
        st.n_alerts += 1
        self.alerts.append(alert)
        self._pending.append(alert)
        if reg is not None:
            reg.counter("watchdog_alerts").inc()
        if tracer is not None and tracer.enabled:
            tracer.instant("drift_alert", track="server", cat="watchdog",
                           args=alert.to_dict(), t=t)
        return alert

    def observe_sync(self, elapsed_s: float) -> None:
        """Feed one drain-sync cost (the streaming TokenSink boundary)."""
        if elapsed_s < 0.0:
            return
        self._sync_ewma = self._ewma_update(self._sync_ewma, elapsed_s)

    # ---- queries ----------------------------------------------------------
    def ewma(self, engine: str, phase: str) -> Optional[float]:
        st = self._states.get((engine, phase))
        return st.ewma if st is not None else None

    def curve(self, engine: str, phase: str) -> Optional[LatencyCurve]:
        """Fitted latency(batch) curve; None until >= 2 batch sizes seen."""
        st = self._states.get((engine, phase))
        if st is None:
            return None
        return fit_latency_curve(median_points(st.samples))

    def step_time_fn(self, engine: str, phase: str,
                     analytic_fn: Callable[[int], float],
                     ) -> Tuple[Callable[[int], float], str]:
        """Best available pricing for (engine, phase).

        Fitted curve when the run observed >= 2 distinct batch sizes;
        otherwise the analytic shape scaled by the observed divergence
        ratio (a single telemetry point fixes scale, not shape); the bare
        analytic model when nothing was observed at all.
        """
        fitted = self.curve(engine, phase)
        if fitted is not None:
            return fitted.predict, "fitted-curve"
        ratio = self.ewma(engine, phase)
        if ratio is not None and ratio > 0.0:
            return (lambda n: analytic_fn(n) * ratio), "scaled-analytic"
        return analytic_fn, "analytic"

    def pending_actions(self) -> List[DriftAlert]:
        """Drain alerts awaiting a re-price (driver calls at burst bounds)."""
        out, self._pending = self._pending, []
        return out

    def sync_cadence(self) -> int:
        """Streaming sync cadence k (drain every k-th boundary).

        1 while drain-sync cost stays within ``sync_budget_frac`` of the
        burst cost; stretches proportionally (capped) when syncs dominate.
        """
        if not self._sync_ewma or not self._burst_ewma:
            return 1
        budget = self.sync_budget_frac * self._burst_ewma
        if budget <= 0.0 or self._sync_ewma <= budget:
            return 1
        k = int(self._sync_ewma / budget) + 1
        return min(k, self.max_sync_every)

    # ---- actions ----------------------------------------------------------
    def note_reprice(self, alert: DriftAlert, detail: dict) -> None:
        """Record that the loop acted on ``alert`` and re-arm the detector."""
        st = self._state(alert.engine, alert.phase)
        st.alert_active = False
        st.n_obs = 0          # drift must re-warm against the new price
        tracer = self._tracer
        t = tracer.now() if tracer is not None else 0.0
        event = {"engine": alert.engine, "phase": alert.phase, "t": t,
                 "ewma_ratio": alert.ewma_ratio, **detail}
        self.reprices.append(event)
        if self._registry is not None:
            self._registry.counter("watchdog_reprices").inc()
        if tracer is not None and tracer.enabled:
            tracer.instant("reprice", track="server", cat="watchdog",
                           args=event, t=t)

    # ---- reporting --------------------------------------------------------
    def report(self) -> dict:
        """JSON-safe ``watchdog`` section for the metrics snapshot."""
        streams = {}
        for (engine, phase), st in sorted(self._states.items()):
            fitted = self.curve(engine, phase)
            streams[f"{engine}/{phase}"] = {
                "ewma_ratio": st.ewma,
                "n_obs_since_action": st.n_obs,
                "n_alerts": st.n_alerts,
                "alert_active": st.alert_active,
                "batches_observed": sorted(st.samples),
                "curve": fitted.summary() if fitted is not None else None,
            }
        return {
            "config": {"ewma_alpha": self.ewma_alpha,
                       "drift_gate": self.drift_gate,
                       "warmup": self.warmup,
                       "skip_first": self.skip_first,
                       "sync_budget_frac": self.sync_budget_frac},
            "streams": streams,
            "alerts": [a.to_dict() for a in self.alerts],
            "reprices": list(self.reprices),
            "sync_cadence": self.sync_cadence(),
            "sync_cost_ewma_s": self._sync_ewma,
            "burst_cost_ewma_s": self._burst_ewma,
        }


# ---------------------------------------------------------------------------
# Speculative-decoding acceptance (online EWMA + re-decision veto)
# ---------------------------------------------------------------------------
DEFAULT_ACCEPTANCE_REDECIDE_EVERY = 8


class AcceptanceTracker:
    """Online EWMA of the draft's per-token acceptance rate.

    The trade-off analyzer prices speculation on a *prior* (or cached)
    acceptance rate; this tracker watches the rate the run actually
    delivers — the same observed-vs-priced discipline the
    :class:`PerfWatchdog` applies to step times.  Every
    ``redecide_every`` rounds past ``warmup`` it calls ``decide(alpha)``
    — a closure over :func:`repro.serving.placement.choose_speculation`
    — and latches ``disabled`` the first time the re-decision says
    speculation now prices worse than plain decode.  The veto is
    one-way: re-enabling mid-run would need the draft caches re-synced
    for every slot, and a wrongly-disabled run merely decodes plain.
    """

    def __init__(self, *, ewma_alpha: float = DEFAULT_EWMA_ALPHA,
                 warmup: int = DEFAULT_WARMUP,
                 redecide_every: int = DEFAULT_ACCEPTANCE_REDECIDE_EVERY,
                 decide: Optional[Callable[[float], object]] = None):
        self.ewma_alpha = ewma_alpha
        self.warmup = warmup
        self.redecide_every = max(int(redecide_every), 1)
        self.decide = decide
        self.ewma: Optional[float] = None
        self.n_rounds = 0
        self.n_proposed = 0
        self.n_accepted = 0
        self.disabled = False
        self.decisions: List[dict] = []

    def observe_round(self, proposed: int, accepted: int) -> None:
        """Feed one speculative round's draft-token tallies."""
        if proposed <= 0:
            return
        r = accepted / proposed
        self.n_rounds += 1
        self.n_proposed += int(proposed)
        self.n_accepted += int(accepted)
        a = self.ewma_alpha
        self.ewma = r if self.ewma is None else (1 - a) * self.ewma + a * r
        if (self.decide is not None and not self.disabled
                and self.n_rounds >= self.warmup
                and self.n_rounds % self.redecide_every == 0):
            decision = self.decide(self.acceptance)
            if decision is not None:
                self.decisions.append(
                    {"round": self.n_rounds,
                     "acceptance": self.acceptance,
                     "use": bool(getattr(decision, "use", True))})
                if not getattr(decision, "use", True):
                    self.disabled = True

    @property
    def acceptance(self) -> float:
        """Best current estimate of the per-token acceptance rate."""
        if self.ewma is not None:
            return self.ewma
        if self.n_proposed > 0:
            return self.n_accepted / self.n_proposed
        return 0.0

    def report(self) -> dict:
        return {"acceptance_ewma": self.ewma,
                "acceptance_cum": (self.n_accepted / self.n_proposed
                                   if self.n_proposed else None),
                "n_rounds": self.n_rounds,
                "n_proposed": self.n_proposed,
                "n_accepted": self.n_accepted,
                "disabled": self.disabled,
                "decisions": list(self.decisions)}


# ---------------------------------------------------------------------------
# SLO attainment (serve --slo-report)
# ---------------------------------------------------------------------------
def request_class(req, boundaries: Tuple[int, int]) -> str:
    """Bucket a request by generation length (short/medium/long)."""
    if req.max_new_tokens <= boundaries[0]:
        return "short"
    if req.max_new_tokens <= boundaries[1]:
        return "medium"
    return "long"


def class_boundaries(requests) -> Tuple[int, int]:
    """Tercile boundaries over the workload's generation lengths."""
    lens = sorted(r.max_new_tokens for r in requests)
    if not lens:
        return (0, 0)
    return (lens[len(lens) // 3], lens[(2 * len(lens)) // 3])


def slo_attainment(requests, *, ttft_slo_s: float,
                   tpot_slo_s: float) -> List[dict]:
    """Per-request-class TTFT/TPOT SLO attainment rows (+ an `all` row)."""
    done = [r for r in requests if r.t_done is not None]
    bounds = class_boundaries(done)
    groups: Dict[str, list] = {"short": [], "medium": [], "long": []}
    for r in done:
        groups[request_class(r, bounds)].append(r)
    rows = []
    for name in ("short", "medium", "long", "all"):
        members = done if name == "all" else groups[name]
        ttfts = [r.ttft for r in members if r.ttft is not None]
        tpots = [r.tpot for r in members if r.tpot is not None]
        rows.append({
            "class": name,
            "n": len(members),
            "gen_len_max": max((r.max_new_tokens for r in members),
                               default=None),
            "ttft_p50_s": (sorted(ttfts)[len(ttfts) // 2] if ttfts else None),
            "tpot_p50_s": (sorted(tpots)[len(tpots) // 2] if tpots else None),
            "ttft_attained": (sum(1 for t in ttfts if t <= ttft_slo_s)
                              / len(ttfts) if ttfts else None),
            "tpot_attained": (sum(1 for t in tpots if t <= tpot_slo_s)
                              / len(tpots) if tpots else None),
        })
    return rows


def format_slo_report(rows: List[dict], *, ttft_slo_s: float,
                      tpot_slo_s: float) -> str:
    """Render the attainment rows as the table ``--slo-report`` prints."""
    def pct(v):
        return "    --" if v is None else f"{100.0 * v:5.1f}%"

    def ms(v):
        return "     --" if v is None else f"{1e3 * v:7.1f}"

    lines = [
        f"SLO attainment (TTFT <= {1e3 * ttft_slo_s:.0f} ms, "
        f"TPOT <= {1e3 * tpot_slo_s:.0f} ms)",
        f"{'class':<8}{'n':>4}{'ttft p50 ms':>13}{'ttft ok':>9}"
        f"{'tpot p50 ms':>13}{'tpot ok':>9}",
    ]
    for row in rows:
        lines.append(
            f"{row['class']:<8}{row['n']:>4}{ms(row['ttft_p50_s']):>13}"
            f"{pct(row['ttft_attained']):>9}{ms(row['tpot_p50_s']):>13}"
            f"{pct(row['tpot_attained']):>9}")
    return "\n".join(lines)
