"""Serving-wide metrics registry: counters, gauges, histograms, snapshots.

Before this module the serving runtime's numbers lived in four ad-hoc
places — ``ServeMetrics`` aggregate lists, the ``HandoffLedger``'s own
counters, end-of-run ``KVPool.stats()``, and the ``ContinuousBatcher``'s
admitted/rejected/deferred tallies — and only *means* survived the run
(``kv_occupancy_mean`` told you nothing about the occupancy spike that
deferred half the queue).  The registry unifies them:

  * **counters** — monotone totals (requests done, hand-off bytes moved);
  * **gauges** — last-written values (queue depth, KV occupancy, slots in
    flight), which the driver refreshes every iteration;
  * **histograms** — bounded samples with percentile summaries (TTFT,
    TPOT, latency — what ``ServeMetrics`` keeps as raw lists);
  * **time series** — :meth:`MetricsRegistry.sample` snapshots every gauge
    at the driver's iteration cadence into a bounded ring, so the run's
    occupancy/queue-depth/in-flight *trajectories* survive, not just their
    means.

Everything is plain floats and dicts (no jax) and :meth:`snapshot` returns
a JSON-safe tree; :func:`repro.obs.export.write_metrics` dumps it.  The
``HandoffLedger`` keeps its public shape as a thin view over counters
registered here (see :mod:`repro.serving.disagg`).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]


@dataclasses.dataclass
class Counter:
    """Monotone total.  ``inc`` with a negative amount is a bug upstream."""

    name: str
    value: float = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


@dataclasses.dataclass
class Gauge:
    """Last-written value (sampled into the time series by the driver)."""

    name: str
    value: float = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Bounded sample reservoir with percentile summaries.

    Keeps the newest ``capacity`` observations (a serving run's TTFT list
    is small; a long-lived server's is not) plus a monotone total count.
    """

    def __init__(self, name: str, *, capacity: int = 65536):
        self.name = name
        self.samples: deque = deque(maxlen=capacity)
        self.count = 0

    def observe(self, v: float) -> None:
        self.samples.append(float(v))
        self.count += 1

    def summary(self) -> Dict[str, Optional[float]]:
        """Percentile summary; ``None`` (JSON null) when empty — never NaN,
        so a zero-completion run still serializes as strict JSON."""
        if not self.samples:
            return {"count": self.count, "n_samples": 0, "mean": None,
                    "p50": None, "p99": None, "min": None, "max": None}
        xs = np.asarray(self.samples)
        return {
            "count": self.count,
            # retained reservoir size: < count means the ring truncated and
            # the percentiles below only describe the newest samples
            "n_samples": len(self.samples),
            "mean": float(xs.mean()),
            "p50": float(np.percentile(xs, 50)),
            "p99": float(np.percentile(xs, 99)),
            "min": float(xs.min()),
            "max": float(xs.max()),
        }


class MetricsRegistry:
    """Create-or-get registry + the sampled gauge time series."""

    def __init__(self, *, series_capacity: int = 8192,
                 histogram_capacity: int = 65536):
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.histograms: Dict[str, Histogram] = {}
        self.series: deque = deque(maxlen=series_capacity)
        self.n_samples = 0               # ever taken (ring may have dropped)
        self._hist_capacity = histogram_capacity

    # ---- create-or-get ---------------------------------------------------
    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(
                name, capacity=self._hist_capacity)
        return h

    # ---- time series -----------------------------------------------------
    def sample(self, t: float) -> None:
        """Snapshot every gauge at time ``t`` into the series ring — the
        in-run trajectory (KV occupancy, queue depth, in-flight slots)
        end-of-run means cannot reconstruct."""
        point = {"t": float(t)}
        for name, g in self.gauges.items():
            point[name] = g.value
        self.series.append(point)
        self.n_samples += 1

    # ---- export ----------------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-safe dump: counter/gauge values, histogram summaries, and
        the sampled time series (newest ``series_capacity`` points)."""
        return {
            "counters": {n: c.value for n, c in sorted(self.counters.items())},
            "gauges": {n: g.value for n, g in sorted(self.gauges.items())},
            "histograms": {n: h.summary()
                           for n, h in sorted(self.histograms.items())},
            "series": [dict(p) for p in self.series],
            "n_samples": self.n_samples,
            "series_len": len(self.series),
            "series_dropped": self.n_samples - len(self.series),
        }

    def series_values(self, name: str) -> List[float]:
        """One gauge's sampled trajectory (points recorded before the gauge
        first existed are skipped)."""
        return [p[name] for p in self.series if name in p]
