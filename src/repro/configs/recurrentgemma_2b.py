"""recurrentgemma-2b [hybrid] (arXiv:2402.19427; hf) — RG-LRU + local attn 1:2.

26L, d_model=2560, 10 heads (MQA kv=1, head_dim=256), d_ff=7680,
vocab=256000; pattern [rec, rec, attn] with 2048-token local attention;
lru_width == d_model (expand=1).  26 = 8x3 + 2 -> 8 scanned super-blocks +
2 remainder layers.
"""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680,
    vocab=256000, block_pattern=("rec", "rec", "attn"), attn_window=2048,
    ssm_expand=1, tie_embeddings=True, grad_accum=4,
    attention_impl="chunked", attn_chunk=2048, scan_chunk=512,
)

SMOKE = ModelConfig(
    name="recurrentgemma-smoke",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128, vocab=512,
    block_pattern=("rec", "rec", "attn"), attn_window=16, ssm_expand=1,
    tie_embeddings=True, attention_impl="dot", scan_chunk=16,
)
LR_SCHEDULE = "cosine"
