"""minicpm-2b [dense] (arXiv:2404.06395; hf) — trains with the WSD schedule.

40L, d_model=2304, 36 heads (MHA kv=36), d_ff=5760, vocab=122753.
"""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36, d_ff=5760,
    vocab=122753, tie_embeddings=True,
    attention_impl="chunked", attn_chunk=2048,
)

SMOKE = ModelConfig(
    name="minicpm-smoke",
    n_layers=4, d_model=96, n_heads=6, n_kv_heads=6, d_ff=192, vocab=512,
    tie_embeddings=True, attention_impl="dot", scan_chunk=16,
)
LR_SCHEDULE = "wsd"          # the paper's schedule, wired in optim/schedules
