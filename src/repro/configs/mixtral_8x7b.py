"""mixtral-8x7b [moe] (arXiv:2401.04088; hf) — 8 experts top-2, SWA.

32L, d_model=4096, 32 heads (GQA kv=8), d_ff=14336, vocab=32000,
sliding-window attention (4096).  SWA makes long_500k runnable (rolling
4096-slot KV cache).  8 experts on a 16-way model axis -> expert dim stays
local, d_ff shards (DESIGN.md §5).
"""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=32000, n_experts=8, moe_top_k=2, attn_window=4096,
    rope_theta=1e6, tie_embeddings=False,
    attention_impl="chunked", attn_chunk=2048, grad_accum=4,
)

SMOKE = ModelConfig(
    name="mixtral-smoke",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    n_experts=4, moe_top_k=2, attn_window=16, tie_embeddings=False,
    attention_impl="dot", scan_chunk=16,
)
LR_SCHEDULE = "cosine"
