"""Architecture & shape registry.

Each assigned architecture contributes a module defining:
  CONFIG        — the exact published configuration (ModelConfig)
  SMOKE         — a reduced same-family config for CPU smoke tests
  LR_SCHEDULE   — the schedule the arch trains with (minicpm: WSD)

Shapes are the assignment's four workloads.  ``supports()`` encodes the
skip rules (long_500k needs sub-quadratic attention; see DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.transformer import ModelConfig, init_cache


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    mode: str               # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

ARCH_NAMES = [
    "deepseek_coder_33b",
    "minicpm_2b",
    "qwen2_1_5b",
    "granite_34b",
    "recurrentgemma_2b",
    "seamless_m4t_medium",
    "mixtral_8x7b",
    "phi35_moe",
    "falcon_mamba_7b",
    "llama32_vision_90b",
    "alexnet",             # the paper's own network (CNN path)
]


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    name: str
    config: Optional[ModelConfig]        # None for the CNN (AlexNet) path
    smoke: Optional[ModelConfig]
    lr_schedule: str = "cosine"
    family: str = "lm"                   # lm | cnn


def get(name: str) -> ArchSpec:
    name = name.replace("-", "_").replace(".", "")
    mod = importlib.import_module(f"repro.configs.{name}")
    return ArchSpec(
        name=name,
        config=getattr(mod, "CONFIG", None),
        smoke=getattr(mod, "SMOKE", None),
        lr_schedule=getattr(mod, "LR_SCHEDULE", "cosine"),
        family=getattr(mod, "FAMILY", "lm"),
    )


def all_archs() -> Dict[str, ArchSpec]:
    return {n: get(n) for n in ARCH_NAMES}


def _is_subquadratic(cfg: ModelConfig) -> bool:
    types = set(cfg.layer_types())
    has_full_attn = ("attn" in types or "xattn" in types) \
        and cfg.attn_window is None
    return not has_full_attn


def supports(arch: ArchSpec, shape_name: str) -> Tuple[bool, str]:
    if arch.family == "cnn":
        return False, "CNN arch: LM shapes not applicable (paper network)"
    cfg = arch.config
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not _is_subquadratic(cfg):
        return False, ("pure full-attention arch: O(L^2) attention at "
                       "L=524288 is not servable — skipped per assignment")
    if shape.mode == "decode" and cfg.encoder_decoder:
        # decoder decodes; encoder states come from a 32k prefill
        return True, ""
    return True, ""


def runnable_cells():
    """All (arch, shape) pairs that must pass the dry-run."""
    cells = []
    for name in ARCH_NAMES:
        arch = get(name)
        if arch.family == "cnn":
            continue
        for shape_name in SHAPES:
            ok, _ = supports(arch, shape_name)
            if ok:
                cells.append((name, shape_name))
    return cells


# ---------------------------------------------------------------------------
# Abstract input specs (ShapeDtypeStruct stand-ins — no allocation)
# ---------------------------------------------------------------------------
def input_specs(arch: ArchSpec, shape_name: str) -> Dict:
    """Returns the abstract inputs for the step function of this cell.

    train:   {"batch": {tokens, labels[, enc_inputs | img_embeds]}}
    prefill: {"tokens": (B,S)[, enc_inputs | img_embeds]}
    decode:  {"tokens": (B,1), "cache": <abstract cache pytree>}
    """
    cfg = arch.config
    shape = SHAPES[shape_name]
    b, s = shape.global_batch, shape.seq_len
    tok = lambda seq: jax.ShapeDtypeStruct((b, seq), jnp.int32)

    def frontend(seq):
        ex = {}
        if cfg.encoder_decoder:
            ex["enc_inputs"] = jax.ShapeDtypeStruct((b, seq, cfg.d_model),
                                                    jnp.bfloat16)
        elif cfg.frontend == "vision":
            ex["img_embeds"] = jax.ShapeDtypeStruct((b, cfg.img_seq,
                                                     cfg.d_model),
                                                    jnp.bfloat16)
        return ex

    if shape.mode == "train":
        batch = {"tokens": tok(s), "labels": tok(s)}
        batch.update(frontend(s))
        return {"batch": batch}
    if shape.mode == "prefill":
        out = {"tokens": tok(s)}
        out.update(frontend(s))
        return out
    # decode: abstract cache of length s
    cache = jax.eval_shape(lambda: init_cache(cfg, b, max_seq=s))
    return {"tokens": tok(1), "cache": cache}
