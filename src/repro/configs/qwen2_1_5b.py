"""qwen2-1.5b [dense] (arXiv:2407.10671; hf) — GQA, QKV bias.

28L, d_model=1536, 12 heads (GQA kv=2), d_ff=8960, vocab=151936.
"""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, d_ff=8960,
    vocab=151936, qkv_bias=True, rope_theta=1e6, tie_embeddings=True,
    attention_impl="chunked", attn_chunk=2048,
)

SMOKE = ModelConfig(
    name="qwen2-smoke",
    n_layers=4, d_model=96, n_heads=6, n_kv_heads=2, d_ff=192, vocab=512,
    qkv_bias=True, tie_embeddings=True, attention_impl="dot", scan_chunk=16,
)
LR_SCHEDULE = "cosine"
