"""phi3.5-moe-42b-a6.6b [moe] (hf:microsoft/Phi-3.5-MoE-instruct).

32L, d_model=4096, 32 heads (GQA kv=8), d_ff=6400, vocab=32064,
16 experts top-2.  16 experts divide the 16-way model axis exactly ->
true expert parallelism (one expert per model shard).
"""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=6400,
    vocab=32064, n_experts=16, moe_top_k=2, tie_embeddings=False,
    attention_impl="chunked", attn_chunk=2048, grad_accum=4,
)

SMOKE = ModelConfig(
    name="phi35-moe-smoke",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96, vocab=512,
    n_experts=4, moe_top_k=2, tie_embeddings=False,
    attention_impl="dot", scan_chunk=16,
)
LR_SCHEDULE = "cosine"
