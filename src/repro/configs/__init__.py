"""Per-architecture configs (exact published settings) + shape registry."""
from .registry import (ARCH_NAMES, SHAPES, ArchSpec, ShapeSpec, all_archs,  # noqa
                       get, input_specs, runnable_cells, supports)
