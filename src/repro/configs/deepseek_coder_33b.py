"""deepseek-coder-33b [dense, llama-arch] (arXiv:2401.14196; hf).

62L, d_model=7168, 56 heads (GQA kv=8), d_ff=19200, vocab=32256.
56 heads % 16-way model axis != 0 -> FSDP/SP sharding mode (DESIGN.md §4).
"""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=19200,
    vocab=32256, rope_theta=1e5, tie_embeddings=False,
    attention_impl="chunked", attn_chunk=2048, grad_accum=4,
)

SMOKE = ModelConfig(
    name="deepseek-coder-smoke",
    n_layers=4, d_model=128, n_heads=8, n_kv_heads=2, d_ff=256, vocab=512,
    tie_embeddings=False, attention_impl="dot", scan_chunk=16,
)
LR_SCHEDULE = "cosine"
