"""granite-34b code [dense] (arXiv:2405.04324; hf) — MQA (kv=1), 88 layers.

88L, d_model=6144, 48 heads (kv=1), d_ff=24576, vocab=49152.
"""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1, d_ff=24576,
    vocab=49152, gated_mlp=False, tie_embeddings=False,
    attention_impl="chunked", attn_chunk=2048, grad_accum=4,
)

SMOKE = ModelConfig(
    name="granite-smoke",
    n_layers=5, d_model=128, n_heads=8, n_kv_heads=1, d_ff=256, vocab=512,
    tie_embeddings=False, attention_impl="dot", scan_chunk=16,
)
LR_SCHEDULE = "cosine"
