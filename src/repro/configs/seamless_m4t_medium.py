"""seamless-m4t-medium [audio enc-dec] (arXiv:2308.11596; hf).

12L encoder + 12L decoder, d_model=1024, 16 heads (kv=16), d_ff=4096,
vocab=256206.  The audio frontend (fbank -> conformer embedding) is a STUB:
input_specs()/the data pipeline provide precomputed frame embeddings
(B, T, d_model), per the assignment.
"""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab=256206, gated_mlp=False, encoder_decoder=True, enc_layers=12, frontend="audio",
    tie_embeddings=True, attention_impl="chunked", attn_chunk=2048,
    grad_accum=2,
)

SMOKE = ModelConfig(
    name="seamless-smoke",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
    encoder_decoder=True, enc_layers=2, frontend="audio",
    tie_embeddings=True, attention_impl="dot", scan_chunk=16,
)
LR_SCHEDULE = "cosine"
