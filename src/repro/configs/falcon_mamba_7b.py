"""falcon-mamba-7b [ssm] (arXiv:2410.05355) — attention-free Mamba-1.

64L, d_model=4096, d_inner=8192 (expand 2), ssm_state=16, conv 4,
vocab=65024, d_ff=0 (the mamba block carries its own 2x expansion).
Sub-quadratic -> long_500k runs (state is O(1) in sequence length).
"""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab=65024, block_pattern=("mamba",), ssm_state=16, ssm_conv=4,
    ssm_expand=2, tie_embeddings=False, scan_chunk=256,
)

SMOKE = ModelConfig(
    name="falcon-mamba-smoke",
    n_layers=4, d_model=64, n_heads=0, n_kv_heads=0, d_ff=0, vocab=512,
    block_pattern=("mamba",), ssm_state=8, ssm_conv=4, ssm_expand=2,
    tie_embeddings=False, scan_chunk=16,
)
LR_SCHEDULE = "cosine"
