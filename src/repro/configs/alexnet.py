"""AlexNet — the paper's own experimental network (Table I), CNN family.

Runs through the CNNLab core (layer tuples -> scheduler -> engines), not the
LM substrate; exercised by examples/cnnlab_alexnet.py and the Fig. 6
benchmarks.  LM shapes do not apply.
"""
FAMILY = "cnn"
CONFIG = None
SMOKE = None
LR_SCHEDULE = "cosine"
