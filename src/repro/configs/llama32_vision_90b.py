"""llama-3.2-vision-90b [vlm] (hf:meta-llama/Llama-3.2-90B-Vision backbone).

100L, d_model=8192, 64 heads (GQA kv=8), d_ff=28672, vocab=128256,
gated cross-attention image layers every 5th layer (20 of 100).  The vision
frontend (ViT) is a STUB: input_specs() provides precomputed patch
embeddings (B, 6404, d_model) — 4 tiles x 1601 patches.
"""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=28672,
    vocab=128256, cross_attn_every=5, frontend="vision", img_seq=6404,
    rope_theta=5e5, tie_embeddings=False,
    attention_impl="chunked", attn_chunk=2048, grad_accum=8,
)

SMOKE = ModelConfig(
    name="llama32-vision-smoke",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    cross_attn_every=2, frontend="vision", img_seq=32, tie_embeddings=False,
    attention_impl="dot", scan_chunk=16,
)
LR_SCHEDULE = "cosine"
