"""Profiling CLI: measure engines, calibrate device models, compare plans.

    PYTHONPATH=src python -m repro.launch.profile --net alexnet-full \
        --cache profile_cache.json

The paper's runtime flow in one command: microbenchmark every buildable
engine on every layer of the chosen network (cache-on-hit,
measure-on-miss), persist the profile cache, fit calibrated device models
and print the before/after prediction error, then run the DSE twice —
analytic vs measured pricing — and show what the measurements changed.

``--net tiny`` is a two-layer spec for CI smoke runs.
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp

from ..core import engines as engines_lib
from ..core import scheduler
from ..core.layer_model import (ConvSpec, FCSpec, NetworkSpec, alexnet_spec,
                                alexnet_full_spec)
from ..profiling import (MeasuredPricer, ProfileCache, calibration_report,
                         profile_network)

_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


def tiny_net() -> NetworkSpec:
    """Two tiny layers (one conv, one fc) — the CI smoke workload."""
    return NetworkSpec("tiny", (
        ConvSpec("TConv", m_i=(8, 8, 3), m_k=(8, 3, 3, 3), m_o=(8, 8, 8),
                 stride=1, padding=1),
        FCSpec("TFC", m_i=(8, 8, 8), k_o=16),
    ))


NETS = {
    "alexnet": alexnet_spec,
    "alexnet-full": alexnet_full_spec,
    "tiny": tiny_net,
}


def build_parser() -> argparse.ArgumentParser:
    """The profile CLI's argument parser (module-level so tests and the
    docs consistency gate can introspect the flag set)."""
    ap = argparse.ArgumentParser(prog="repro.launch.profile",
                                 description=__doc__.splitlines()[0])
    ap.add_argument("--net", default="alexnet-full", choices=sorted(NETS))
    ap.add_argument("--engines", default=None,
                    help="comma-separated engine names (default: all "
                         "buildable engines)")
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--dtype", default="float32", choices=sorted(_DTYPES))
    ap.add_argument("--warmup", type=int, default=2)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--cache", default="profile_cache.json")
    ap.add_argument("--objective", default="latency",
                    help="DSE objective for the plan comparison")
    ap.add_argument("--no-measure", action="store_true",
                    help="cache-only: never run benchmarks (report on "
                         "whatever the cache already holds)")
    ap.add_argument("--invalidate-stale", action="store_true",
                    help="drop cache entries from other jax versions / "
                         "backends before profiling")
    return ap


def main() -> None:
    args = build_parser().parse_args()

    net = NETS[args.net]()
    if args.engines:
        engines = [engines_lib.ENGINES_BY_NAME[n]
                   for n in args.engines.split(",")]
    else:
        engines = [e for e in engines_lib.ALL_ENGINES if e.buildable]
    dtype = _DTYPES[args.dtype]

    cache = ProfileCache.load(args.cache, strict=False)
    if args.invalidate_stale:
        n = cache.invalidate_stale()
        print(f"[profile] invalidated {n} stale cache entr"
              f"{'y' if n == 1 else 'ies'}")
    n_before = len(cache)
    measurements = profile_network(
        net, engines, batch=args.batch, dtype=dtype, warmup=args.warmup,
        repeats=args.repeats, cache=cache,
        measure_on_miss=not args.no_measure)
    path = cache.save(args.cache)
    print(f"[profile] {len(measurements)} measurements for {net.name} "
          f"({len(cache) - n_before} new) -> {path}")

    for eng in engines:
        if not any(m.engine == eng.name for m in measurements):
            continue
        rep = calibration_report(eng, list(net), measurements,
                                 batch=args.batch, register=True)
        print(f"\n== calibration: engine {eng.name} "
              f"(registered {rep.model.name}) ==")
        print(rep.summary())

    # the paper's before/after: what does measuring change about the plan?
    pricer = MeasuredPricer(cache, measure_on_miss=not args.no_measure,
                            warmup=args.warmup, repeats=args.repeats,
                            dtype=dtype)
    plan_a = scheduler.schedule(net, engines, objective=args.objective,
                                batch=args.batch)
    plan_m = scheduler.schedule(net, engines, objective=args.objective,
                                batch=args.batch, price="measured",
                                pricer=pricer)
    print(f"\n== plan ({args.objective}), analytic pricing ==")
    print(plan_a.summary())
    print(f"\n== plan ({args.objective}), measured pricing "
          f"({pricer.hits} cache hits, {pricer.misses} measured) ==")
    print(plan_m.summary())
    changed = [a.spec.name for a, b in zip(plan_a.assignments,
                                           plan_m.assignments)
               if a.engine != b.engine]
    print(f"\n[profile] measurement moved {len(changed)}/{len(net)} layers"
          + (f": {', '.join(changed)}" if changed else ""))


if __name__ == "__main__":
    main()
