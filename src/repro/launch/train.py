"""Training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2_1_5b \
        --steps 200 --batch 8 --seq 128 --scale smoke --ckpt-dir /tmp/ckpt

Production features wired in:
  * pjit over the mesh (host mesh on CPU; production mesh on pods);
  * checkpoint/restore with atomic publish, keep-k, elastic resharding
    (restart with a different mesh reshard-restores);
  * preemption handling (SIGTERM -> checkpoint -> clean exit);
  * straggler detection (EWMA step timer);
  * gradient accumulation (--grad-accum) and int8 gradient compression with
    error feedback (--grad-compression int8) for cross-pod all-reduce;
  * WSD or cosine schedule per the arch registry.

XLA collective/compute overlap: on real TPU runtimes, enable the
latency-hiding scheduler with
  LIBTPU_INIT_ARGS="--xla_tpu_enable_async_collective_fusion=true" and
  XLA_FLAGS="--xla_tpu_enable_latency_hiding_scheduler=true" — documented
here because this container's CPU backend ignores them.
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import time

import jax

from ..checkpoint import CheckpointManager
from ..configs import registry
from ..data import DataConfig, make_pipeline
from ..models import sharding as shard_lib
from ..models import transformer as T
from ..optim import adamw, compression, schedules
from ..runtime import PreemptionHandler, StepTimer
from .mesh import make_host_mesh, make_production_mesh


def _schedule(name: str, steps: int):
    if name == "wsd":
        return schedules.wsd_schedule(3e-3, max(steps // 20, 1),
                                      int(steps * 0.7), int(steps * 0.25))
    return schedules.cosine_schedule(3e-3, max(steps // 20, 1), steps)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_1_5b")
    ap.add_argument("--scale", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8"])
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-interval", type=int, default=50)
    ap.add_argument("--corpus", default="")
    ap.add_argument("--mesh", default="host", choices=["host", "pod",
                                                       "multipod"])
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    arch = registry.get(args.arch)
    cfg = arch.smoke if args.scale == "smoke" else arch.config
    assert cfg is not None, f"{args.arch} has no LM config"
    if args.seq % max(cfg.scan_chunk, 1):
        cfg = dataclasses.replace(cfg, scan_chunk=min(cfg.scan_chunk,
                                                      args.seq))

    mesh = (make_host_mesh() if args.mesh == "host" else
            make_production_mesh(multi_pod=args.mesh == "multipod"))
    policy = shard_lib.make_policy(cfg, mesh)

    # ---- data -------------------------------------------------------
    dcfg = DataConfig(global_batch=args.batch, seq_len=args.seq,
                      vocab=cfg.vocab, frontend=cfg.frontend,
                      d_model=cfg.d_model, img_seq=cfg.img_seq,
                      enc_len=args.seq)
    pipe = make_pipeline(dcfg, corpus=args.corpus or None)

    # ---- state ------------------------------------------------------
    init_opt, update = adamw.make_optimizer(
        _schedule(arch.lr_schedule, args.steps))
    p_shapes = jax.eval_shape(
        functools.partial(T.init_params, cfg=cfg), jax.random.PRNGKey(0))
    p_sh = shard_lib.param_shardings(cfg, policy, p_shapes)

    with mesh:
        params = jax.jit(functools.partial(T.init_params, cfg=cfg),
                         out_shardings=p_sh)(jax.random.PRNGKey(0))
        opt_state = init_opt(params)
        err_fb = (compression.init_error(params)
                  if args.grad_compression == "int8" else None)

    # ---- restore (elastic: shardings are the *current* mesh's) ------
    start_step = 0
    ckpt = CheckpointManager(args.ckpt_dir, args.ckpt_interval) \
        if args.ckpt_dir else None
    if ckpt:
        restored = ckpt.restore({"params": params, "opt": opt_state})
        if restored:
            start_step, state, extra = restored
            params, opt_state = state["params"], state["opt"]
            with mesh:
                params = jax.device_put(params, p_sh)
            if "data" in extra:
                pipe.restore(extra["data"])
            print(f"[restore] resumed at step {start_step}")

    # ---- step -------------------------------------------------------
    def train_step(params, opt_state, err, batch):
        def lf(p):
            return T.loss_fn(p, cfg, batch)
        loss, grads = jax.value_and_grad(lf)(params)
        if err is not None:
            grads, err = compression.compressed_allreduce_update(grads, err)
        new_p, new_o, metrics = update(grads, opt_state, params)
        return new_p, new_o, err, {"loss": loss, **metrics}

    jstep = jax.jit(train_step, donate_argnums=(0, 1, 2))

    timer = StepTimer()
    preempt = PreemptionHandler()
    t_start = time.time()
    step = start_step
    for step in range(start_step, args.steps):
        batch = next(pipe)
        timer.start()
        with mesh:
            params, opt_state, err_fb, metrics = jstep(
                params, opt_state, err_fb, batch)
        metrics = jax.device_get(metrics)
        straggler = timer.stop(step)
        if straggler:
            print(straggler)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f}", flush=True)
        if ckpt:
            ckpt.maybe_save(step, {"params": params, "opt": opt_state},
                            extra={"data": pipe.state()})
        if preempt.should_stop:
            print("[preempt] saving final checkpoint and exiting")
            if ckpt:
                ckpt.maybe_save(step, {"params": params, "opt": opt_state},
                                extra={"data": pipe.state()}, force=True)
            break

    if ckpt and not preempt.should_stop:
        ckpt.maybe_save(step, {"params": params, "opt": opt_state},
                        extra={"data": pipe.state()}, force=True)
    dt = time.time() - t_start
    n = max(step - start_step + 1, 1)
    print(f"done: {n} steps in {dt:.1f}s ({dt / n * 1e3:.0f} ms/step); "
          f"stragglers flagged: {len(timer.stragglers)}")


if __name__ == "__main__":
    main()
