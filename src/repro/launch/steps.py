"""Step builders shared by the dry-run, trainer and server.

Each builder returns (fn, in_shardings, out_shardings, donate_argnums,
abstract_args) so callers can jit/lower uniformly:

    fn, in_sh, out_sh, donate, args = build_step(arch, shape_name, mesh)
    lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                      donate_argnums=donate).lower(*args)
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import registry
from ..models import sharding as shard_lib
from ..models import transformer as T
from ..optim import adamw, schedules

PyTree = Any


def _schedule(name: str):
    if name == "wsd":
        return schedules.wsd_schedule(3e-4, 500, 8000, 1500)
    return schedules.cosine_schedule(3e-4, 500, 10000)


def _replicated(mesh):
    return NamedSharding(mesh, P())


def _opt_shardings(mesh, param_sh):
    return adamw.AdamWState(step=_replicated(mesh), mu=param_sh, nu=param_sh)


def abstract_params(cfg: T.ModelConfig):
    return jax.eval_shape(
        functools.partial(T.init_params, cfg=cfg), jax.random.PRNGKey(0))


def build_step(arch: registry.ArchSpec, shape_name: str, mesh,
               *, grad_accum: int = 1):
    import dataclasses

    cfg = arch.config
    shape = registry.SHAPES[shape_name]
    policy = shard_lib.make_policy(cfg, mesh)

    # pin activation sharding (ZeRO-3: params' storage shards must not steal
    # the batch/seq axes from activations — see models/transformer.py).
    # resolve against the MICRObatch size: with gradient accumulation the
    # forward sees global_batch / accum sequences
    accum_eff = max(grad_accum, cfg.grad_accum)
    micro_b = max(shape.global_batch // accum_eff, 1)
    tok_spec = policy.resolve((micro_b, shape.seq_len), ["batch", "seq"])
    cfg = dataclasses.replace(
        cfg, act_sharding=(tok_spec[0] if len(tok_spec) > 0 else None,
                           tok_spec[1] if len(tok_spec) > 1 else None))
    arch = dataclasses.replace(arch, config=cfg)

    p_shapes = abstract_params(cfg)
    p_sh = shard_lib.param_shardings(cfg, policy, p_shapes)
    spec = registry.input_specs(arch, shape_name)

    if shape.mode == "train":
        o_shapes = jax.eval_shape(adamw.adamw_init, p_shapes)
        o_sh = _opt_shardings(mesh, p_sh)
        b_sh = shard_lib.batch_shardings(cfg, policy, spec["batch"])
        init_opt, update = adamw.make_optimizer(_schedule(arch.lr_schedule))

        accum = max(grad_accum, cfg.grad_accum)

        def train_step(params, opt_state, batch):
            if accum > 1:
                loss, grads = _accum_grads(params, cfg, batch, accum)
            else:
                loss, grads = jax.value_and_grad(T.loss_fn)(params, cfg, batch)
            new_p, new_o, metrics = update(grads, opt_state, params)
            return new_p, new_o, {"loss": loss, **metrics}

        metrics_sh = {"loss": _replicated(mesh), "lr": _replicated(mesh),
                      "grad_norm": _replicated(mesh)}
        return (train_step,
                (p_sh, o_sh, b_sh),
                (p_sh, o_sh, metrics_sh),
                (0, 1),
                (p_shapes, o_shapes, spec["batch"]))

    if shape.mode == "prefill":
        extras = [k for k in ("enc_inputs", "img_embeds") if k in spec]
        tok_sh = policy.named(tuple(spec["tokens"].shape), ["batch", "seq"])
        extra_sh = tuple(
            policy.named(tuple(spec[k].shape), ["batch", "seq", None])
            for k in extras)
        logits_sh = policy.named(
            (shape.global_batch, 1, cfg.vocab), ["batch", None, "vocab"])

        def prefill_step(params, tokens, *extra):
            kw = dict(zip(extras, extra))
            logits, cache = T.forward(params, cfg, tokens, emit_cache=True,
                                      **kw)
            return logits[:, -1:], cache

        abstract_args = (p_shapes, spec["tokens"]) + tuple(
            spec[k] for k in extras)
        # cache sharding from the *emitted* structure (matches serve_step's);
        # eval under the mesh context: the activation sharding constraints
        # inside forward() reference mesh axis names
        with mesh:
            cache_shapes = jax.eval_shape(prefill_step, *abstract_args)[1]
        cache_sh = shard_lib.cache_shardings(cfg, policy, cache_shapes)

        return (prefill_step,
                (p_sh, tok_sh) + extra_sh,
                (logits_sh, cache_sh),
                (),
                abstract_args)

    # decode
    cache_shapes = spec["cache"]
    cache_sh = shard_lib.cache_shardings(cfg, policy, cache_shapes)
    tok_sh = policy.named((shape.global_batch, 1), ["batch", None])
    logits_sh = policy.named(
        (shape.global_batch, 1, cfg.vocab), ["batch", None, "vocab"])

    def serve_step(params, cache, tokens):
        return T.decode_step(params, cfg, cache, tokens)

    return (serve_step,
            (p_sh, cache_sh, tok_sh),
            (logits_sh, cache_sh),
            (1,),
            (p_shapes, cache_shapes, spec["tokens"]))


def _accum_grads(params, cfg, batch, n):
    """Gradient accumulation over n microbatches (scan over batch splits)."""
    def micro(carry, mb):
        loss_acc, grads_acc = carry
        loss, grads = jax.value_and_grad(T.loss_fn)(params, cfg, mb)
        return (loss_acc + loss / n,
                jax.tree.map(lambda a, g: a + g / n, grads_acc, grads)), None

    def split(x):
        b = x.shape[0]
        return x.reshape(n, b // n, *x.shape[1:])

    micro_batches = jax.tree.map(split, batch)
    zero_grads = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
    (loss, grads), _ = jax.lax.scan(micro, (jnp.zeros((), jnp.float32),
                                            zero_grads), micro_batches)
    return loss, grads


