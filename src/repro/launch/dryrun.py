import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod);
  2. builds the step function + shardings (launch/steps.py);
  3. ``jax.jit(...).lower(*abstract).compile()`` — ShapeDtypeStruct inputs,
     so nothing is allocated; success proves the distribution config is
     coherent (shardings consistent, collectives legal, memory fits);
  4. records memory_analysis(), cost_analysis() and the per-collective byte
     counts parsed from the post-SPMD optimized HLO into a JSON file that
     benchmarks/bench_roofline.py turns into EXPERIMENTS.md §Roofline.

The XLA_FLAGS line above MUST run before any jax import (device count locks
on first init) — which is why it is the first statement of this module, and
why nothing else in the repo sets it globally.
"""
import argparse
import json
import re
import traceback
from typing import Callable, Dict, Optional

import jax

from ..configs import registry
from ..obs.trace import default_clock
from .mesh import make_production_mesh
from .steps import build_step

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "f16": 2, "bf16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes of every collective op in the (per-device,
    post-SPMD) optimized HLO.  Result bytes ≈ bytes moved per chip per op
    (all-gather result = gathered tensor; all-reduce result = full tensor;
    reduce-scatter result = shard)."""
    out = {k: 0.0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for coll in _COLLECTIVES:
            # match "<op> = <type> <collective>(" — post-optimization form
            m = re.search(rf"=\s+(.*?)\s+{coll}(?:-start|-done)?\(", stripped)
            if m:
                # `-done` ops repeat the type of `-start`; count starts only
                if f"{coll}-done" in stripped:
                    break
                out[coll] += _shape_bytes(m.group(1))
                out["count"] += 1
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             extra_cfg: Optional[dict] = None,
             now_fn: Callable[[], float] = default_clock) -> Dict:
    """Lower + compile one cell; returns the roofline record.

    ``now_fn`` is the same injectable monotonic clock the serving stack
    times with (``repro.obs.default_clock``); the old ``time.time()`` wall
    clock steps under NTP and mis-measures lower/compile durations."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    arch = registry.get(arch_name)
    if extra_cfg:
        import dataclasses
        arch = dataclasses.replace(
            arch, config=dataclasses.replace(arch.config, **extra_cfg))
    ok, reason = registry.supports(arch, shape_name)
    if not ok:
        return {"arch": arch_name, "shape": shape_name,
                "mesh": "multipod" if multi_pod else "pod",
                "status": "skipped", "reason": reason}

    t0 = now_fn()
    fn, in_sh, out_sh, donate, args = build_step(arch, shape_name, mesh)
    jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                  donate_argnums=donate)
    with mesh:
        lowered = jfn.lower(*args)
        t_lower = now_fn() - t0
        compiled = lowered.compile()
        t_compile = now_fn() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = parse_collective_bytes(compiled.as_text())
    n_chips = mesh.devices.size

    record = {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": "multipod" if multi_pod else "pod",
        "status": "ok",
        "n_chips": int(n_chips),
        "mode": registry.SHAPES[shape_name].mode,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        # per-device numbers (the compiled module is the per-device program)
        "flops_per_device": float(cost.get("flops", 0.0)),
        "bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes_per_device": coll["total"],
        "collectives": {k: coll[k] for k in _COLLECTIVES},
        "collective_count": coll["count"],
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
    }
    return record


def calibration_overrides(arch: "registry.ArchSpec", shape_name: str):
    """Two config variants whose HLO cost is exactly countable (no inner
    while loops: dot attention, single-chunk scans) at depth 0 and depth
    one-super-block.  bench_roofline reconstructs full-depth FLOPs/bytes as
        corrected = L0 + (n_layers / unit_len) * (L1 - L0)
    because XLA's HloCostAnalysis counts while bodies once, not x trip count.
    """
    cfg = arch.config
    shape = registry.SHAPES[shape_name]
    unit = len(cfg.pattern_unit())
    base = {"attention_impl": "dot"}
    if shape.mode != "decode":
        base["scan_chunk"] = shape.seq_len         # single-chunk SSM scans
    l0 = dict(base, n_layers=0)
    l1 = dict(base, n_layers=unit)
    if cfg.encoder_decoder:
        l0["enc_layers"] = 0
        l1["enc_layers"] = 1
    return l0, l1


def run_calibration(arch_name: str, shape_name: str) -> Dict:
    arch = registry.get(arch_name)
    ok, reason = registry.supports(arch, shape_name)
    if not ok:
        return {"arch": arch_name, "shape": shape_name, "mesh": "pod",
                "status": "skipped", "calibration": True, "reason": reason}
    l0, l1 = calibration_overrides(arch, shape_name)
    rec0 = run_cell(arch_name, shape_name, False, extra_cfg=l0)
    rec1 = run_cell(arch_name, shape_name, False, extra_cfg=l1)
    out = {"arch": arch_name, "shape": shape_name, "mesh": "pod",
           "status": "ok", "calibration": True,
           "unit_len": len(arch.config.pattern_unit()),
           "n_layers": arch.config.n_layers}
    for tag, rec in (("L0", rec0), ("L1", rec1)):
        for k in ("flops_per_device", "bytes_per_device",
                  "collective_bytes_per_device"):
            out[f"{tag}_{k}"] = rec[k]
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["pod", "multipod",
                                                       "both"])
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (perf iterations)")
    ap.add_argument("--calibrate", action="store_true",
                    help="run the L0/L1 cost-calibration compiles (pod mesh)")
    args = ap.parse_args()

    extra = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        extra[k] = v

    archs = ([a for a in registry.ARCH_NAMES if a != "alexnet"]
             if args.arch == "all" else [args.arch])
    shapes = list(registry.SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.mesh == "both" else \
        [args.mesh == "multipod"]

    if args.calibrate:
        results = []
        if os.path.exists(args.out):
            with open(args.out) as f:
                results = json.load(f)
        done = {(r["arch"], r["shape"]) for r in results
                if r.get("calibration") and r.get("status") in ("ok",
                                                                "skipped")}
        for arch_name in archs:
            for shape_name in shapes:
                if (arch_name, shape_name) in done:
                    print(f"[skip cached cal] {(arch_name, shape_name)}")
                    continue
                print(f"[calibrate] {(arch_name, shape_name)} ...",
                      flush=True)
                try:
                    rec = run_calibration(arch_name, shape_name)
                except Exception as e:
                    rec = {"arch": arch_name, "shape": shape_name,
                           "mesh": "pod", "status": "error",
                           "calibration": True,
                           "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                results.append(rec)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
                print(f"[{rec['status']}] cal {(arch_name, shape_name)}",
                      flush=True)
        return

    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results
            if r.get("status") == "ok" and not extra}

    for arch_name in archs:
        for shape_name in shapes:
            for multi_pod in meshes:
                key = (arch_name, shape_name,
                       "multipod" if multi_pod else "pod")
                if key in done:
                    print(f"[skip cached] {key}")
                    continue
                print(f"[dryrun] {key} ...", flush=True)
                try:
                    rec = run_cell(arch_name, shape_name, multi_pod,
                                   extra_cfg=extra or None)
                except Exception as e:  # a failure here is a bug; record it
                    rec = {"arch": arch_name, "shape": shape_name,
                           "mesh": "multipod" if multi_pod else "pod",
                           "status": "error", "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                if extra:
                    rec["overrides"] = extra
                results = [r for r in results if
                           (r["arch"], r["shape"], r["mesh"]) != key
                           or r.get("overrides") != rec.get("overrides")]
                results.append(rec)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
                status = rec["status"]
                msg = rec.get("error", "")
                if status == "ok":
                    msg = (f"compile {rec['compile_s']}s, "
                           f"{rec['flops_per_device']/1e9:.1f} GFLOP/dev, "
                           f"coll {rec['collective_bytes_per_device']/1e6:.1f} MB/dev")
                print(f"[{status}] {key} {msg}", flush=True)

    n_ok = sum(r["status"] == "ok" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\ndry-run complete: {n_ok} ok, {n_err} errors -> {args.out}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
