"""Serving driver: continuous-batching engine loop (default) or the legacy
static-batch server (``--static-batching``).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2_1_5b \
        --scale smoke --slots 8 --requests 32 --rate 16

This module is only the CLI skin: every flag maps 1:1 onto a field of
:class:`repro.serving.api.ServeOptions` and ``main()`` does nothing but
parse -> ``ServeOptions.from_args`` -> ``validate`` -> ``serve``.  The
serving logic itself — engine construction, placement, speculation,
observability exports — lives in :func:`repro.serving.api.serve`, the
same entry point benchmarks and tests drive programmatically.

Continuous path (repro.serving): an open-loop arrival stream feeds a
slot-based KV pool; the batcher prices admission with core/cost_model.py and
the jitted engine step interleaves prefill with the running decode batch.
``--kv-layout paged`` (default) stores KV in fixed-size physical blocks
gathered through per-slot block tables (vLLM-style paging; outputs stay
bit-identical to ``--kv-layout dense``), so ``--total-blocks`` can
provision the pool for tokens-in-flight instead of slots x max_seq.
``--placement auto`` additionally runs the phase-placement DSE
(repro.serving.placement): prefill and decode are priced separately over
the engine set and the serving loop disaggregates onto the winning pair
(explicit control: ``--placement disagg --prefill-engine X
--decode-engine Y``).
Static path: requests accumulate into a batch; prefill replays the prompt
into a max_len cache; decode emits one token per step for the whole batch —
the queue refills only between generations (head-of-line blocking).

Speculative decoding (``--speculate``): a draft model proposes k tokens
per slot and the target verifies all k in ONE multi-position step over
the paged KV cache, committing only the accepted prefix — greedy outputs
stay bit-identical to plain decode.  The trade-off analyzer
(repro.serving.placement.choose_speculation) prices draft steps + the
verify step against plain decode at the measured-or-prior acceptance
rate, picks the depth k, and falls back to plain decode when speculation
prices worse; an online acceptance tracker re-prices mid-run and can
veto a drafting model that stops earning its keep.  ``--draft-k K``
forces depth K regardless of price (the CI/identity knob).

Observability (``--trace``, ``--metrics-out``, ``--feed-cache``): the
continuous path can record every request's lifecycle spans into a Chrome
trace-event JSON (load it in Perfetto / ``chrome://tracing``), dump the
metrics-registry snapshot (counters, histogram summaries, sampled KV/queue
time series), and feed the observed decode-burst step timings back into the
profiling cache as measured points — the telemetry leg of ROADMAP's
online-recalibration item.

Watchdog (``--watchdog``, ``--slo-report``): the online performance
watchdog compares each burst's observed step time against the admission
price, fits piecewise-linear latency(batch) curves from the telemetry, and
— when the EWMA divergence crosses the gate — re-prices admission mid-run
(and records fresh placement advice on the disaggregated path).
``--misprice FACTOR`` injects a known pricing error for CI; ``--slo-report``
prints per-request-class TTFT/TPOT SLO attainment afterwards.

On the production mesh, params/caches shard per models/sharding.py — the
same shardings the dry-run validates for the decode_32k / long_500k cells.
"""
from __future__ import annotations

import argparse

from ..serving import placement as placement_lib
# re-exported for compatibility: the static server and param builder grew
# up here before the programmatic API extracted them
from ..serving.api import (ServeOptions, ServeReport, Server,  # noqa: F401
                           build_params, serve)


def build_parser() -> argparse.ArgumentParser:
    """The serve CLI's argument parser (module-level so tests and the docs
    consistency gate can introspect the flag set without running a
    server).  Every dest matches a ServeOptions leaf field; flags whose
    absence matters to validation default to None and get their effective
    default (noted in the help) inside serve()."""
    ap = argparse.ArgumentParser(prog="repro.launch.serve",
                                 description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="qwen2_1_5b")
    ap.add_argument("--scale", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--batch", type=int, default=4,
                    help="static path: batch size")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--mesh", default="host", choices=["host", "pod",
                                                       "multipod"])
    ap.add_argument("--static-batching", action="store_true",
                    help="legacy fallback: static batches instead of the "
                         "continuous engine")
    ap.add_argument("--slots", type=int, default=8,
                    help="continuous path: KV pool slots")
    ap.add_argument("--kv-layout", default="paged",
                    choices=["dense", "paged"],
                    help="continuous path: KV cache layout — paged stores "
                         "KV in fixed-size physical blocks gathered "
                         "through per-slot block tables (vLLM-style; "
                         "outputs bit-identical to dense), dense keeps "
                         "physically max_seq-long slot rows")
    ap.add_argument("--total-blocks", type=int, default=None,
                    help="paged layout: physical KV blocks to provision "
                         "(default: the dense equivalent; smaller values "
                         "provision for tokens-in-flight and admission "
                         "defers when pages run out)")
    ap.add_argument("--prefix-sharing", action="store_true",
                    help="continuous path, paged layout: maintain a prefix "
                         "index over prompt-token block prefixes and map "
                         "matching prefixes onto already-written KV pages "
                         "(refcounted, copy-on-write at a divergent tail) — "
                         "shared prefixes skip prefill and draw no fresh "
                         "blocks, so more requests fit the same pool")
    ap.add_argument("--shared-prefix-len", type=int, default=None,
                    metavar="N",
                    help="workload: front-load one common N-token prefix "
                         "onto --shared-frac of the requests (the chat/"
                         "agent system-prompt pattern prefix sharing "
                         "exploits); default: fully unique prompts")
    ap.add_argument("--shared-frac", type=float, default=None,
                    help="workload: fraction of requests carrying the "
                         "--shared-prefix-len common prefix (default 0.9; "
                         "requires --shared-prefix-len)")
    ap.add_argument("--rate", type=float, default=16.0,
                    help="continuous path: offered load (req/s)")
    ap.add_argument("--stream", action="store_true",
                    help="continuous path: stream tokens incrementally — "
                         "the engine syncs at burst boundaries and prints "
                         "each request's newly readable tokens (TTFT then "
                         "measures delivered tokens; costs one host sync "
                         "per burst)")
    ap.add_argument("--step-slo-ms", type=float, default=None,
                    help="continuous path: per-step latency objective the "
                         "cost model prices admission against")
    ap.add_argument("--device-model", default="tpu-v5e",
                    help="continuous path: core/device_models entry used to "
                         "price admission")
    ap.add_argument("--calibrated-cache", default=None, metavar="PATH",
                    help="price admission on a profiling-calibrated device "
                         "model fitted from this profile cache "
                         "(repro.profiling) instead of nominal constants")
    ap.add_argument("--calibrated-engine", default=None,
                    help="engine whose measurements to calibrate from when "
                         "--calibrated-cache is given (default xla)")
    ap.add_argument("--placement", default="colocated",
                    choices=["colocated", "disagg", "auto"],
                    help="auto: price prefill/decode separately over the "
                         "placement engine set (repro.serving.placement) "
                         "and run the winning pair; disagg: force the "
                         "disaggregated loop on --prefill-engine/"
                         "--decode-engine")
    ap.add_argument("--placement-objective", default="latency",
                    choices=list(placement_lib.OBJECTIVES),
                    help="objective the phase placement minimizes")
    ap.add_argument("--prefill-engine", default=None, metavar="ENGINE",
                    help="engine (core/engines name) whose device model "
                         "prices the prefill phase (implies --placement "
                         "disagg unless auto)")
    ap.add_argument("--decode-engine", default=None, metavar="ENGINE",
                    help="engine whose device model prices the decode phase")
    ap.add_argument("--prefill-slots", type=int, default=None,
                    help="disaggregated path: prefill-engine slots "
                         "(default: --slots)")
    ap.add_argument("--device-assignment", default="single",
                    choices=["single", "auto"],
                    help="disaggregated path: auto pins the prefill and "
                         "decode engines onto distinct jax devices when "
                         ">= 2 are visible (params + KV arenas live per "
                         "phase, hand-offs become real inter-device "
                         "copies; on CPU hosts set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N); "
                         "single keeps everything on the default device")
    ap.add_argument("--sync-handoff", action="store_true",
                    help="disaggregated path: adopt each phase hand-off "
                         "immediately after dispatch instead of letting "
                         "the transfer overlap the prefill engine's next "
                         "bursts (the synchronous baseline the async "
                         "hand-off is measured against)")
    ap.add_argument("--handoff-link-bw", type=float, default=None,
                    metavar="BYTES_PER_S",
                    help="disaggregated path: price phase hand-offs at "
                         "this link bandwidth instead of the device "
                         "models' datasheet fallback (wins over "
                         "--measure-link-bw)")
    ap.add_argument("--measure-link-bw", nargs="?", default=None,
                    const=True, metavar="PATH",
                    help="measure an actual inter-device jax.device_put "
                         "of a representative page batch between the two "
                         "phase devices at startup, record it in the "
                         "profile cache (default path: the "
                         "REPRO_PROFILE_CACHE cache) for "
                         "place_phases(price=\"measured\"), and price "
                         "this run's hand-offs with it")
    ap.add_argument("--persist-curves", default=None, metavar="PATH",
                    help="continuous path: prime admission pricing from "
                         "the latency(batch) curve a previous run fed "
                         "into this profile cache (source="
                         "serving-telemetry), and flush this run's burst "
                         "telemetry back on exit — a restarted server "
                         "prices from the last run's curve instead of "
                         "re-warming")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="continuous path: record per-request lifecycle "
                         "spans + engine burst/sync spans and write a "
                         "Chrome trace-event JSON (open in Perfetto or "
                         "chrome://tracing)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="continuous path: dump the metrics-registry "
                         "snapshot (counters, histogram summaries, sampled "
                         "KV-occupancy/queue-depth time series) as JSON")
    ap.add_argument("--feed-cache", nargs="?", default=None,
                    const=True, metavar="PATH",
                    help="continuous path: feed observed decode-burst step "
                         "timings back into the profiling cache as measured "
                         "points (default path: the REPRO_PROFILE_CACHE "
                         "profile cache), so price=\"measured\" learns from "
                         "this run's traffic; with --speculate also "
                         "persists the measured acceptance rate the "
                         "analyzer prices later runs on")
    ap.add_argument("--watchdog", action="store_true",
                    help="continuous path: run the online performance "
                         "watchdog — compare observed burst step times "
                         "against the priced cost model, fit latency(batch) "
                         "curves from telemetry, and re-price admission "
                         "mid-run when the EWMA divergence crosses the gate")
    ap.add_argument("--drift-gate", type=float, default=None,
                    help="watchdog: observed/priced EWMA ratio (or its "
                         "inverse) that raises a DriftAlert (default 1.5; "
                         "requires --watchdog)")
    ap.add_argument("--misprice", type=float, default=None, metavar="FACTOR",
                    help="debug/CI: scale the admission device model's "
                         "throughput down by FACTOR (drift_scaled_device) "
                         "so the priced step time is FACTOR x too slow — "
                         "an injected mispricing the watchdog must detect "
                         "and correct (FACTOR < 1 prices too FAST, so the "
                         "drifted device looks slow and placement moves "
                         "work off it; requires --watchdog)")
    ap.add_argument("--misprice-phase", default=None,
                    choices=["both", "prefill", "decode"],
                    help="--misprice scope on the disaggregated path: "
                         "misprice only one phase's device model so "
                         "exactly that stream drifts (the deterministic "
                         "trigger for mid-run placement actuation; "
                         "default both, requires --misprice)")
    ap.add_argument("--slo-report", action="store_true",
                    help="continuous path: print per-request-class "
                         "(short/medium/long by generation length) "
                         "TTFT/TPOT SLO attainment after the run")
    ap.add_argument("--slo-ttft-ms", type=float, default=None,
                    help="--slo-report: time-to-first-token objective "
                         "(ms, default 2000; requires --slo-report)")
    ap.add_argument("--slo-tpot-ms", type=float, default=None,
                    help="--slo-report: time-per-output-token objective "
                         "(ms, default 200; requires --slo-report)")
    ap.add_argument("--speculate", action="store_true",
                    help="continuous path, paged layout: draft-model "
                         "speculative decoding — the draft proposes k "
                         "tokens per slot, the target verifies all k in "
                         "one multi-position step over the paged cache "
                         "(greedy outputs stay bit-identical to plain "
                         "decode); the trade-off analyzer prices the "
                         "draft and depth against plain decode at the "
                         "measured-or-prior acceptance rate and serves "
                         "plain when speculation prices worse")
    ap.add_argument("--draft-arch", default=None, metavar="ARCH",
                    help="--speculate: registry arch proposing draft "
                         "tokens (default qwen2_1_5b; must share the "
                         "target's vocab)")
    ap.add_argument("--draft-k", type=int, default=None, metavar="K",
                    help="--speculate: force draft depth K and skip the "
                         "analyzer's engage/veto pricing (the CI and "
                         "bit-identity knob)")
    return ap


def main() -> None:
    ap = build_parser()
    args = ap.parse_args()
    options = ServeOptions.from_args(args)
    try:
        options.validate()
    except ValueError as err:
        ap.error(str(err))
    try:
        serve(options, verbose=True)
    except ValueError as err:
        raise SystemExit(f"[serve] {err}")


if __name__ == "__main__":
    main()
