"""Serving driver: batched prefill + decode loop with a request queue.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2_1_5b \
        --scale smoke --batch 4 --prompt-len 32 --gen-len 32

Implements the standard two-phase serving flow:
  * requests accumulate into a batch (static batching; the queue refills
    between generations);
  * prefill computes the KV cache (padded to max_len so decode's rolling
    writes never overflow);
  * decode greedily emits one token per step for the whole batch.

On the production mesh, params/caches shard per models/sharding.py — the
same shardings the dry-run validates for the decode_32k / long_500k cells.
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import time
from typing import List

import jax
import jax.numpy as jnp

from ..configs import registry
from ..models import sharding as shard_lib
from ..models import transformer as T
from .mesh import make_host_mesh, make_production_mesh


class Server:
    def __init__(self, cfg: T.ModelConfig, params, mesh, max_len: int):
        self.cfg = cfg
        self.params = params
        self.mesh = mesh
        self.max_len = max_len
        self._decode = jax.jit(
            functools.partial(T.decode_step, cfg=self.cfg), donate_argnums=(1,),
            static_argnames=()) if False else jax.jit(
            lambda p, c, t: T.decode_step(p, cfg, c, t), donate_argnums=(1,))
        self._prefill = jax.jit(
            lambda p, t: T.forward(p, cfg, t, emit_cache=True))

    def generate(self, prompts: jnp.ndarray, gen_len: int) -> jnp.ndarray:
        """prompts: (B, P) int32.  Returns (B, gen_len)."""
        b, plen = prompts.shape
        logits, _ = self._prefill(self.params, prompts)
        # build a max_len cache and replay the prompt through decode steps
        # (keeps the cache layout identical to the dry-run serve_step cells)
        cache = T.init_cache(self.cfg, b, max_seq=self.max_len)
        for i in range(plen):
            step_logits, cache = self._decode(self.params, cache,
                                              prompts[:, i:i + 1])
        next_tok = jnp.argmax(step_logits[:, -1], axis=-1)[:, None]
        out: List[jnp.ndarray] = [next_tok]
        for _ in range(gen_len - 1):
            step_logits, cache = self._decode(self.params, cache, out[-1])
            out.append(jnp.argmax(step_logits[:, -1], axis=-1)[:, None])
        return jnp.concatenate(out, axis=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_1_5b")
    ap.add_argument("--scale", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--mesh", default="host", choices=["host", "pod",
                                                       "multipod"])
    args = ap.parse_args()

    arch = registry.get(args.arch)
    cfg = arch.smoke if args.scale == "smoke" else arch.config
    assert cfg is not None and not cfg.encoder_decoder \
        and cfg.frontend == "none", "serve CLI supports decoder-only LMs"
    cfg = dataclasses.replace(cfg, scan_chunk=min(cfg.scan_chunk, 16))

    mesh = (make_host_mesh() if args.mesh == "host" else
            make_production_mesh(multi_pod=args.mesh == "multipod"))
    policy = shard_lib.make_policy(cfg, mesh)
    p_shapes = jax.eval_shape(
        functools.partial(T.init_params, cfg=cfg), jax.random.PRNGKey(0))
    p_sh = shard_lib.param_shardings(cfg, policy, p_shapes)
    with mesh:
        params = jax.jit(functools.partial(T.init_params, cfg=cfg),
                         out_shardings=p_sh)(jax.random.PRNGKey(0))

    server = Server(cfg, params, mesh, max_len=args.prompt_len + args.gen_len)

    rng = jax.random.PRNGKey(1)
    done = 0
    t0 = time.time()
    while done < args.requests:
        n = min(args.batch, args.requests - done)
        rng, k = jax.random.split(rng)
        prompts = jax.random.randint(k, (n, args.prompt_len), 0, cfg.vocab)
        with mesh:
            toks = server.generate(prompts, args.gen_len)
        toks.block_until_ready()
        done += n
        print(f"[serve] batch of {n}: generated {toks.shape} "
              f"first row: {toks[0, :8].tolist()}", flush=True)
    dt = time.time() - t0
    total_toks = args.requests * args.gen_len
    print(f"served {args.requests} requests, {total_toks} tokens in "
          f"{dt:.1f}s ({total_toks / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
