"""Serving driver: continuous-batching engine loop (default) or the legacy
static-batch server (``--static-batching``).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2_1_5b \
        --scale smoke --slots 8 --requests 32 --rate 16

Continuous path (repro.serving): an open-loop arrival stream feeds a
slot-based KV pool; the batcher prices admission with core/cost_model.py and
the jitted engine step interleaves prefill with the running decode batch.
``--kv-layout paged`` (default) stores KV in fixed-size physical blocks
gathered through per-slot block tables (vLLM-style paging; outputs stay
bit-identical to ``--kv-layout dense``), so ``--total-blocks`` can
provision the pool for tokens-in-flight instead of slots x max_seq.
``--placement auto`` additionally runs the phase-placement DSE
(repro.serving.placement): prefill and decode are priced separately over
the engine set and the serving loop disaggregates onto the winning pair
(explicit control: ``--placement disagg --prefill-engine X
--decode-engine Y``).
Static path: requests accumulate into a batch; prefill replays the prompt
into a max_len cache; decode emits one token per step for the whole batch —
the queue refills only between generations (head-of-line blocking).

Observability (``--trace``, ``--metrics-out``, ``--feed-cache``): the
continuous path can record every request's lifecycle spans into a Chrome
trace-event JSON (load it in Perfetto / ``chrome://tracing``), dump the
metrics-registry snapshot (counters, histogram summaries, sampled KV/queue
time series), and feed the observed decode-burst step timings back into the
profiling cache as measured points — the telemetry leg of ROADMAP's
online-recalibration item.

Watchdog (``--watchdog``, ``--slo-report``): the online performance
watchdog compares each burst's observed step time against the admission
price, fits piecewise-linear latency(batch) curves from the telemetry, and
— when the EWMA divergence crosses the gate — re-prices admission mid-run
(and records fresh placement advice on the disaggregated path).
``--misprice FACTOR`` injects a known pricing error for CI; ``--slo-report``
prints per-request-class TTFT/TPOT SLO attainment afterwards.

On the production mesh, params/caches shard per models/sharding.py — the
same shardings the dry-run validates for the decode_32k / long_500k cells.
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
from typing import List

import jax
import jax.numpy as jnp

from ..configs import registry
from ..models import sharding as shard_lib
from ..models import transformer as T
from ..obs import Observability, TelemetryFeedback, Tracer, default_clock
from ..obs.export import write_metrics, write_trace
from ..serving import (DisaggregatedEngineLoop, EngineLoop, place_phases,
                       prefix_shared_workload, synthetic_workload)
from ..serving import placement as placement_lib
from .mesh import device_assignment, make_host_mesh, make_production_mesh


class Server:
    """Legacy static-batching server (the continuous engine's baseline)."""

    def __init__(self, cfg: T.ModelConfig, params, mesh, max_len: int):
        self.cfg = cfg
        self.params = params
        self.mesh = mesh
        self.max_len = max_len
        self._decode = jax.jit(
            lambda p, c, t: T.decode_step(p, cfg, c, t), donate_argnums=(1,))

    def generate(self, prompts: jnp.ndarray, gen_len: int) -> jnp.ndarray:
        """prompts: (B, P) int32.  Returns (B, gen_len)."""
        b, plen = prompts.shape
        # build a max_len cache and replay the prompt through decode steps
        # (keeps the cache layout identical to the dry-run serve_step cells)
        cache = T.init_cache(self.cfg, b, max_seq=self.max_len)
        for i in range(plen):
            step_logits, cache = self._decode(self.params, cache,
                                              prompts[:, i:i + 1])
        next_tok = jnp.argmax(step_logits[:, -1], axis=-1)[:, None]
        out: List[jnp.ndarray] = [next_tok]
        for _ in range(gen_len - 1):
            step_logits, cache = self._decode(self.params, cache, out[-1])
            out.append(jnp.argmax(step_logits[:, -1], axis=-1)[:, None])
        return jnp.concatenate(out, axis=1)


def build_params(cfg: T.ModelConfig, mesh):
    policy = shard_lib.make_policy(cfg, mesh)
    p_shapes = jax.eval_shape(
        functools.partial(T.init_params, cfg=cfg), jax.random.PRNGKey(0))
    p_sh = shard_lib.param_shardings(cfg, policy, p_shapes)
    with mesh:
        return jax.jit(functools.partial(T.init_params, cfg=cfg),
                       out_shardings=p_sh)(jax.random.PRNGKey(0))


def build_parser() -> argparse.ArgumentParser:
    """The serve CLI's argument parser (module-level so tests and the docs
    consistency gate can introspect the flag set without running a
    server)."""
    ap = argparse.ArgumentParser(prog="repro.launch.serve",
                                 description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="qwen2_1_5b")
    ap.add_argument("--scale", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--batch", type=int, default=4,
                    help="static path: batch size")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--mesh", default="host", choices=["host", "pod",
                                                       "multipod"])
    ap.add_argument("--static-batching", action="store_true",
                    help="legacy fallback: static batches instead of the "
                         "continuous engine")
    ap.add_argument("--slots", type=int, default=8,
                    help="continuous path: KV pool slots")
    ap.add_argument("--kv-layout", default="paged",
                    choices=["dense", "paged"],
                    help="continuous path: KV cache layout — paged stores "
                         "KV in fixed-size physical blocks gathered "
                         "through per-slot block tables (vLLM-style; "
                         "outputs bit-identical to dense), dense keeps "
                         "physically max_seq-long slot rows")
    ap.add_argument("--total-blocks", type=int, default=None,
                    help="paged layout: physical KV blocks to provision "
                         "(default: the dense equivalent; smaller values "
                         "provision for tokens-in-flight and admission "
                         "defers when pages run out)")
    ap.add_argument("--prefix-sharing", action="store_true",
                    help="continuous path, paged layout: maintain a prefix "
                         "index over prompt-token block prefixes and map "
                         "matching prefixes onto already-written KV pages "
                         "(refcounted, copy-on-write at a divergent tail) — "
                         "shared prefixes skip prefill and draw no fresh "
                         "blocks, so more requests fit the same pool")
    ap.add_argument("--shared-prefix-len", type=int, default=None,
                    metavar="N",
                    help="workload: front-load one common N-token prefix "
                         "onto --shared-frac of the requests (the chat/"
                         "agent system-prompt pattern prefix sharing "
                         "exploits); default: fully unique prompts")
    ap.add_argument("--shared-frac", type=float, default=0.9,
                    help="workload: fraction of requests carrying the "
                         "--shared-prefix-len common prefix (default 0.9)")
    ap.add_argument("--rate", type=float, default=16.0,
                    help="continuous path: offered load (req/s)")
    ap.add_argument("--stream", action="store_true",
                    help="continuous path: stream tokens incrementally — "
                         "the engine syncs at burst boundaries and prints "
                         "each request's newly readable tokens (TTFT then "
                         "measures delivered tokens; costs one host sync "
                         "per burst)")
    ap.add_argument("--step-slo-ms", type=float, default=None,
                    help="continuous path: per-step latency objective the "
                         "cost model prices admission against")
    ap.add_argument("--device-model", default="tpu-v5e",
                    help="continuous path: core/device_models entry used to "
                         "price admission")
    ap.add_argument("--calibrated-cache", default=None, metavar="PATH",
                    help="price admission on a profiling-calibrated device "
                         "model fitted from this profile cache "
                         "(repro.profiling) instead of nominal constants")
    ap.add_argument("--calibrated-engine", default="xla",
                    help="engine whose measurements to calibrate from when "
                         "--calibrated-cache is given")
    ap.add_argument("--placement", default="colocated",
                    choices=["colocated", "disagg", "auto"],
                    help="auto: price prefill/decode separately over the "
                         "placement engine set (repro.serving.placement) "
                         "and run the winning pair; disagg: force the "
                         "disaggregated loop on --prefill-engine/"
                         "--decode-engine")
    ap.add_argument("--placement-objective", default="latency",
                    choices=list(placement_lib.OBJECTIVES),
                    help="objective the phase placement minimizes")
    ap.add_argument("--prefill-engine", default=None, metavar="ENGINE",
                    help="engine (core/engines name) whose device model "
                         "prices the prefill phase (implies --placement "
                         "disagg unless auto)")
    ap.add_argument("--decode-engine", default=None, metavar="ENGINE",
                    help="engine whose device model prices the decode phase")
    ap.add_argument("--prefill-slots", type=int, default=None,
                    help="disaggregated path: prefill-engine slots "
                         "(default: --slots)")
    ap.add_argument("--device-assignment", default="single",
                    choices=["single", "auto"],
                    help="disaggregated path: auto pins the prefill and "
                         "decode engines onto distinct jax devices when "
                         ">= 2 are visible (params + KV arenas live per "
                         "phase, hand-offs become real inter-device "
                         "copies; on CPU hosts set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N); "
                         "single keeps everything on the default device")
    ap.add_argument("--sync-handoff", action="store_true",
                    help="disaggregated path: adopt each phase hand-off "
                         "immediately after dispatch instead of letting "
                         "the transfer overlap the prefill engine's next "
                         "bursts (the synchronous baseline the async "
                         "hand-off is measured against)")
    ap.add_argument("--handoff-link-bw", type=float, default=None,
                    metavar="BYTES_PER_S",
                    help="disaggregated path: price phase hand-offs at "
                         "this link bandwidth instead of the device "
                         "models' datasheet fallback (wins over "
                         "--measure-link-bw)")
    ap.add_argument("--measure-link-bw", nargs="?", default=None,
                    const=True, metavar="PATH",
                    help="measure an actual inter-device jax.device_put "
                         "of a representative page batch between the two "
                         "phase devices at startup, record it in the "
                         "profile cache (default path: the "
                         "REPRO_PROFILE_CACHE cache) for "
                         "place_phases(price=\"measured\"), and price "
                         "this run's hand-offs with it")
    ap.add_argument("--persist-curves", default=None, metavar="PATH",
                    help="continuous path: prime admission pricing from "
                         "the latency(batch) curve a previous run fed "
                         "into this profile cache (source="
                         "serving-telemetry), and flush this run's burst "
                         "telemetry back on exit — a restarted server "
                         "prices from the last run's curve instead of "
                         "re-warming")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="continuous path: record per-request lifecycle "
                         "spans + engine burst/sync spans and write a "
                         "Chrome trace-event JSON (open in Perfetto or "
                         "chrome://tracing)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="continuous path: dump the metrics-registry "
                         "snapshot (counters, histogram summaries, sampled "
                         "KV-occupancy/queue-depth time series) as JSON")
    ap.add_argument("--feed-cache", nargs="?", default=None,
                    const=True, metavar="PATH",
                    help="continuous path: feed observed decode-burst step "
                         "timings back into the profiling cache as measured "
                         "points (default path: the REPRO_PROFILE_CACHE "
                         "profile cache), so price=\"measured\" learns from "
                         "this run's traffic")
    ap.add_argument("--watchdog", action="store_true",
                    help="continuous path: run the online performance "
                         "watchdog — compare observed burst step times "
                         "against the priced cost model, fit latency(batch) "
                         "curves from telemetry, and re-price admission "
                         "mid-run when the EWMA divergence crosses the gate")
    ap.add_argument("--drift-gate", type=float, default=None,
                    help="watchdog: observed/priced EWMA ratio (or its "
                         "inverse) that raises a DriftAlert (default 1.5)")
    ap.add_argument("--misprice", type=float, default=None, metavar="FACTOR",
                    help="debug/CI: scale the admission device model's "
                         "throughput down by FACTOR (drift_scaled_device) "
                         "so the priced step time is FACTOR x too slow — "
                         "an injected mispricing the watchdog must detect "
                         "and correct (FACTOR < 1 prices too FAST, so the "
                         "drifted device looks slow and placement moves "
                         "work off it)")
    ap.add_argument("--misprice-phase", default="both",
                    choices=["both", "prefill", "decode"],
                    help="--misprice scope on the disaggregated path: "
                         "misprice only one phase's device model so "
                         "exactly that stream drifts (the deterministic "
                         "trigger for mid-run placement actuation)")
    ap.add_argument("--slo-report", action="store_true",
                    help="continuous path: print per-request-class "
                         "(short/medium/long by generation length) "
                         "TTFT/TPOT SLO attainment after the run")
    ap.add_argument("--slo-ttft-ms", type=float, default=2000.0,
                    help="--slo-report: time-to-first-token objective (ms)")
    ap.add_argument("--slo-tpot-ms", type=float, default=200.0,
                    help="--slo-report: time-per-output-token objective (ms)")
    return ap


def _prime_curves(args, cfg, kv_len: int, batcher) -> None:
    """--persist-curves startup leg: fit the latency(batch) curve from the
    telemetry a previous run fed into the cache and install it as the
    decode batcher's pricing — a restarted server prices from the last
    run's observed curve instead of re-warming through the watchdog."""
    if not args.persist_curves:
        return
    import os

    from ..obs.curves import curve_points_from_cache, fit_latency_curve
    from ..profiling.cache import ProfileCache
    if not os.path.exists(args.persist_curves):
        print(f"[serve] curves: {args.persist_curves} does not exist yet "
              f"(first run warms it)", flush=True)
        return
    cache = ProfileCache.load(args.persist_curves, strict=False)
    points = curve_points_from_cache(cache, cfg, kv_len=kv_len)
    curve = fit_latency_curve(points, source="cache-curve")
    if curve is None:
        print(f"[serve] curves: {args.persist_curves} holds "
              f"{len(points)} usable batch point(s) — need >= 2 for a "
              f"curve; pricing stays analytic", flush=True)
        return
    detail = batcher.reprice(curve.predict, source="cache-curve")
    print(f"[serve] curves: primed {batcher.phase} pricing from "
          f"{args.persist_curves} (batches {list(curve.batches)}, "
          f"token budget {detail['token_budget_old']} -> "
          f"{detail['token_budget']})", flush=True)


def main() -> None:
    ap = build_parser()
    args = ap.parse_args()
    if args.placement == "auto" and (args.prefill_engine
                                     or args.decode_engine):
        ap.error("--placement auto chooses the engines; drop "
                 "--prefill-engine/--decode-engine or use --placement disagg")
    if args.stream and args.static_batching:
        ap.error("--stream needs the continuous engine (the static server "
                 "only surfaces tokens at batch end)")
    if args.static_batching and (args.trace or args.metrics_out
                                 or args.feed_cache or args.watchdog
                                 or args.slo_report):
        ap.error("--trace/--metrics-out/--feed-cache/--watchdog/--slo-report "
                 "instrument the continuous engine; drop --static-batching")
    if args.misprice is not None and args.misprice <= 0:
        ap.error("--misprice must be > 0")
    if args.static_batching and (args.device_assignment != "single"
                                 or args.sync_handoff or args.persist_curves
                                 or args.measure_link_bw):
        ap.error("--device-assignment/--sync-handoff/--persist-curves/"
                 "--measure-link-bw drive the continuous engine; drop "
                 "--static-batching")
    if args.prefix_sharing and args.kv_layout == "dense":
        ap.error("--prefix-sharing maps physical KV pages; it requires "
                 "--kv-layout paged")
    if args.prefix_sharing and args.static_batching:
        ap.error("--prefix-sharing needs the continuous engine's KV pool")
    if args.shared_prefix_len is not None and args.shared_prefix_len <= 0:
        ap.error("--shared-prefix-len must be > 0")

    arch = registry.get(args.arch)
    cfg = arch.smoke if args.scale == "smoke" else arch.config
    assert cfg is not None and not cfg.encoder_decoder \
        and cfg.frontend == "none", "serve CLI supports decoder-only LMs"
    cfg = dataclasses.replace(cfg, scan_chunk=min(cfg.scan_chunk, 16))
    if args.kv_layout == "paged" and cfg.attn_window is not None:
        # the paged arena has no rolling-buffer mode yet (ROADMAP follow-on)
        print(f"[serve] {args.arch} uses sliding-window attention "
              f"(window={cfg.attn_window}); paged KV layout does not "
              f"support rolling buffers yet — falling back to dense",
              flush=True)
        args.kv_layout = "dense"
    if args.prefix_sharing:
        if args.kv_layout != "paged":
            raise SystemExit(f"[serve] --prefix-sharing requires the paged "
                             f"KV layout, but {args.arch} fell back to "
                             f"dense (sliding-window attention)")
        if any(t != "attn" for t in cfg.layer_types()):
            raise SystemExit(f"[serve] --prefix-sharing requires an all-"
                             f"attention config; {args.arch} mixes layer "
                             f"types {sorted(set(cfg.layer_types()))} "
                             f"(recurrent/cross state is slot-local)")

    mesh = (make_host_mesh() if args.mesh == "host" else
            make_production_mesh(multi_pod=args.mesh == "multipod"))
    params = build_params(cfg, mesh)
    max_len = args.prompt_len + args.gen_len

    if args.static_batching:
        server = Server(cfg, params, mesh, max_len=max_len)
        rng = jax.random.PRNGKey(1)
        done = 0
        # monotonic clock (shared with the serving loops' timing): wall
        # clock steps under NTP and must not measure intervals
        t0 = default_clock()
        while done < args.requests:
            n = min(args.batch, args.requests - done)
            rng, k = jax.random.split(rng)
            prompts = jax.random.randint(k, (n, args.prompt_len), 0,
                                         cfg.vocab)
            with mesh:
                toks = server.generate(prompts, args.gen_len)
            toks.block_until_ready()
            done += n
            print(f"[serve] batch of {n}: generated {toks.shape} "
                  f"first row: {toks[0, :8].tolist()}", flush=True)
        dt = default_clock() - t0
        total_toks = args.requests * args.gen_len
        print(f"served {args.requests} requests, {total_toks} tokens in "
              f"{dt:.1f}s ({total_toks / dt:.1f} tok/s)")
        return

    # continuous batching: mixed-length open-loop traffic.  With
    # --shared-prefix-len the stream front-loads one common prefix onto
    # --shared-frac of the requests (prompts grow by the prefix, so the
    # pool's max_seq grows with them)
    gen_lens = (max(args.gen_len // 8, 1), max(args.gen_len // 2, 1),
                args.gen_len)
    if args.shared_prefix_len is not None:
        requests = prefix_shared_workload(
            args.requests, rate=args.rate, vocab=cfg.vocab,
            shared_prefix_len=args.shared_prefix_len,
            shared_frac=args.shared_frac,
            suffix_lens=(max(args.prompt_len // 2, 1), args.prompt_len),
            gen_lens=gen_lens, seed=1)
        max_len += args.shared_prefix_len
    else:
        requests = synthetic_workload(
            args.requests, rate=args.rate, vocab=cfg.vocab,
            prompt_lens=(max(args.prompt_len // 2, 1), args.prompt_len),
            gen_lens=gen_lens, seed=1)
    device_model = None
    if args.calibrated_cache is not None:
        import os

        from ..core.engines import ENGINES_BY_NAME
        from ..profiling import Measurement, ProfileCache, calibrate_engine
        if not os.path.exists(args.calibrated_cache):
            raise SystemExit(
                f"[serve] --calibrated-cache {args.calibrated_cache}: no "
                f"such file (run `python -m repro.launch.profile` first)")
        cache = ProfileCache.load(args.calibrated_cache)
        eng = ENGINES_BY_NAME[args.calibrated_engine]
        ms = [Measurement.from_dict(d)
              for d in cache.measurements(engine=eng.name)]
        if not ms:
            n_stale = len(cache.measurements(engine=eng.name, stale=True))
            raise SystemExit(
                f"[serve] {args.calibrated_cache} has no measurements for "
                f"engine {eng.name} under this environment "
                f"({n_stale} from other jax versions/backends; re-profile "
                f"here or pass a matching cache)")
        device_model = calibrate_engine(eng, ms, register=True)
        print(f"[serve] admission priced on {device_model.name} "
              f"({device_model.n_measurements} measurements, kinds "
              f"{sorted(device_model.throughput)}; other kinds fall back to "
              f"{device_model.base_efficiency:.2f} x peak)")

    # phase placement: which engine's device model prices each phase
    from ..core.engines import ENGINES_BY_NAME

    def _engine(name: str):
        if name not in ENGINES_BY_NAME:
            raise SystemExit(f"[serve] unknown engine {name!r} (choose from "
                             f"{', '.join(sorted(ENGINES_BY_NAME))})")
        return ENGINES_BY_NAME[name]

    on_delta = None
    if args.stream:
        def on_delta(d):
            toks = ",".join(str(t) for t in d.tokens)
            tag = " [done]" if d.done else ""
            print(f"[stream] t={d.t:8.3f}s rid={d.rid:>4} "
                  f"+{len(d.tokens)} [{toks}]{tag}", flush=True)

    step_slo_s = None if args.step_slo_ms is None else args.step_slo_ms / 1e3

    # device topology: pin the two phase engines onto distinct devices
    # (degrades gracefully to one device when only one is visible)
    assignment = None
    if args.device_assignment == "auto":
        assignment = device_assignment()
        print(f"[serve] device assignment: {assignment.summary()}",
              flush=True)

    # measured inter-device link bandwidth: an actual device_put of a
    # representative page batch, persisted environment-keyed in the
    # profile cache so place_phases(price="measured") prices hand-offs
    # from it on later runs too
    measured_link_bw = None
    if args.measure_link_bw:
        from ..profiling import record_link_bw
        from ..profiling.cache import DEFAULT_CACHE_PATH, ProfileCache
        link_cache_path = (DEFAULT_CACHE_PATH
                           if args.measure_link_bw is True
                           else args.measure_link_bw)
        devs = assignment if assignment is not None else device_assignment()
        link_cache = ProfileCache.load(link_cache_path, strict=False)
        m = record_link_bw(link_cache, devs.prefill, devs.decode)
        link_cache.save(link_cache_path)
        measured_link_bw = m["link_bw"]
        print(f"[serve] link {m['src']} -> {m['dst']}: "
              f"{measured_link_bw / 1e9:.2f} GB/s "
              f"({m['n_bytes']} bytes in {m['t_median'] * 1e3:.3f} ms) "
              f"-> {link_cache_path}", flush=True)
    handoff_link_bw = (args.handoff_link_bw if args.handoff_link_bw
                       is not None else measured_link_bw)
    # one observability bundle for whichever loop runs: tracing only when
    # asked (NullTracer otherwise — near-zero cost), registry always (it
    # backs the hand-off ledger and the metrics dump), feedback only with
    # --feed-cache (it syncs each decode burst to time it)
    watchdog = None
    if args.watchdog:
        from ..obs import PerfWatchdog
        watchdog = (PerfWatchdog() if args.drift_gate is None
                    else PerfWatchdog(drift_gate=args.drift_gate))
    obs = Observability(
        tracer=Tracer() if args.trace else None,
        feedback=(TelemetryFeedback(cfg, kv_len=max_len)
                  if args.feed_cache or args.persist_curves else None),
        watchdog=watchdog)

    def _misprice(dev, phase=None):
        """Inject an admission-pricing error for watchdog CI/debug runs.
        ``--misprice-phase`` scopes it to one phase's device model so
        exactly that stream drifts (the placement-actuation trigger)."""
        if args.misprice is None:
            return dev
        if (phase is not None and args.misprice_phase != "both"
                and args.misprice_phase != phase):
            return dev
        from ..core import device_models
        from ..serving.placement import drift_scaled_device
        if dev is None:
            dev = device_models.get(args.device_model)
        return drift_scaled_device(dev, args.misprice)

    pre_eng = dec_eng = None
    if args.placement == "auto":
        decision = place_phases(
            cfg, objective=args.placement_objective,
            prompt_len=args.prompt_len, gen_len=args.gen_len,
            batch=args.slots,
            price="measured" if args.calibrated_cache else "analytic",
            cache_path=args.calibrated_cache)
        print(f"[serve] {decision.summary()}", flush=True)
        pre_eng = ENGINES_BY_NAME[decision.prefill_engine]
        dec_eng = ENGINES_BY_NAME[decision.decode_engine]
    elif args.placement == "disagg" or args.prefill_engine or args.decode_engine:
        pre_eng = _engine(args.prefill_engine or "xla")
        dec_eng = _engine(args.decode_engine or "xla")
        for eng, phase in ((pre_eng, "prefill"), (dec_eng, "decode")):
            try:
                c = placement_lib.phase_cost(
                    cfg, eng, phase, prompt_len=args.prompt_len,
                    gen_len=args.gen_len, batch=args.slots)
            except ValueError as e:      # cost-only CNN engine, LM model
                raise SystemExit(f"[serve] {e}")
            print(f"[serve] {phase} on {eng.name}: modeled "
                  f"{c.time_s*1e3:.3f}ms, {c.energy_j:.4f}J", flush=True)

    def _phase_device(eng):
        """Calibrated model when the cache covers this engine, else its own."""
        if device_model is not None and eng.name == args.calibrated_engine:
            return device_model
        return eng.device

    # auto placement only disaggregates when the analyzer says the split
    # wins; an explicit --placement disagg always runs the two-engine loop
    # (same-engine disagg measures the bare phase-boundary overhead)
    if pre_eng is not None and (args.placement == "disagg"
                                or pre_eng.name != dec_eng.name):
        engine = DisaggregatedEngineLoop(
            cfg, params, n_prefill_slots=args.prefill_slots or args.slots,
            n_decode_slots=args.slots, max_seq=max_len,
            kv_layout=args.kv_layout,
            decode_total_blocks=args.total_blocks,
            prefix_sharing=args.prefix_sharing,
            prefill_device=_misprice(_phase_device(pre_eng), "prefill"),
            decode_device=_misprice(_phase_device(dec_eng), "decode"),
            step_slo_s=step_slo_s, obs=obs,
            handoff_link_bw=handoff_link_bw,
            assignment=assignment,
            async_handoff=not args.sync_handoff,
            placement_engine_name=dec_eng.name,
            prefill_placement_engine_name=pre_eng.name,
            decode_placement_engine_name=dec_eng.name)
        _prime_curves(args, cfg, max_len, engine.decode_batcher)
        with mesh:
            metrics = engine.run(requests, on_delta=on_delta)
        for b in engine.batchers:
            print(f"[serve] {b.phase} token budget {b.token_budget}/"
                  f"{b.pool.n_slots} slots (device model {b.device_name})")
        pools = (("prefill", engine.prefill.pool),
                 ("decode", engine.decode.pool))
        batchers = engine.batchers
        for k, v in engine.handoff.stats().items():
            val = f"{v:.4f}" if isinstance(v, float) else str(v)
            print(f"[serve] handoff.{k:>17}: {val}", flush=True)
        print(f"[serve] decode target: {engine.decode_target} engine "
              f"({'async' if not args.sync_handoff else 'sync'} hand-off)",
              flush=True)
    else:
        if pre_eng is not None:          # colocated by choice of placement
            device_model = _phase_device(pre_eng)
        engine = EngineLoop(
            cfg, params, n_slots=args.slots, max_seq=max_len,
            kv_layout=args.kv_layout, total_blocks=args.total_blocks,
            prefix_sharing=args.prefix_sharing,
            device_name=args.device_model,
            device_model=_misprice(device_model),
            step_slo_s=step_slo_s, obs=obs)
        _prime_curves(args, cfg, max_len, engine.batcher)
        with mesh:
            metrics = engine.run(requests, on_delta=on_delta)
        print(f"[serve] token budget {engine.batcher.token_budget}/"
              f"{args.slots} slots (device model "
              f"{engine.batcher.device_name})")
        pools = (("", engine.pool),)
        batchers = (engine.batcher,)
    for k, v in metrics.summary().items():
        val = f"{v:.4f}" if isinstance(v, float) else str(v)
        print(f"[serve] {k:>22}: {val}", flush=True)
    # KV-pool ledger + admission accounting (end-of-run state of the block
    # ledger, plus what the batcher did to the queue over the whole run)
    for tag, pool in pools:
        prefix = f"kv_pool{'.' + tag if tag else ''}"
        for k, v in pool.stats().items():
            val = f"{v:.4f}" if isinstance(v, float) else str(v)
            print(f"[serve] {prefix}.{k:>15}: {val}", flush=True)
    for b in batchers:
        tag = f" [{b.phase}]" if len(batchers) > 1 else ""
        print(f"[serve] admission{tag}: {b.n_admitted} admitted, "
              f"{b.n_rejected} rejected (deadline/oversize), "
              f"{b.n_deferred} deferrals (budget or pool pressure)",
              flush=True)

    # ---- watchdog + SLO reporting ----------------------------------------
    if watchdog is not None:
        rep = watchdog.report()
        print(f"[serve] watchdog: {len(rep['alerts'])} drift alerts, "
              f"{len(rep['reprices'])} re-price events, sync cadence "
              f"{rep['sync_cadence']}", flush=True)
        for a in rep["alerts"]:
            print(f"[serve] watchdog.alert: {a['engine']}/{a['phase']} "
                  f"{a['direction']} ewma={a['ewma_ratio']:.2f} "
                  f"(priced {a['priced_step_s']*1e3:.2f}ms, observed "
                  f"{a['observed_step_s']*1e3:.2f}ms)", flush=True)
        for r in rep["reprices"]:
            print(f"[serve] watchdog.reprice: {r['engine']}/{r['phase']} "
                  f"pricing={r.get('pricing')} token_budget "
                  f"{r.get('token_budget_old')} -> {r.get('token_budget')}",
                  flush=True)
        for b in batchers:
            if b.n_reprices:
                print(f"[serve] admission [{b.phase}] re-priced "
                      f"{b.n_reprices}x ({b.price_source}); final budget "
                      f"{b.token_budget}/{b.pool.n_slots}", flush=True)
    if args.slo_report:
        from ..obs.watchdog import format_slo_report, slo_attainment
        rows = slo_attainment(requests, ttft_slo_s=args.slo_ttft_ms / 1e3,
                              tpot_slo_s=args.slo_tpot_ms / 1e3)
        print(format_slo_report(rows, ttft_slo_s=args.slo_ttft_ms / 1e3,
                                tpot_slo_s=args.slo_tpot_ms / 1e3),
              flush=True)

    # ---- observability exports -------------------------------------------
    if args.trace:
        path = write_trace(obs.tracer, args.trace)
        print(f"[serve] trace: {len(obs.tracer.events)} events "
              f"({obs.tracer.n_dropped} dropped, {obs.tracer.n_open} "
              f"unclosed) -> {path}", flush=True)
    if args.metrics_out:
        extra = {"summary": metrics.summary()}
        if watchdog is not None:
            extra["watchdog"] = watchdog.report()
        path = write_metrics(obs.registry, args.metrics_out,
                             tracer=obs.tracer if args.trace else None,
                             extra=extra)
        print(f"[serve] metrics snapshot -> {path}", flush=True)
    if args.feed_cache:
        from ..profiling.cache import DEFAULT_CACHE_PATH, ProfileCache
        cache_path = (DEFAULT_CACHE_PATH if args.feed_cache is True
                      else args.feed_cache)
        cache = ProfileCache.load(cache_path, strict=False)
        n = obs.feedback.flush(cache)
        cache.save(cache_path)
        print(f"[serve] fed {n} telemetry measurements from "
              f"{obs.feedback.n_bursts} bursts (batch sizes "
              f"{obs.feedback.batches}) -> {cache_path}", flush=True)
    if args.persist_curves:
        # --persist-curves exit leg: flush this run's burst telemetry so
        # the next serve's _prime_curves finds a fresh curve
        from ..profiling.cache import ProfileCache
        cache = ProfileCache.load(args.persist_curves, strict=False)
        n = obs.feedback.flush(cache)
        cache.save(args.persist_curves)
        print(f"[serve] curves: persisted {n} telemetry measurements "
              f"(batch sizes {obs.feedback.batches}) -> "
              f"{args.persist_curves}", flush=True)


if __name__ == "__main__":
    main()
