"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (v5e pod),
axes (data, model).  Multi-pod: 2 pods = 512 chips, axes (pod, data, model);
'pod' is the outer data-parallel axis whose collectives cross DCN.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices but only {len(devs)} are "
            f"visible. For the dry-run, set XLA_FLAGS="
            f"--xla_force_host_platform_device_count=512 BEFORE importing "
            f"jax (launch/dryrun.py does this).")
    try:
        return jax.make_mesh(shape, axes, devices=devs[:need])
    except TypeError:  # older make_mesh without `devices`
        return Mesh(np.asarray(devs[:need]).reshape(shape), axes)


def make_host_mesh() -> Mesh:
    """Degenerate 1x1 mesh for CPU smoke tests / examples."""
    dev = np.asarray(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))
