"""Device topology: mesh construction and phase device assignment.

Functions (not module-level constants) so importing this module never
touches jax device state.  Two concerns live here:

* **Meshes** for params/cache sharding.  Single pod: 16x16 = 256 chips
  (v5e pod), axes (data, model).  Multi-pod: 2 pods = 512 chips, axes
  (pod, data, model); 'pod' is the outer data-parallel axis whose
  collectives cross DCN.
* **Phase device assignment** for disaggregated serving
  (:class:`DeviceAssignment`): enumerate the visible devices and pin the
  prefill and decode engines to *distinct* devices when the host has at
  least two, degrading gracefully to a single shared device otherwise.
  On CPU-only hosts (CI, dev containers) set
  ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before* the
  first jax import to split the host into N logical devices — the
  multi-device hand-off path is then exercised everywhere, not just on
  accelerator fleets.
"""
from __future__ import annotations

import dataclasses
import os
from typing import List, Optional

import numpy as np

import jax
from jax.sharding import Mesh

# the env var + flag that fakes a multi-device host on CPU; quoted in
# error messages so a single-device failure tells the user how to get
# the multi-device path locally
MULTI_DEVICE_HINT = ("set XLA_FLAGS=--xla_force_host_platform_device_count=N "
                     "BEFORE the first jax import to split a CPU host into "
                     "N logical devices")


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices but only {len(devs)} are "
            f"visible. For the dry-run, set XLA_FLAGS="
            f"--xla_force_host_platform_device_count=512 BEFORE importing "
            f"jax (launch/dryrun.py does this).")
    try:
        return jax.make_mesh(shape, axes, devices=devs[:need])
    except TypeError:  # older make_mesh without `devices`
        return Mesh(np.asarray(devs[:need]).reshape(shape), axes)


def make_host_mesh() -> Mesh:
    """Degenerate 1x1 mesh for CPU smoke tests / examples."""
    dev = np.asarray(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


# --------------------------------------------------- phase device assignment
@dataclasses.dataclass(frozen=True)
class DeviceAssignment:
    """Which physical device each serving phase's engine lives on.

    ``prefill`` and ``decode`` are jax Devices; ``distinct`` is the one
    bit the hand-off path branches on — when False both engines share
    one device and the page transfer is a (nearly free) same-device
    ``device_put``, when True the transfer crosses a real device
    boundary and the async hand-off has actual latency to hide.
    """
    prefill: jax.Device
    decode: jax.Device

    @property
    def distinct(self) -> bool:
        return self.prefill != self.decode

    def summary(self) -> str:
        tag = "distinct" if self.distinct else "shared"
        return (f"prefill -> {device_label(self.prefill)}, "
                f"decode -> {device_label(self.decode)} ({tag})")


def device_label(dev: jax.Device) -> str:
    """Stable human/cache-readable name for one device, e.g. ``cpu:1``."""
    return f"{dev.platform}:{dev.id}"


def visible_devices(backend: Optional[str] = None) -> List[jax.Device]:
    """The devices a phase engine may be pinned to (jax.devices, but
    behind a function so tests can reason about the call site)."""
    return jax.devices(backend) if backend else jax.devices()


def device_assignment(*, prefill_index: Optional[int] = None,
                      decode_index: Optional[int] = None,
                      backend: Optional[str] = None) -> DeviceAssignment:
    """Pin the two serving phases to devices.

    Default policy: with >= 2 visible devices, prefill takes device 0
    and decode device 1 (distinct, so the hand-off pipeline has a real
    boundary to overlap); with one device both phases share it — the
    code path is identical, the transfer is just free.  Explicit
    ``prefill_index`` / ``decode_index`` override the policy; an
    out-of-range index raises with the ``XLA_FLAGS`` hint rather than
    silently colocating.
    """
    devs = visible_devices(backend)
    if not devs:
        raise RuntimeError("no jax devices visible")

    def pick(idx: Optional[int], default: int, phase: str) -> jax.Device:
        if idx is None:
            idx = default if default < len(devs) else 0
        if not 0 <= idx < len(devs):
            raise ValueError(
                f"{phase} device index {idx} out of range: only "
                f"{len(devs)} device(s) visible ({MULTI_DEVICE_HINT})")
        return devs[idx]

    return DeviceAssignment(prefill=pick(prefill_index, 0, "prefill"),
                            decode=pick(decode_index, 1, "decode"))


def forced_host_device_env(n: int) -> dict:
    """Environment overlay that makes a *subprocess* see ``n`` CPU
    devices (the in-process backend is already initialized, so the flag
    only helps processes launched after it is set)."""
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    flag = f"--xla_force_host_platform_device_count={n}"
    env["XLA_FLAGS"] = f"{flags} {flag}".strip()
    return env
