"""Runtime substrate: straggler monitoring, preemption handling, step loop."""
from .fault_tolerance import (PreemptionHandler, StepTimer,  # noqa
                              StragglerReport)
