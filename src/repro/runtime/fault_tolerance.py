"""Fault-tolerance runtime pieces.

* StepTimer — EWMA step-time tracker with straggler detection: a step that
  exceeds mean + k·σ (or m× the EWMA) is flagged; the launcher logs the
  offending host so an operator (or the elastic controller) can drain it.
  On a real pod, per-host step times come from a lightweight all-gather of
  host timestamps; here the single-process view is the same code path.

* PreemptionHandler — SIGTERM/SIGINT → "checkpoint then exit" flag, the
  standard TPU-preemption dance.  The train loop polls `should_stop` each
  step and saves a final checkpoint before exiting, so a preempted worker
  loses at most one step.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import List, Optional


@dataclasses.dataclass
class StragglerReport:
    step: int
    duration_s: float
    ewma_s: float
    threshold_s: float

    def __str__(self) -> str:
        return (f"[straggler] step {self.step}: {self.duration_s:.3f}s "
                f"(ewma {self.ewma_s:.3f}s, threshold {self.threshold_s:.3f}s)")


class StepTimer:
    def __init__(self, alpha: float = 0.1, k_sigma: float = 3.0,
                 min_steps: int = 5, ratio: float = 2.0):
        self.alpha = alpha
        self.k_sigma = k_sigma
        self.min_steps = min_steps
        self.ratio = ratio
        self.ewma: Optional[float] = None
        self.ewvar: float = 0.0
        self.n = 0
        self.stragglers: List[StragglerReport] = []
        self._t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.monotonic()

    def stop(self, step: int) -> Optional[StragglerReport]:
        assert self._t0 is not None, "start() not called"
        dt = time.monotonic() - self._t0
        self._t0 = None
        report = None
        if self.ewma is None:
            self.ewma = dt
        else:
            # flag if EITHER criterion trips: ratio-based always (after
            # warmup), sigma-based once variance statistics exist
            thresh = self.ratio * self.ewma
            if self.ewvar > 0:
                thresh = min(thresh,
                             self.ewma + self.k_sigma * (self.ewvar ** 0.5))
            if self.n >= self.min_steps and dt > thresh:
                report = StragglerReport(step, dt, self.ewma, thresh)
                self.stragglers.append(report)
            delta = dt - self.ewma
            self.ewma += self.alpha * delta
            self.ewvar = (1 - self.alpha) * (self.ewvar
                                             + self.alpha * delta * delta)
        self.n += 1
        return report


class PreemptionHandler:
    """Installs SIGTERM/SIGINT handlers that request a graceful stop."""

    def __init__(self, install: bool = True):
        self._stop = False
        self._prev = {}
        if install:
            for sig in (signal.SIGTERM, signal.SIGINT):
                try:
                    self._prev[sig] = signal.signal(sig, self._handler)
                except ValueError:      # not main thread (tests)
                    pass

    def _handler(self, signum, frame):
        self._stop = True

    @property
    def should_stop(self) -> bool:
        return self._stop

    def request_stop(self) -> None:    # for tests / manual drain
        self._stop = True

    def uninstall(self) -> None:
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)
