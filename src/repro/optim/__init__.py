"""Optimizer substrate: AdamW, LR schedules (cosine + minicpm's WSD),
gradient accumulation, and int8 gradient compression with error feedback."""
from .adamw import (AdamWState, adamw_init, adamw_update, clip_by_global_norm,  # noqa
                    make_optimizer)
from .schedules import constant, cosine_schedule, wsd_schedule  # noqa
from .compression import (compress_int8, decompress_int8,  # noqa
                          compressed_allreduce_update)
