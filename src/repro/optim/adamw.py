"""AdamW with decoupled weight decay + global-norm clipping + grad accum.

Implemented directly (no optax dependency) over arbitrary param pytrees.
Optimizer state shards exactly like the params (the sharding policy maps the
same logical axes), which is what makes ZeRO-style partitioning fall out of
pjit for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    step: jax.Array
    mu: PyTree
    nu: PyTree


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0


def adamw_init(params: PyTree) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def global_norm(tree: PyTree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads: PyTree, max_norm: float
                        ) -> Tuple[PyTree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(grads: PyTree, state: AdamWState, params: PyTree,
                 lr: jax.Array, cfg: AdamWConfig
                 ) -> Tuple[PyTree, AdamWState, jax.Array]:
    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v), gnorm


def make_optimizer(schedule: Callable, cfg: Optional[AdamWConfig] = None):
    """Returns (init_fn, update_fn(grads, state, params) -> (params', state',
    metrics)) — the (init, update) pair the train loop consumes."""
    cfg = cfg or AdamWConfig()

    def update(grads, state, params):
        lr = schedule(state.step)
        new_p, new_s, gnorm = adamw_update(grads, state, params, lr, cfg)
        return new_p, new_s, {"lr": lr, "grad_norm": gnorm}

    return adamw_init, update
