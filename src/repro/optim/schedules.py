"""Learning-rate schedules.

Includes WSD (Warmup-Stable-Decay) — the schedule minicpm (arXiv:2404.06395)
trains with — as a first-class citizen since that arch is assigned.
"""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(peak_lr: float, warmup_steps: int, total_steps: int,
                    final_frac: float = 0.1):
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)
    return f


def wsd_schedule(peak_lr: float, warmup_steps: int, stable_steps: int,
                 decay_steps: int, final_frac: float = 0.01):
    """Warmup-Stable-Decay (minicpm): linear warmup, long flat stage, then a
    fast exponential-style decay to final_frac*peak over decay_steps."""
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        d0 = warmup_steps + stable_steps
        prog = jnp.clip((step - d0) / max(decay_steps, 1), 0.0, 1.0)
        decay = peak_lr * jnp.exp(jnp.log(final_frac) * prog)
        out = jnp.where(step < warmup_steps, warm,
                        jnp.where(step < d0, peak_lr, decay))
        return out
    return f
