"""Gradient compression for cross-pod all-reduce (distributed-optim trick).

On a multi-pod deployment the inter-pod link (DCN) is an order of magnitude
slower than intra-pod ICI, so the pod-axis gradient all-reduce dominates.
We provide int8 block-quantized compression with **error feedback** (the
residual of quantization is carried to the next step, which keeps SGD/Adam
convergence — Seide et al. 2014, Karimireddy et al. 2019):

    q, scale   = quantize(g + e)
    g_hat      = dequantize(allreduce(q))        # 4x less DCN traffic
    e'         = (g + e) - dequantize(q)

Wired into the train loop behind ``--grad-compression int8``; the all-reduce
itself is whatever pjit inserts for the 'pod' axis — we quantize the summand.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
_BLOCK = 256


def _pad_len(n: int) -> int:
    return (-n) % _BLOCK


def compress_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Block-wise symmetric int8 quantization.  Returns (q, scales)."""
    flat = g.astype(jnp.float32).reshape(-1)
    pad = _pad_len(flat.size)
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array, shape, dtype) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def compressed_allreduce_update(grads: PyTree, error: PyTree
                                ) -> Tuple[PyTree, PyTree]:
    """Quantize (grads + error) and return (dequantized grads, new error).

    The caller feeds the dequantized grads into the optimizer; pjit's pod
    all-reduce then moves int8-rounded values (the rounding is deterministic
    across replicas, so the sum of quantized values == quantized values
    summed by the collective)."""
    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, s = compress_int8(target)
        deq = decompress_int8(q, s, g.shape, jnp.float32)
        return deq.astype(g.dtype), target - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def init_error(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
