"""Microbenchmark harness: time any buildable engine on any LayerSpec.

The measurement discipline is the usual JAX one:

* build the engine callable once, ``jax.jit`` it, and feed device-committed
  inputs so compile time and H2D transfers stay out of the timed region;
* ``warmup`` untimed calls (first triggers compilation) with
  ``block_until_ready`` so the async dispatch queue is drained;
* ``repeats`` timed calls, each individually synchronized, reduced to
  **median + IQR** (robust to scheduler noise; a mean would let one
  preempted repeat poison the calibration).

A measurement records everything the calibrator and the measured-pricing
scheduler need: the spec fingerprint, achieved time statistics, FLOPs, and
the (jax version, backend) environment it is valid under.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.engines import ExecutionEngine, init_layer_params
from ..core.layer_model import (AttentionSpec, ConvSpec, FCSpec, LayerSpec,
                                MLPSpec, MoESpec, NetworkSpec, NormSpec,
                                PoolSpec, SSMSpec)
from . import cache as cache_lib


@dataclasses.dataclass(frozen=True)
class Measurement:
    """One (layer spec, engine) timing under one environment."""

    layer: str
    kind: str
    engine: str
    batch: int
    dtype: str
    repeats: int
    t_median: float              # seconds
    t_iqr: float                 # interquartile range of the repeats
    t_min: float
    t_mean: float
    flops: int                   # forward FLOPs at `batch`
    fingerprint: str
    jax_version: str
    backend: str

    @property
    def achieved_flops(self) -> float:
        """Measured FLOP/s (the quantity the calibrator fits)."""
        return self.flops / self.t_median if self.t_median > 0 else 0.0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "Measurement":
        return cls(**{f.name: d[f.name] for f in dataclasses.fields(cls)})


def make_input(spec: LayerSpec, batch: int = 1,
               dtype=jnp.float32) -> jax.Array:
    """Synthesize the layer's forward input from its declarative tuple."""
    if isinstance(spec, (ConvSpec, NormSpec, PoolSpec)):
        h, w, c = spec.m_i
        shape = (batch, h, w, c)
    elif isinstance(spec, FCSpec):
        shape = (batch,) + tuple(spec.m_i)
    elif isinstance(spec, (AttentionSpec, MLPSpec, MoESpec, SSMSpec)):
        # the decode-step / prefill kinds serving admission prices: a
        # (batch, seq, d_model) activation (seq=1 for decode-step specs)
        shape = (batch, spec.seq, spec.d_model)
    else:
        raise NotImplementedError(
            f"no input synthesizer for {type(spec).__name__}")
    key = jax.random.PRNGKey(0)
    return jax.random.normal(key, shape, dtype)


def time_layer(
    engine: ExecutionEngine,
    spec: LayerSpec,
    *,
    batch: int = 1,
    dtype=jnp.float32,
    warmup: int = 2,
    repeats: int = 5,
) -> Measurement:
    """Measure one layer on one buildable engine (compile excluded)."""
    if not engine.buildable:
        raise ValueError(f"engine {engine.name} is cost-only; nothing to "
                         "measure (the paper devices live in device_models)")
    if warmup < 1 or repeats < 1:
        raise ValueError("warmup and repeats must both be >= 1")
    fn = jax.jit(engine.build(spec))
    params = init_layer_params(spec, jax.random.PRNGKey(1), dtype)
    x = make_input(spec, batch, dtype)
    x.block_until_ready()

    for _ in range(warmup):
        fn(x, params).block_until_ready()
    times = np.empty(repeats)
    for i in range(repeats):
        t0 = time.perf_counter()
        fn(x, params).block_until_ready()
        times[i] = time.perf_counter() - t0

    q25, q50, q75 = np.percentile(times, (25, 50, 75))
    env = cache_lib.environment()
    dtype_name = jnp.dtype(dtype).name
    return Measurement(
        layer=spec.name, kind=spec.kind, engine=engine.name,
        batch=batch, dtype=dtype_name, repeats=repeats,
        t_median=float(q50), t_iqr=float(q75 - q25),
        t_min=float(times.min()), t_mean=float(times.mean()),
        flops=spec.flops(batch),
        fingerprint=cache_lib.fingerprint(spec, batch, dtype_name),
        jax_version=env["jax_version"], backend=env["backend"],
    )


def profile_network(
    net: Iterable[LayerSpec] | NetworkSpec,
    engines: Sequence[ExecutionEngine],
    *,
    batch: int = 1,
    dtype=jnp.float32,
    warmup: int = 2,
    repeats: int = 5,
    cache: Optional[cache_lib.ProfileCache] = None,
    measure_on_miss: bool = True,
) -> List[Measurement]:
    """Profile every (layer, buildable engine) pair, cache-aware.

    Cache hits (same fingerprint/engine/jax/backend) are returned without
    re-measuring; misses are measured and written back to ``cache`` when
    ``measure_on_miss`` (otherwise skipped).
    """
    specs = tuple(net)                   # net may be a one-shot iterable
    dtype_name = jnp.dtype(dtype).name
    out: List[Measurement] = []
    for engine in engines:
        if not engine.buildable:
            continue
        for spec in specs:
            if not engine.supports(spec):
                continue
            if cache is not None:
                hit = cache.get(spec, engine.name, batch=batch,
                                dtype=dtype_name)
                if hit is not None:
                    out.append(Measurement.from_dict(hit))
                    continue
            if not measure_on_miss:
                continue
            try:
                m = time_layer(engine, spec, batch=batch, dtype=dtype,
                               warmup=warmup, repeats=repeats)
            except NotImplementedError:
                # the engine registry advertises kinds (attention, mlp, ...)
                # whose builders/input synthesizers are not implemented yet;
                # skip those pairs rather than abort the whole sweep
                continue
            if cache is not None:
                cache.put(m)
            out.append(m)
    return out
