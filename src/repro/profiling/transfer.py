"""Measured inter-device link bandwidth for hand-off pricing.

``core.cost_model.transfer_cost`` historically *assumed* the hand-off
link: absent an explicit ``link_bw`` it bounds the transfer by the
slower endpoint's memory bandwidth — a device-datasheet number, not a
measurement, and on real hosts the device-to-device path (PCIe, ICI,
or a plain host memcpy between CPU logical devices) is nothing like
HBM bandwidth.  This module closes that gap the same way PR 2 closed
the compute one: **measure** an actual ``jax.device_put`` of a
representative page batch between the two phase devices, and persist
the result in the PR 2 profile cache (environment-keyed, so a cache
written under one jax/backend never prices another).

The cache entry is a full :data:`~repro.profiling.cache.REQUIRED_FIELDS`
measurement (``kind="transfer"``, ``t_*`` = seconds for the timed copy,
``flops=0``) plus the derived ``link_bw`` (bytes/s) and the endpoint
labels — so ``python -m repro.profiling.cache --validate`` accepts it
and :func:`cached_link_bw` can find it again next run.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import cache as cache_lib

# engine name the link measurements are filed under in the profile cache
LINK_ENGINE = "interconnect"
# provenance tag (ProfileCache.measurements(source=...))
LINK_SOURCE = "link-calibration"

# default representative payload: 64 KV pages of a smallish model — big
# enough to amortize dispatch overhead, small enough to measure at startup
DEFAULT_LINK_PROBE_BYTES = 1 << 22          # 4 MiB


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """Declarative spec of one measured device-to-device copy (a
    dataclass so :func:`repro.profiling.cache.fingerprint` can hash it
    like any layer spec)."""
    name: str
    src: str                     # device label, e.g. "cpu:0"
    dst: str
    n_bytes: int


def measure_link_bandwidth(src_dev, dst_dev, *, n_bytes: int =
                           DEFAULT_LINK_PROBE_BYTES, warmup: int = 1,
                           repeats: int = 5) -> dict:
    """Time ``jax.device_put`` of an ``n_bytes`` buffer from ``src_dev``
    to ``dst_dev`` and return a profile-cache measurement dict.

    Discipline matches the PR 2 bench harness: the source buffer is
    committed (and synced) to ``src_dev`` before timing, every timed
    copy is individually ``block_until_ready``'d, and the repeats reduce
    to median + IQR.  Same-device "copies" are measured too — they give
    the honest (near-zero) price of a colocated hand-off.
    """
    from ..launch.mesh import device_label

    n_f32 = max(1, n_bytes // 4)
    src_label = device_label(src_dev)
    dst_label = device_label(dst_dev)
    x = jax.device_put(jnp.zeros((n_f32,), jnp.float32), src_dev)
    x.block_until_ready()
    for _ in range(max(0, warmup)):
        jax.device_put(x, dst_dev).block_until_ready()
    times = []
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        jax.device_put(x, dst_dev).block_until_ready()
        times.append(time.perf_counter() - t0)
    arr = np.asarray(times)
    t_median = float(np.median(arr))
    q1, q3 = np.percentile(arr, [25, 75])
    spec = LinkSpec(name=f"link:{src_label}->{dst_label}",
                    src=src_label, dst=dst_label, n_bytes=4 * n_f32)
    env = cache_lib.environment()
    return {
        "layer": spec.name, "kind": "transfer", "engine": LINK_ENGINE,
        "batch": 1, "dtype": "float32", "repeats": int(repeats),
        "t_median": t_median, "t_iqr": float(q3 - q1),
        "t_min": float(arr.min()), "t_mean": float(arr.mean()),
        "flops": 0,
        "fingerprint": cache_lib.fingerprint(spec, 1, "float32"),
        "jax_version": env["jax_version"], "backend": env["backend"],
        # derived + provenance (extra fields survive cache validation)
        "link_bw": (4 * n_f32) / t_median if t_median > 0 else float("inf"),
        "n_bytes": 4 * n_f32, "src": src_label, "dst": dst_label,
        "source": LINK_SOURCE,
    }


def record_link_bw(cache: cache_lib.ProfileCache, src_dev, dst_dev, *,
                   n_bytes: int = DEFAULT_LINK_PROBE_BYTES,
                   repeats: int = 5) -> dict:
    """Measure the ``src -> dst`` link and store it in ``cache`` (not
    saved to disk here — the caller owns persistence)."""
    m = measure_link_bandwidth(src_dev, dst_dev, n_bytes=n_bytes,
                               repeats=repeats)
    cache.put(m)
    return m


def cached_link_bw(cache: cache_lib.ProfileCache, *,
                   src: Optional[str] = None,
                   dst: Optional[str] = None) -> Optional[float]:
    """The measured link bandwidth (bytes/s) for this environment, or
    None when the cache holds no usable link measurement.

    ``src``/``dst`` filter on device labels; without them the
    largest-payload measurement wins (the most amortized probe is the
    best steady-state estimate).
    """
    best = None
    for m in cache.measurements(engine=LINK_ENGINE, source=LINK_SOURCE):
        if src is not None and m.get("src") != src:
            continue
        if dst is not None and m.get("dst") != dst:
            continue
        bw = m.get("link_bw")
        if not isinstance(bw, (int, float)) or bw <= 0:
            continue
        if best is None or m.get("n_bytes", 0) > best.get("n_bytes", 0):
            best = m
    return float(best["link_bw"]) if best else None
