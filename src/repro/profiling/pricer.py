"""Measured pricing for the scheduler: profile-then-offload.

CNNLab "at runtime leverages the trade-offs between GPU and FPGA *before
offloading* the tasks" — the decision input is a measurement, not a model.
:class:`MeasuredPricer` is that runtime flow for our scheduler: asked to
price a (layer, engine) candidate it consults the profile cache, measures
on miss (warmup + repeats via the bench harness), persists the new
measurement, and returns a :class:`~repro.core.cost_model.CostBreakdown`
whose time term *is* the measured median.  ``schedule(...,
price="measured")`` plugs it in; engines the pricer cannot measure
(cost-only paper devices, backward passes, multi-chip plans) silently fall
back to the analytic cost model so planning always completes.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from ..core.cost_model import CostBreakdown
from ..core.engines import ExecutionEngine
from ..core.layer_model import LayerSpec
from . import bench
from .cache import DEFAULT_CACHE_PATH, ProfileCache


_DTYPE_FOR_BYTES = {4: jnp.float32, 2: jnp.bfloat16}


class MeasuredPricer:
    """Callable the scheduler consults before falling back to analytic."""

    def __init__(self, cache: Optional[ProfileCache] = None, *,
                 measure_on_miss: bool = True, warmup: int = 2,
                 repeats: int = 5, dtype=None,
                 autosave: bool = True):
        """``dtype=None`` (default) derives the measurement dtype from the
        schedule's ``dtype_bytes`` so a bf16-priced plan gets bf16 timings;
        pass an explicit dtype to pin it."""
        if cache is None:
            cache = ProfileCache.load(DEFAULT_CACHE_PATH, strict=False)
        self.cache = cache
        self.measure_on_miss = measure_on_miss
        self.warmup = warmup
        self.repeats = repeats
        self.dtype = dtype
        self.autosave = autosave
        self.hits = 0
        self.misses = 0

    def measurement_for(self, spec: LayerSpec, engine: ExecutionEngine, *,
                        batch: int = 1,
                        dtype=jnp.float32) -> Optional[bench.Measurement]:
        """Cache-or-measure.  None when the pair is unmeasurable."""
        if not engine.buildable:
            return None
        dtype_name = jnp.dtype(dtype).name
        hit = self.cache.get(spec, engine.name, batch=batch,
                             dtype=dtype_name)
        # a degenerate 0-cost entry (e.g. underflowed telemetry
        # apportionment) would price the layer as free and poison every
        # achieved-FLOPs fit downstream — treat it as a miss, not a hit
        if hit is not None and float(hit.get("t_median", 0.0)) > 0.0:
            self.hits += 1
            return bench.Measurement.from_dict(hit)
        if not self.measure_on_miss:
            return None
        try:
            m = bench.time_layer(engine, spec, batch=batch, dtype=dtype,
                                 warmup=self.warmup, repeats=self.repeats)
        except NotImplementedError:
            return None
        self.misses += 1
        self.cache.put(m)
        if self.autosave:
            self.cache.save()
        return m

    def price(self, spec: LayerSpec, engine: ExecutionEngine, *,
              batch: int = 1, dtype_bytes: int = 4, n_chips: int = 1,
              direction: str = "fwd") -> Optional[CostBreakdown]:
        """Measured CostBreakdown, or None -> caller uses analytic.

        Only forward single-chip execution is measurable on this harness;
        the power term stays the device model's (no meter on the target),
        so energy/EDP objectives mix measured time with modeled watts.
        """
        if direction != "fwd" or n_chips != 1:
            return None
        dtype = self.dtype or _DTYPE_FOR_BYTES.get(dtype_bytes)
        if dtype is None:                # no measurable dtype at this width
            return None
        m = self.measurement_for(spec, engine, batch=batch, dtype=dtype)
        if m is None or m.t_median <= 0:
            return None
        return CostBreakdown(
            layer=spec.name, kind=spec.kind, device=engine.device.name,
            flops=m.flops,
            bytes_moved=(spec.activation_bytes(batch, dtype_bytes)
                         + spec.param_bytes(dtype_bytes)),
            collective_bytes=0,
            t_compute=m.t_median, t_memory=0.0, t_collective=0.0,
            power_w=engine.device.watts(spec.kind, direction))
