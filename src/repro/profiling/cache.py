"""Persistent profile cache: measured layer timings keyed by environment.

CNNLab's middleware knows its accelerators because it *measured* them; the
cache is where those measurements live between runs.  Each entry is one
:class:`~repro.profiling.bench.Measurement` keyed by

    (layer-spec fingerprint, engine, jax version, backend)

so a cache written on one jax/backend combination never silently prices a
plan on another: lookups only return entries whose environment matches the
running process, and :meth:`ProfileCache.invalidate_stale` drops the rest.

On-disk format (``schema`` guards future layout changes)::

    {"schema": 1, "entries": {"<key>": {<measurement dict>}, ...}}

``python -m repro.profiling.cache --validate PATH`` checks a cache file
against the schema (used by CI after the profiling smoke step).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Dict, List, Optional

import jax

from ..core.layer_model import LayerSpec

SCHEMA_VERSION = 1

# measurement dict fields every entry must carry (mirrors bench.Measurement)
REQUIRED_FIELDS = (
    "layer", "kind", "engine", "batch", "dtype", "repeats",
    "t_median", "t_iqr", "t_min", "t_mean", "flops",
    "fingerprint", "jax_version", "backend",
)

DEFAULT_CACHE_PATH = os.environ.get("REPRO_PROFILE_CACHE",
                                    "profile_cache.json")


def environment() -> Dict[str, str]:
    """The (jax version, backend) pair measurements are valid under."""
    return {"jax_version": jax.__version__,
            "backend": jax.default_backend()}


def fingerprint(spec: LayerSpec, batch: int, dtype: str) -> str:
    """Stable digest of a layer spec + measurement shape.

    Hashes the spec's declarative tuple (type + all dataclass fields), the
    batch and the dtype — everything that determines the timed computation.
    """
    payload = json.dumps(
        {"type": type(spec).__name__,
         "fields": {f.name: repr(getattr(spec, f.name))
                    for f in dataclasses.fields(spec)},
         "batch": int(batch), "dtype": str(dtype)},
        sort_keys=True)
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


def entry_key(fp: str, engine: str, env: Optional[Dict[str, str]] = None) -> str:
    env = env or environment()
    return "|".join((fp, engine, env["jax_version"], env["backend"]))


def validate_dict(data) -> List[str]:
    """Schema check for a loaded cache dict.  Returns a list of problems
    (empty == valid)."""
    errors: List[str] = []
    if not isinstance(data, dict):
        return [f"cache root must be an object, got {type(data).__name__}"]
    if data.get("schema") != SCHEMA_VERSION:
        errors.append(f"schema must be {SCHEMA_VERSION}, "
                      f"got {data.get('schema')!r}")
    entries = data.get("entries")
    if not isinstance(entries, dict):
        return errors + ["entries must be an object"]
    for key, m in entries.items():
        if not isinstance(m, dict):
            errors.append(f"{key}: entry must be an object")
            continue
        missing = [f for f in REQUIRED_FIELDS if f not in m]
        if missing:
            errors.append(f"{key}: missing fields {missing}")
            continue
        want = entry_key(m["fingerprint"], m["engine"],
                         {"jax_version": m["jax_version"],
                          "backend": m["backend"]})
        if key != want:
            errors.append(f"{key}: key does not match entry ({want})")
        for f in ("t_median", "t_iqr", "t_min", "t_mean"):
            if not (isinstance(m[f], (int, float)) and m[f] >= 0):
                errors.append(f"{key}: {f} must be a non-negative number")
    return errors


class ProfileCache:
    """In-memory view of the persistent cache, environment-scoped lookups."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.entries: Dict[str, dict] = {}

    # ---- persistence -----------------------------------------------------
    @classmethod
    def load(cls, path: str, *, strict: bool = True) -> "ProfileCache":
        """Read a cache file.  Missing file -> empty cache (profiling always
        has a cold-start path); malformed file raises when ``strict``."""
        cache = cls(path)
        if not os.path.exists(path):
            return cache
        with open(path) as f:
            data = json.load(f)
        errors = validate_dict(data)
        if errors:
            if strict:
                raise ValueError(f"invalid profile cache {path}: {errors}")
            return cache
        cache.entries = dict(data["entries"])
        return cache

    def save(self, path: Optional[str] = None) -> str:
        path = path or self.path or DEFAULT_CACHE_PATH
        self.path = path
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(path, "w") as f:
            json.dump({"schema": SCHEMA_VERSION, "entries": self.entries},
                      f, indent=2, sort_keys=True)
        return path

    # ---- lookups (current environment only) ------------------------------
    def get(self, spec: LayerSpec, engine: str, *, batch: int = 1,
            dtype: str = "float32") -> Optional[dict]:
        return self.entries.get(
            entry_key(fingerprint(spec, batch, dtype), engine))

    def put(self, measurement) -> None:
        m = (measurement.to_dict() if hasattr(measurement, "to_dict")
             else dict(measurement))
        self.entries[entry_key(
            m["fingerprint"], m["engine"],
            {"jax_version": m["jax_version"], "backend": m["backend"]})] = m

    def measurements(self, *, engine: Optional[str] = None,
                     stale: bool = False,
                     source: Optional[str] = None) -> List[dict]:
        """Entries for the current environment (all envs when ``stale``).

        ``source`` filters on the provenance tag (``"serving-telemetry"``
        for entries fed by :class:`~repro.obs.feedback.TelemetryFeedback`;
        bench-harness entries carry no tag)."""
        env = environment()
        out = []
        for m in self.entries.values():
            if engine is not None and m["engine"] != engine:
                continue
            if source is not None and m.get("source") != source:
                continue
            if not stale and (m["jax_version"] != env["jax_version"]
                              or m["backend"] != env["backend"]):
                continue
            out.append(m)
        return out

    # ---- maintenance -----------------------------------------------------
    def merge(self, other: "ProfileCache") -> int:
        """Fold another cache in (other wins on key collision).  Returns the
        number of new/updated entries."""
        changed = 0
        for key, m in other.entries.items():
            if self.entries.get(key) != m:
                self.entries[key] = dict(m)
                changed += 1
        return changed

    def invalidate(self, *, engine: Optional[str] = None) -> int:
        """Drop entries (optionally only one engine's).  Returns count."""
        keep = {k: m for k, m in self.entries.items()
                if engine is not None and m["engine"] != engine}
        dropped = len(self.entries) - len(keep)
        self.entries = keep
        return dropped

    def invalidate_stale(self) -> int:
        """Drop entries measured under a different jax version / backend."""
        env = environment()
        keep = {k: m for k, m in self.entries.items()
                if m["jax_version"] == env["jax_version"]
                and m["backend"] == env["backend"]}
        dropped = len(self.entries) - len(keep)
        self.entries = keep
        return dropped

    def __len__(self) -> int:
        return len(self.entries)


def _main() -> None:
    import argparse

    ap = argparse.ArgumentParser(
        description="profile-cache maintenance (schema validation)")
    ap.add_argument("--validate", metavar="PATH", required=True,
                    help="check PATH against the cache JSON schema and "
                         "require usable entries for this environment")
    ap.add_argument("--allow-empty", action="store_true",
                    help="accept a schema-valid cache with no entries "
                         "usable under the current jax version / backend")
    args = ap.parse_args()
    if not os.path.exists(args.validate):
        raise SystemExit(f"[cache] INVALID: {args.validate}: no such file "
                         f"(run `python -m repro.launch.profile` first)")
    with open(args.validate) as f:
        try:
            data = json.load(f)
        except json.JSONDecodeError as e:
            raise SystemExit(f"[cache] INVALID: {args.validate}: "
                             f"not JSON ({e})")
    errors = validate_dict(data)
    if errors:
        for e in errors:
            print(f"[cache] INVALID: {e}")
        raise SystemExit(1)
    # a schema-valid cache that no lookup can use is a failure too: the
    # consumers (serve --calibrated-cache, measured placement) only see
    # entries matching the running jax version / backend, so validating a
    # cache this environment cannot read must not report success
    n = len(data["entries"])
    env = environment()
    usable = sum(1 for m in data["entries"].values()
                 if m["jax_version"] == env["jax_version"]
                 and m["backend"] == env["backend"])
    if usable == 0 and not args.allow_empty:
        raise SystemExit(
            f"[cache] INVALID: {args.validate}: schema OK but no usable "
            f"entries for jax {env['jax_version']} / {env['backend']} "
            f"({n} total; measured-pricing lookups would find nothing — "
            f"re-profile here, or pass --allow-empty to accept)")
    print(f"[cache] {args.validate}: schema v{data['schema']} OK, "
          f"{n} entr{'y' if n == 1 else 'ies'} ({usable} usable in this "
          f"environment)")


if __name__ == "__main__":
    _main()
