"""Measured speculative-decoding acceptance rates, persisted per pair.

`serving.placement.choose_speculation` prices speculation on a per-token
acceptance rate; a *prior* is the one number in that formula the device
models cannot supply — it depends on how well the draft actually imitates
the target on the served traffic.  This module closes that gap the same
way :mod:`~repro.profiling.transfer` closed the link-bandwidth one: the
rate a serve run measured is persisted into the PR 2 profile cache
(environment-keyed), and the next run prices its speculation decision on
the measured value instead of the prior.

The cache entry is a full :data:`~repro.profiling.cache.REQUIRED_FIELDS`
measurement (``kind="acceptance"``, ``t_*`` = 0 — acceptance is a rate,
not a time; ``flops=0``) plus the derived ``acceptance_rate`` and the
(draft, target) pair labels, so ``python -m repro.profiling.cache
--validate`` accepts it and :func:`cached_acceptance` can find it again.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from . import cache as cache_lib

# engine name acceptance measurements are filed under in the profile cache
ACCEPTANCE_ENGINE = "speculative"
# provenance tag (ProfileCache.measurements(source=...))
ACCEPTANCE_SOURCE = "acceptance-measurement"


@dataclasses.dataclass(frozen=True)
class AcceptanceSpec:
    """Declarative spec of one measured (draft, target) pairing (a
    dataclass so :func:`repro.profiling.cache.fingerprint` can hash it
    like any layer spec)."""
    name: str
    draft: str
    target: str
    k: int


def acceptance_measurement(*, draft_arch: str, target_arch: str, k: int,
                           n_proposed: int, n_accepted: int,
                           n_rounds: int) -> dict:
    """Build a profile-cache measurement dict from a run's tallies."""
    if n_proposed <= 0:
        raise ValueError("acceptance needs at least one proposed token")
    spec = AcceptanceSpec(name=f"accept:{draft_arch}->{target_arch}",
                          draft=draft_arch, target=target_arch, k=int(k))
    env = cache_lib.environment()
    return {
        "layer": spec.name, "kind": "acceptance",
        "engine": ACCEPTANCE_ENGINE, "batch": 1, "dtype": "int32",
        "repeats": int(n_rounds), "t_median": 0.0, "t_iqr": 0.0,
        "t_min": 0.0, "t_mean": 0.0, "flops": 0,
        "fingerprint": cache_lib.fingerprint(spec, 1, "int32"),
        "jax_version": env["jax_version"], "backend": env["backend"],
        # derived + provenance (extra fields survive cache validation)
        "acceptance_rate": n_accepted / n_proposed,
        "n_proposed": int(n_proposed), "n_accepted": int(n_accepted),
        "n_rounds": int(n_rounds), "k": int(k),
        "draft": draft_arch, "target": target_arch,
        "source": ACCEPTANCE_SOURCE,
    }


def record_acceptance(cache: cache_lib.ProfileCache, *, draft_arch: str,
                      target_arch: str, k: int, n_proposed: int,
                      n_accepted: int, n_rounds: int) -> dict:
    """Store a run's measured acceptance in ``cache`` (not saved to disk
    here — the caller owns persistence)."""
    m = acceptance_measurement(draft_arch=draft_arch,
                               target_arch=target_arch, k=k,
                               n_proposed=n_proposed,
                               n_accepted=n_accepted, n_rounds=n_rounds)
    cache.put(m)
    return m


def cached_acceptance(cache: cache_lib.ProfileCache, *, draft_arch: str,
                      target_arch: str) -> Optional[float]:
    """The measured acceptance rate for this (draft, target) pair in this
    environment, or None when the cache holds no usable measurement.
    The largest-sample measurement wins (most proposed tokens — the best
    steady-state estimate)."""
    best = None
    for m in cache.measurements(engine=ACCEPTANCE_ENGINE,
                                source=ACCEPTANCE_SOURCE):
        if m.get("draft") != draft_arch or m.get("target") != target_arch:
            continue
        rate = m.get("acceptance_rate")
        if not isinstance(rate, (int, float)) or not 0.0 <= rate <= 1.0:
            continue
        if best is None or m.get("n_proposed", 0) > best.get("n_proposed", 0):
            best = m
    return float(best["acceptance_rate"]) if best else None
