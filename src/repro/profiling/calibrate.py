"""Calibrator: fit device-model parameters from measurements.

The analytic TPU model prices a layer from first principles (roofline with
an engine ``efficiency`` guess).  Calibration replaces the guess with the
achieved rate the microbenchmarks actually observed, exactly how CNNLab
built its K40/DE5 models from measured boards (§IV.B):

    achieved[kind] = sum(FLOPs) / sum(median time)      over that kind

— a FLOP-weighted fit, so big layers (which dominate plan time) dominate
the per-kind rate.  The result is a :class:`CalibratedDeviceModel`, an
``analytic=False`` :class:`~repro.core.device_models.DeviceModel` that
drops straight into ``core/cost_model.layer_cost`` and everything above it
(scheduler, batcher, trade-off analysis).  Kinds never measured fall back
to ``base_efficiency x peak_flops`` — the engine's nominal analytic guess —
instead of raw peak, so an under-profiled cache cannot make unmeasured
layers look infinitely fast.

:func:`calibration_report` quantifies the win: per-layer analytic vs
calibrated predicted time against the measurement, aggregated as MAPE
(mean absolute percentage error).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Sequence, Tuple

from ..core import device_models as dm
from ..core.cost_model import layer_cost
from ..core.engines import ExecutionEngine
from ..core.layer_model import LayerSpec
from .bench import Measurement


@dataclasses.dataclass(frozen=True)
class CalibratedDeviceModel(dm.DeviceModel):
    """A DeviceModel whose per-kind throughput came from measurements.

    Measured kinds are priced empirically (the measurement folds in memory
    behaviour).  Unmeasured kinds fall back to the *base* model's pricing
    discipline: if the base was analytic, the full roofline — including
    the memory and collective terms — scaled by the engine's nominal
    ``base_efficiency``, so an under-profiled cache cannot under-price
    memory-bound layers (e.g. serving decode) to compute-only optimism.
    """

    base_efficiency: float = 1.0         # fallback for unmeasured kinds
    base_analytic: bool = False          # was the base model roofline-priced?
    source_engine: str = ""
    n_measurements: int = 0

    def achieved_flops(self, kind: str, direction: str = "fwd") -> float:
        if direction == "bwd" and kind in self.throughput_bwd:
            return self.throughput_bwd[kind]
        if kind in self.throughput:
            return self.throughput[kind]
        return self.base_efficiency * self.peak_flops

    def analytic_for(self, kind: str) -> bool:
        return self.base_analytic and kind not in self.throughput

    def roofline_efficiency(self, kind: str) -> float:
        return self.base_efficiency


def fit_kind_rates(measurements: Iterable[Measurement]) -> Dict[str, float]:
    """FLOP-weighted achieved rate per layer kind."""
    flops: Dict[str, float] = {}
    seconds: Dict[str, float] = {}
    for m in measurements:
        flops[m.kind] = flops.get(m.kind, 0.0) + m.flops
        seconds[m.kind] = seconds.get(m.kind, 0.0) + m.t_median
    return {k: flops[k] / seconds[k]
            for k in flops if seconds[k] > 0 and flops[k] > 0}


def calibrate_engine(
    engine: ExecutionEngine,
    measurements: Sequence[Measurement],
    *,
    register: bool = False,
) -> CalibratedDeviceModel:
    """Fit a calibrated device model for ``engine`` from its measurements.

    When ``register`` the model joins ``core.device_models.REGISTRY`` under
    ``"<device>-measured-<engine>"`` so name-keyed consumers (the serving
    batcher's ``device_name``) can price on it.
    """
    mine = [m for m in measurements if m.engine == engine.name]
    if not mine:
        raise ValueError(f"no measurements for engine {engine.name}")
    base = engine.device
    model = CalibratedDeviceModel(
        name=f"{base.name}-measured-{engine.name}",
        peak_flops=base.peak_flops,
        mem_bw=base.mem_bw,
        link_bw=base.link_bw,
        vmem_bytes=base.vmem_bytes,
        analytic=False,
        throughput=fit_kind_rates(mine),
        power=dict(base.power),
        power_active=base.power_active,
        power_idle=base.power_idle,
        frequency_hz=base.frequency_hz,
        base_efficiency=engine.efficiency if base.analytic else 1.0,
        base_analytic=base.analytic,
        source_engine=engine.name,
        n_measurements=len(mine),
    )
    if register:
        dm.register(model, overwrite=True)
    return model


# ---------------------------------------------------------------------------
# Prediction-error reporting (before/after calibration)
# ---------------------------------------------------------------------------
def analytic_predicted_time(spec: LayerSpec, engine: ExecutionEngine, *,
                            batch: int = 1, dtype_bytes: int = 4) -> float:
    """What the uncalibrated scheduler believes this layer costs."""
    eff = engine.efficiency if engine.device.analytic else 1.0
    return layer_cost(spec, engine.device, batch=batch,
                      dtype_bytes=dtype_bytes, mxu_efficiency=eff).t_total


@dataclasses.dataclass(frozen=True)
class LayerPrediction:
    layer: str
    kind: str
    measured_s: float
    analytic_s: float
    calibrated_s: float

    @property
    def analytic_err(self) -> float:
        return abs(self.analytic_s - self.measured_s) / self.measured_s

    @property
    def calibrated_err(self) -> float:
        return abs(self.calibrated_s - self.measured_s) / self.measured_s


@dataclasses.dataclass(frozen=True)
class CalibrationReport:
    engine: str
    model: CalibratedDeviceModel
    predictions: Tuple[LayerPrediction, ...]

    def _mape(self, attr: str) -> float:
        errs = [getattr(p, attr) for p in self.predictions]
        return sum(errs) / len(errs) if errs else float("nan")

    @property
    def analytic_mape(self) -> float:
        return self._mape("analytic_err")

    @property
    def calibrated_mape(self) -> float:
        return self._mape("calibrated_err")

    def per_kind(self) -> Dict[str, Dict[str, float]]:
        kinds: Dict[str, List[LayerPrediction]] = {}
        for p in self.predictions:
            kinds.setdefault(p.kind, []).append(p)
        return {
            k: {
                "n": len(ps),
                "analytic_mape": sum(p.analytic_err for p in ps) / len(ps),
                "calibrated_mape": sum(p.calibrated_err for p in ps) / len(ps),
            }
            for k, ps in kinds.items()
        }

    def summary(self) -> str:
        rows = [f"{'layer':<8} {'kind':<6} {'measured':>11} {'analytic':>11} "
                f"{'calibrated':>11} {'err_a':>8} {'err_c':>8}"]
        for p in self.predictions:
            rows.append(
                f"{p.layer:<8} {p.kind:<6} {p.measured_s*1e3:>9.3f}ms "
                f"{p.analytic_s*1e3:>9.3f}ms {p.calibrated_s*1e3:>9.3f}ms "
                f"{p.analytic_err:>8.2%} {p.calibrated_err:>8.2%}")
        rows.append(f"[{self.engine}] MAPE analytic {self.analytic_mape:.2%} "
                    f"-> calibrated {self.calibrated_mape:.2%} "
                    f"({len(self.predictions)} layers)")
        return "\n".join(rows)


def calibration_report(
    engine: ExecutionEngine,
    specs: Sequence[LayerSpec],
    measurements: Sequence[Measurement],
    *,
    batch: int = 1,
    dtype_bytes: int = 4,
    register: bool = False,
) -> CalibrationReport:
    """Fit + score: calibrate ``engine`` and report prediction error
    before/after on every measured layer in ``specs``."""
    model = calibrate_engine(engine, measurements, register=register)
    by_layer = {(m.layer, m.engine): m for m in measurements}
    preds = []
    for spec in specs:
        m = by_layer.get((spec.name, engine.name))
        if m is None or m.t_median <= 0:
            continue
        cal = layer_cost(spec, model, batch=batch,
                         dtype_bytes=dtype_bytes).t_total
        preds.append(LayerPrediction(
            layer=spec.name, kind=spec.kind, measured_s=m.t_median,
            analytic_s=analytic_predicted_time(
                spec, engine, batch=batch, dtype_bytes=dtype_bytes),
            calibrated_s=cal))
    return CalibrationReport(engine=engine.name, model=model,
                             predictions=tuple(preds))
