"""Empirical profiling & cost-model calibration runtime.

CNNLab's middleware decides offload targets from *measured* device
behaviour; this package is that measurement layer for the reproduction:

* ``bench``     — microbenchmark harness (warmup, ``block_until_ready``,
  median + IQR over repeats) for any buildable engine x LayerSpec;
* ``cache``     — persistent JSON profile cache keyed by (spec
  fingerprint, engine, jax version, backend) with load/merge/invalidate;
* ``calibrate`` — fits per-kind achieved rates into a
  ``CalibratedDeviceModel`` that drops into ``core/cost_model.py``, and
  reports prediction error before/after;
* ``pricer``    — ``MeasuredPricer``, the measure-on-miss pricing source
  behind ``core.scheduler.schedule(..., price="measured")``.

CLI: ``python -m repro.launch.profile`` (measure + calibrate + compare
plans); benchmark: ``python -m benchmarks.bench_profiling``.
"""
from .acceptance import (ACCEPTANCE_ENGINE, ACCEPTANCE_SOURCE,
                         cached_acceptance, record_acceptance)
from .bench import Measurement, make_input, profile_network, time_layer
from .cache import (DEFAULT_CACHE_PATH, ProfileCache, entry_key, environment,
                    fingerprint, validate_dict)
from .calibrate import (CalibratedDeviceModel, CalibrationReport,
                        LayerPrediction, analytic_predicted_time,
                        calibrate_engine, calibration_report, fit_kind_rates)
from .pricer import MeasuredPricer
from .transfer import (LINK_ENGINE, LINK_SOURCE, cached_link_bw,
                       measure_link_bandwidth, record_link_bw)

__all__ = [
    "ACCEPTANCE_ENGINE", "ACCEPTANCE_SOURCE", "CalibratedDeviceModel",
    "CalibrationReport", "DEFAULT_CACHE_PATH",
    "LINK_ENGINE", "LINK_SOURCE", "LayerPrediction", "Measurement",
    "MeasuredPricer", "ProfileCache", "analytic_predicted_time",
    "cached_acceptance", "cached_link_bw", "calibrate_engine",
    "calibration_report", "record_acceptance",
    "entry_key", "environment", "fingerprint", "fit_kind_rates",
    "make_input", "measure_link_bandwidth", "profile_network",
    "record_link_bw", "time_layer", "validate_dict",
]
